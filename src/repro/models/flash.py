"""Chunked online-softmax attention with a memory-lean custom VJP.

Forward: scan over (q-chunk x k-chunk) tiles with running (max, sum, acc) —
the streaming schedule a Pallas splash-attention kernel executes from VMEM.

Backward: FlashAttention-2 style recompute — the ONLY residuals saved are
(q, k, v, out, lse).  Without the custom VJP, ``lax.scan``'s autodiff stores
every per-chunk probability tile (O(S^2) bytes), which is exactly the
memory-term blowup the dry-run exposed (37 GB/device for GPT-2 @ 4k).

All tensors: q (B, Sq, KV, G, D); k/v (B, Sk, KV, D[v]); GQA via the G dim.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pad_to(x, axis: int, size: int):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def _mask_for(q_pos, k_pos, Sk, causal, kv_valid_len):
    mask = k_pos[None, :] < Sk
    if kv_valid_len is not None:
        mask = mask & (k_pos[None, :] < kv_valid_len)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    return mask  # (qc, kc)


def _fwd_impl(q, k, v, *, causal, q_offset, q_chunk, k_chunk, kv_valid_len):
    B, Sq, KV, G, D = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    scale = 1.0 / math.sqrt(D)
    qc = min(q_chunk, Sq)
    kc = min(k_chunk, Sk)
    nq, nk = -(-Sq // qc), -(-Sk // kc)

    qp = _pad_to(q, 1, nq * qc).reshape(B, nq, qc, KV, G, D).transpose(1, 0, 2, 3, 4, 5)
    kp = _pad_to(k, 1, nk * kc).reshape(B, nk, kc, KV, D).transpose(1, 0, 2, 3, 4)
    vp = _pad_to(v, 1, nk * kc).reshape(B, nk, kc, KV, Dv).transpose(1, 0, 2, 3, 4)

    def q_step(qi, q_tile):
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        def k_step(carry, inp):
            m, l, acc = carry
            ki, k_tile, v_tile = inp
            k_pos = ki * kc + jnp.arange(kc)
            s = jnp.einsum("bqkgd,bckd->bkgqc", q_tile, k_tile,
                           preferred_element_type=jnp.float32) * scale
            mask = _mask_for(q_pos, k_pos, Sk, causal, kv_valid_len)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(v_tile.dtype), v_tile,
                            preferred_element_type=jnp.float32)
            return (m_new, l_new, acc * corr[..., None] + pv), None

        m0 = jnp.full((B, KV, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0), (jnp.arange(nk), kp, vp))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse  # (B,KV,G,qc,Dv), (B,KV,G,qc)

    outs, lses = jax.lax.map(lambda a: q_step(*a), (jnp.arange(nq), qp))
    # outs: (nq, B, KV, G, qc, Dv) -> (B, nq, qc, KV, G, Dv)
    out = outs.transpose(1, 0, 4, 2, 3, 5)
    out = out.reshape(B, nq * qc, KV, G, Dv)[:, :Sq].astype(v.dtype)
    # lses: (nq, B, KV, G, qc) -> (B, nq, qc, KV, G)
    lse = lses.transpose(1, 0, 4, 2, 3).reshape(B, nq * qc, KV, G)[:, :Sq]
    return out, lse


def _bwd_impl(q, k, v, out, lse, dout, *, causal, q_offset, q_chunk, k_chunk,
              kv_valid_len):
    B, Sq, KV, G, D = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    scale = 1.0 / math.sqrt(D)
    qc = min(q_chunk, Sq)
    kc = min(k_chunk, Sk)
    nq, nk = -(-Sq // qc), -(-Sk // kc)

    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    # tile views
    qp = _pad_to(q, 1, nq * qc).reshape(B, nq, qc, KV, G, D).transpose(1, 0, 2, 3, 4, 5)
    dop = _pad_to(dout, 1, nq * qc).reshape(B, nq, qc, KV, G, Dv).transpose(1, 0, 2, 3, 4, 5)
    lsep = _pad_to(lse, 1, nq * qc).reshape(B, nq, qc, KV, G).transpose(1, 0, 2, 3, 4)
    dlp = _pad_to(delta, 1, nq * qc).reshape(B, nq, qc, KV, G).transpose(1, 0, 2, 3, 4)
    kp = _pad_to(k, 1, nk * kc).reshape(B, nk, kc, KV, D).transpose(1, 0, 2, 3, 4)
    vp = _pad_to(v, 1, nk * kc).reshape(B, nk, kc, KV, Dv).transpose(1, 0, 2, 3, 4)

    def q_step(carry, inp):
        dk_acc, dv_acc = carry  # (nk,B,kc,KV,D[v]) fp32
        qi, q_tile, do_tile, lse_tile, dl_tile = inp
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        def k_step(dq_acc, kinp):
            ki, k_tile, v_tile = kinp
            k_pos = ki * kc + jnp.arange(kc)
            s = jnp.einsum("bqkgd,bckd->bkgqc", q_tile, k_tile,
                           preferred_element_type=jnp.float32) * scale
            mask = _mask_for(q_pos, k_pos, Sk, causal, kv_valid_len)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            # p from saved lse (no re-normalization pass needed)
            p = jnp.exp(s - lse_tile.transpose(0, 2, 3, 1)[..., None])  # (B,KV,G,qc,kc)
            dv_t = jnp.einsum("bkgqc,bqkgd->bckd", p, do_tile.astype(jnp.float32),
                              preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqkgd,bckd->bkgqc", do_tile, v_tile,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dl_tile.transpose(0, 2, 3, 1)[..., None]) * scale
            dq_t = jnp.einsum("bkgqc,bckd->bqkgd", ds, k_tile,
                              preferred_element_type=jnp.float32)
            dk_t = jnp.einsum("bkgqc,bqkgd->bckd", ds, q_tile.astype(jnp.float32),
                              preferred_element_type=jnp.float32)
            return dq_acc + dq_t, (dk_t, dv_t)

        dq0 = jnp.zeros((B, qc, KV, G, D), jnp.float32)
        dq_tile, (dk_t, dv_t) = jax.lax.scan(
            k_step, dq0, (jnp.arange(nk), kp, vp)
        )
        return (dk_acc + dk_t, dv_acc + dv_t), dq_tile

    dk0 = jnp.zeros((nk, B, kc, KV, D), jnp.float32)
    dv0 = jnp.zeros((nk, B, kc, KV, Dv), jnp.float32)
    (dk_acc, dv_acc), dq_tiles = jax.lax.scan(
        q_step, (dk0, dv0), (jnp.arange(nq), qp, dop, lsep, dlp)
    )
    dq = dq_tiles.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qc, KV, G, D)[:, :Sq]
    dk = dk_acc.transpose(1, 0, 2, 3, 4).reshape(B, nk * kc, KV, D)[:, :Sk]
    dv = dv_acc.transpose(1, 0, 2, 3, 4).reshape(B, nk * kc, KV, Dv)[:, :Sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, q_offset, q_chunk, k_chunk):
    out, _ = _fwd_impl(q, k, v, causal=causal, q_offset=q_offset,
                       q_chunk=q_chunk, k_chunk=k_chunk, kv_valid_len=None)
    return out


def _flash_fwd(q, k, v, causal, q_offset, q_chunk, k_chunk):
    out, lse = _fwd_impl(q, k, v, causal=causal, q_offset=q_offset,
                         q_chunk=q_chunk, k_chunk=k_chunk, kv_valid_len=None)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_offset, q_chunk, k_chunk, res, dout):
    q, k, v, out, lse = res
    return _bwd_impl(q, k, v, out, lse, dout, causal=causal, q_offset=q_offset,
                     q_chunk=q_chunk, k_chunk=k_chunk, kv_valid_len=None)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_offset: int = 0,
    q_chunk: int = 2048,
    k_chunk: int = 1024,
    kv_valid_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Returns (B, Sq, KV, G, Dv).  Differentiable w.r.t. q/k/v with
    FA2-style recompute; ``kv_valid_len`` path is forward-only (serving)."""
    if kv_valid_len is not None:
        out, _ = _fwd_impl(q, k, v, causal=causal, q_offset=q_offset,
                           q_chunk=q_chunk, k_chunk=k_chunk,
                           kv_valid_len=kv_valid_len)
        return out
    return _flash(q, k, v, causal, q_offset, q_chunk, k_chunk)
