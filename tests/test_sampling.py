"""Per-slot sampling contract (``repro.serve.sampling``).

* ``temperature=0`` is EXACTLY the old inline ``jnp.argmax`` — the three
  scheduler sites collapsed into :class:`SlotSampler` must leave greedy
  streams bitwise unchanged on every serve architecture and scheduler.
* Sampling is canonical-stream: the key for a token depends only on
  ``(seed, uid, generation_index)``, so the same seed reproduces the
  same per-request streams across runs AND across schedulers (wave's
  dense cache, continuous paging, chunked prefill) — while a different
  seed moves them.
* Top-k sampling can never emit a token outside the row's top-k set
  (teacher-forced on synthetic logit rows).
"""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import SlotSampler
from repro.train import steps as steps_mod

SERVE_ARCHS = (
    "gpt2-124m", "qwen3-1.7b", "mamba2-370m", "deepseek-v2-lite-16b",
    "deepseek-moe-16b", "jamba-1.5-large-398b",
)

_MODELS = {}


def _model(arch):
    if arch not in _MODELS:
        cfg = configs.get_smoke_config(arch)
        _MODELS[arch] = (cfg, steps_mod.init_model(jax.random.PRNGKey(0), cfg))
    return _MODELS[arch]


def _traffic(cfg, n=4, seed=11, max_new=6):
    rng = np.random.default_rng(seed)
    return [
        Request(uid=i,
                prompt=rng.integers(1, cfg.vocab,
                                    size=int(rng.integers(3, 9)))
                .astype(np.int32),
                max_new_tokens=max_new)
        for i in range(n)
    ]


def _streams(arch, *, scheduler="continuous", max_batch=2, **eng_kw):
    cfg, params = _model(arch)
    eng = ServeEngine(cfg, params, max_batch=max_batch, max_len=64,
                      block_size=8, scheduler=scheduler, **eng_kw)
    for r in _traffic(cfg):
        eng.submit(r)
    eng.run_until_drained()
    return {uid: r.generated for uid, r in eng.completed.items()}


def _fake_reqs(uids, gen_lens):
    return [types.SimpleNamespace(uid=u, generated=[0] * g)
            for u, g in zip(uids, gen_lens)]


# ---------------------------------------------------------------------------
# unit: the sampler itself
# ---------------------------------------------------------------------------


def test_greedy_matches_argmax_golden():
    """temp=0 select() is bit-identical to the inline argmax it replaced,
    including over padded vocab tails and with reqs absent."""
    rng = np.random.default_rng(0)
    vocab, pad = 37, 48
    rows = jnp.asarray(rng.standard_normal((3, 2, pad)).astype(np.float32))
    s = SlotSampler(vocab)
    assert s.greedy
    got = s.select(rows)
    want = np.asarray(jnp.argmax(rows[..., :vocab], axis=-1))
    np.testing.assert_array_equal(got, want)


def test_top_k_never_escapes_the_top_k_set():
    """Teacher-forced on random logit rows: every sampled token sits in
    that row's top-k set, for many rows / draws."""
    rng = np.random.default_rng(1)
    vocab, k = 64, 5
    s = SlotSampler(vocab, temperature=0.8, top_k=k, seed=7)
    for trial in range(4):
        rows_np = rng.standard_normal((4, 3, vocab)).astype(np.float32)
        reqs = _fake_reqs(range(4), rng.integers(0, 20, size=4))
        toks = s.select(jnp.asarray(rows_np), reqs, offset=trial)
        topk = np.argsort(rows_np, axis=-1)[..., -k:]
        for b in range(4):
            for i in range(3):
                assert toks[b, i] in topk[b, i], (
                    f"row ({b},{i}) sampled {toks[b, i]} outside top-{k} "
                    f"{sorted(topk[b, i])}"
                )


def test_keys_depend_on_uid_and_index_not_slot():
    """The same (uid, generation index) gets the same token no matter
    which slot row it occupies or how the window is offset — the
    canonical-stream property speculation relies on."""
    rng = np.random.default_rng(2)
    vocab = 64
    s = SlotSampler(vocab, temperature=1.0, seed=3)
    row = rng.standard_normal((1, 1, vocab)).astype(np.float32)
    rows2 = np.concatenate([row, row], axis=0)  # same logits, two slots
    # uid 9 at generation index 5, sitting in slot 0 vs slot 1
    a = s.select(jnp.asarray(rows2), _fake_reqs([9, 42], [5, 0]))[0, 0]
    b = s.select(jnp.asarray(rows2), _fake_reqs([42, 9], [0, 5]))[1, 0]
    assert a == b
    # ...and reached via offset instead of len(generated)
    c = s.select(jnp.asarray(row), _fake_reqs([9], [2]), offset=3)[0, 0]
    assert a == c
    # a different index reads a different key (tokens may coincide by
    # chance on tiny vocabs, so check the 8-index stream instead)
    stream5 = [int(s.select(jnp.asarray(row), _fake_reqs([9], [5 + i]))[0, 0])
               for i in range(8)]
    stream6 = [int(s.select(jnp.asarray(row), _fake_reqs([9], [6 + i]))[0, 0])
               for i in range(8)]
    assert stream5 != stream6


def test_sampler_validation():
    with pytest.raises(ValueError):
        SlotSampler(0)
    with pytest.raises(ValueError):
        SlotSampler(8, temperature=-0.1)
    with pytest.raises(ValueError):
        SlotSampler(8, temperature=1.0, top_k=-1)


# ---------------------------------------------------------------------------
# engine: temp=0 greedy golden on every serve architecture
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", SERVE_ARCHS)
def test_temp0_is_greedy_golden_every_arch(arch):
    """An engine with explicit temperature=0 serves byte-identical
    streams to the default (pre-sampling) engine on dense, GQA, MLA,
    MoE, SSM and hybrid paths — the argmax-dedupe satellite."""
    golden = _streams(arch)
    explicit = _streams(arch, temperature=0.0, top_k=0, sample_seed=99)
    assert explicit == golden, arch


def test_temp0_identical_across_all_three_sampler_sites():
    """wave (dense cache), continuous (paged) and chunked prefill hit
    the three formerly-separate argmax sites; at temp=0 all serve the
    same streams."""
    cont = _streams("gpt2-124m", temperature=0.0)
    wave = _streams("gpt2-124m", scheduler="wave", temperature=0.0)
    chunk = _streams("gpt2-124m", temperature=0.0, prefill_chunk=4)
    assert cont == wave == chunk


# ---------------------------------------------------------------------------
# engine: sampled streams are reproducible and scheduler-invariant
# ---------------------------------------------------------------------------


def test_sampled_streams_reproducible_across_runs_and_schedulers():
    kw = dict(temperature=0.8, top_k=10, sample_seed=42)
    runs = {
        "cont-a": _streams("gpt2-124m", **kw),
        "cont-b": _streams("gpt2-124m", **kw),
        "wave": _streams("gpt2-124m", scheduler="wave", **kw),
        "chunked": _streams("gpt2-124m", prefill_chunk=4, **kw),
        "tight": _streams("gpt2-124m", max_batch=1, **kw),
    }
    first = runs["cont-a"]
    assert len(first) == 4 and all(first.values())
    for name, got in runs.items():
        assert got == first, f"{name} diverged from the canonical streams"


def test_sampled_streams_move_with_the_seed():
    a = _streams("gpt2-124m", temperature=0.8, top_k=10, sample_seed=42)
    b = _streams("gpt2-124m", temperature=0.8, top_k=10, sample_seed=43)
    assert a != b, "different sample seeds must move the streams"
    c = _streams("gpt2-124m", temperature=0.8, top_k=10, sample_seed=42)
    assert a == c


def test_report_and_stats_carry_sampling_config():
    cfg, params = _model("gpt2-124m")
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64, block_size=8,
                      temperature=0.7, top_k=5, sample_seed=9)
    assert (eng.temperature, eng.top_k, eng.sample_seed) == (0.7, 5, 9)
    # spec counters exist as zeros on a speculation-off engine (satellite:
    # dashboards never see missing keys)
    for r in _traffic(cfg, n=2, max_new=3):
        eng.submit(r)
    eng.run_until_drained()
    stats = eng.stats()
    assert stats["spec_k"] == 0
    assert stats["drafted_tokens"] == 0
    assert stats["accepted_tokens"] == 0
    assert stats["rejected_tokens"] == 0
    assert stats["draft_steps"] == 0
    assert stats["acceptance_rate"] == 0.0
    assert stats["target_steps"] == stats["fused_steps"]
