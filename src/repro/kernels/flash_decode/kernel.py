"""Flash-decode: one-token attention over a long KV cache, KV-blocked.

The serve-side hot loop of every decode_* cell: q (B, H, D) attends to a
(B, S, KV, D) cache of which only ``valid_len`` positions are live.  The
kernel streams KV blocks through VMEM keeping a running (max, sum, acc) —
online softmax — and PREDICATES each block on ``pos < valid_len``: ragged
context lengths occupy only ceil(valid/bs) block-issues per head instead of
S/bs, the SVE predication insight applied at the token level (a fixed-width
schedule must process the whole padded cache).

Grid: (B, KV-heads, S/bs) with the KV axis innermost (sequential).  GQA via
G query heads per KV head processed together — the q tile is (G, D), MXU
contractions are (G, D) x (D, bs).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, vl_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, bs: int, ns: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = vl_ref[0]
    q = q_ref[0, 0]  # (G, D)
    k = k_ref[0, 0]  # (bs, D)
    v = v_ref[0, 0]
    D = q.shape[-1]
    scale = 1.0 / math.sqrt(D)

    pos = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    pred = pos < valid  # predicate register analogue

    # skip fully-masked blocks entirely (ragged-length win; on TPU this is
    # the "don't issue the tile" branch)
    @pl.when(si * bs < valid)
    def _work():
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, bs)
        s = jnp.where(pred[None, :], s, NEG_INF)
        m_new = jnp.maximum(m_ref[...], s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_ref[...] - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(
    q: jax.Array,       # (B, KV, G, D)
    k: jax.Array,       # (B, S, KV, D)
    v: jax.Array,       # (B, S, KV, D)
    valid_len: jax.Array,  # (B,) int32 — live cache length per sequence
    *,
    block_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Returns (B, KV, G, D) attention output over the predicated cache."""
    B, KV, G, D = q.shape
    S = k.shape[1]
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    ns = S // bs
    kernel = functools.partial(_decode_kernel, bs=bs, ns=ns)
    from jax.experimental.pallas import tpu as pltpu

    kt = k.transpose(0, 2, 1, 3)  # (B, KV, S, D): head-major streaming
    vt = v.transpose(0, 2, 1, 3)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, ns),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1,), lambda b, h, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, kt, vt, valid_len)
