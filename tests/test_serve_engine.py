"""Batched serving engine: lockstep waves must match single-request greedy
decoding exactly, and the queue must drain under mixed workloads."""

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.serve.engine import Request, ServeEngine
from repro.train import steps as steps_mod


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("gpt2-124m")
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_single(cfg, params, prompt, max_new):
    """Reference: unbatched greedy decode."""
    engine = ServeEngine(cfg, params, max_batch=1, max_len=96)
    engine.submit(Request(uid=0, prompt=prompt, max_new_tokens=max_new))
    return engine.run_until_drained()[0].generated


def test_batched_matches_single(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(3, 12)))
               .astype(np.int32) for _ in range(3)]
    singles = [_greedy_single(cfg, params, p, 6) for p in prompts]

    engine = ServeEngine(cfg, params, max_batch=3, max_len=96)
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, prompt=p, max_new_tokens=6))
    done = engine.run_until_drained()
    for uid in range(3):
        assert done[uid].generated == singles[uid], (
            f"req {uid}: batched {done[uid].generated} != single {singles[uid]}"
        )


def test_queue_drains_multiple_waves(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    engine = ServeEngine(cfg, params, max_batch=2, max_len=64)
    for uid in range(5):
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, size=4).astype(np.int32),
            max_new_tokens=3,
        ))
    done = engine.run_until_drained()
    assert len(done) == 5
    assert all(len(r.generated) == 3 for r in done.values())


def test_eos_stops_generation(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
    # find what greedy emits first, then set that token as EOS
    first = _greedy_single(cfg, params, prompt, 1)[0]
    engine = ServeEngine(cfg, params, max_batch=1, max_len=64)
    engine.submit(Request(uid=0, prompt=prompt, max_new_tokens=8, eos_id=first))
    done = engine.run_until_drained()
    assert done[0].generated == [first]
