"""The unified workload description + global registry (paper Sec. 3.2/3.3).

A :class:`Workload` is the single currency of the analysis pipeline: a
callable with example arguments, the dominant element type (the paper's
ELEN, the denominator of Eq. 1's VB = VLEN/ELEN), and — optionally — an
analytic flops/bytes/gather-bytes model of the kind the paper builds per
application (Sec. 3.3).  Everything downstream
(``analysis.pipeline.analyze``) consumes a Workload and nothing else, so
"open a new workload" is one registration instead of edits across the
kernels / benchmarks / examples layers.

Registration is either eager::

    from repro.analysis import workload

    @workload(name="saxpy", dtype="fp32",
              args=lambda: (jnp.ones(1024), jnp.ones(1024)))
    def saxpy(x, y):
        return x + 2.0 * y

or lazy (``register_lazy``), which defers building example arguments until
the workload is actually requested — how the kernel registry and the
13-app paper suite register themselves without paying array-construction
cost at import time.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import hw, metrics


@dataclasses.dataclass
class Workload:
    """One analyzable unit of work: callable + example args + cost model.

    ``args`` may be the literal argument tuple or a zero-argument thunk
    returning it (resolved once, on first use).  The analytic model fields
    (``flops`` / ``hbm_bytes`` / ``gather_bytes``) are optional: when absent,
    the pipeline derives events from the compiled XLA artifact instead.
    """

    name: str
    fn: Optional[Callable] = None
    args: Any = ()
    dtype: str = "fp32"  # dominant ELEN (paper semantics)
    # -- optional analytic cost model (paper Sec. 3.3 style) ---------------
    flops: Optional[float] = None
    hbm_bytes: Optional[float] = None
    gather_bytes: float = 0.0
    vectorizable_fraction: float = 1.0
    collective_bytes: float = 0.0
    n_devices: int = 1
    # -- bookkeeping -------------------------------------------------------
    problem: str = ""  # reduced problem run here
    full_problem: str = ""  # the paper's problem size
    tags: Tuple[str, ...] = ()
    notes: str = ""
    _resolved_args: Optional[Tuple[Any, ...]] = dataclasses.field(
        default=None, init=False, repr=False, compare=False
    )

    def example_args(self) -> Tuple[Any, ...]:
        """The example argument tuple, resolving a lazy thunk once."""
        if self._resolved_args is None:
            a = self.args
            if callable(a):
                a = a()
            self._resolved_args = tuple(a)
        return self._resolved_args

    @property
    def has_analytic_model(self) -> bool:
        return self.flops is not None and self.hbm_bytes is not None

    @property
    def ai(self) -> float:
        """Analytic arithmetic intensity (requires the analytic model)."""
        if not self.has_analytic_model:
            raise ValueError(f"{self.name}: no analytic flops/bytes model")
        return self.flops / max(self.hbm_bytes, 1e-30)

    def issue_model(
        self, chip: hw.ChipSpec = hw.GRACE_CORE, *, dtype: Optional[str] = None
    ) -> Dict[str, float]:
        """Scalar vs vector issue counts at this workload's ELEN (Eq. 1).

        ``dtype`` overrides the workload's own ELEN (the paper's
        fixed-VLEN / varying-ELEN sweep)."""
        dtype = dtype or self.dtype
        elements = (self.flops or 0.0) / 2.0  # FMA-equivalent elements
        vec = metrics.vector_issues(elements, dtype, chip)
        scalar = metrics.scalar_issues(elements)
        # Amdahl over the vectorizable fraction (paper Sec. 4.1)
        vb = metrics.vectorization_bound(chip, dtype)
        r_eff = metrics.amdahl_r_ins(vb, self.vectorizable_fraction)
        return {"scalar": scalar, "vector": vec, "r_ins": r_eff, "vb": vb}

    def report(
        self, chip: hw.ChipSpec = hw.GRACE_CORE, *, dtype: Optional[str] = None
    ) -> metrics.VectorizationReport:
        """VectorizationReport from the analytic model (paper Sec. 3.3)."""
        if not self.has_analytic_model:
            raise ValueError(
                f"{self.name}: no analytic model; use analysis.analyze() "
                "which derives events from the compiled artifact"
            )
        dtype = dtype or self.dtype
        ins = self.issue_model(chip, dtype=dtype)
        return metrics.VectorizationReport(
            name=self.name,
            dtype=dtype,
            flops=self.flops,
            hbm_bytes=self.hbm_bytes,
            gather_bytes=self.gather_bytes,
            ins_scalar=ins["scalar"],
            ins_vec=ins["scalar"] / ins["r_ins"],
            vectorizable_fraction=self.vectorizable_fraction,
            collective_bytes=self.collective_bytes,
        )


# ---------------------------------------------------------------------------
# Global registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Workload] = {}
# name -> (builder, tags); tags are kept registry-side so tag filtering
# never has to materialize a lazy workload
_LAZY: Dict[str, Tuple[Callable[[], Workload], Tuple[str, ...]]] = {}
_discovered = False


def _discover() -> None:
    """(Re-)register the built-in workload providers.

    The kernel registry lives in the installed package; the 13-app paper
    suite lives in the repo-root ``benchmarks`` package, which is importable
    when running from a checkout but may be absent for a bare install.
    Providers expose idempotent registration hooks (module import alone is
    not enough: after ``clear_registry`` the modules are still cached in
    ``sys.modules``, so their import-time side effects would never re-run).
    """
    global _discovered
    if _discovered:
        return
    _discovered = True
    import repro.kernels.registry as _kreg

    _kreg.register_builtin_workloads()
    try:
        import benchmarks.apps as _apps
    except ImportError:
        return
    _apps.register_app_workloads()


def register(wl: Workload, *, name: Optional[str] = None, replace: bool = False) -> Workload:
    """Register a Workload under ``name`` (default: ``wl.name``)."""
    key = name or wl.name
    if not replace and (key in _REGISTRY or key in _LAZY):
        raise ValueError(f"workload {key!r} already registered")
    _LAZY.pop(key, None)
    _REGISTRY[key] = wl
    return wl


def register_lazy(
    name: str,
    builder: Callable[[], Workload],
    *,
    tags: Tuple[str, ...] = (),
    replace: bool = False,
) -> None:
    """Register ``builder`` to be called on first ``get_workload(name)``.

    ``tags`` are stored registry-side so ``list_workloads(tags=...)`` can
    filter without building the workload.
    """
    if not replace and (name in _REGISTRY or name in _LAZY):
        raise ValueError(f"workload {name!r} already registered")
    _REGISTRY.pop(name, None)
    _LAZY[name] = (builder, tuple(tags))


def get_workload(name: str) -> Workload:
    """Resolve a registered workload by name, materializing lazy entries."""
    _discover()
    if name in _REGISTRY:
        return _REGISTRY[name]
    if name in _LAZY:
        builder, tags = _LAZY.pop(name)
        wl = builder()
        if tags and not wl.tags:
            wl.tags = tags
        _REGISTRY[name] = wl
        return wl
    raise KeyError(
        f"unknown workload {name!r}; registered: {sorted(set(_REGISTRY) | set(_LAZY))}"
    )


def list_workloads(*, tags: Optional[Tuple[str, ...]] = None) -> List[str]:
    """Names of every registered workload (lazy entries included, unbuilt)."""
    _discover()
    if not tags:
        return sorted(set(_REGISTRY) | set(_LAZY))
    out = []
    for n, wl in _REGISTRY.items():
        if any(t in wl.tags for t in tags):
            out.append(n)
    for n, (_, lazy_tags) in _LAZY.items():
        if any(t in lazy_tags for t in tags):
            out.append(n)
    return sorted(out)


def clear_registry() -> None:
    """Drop every registration (test isolation only)."""
    global _discovered
    _REGISTRY.clear()
    _LAZY.clear()
    _discovered = False


def workload(
    name: Optional[str] = None,
    *,
    args: Any = (),
    dtype: str = "fp32",
    replace: bool = False,
    **fields: Any,
) -> Callable[[Callable], Callable]:
    """Decorator: register the decorated callable as a Workload.

    The function itself is returned unchanged; the registered Workload is
    attached as ``fn.__workload__``.  Extra keyword fields (``flops``,
    ``hbm_bytes``, ``gather_bytes``, ``problem``, ``tags``, ...) pass
    through to the Workload constructor.
    """

    def deco(fn: Callable) -> Callable:
        wl = Workload(
            name=name or fn.__name__, fn=fn, args=args, dtype=dtype, **fields
        )
        register(wl, replace=replace)
        fn.__workload__ = wl
        return fn

    return deco
