"""GEMM kernel call surface (served by the kernel registry) + the
VMEM-footprint tile model."""

from __future__ import annotations

from repro.kernels.registry import GEMM as gemm

__all__ = ["gemm", "vmem_bytes", "pick_tiles"]


def vmem_bytes(bm: int, bn: int, bk: int, in_bytes: int = 2) -> int:
    """Working set per grid step: x tile + y tile + fp32 acc + out tile."""
    return bm * bk * in_bytes + bk * bn * in_bytes + bm * bn * 4 + bm * bn * in_bytes


def pick_tiles(M: int, N: int, K: int, *, vmem_budget: int = 96 * 2**20,
               in_bytes: int = 2) -> tuple:
    """Largest MXU-aligned (multiple-of-128) tiles fitting the VMEM budget."""
    best = (128, 128, 128)
    for bm in (512, 256, 128):
        for bn in (512, 256, 128):
            for bk in (1024, 512, 256, 128):
                if M % bm or N % bn or K % bk:
                    continue
                if vmem_bytes(bm, bn, bk, in_bytes) <= vmem_budget:
                    if bm * bn * bk > best[0] * best[1] * best[2]:
                        best = (bm, bn, bk)
    return best
