"""Jit wrapper + circuit driver for the RX-gate kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.qc_gate.kernel import rx_gate as _rx


@functools.partial(jax.jit, static_argnames=("qubit", "theta", "block_outer", "interpret"))
def rx_gate(re, im, *, qubit: int, theta: float, block_outer: int = 256,
            interpret: bool = True):
    return _rx(re, im, qubit, theta, block_outer=block_outer, interpret=interpret)


def rx_layer(re, im, n_qubits: int, theta: float, *, interpret: bool = True):
    """The paper's benchmark: one RX on every qubit (21-qubit problem)."""
    for q in range(n_qubits):
        re, im = rx_gate(re, im, qubit=q, theta=theta, interpret=interpret)
    return re, im


def zero_state(n_qubits: int):
    n_amp = 1 << n_qubits
    re = jnp.zeros((n_amp,), jnp.float32).at[0].set(1.0)
    im = jnp.zeros((n_amp,), jnp.float32)
    return re, im
