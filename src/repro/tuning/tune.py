"""Roofline-guided autotuner: prune analytically, time survivors, persist.

``tune()`` turns the paper's adapted roofline (Eq. 2) into a search pruner:
every candidate tile config of a kernel's :class:`~repro.tuning.space.
TuningSpace` is scored with ``max(flops / vector_peak, traffic / bw)`` —
the roofline lower bound read as a time — plus the VMEM working-set
feasibility check, and only the ``keep`` best-predicted survivors are ever
timed with the paper's profiler methodology (:func:`repro.core.profiler.
time_fn`: warmup outside the ROI, best-of-repeats).  The winner is written
to the persistent tuning store as a :class:`~repro.tuning.records.
TuningRecord`, so a second process tuning the same (kernel, chip, dtype)
performs **zero timing runs** — the same zero-recompile contract the
analysis pipeline's ArtifactStore gives compiled-artifact events.

The ELEN-packing axis (paper Eq. 1: VB = VLEN/ELEN) is the ``dtype``
argument: tuning at ``dtype="bf16"`` casts the example operands and scores
against the bf16 roofline, whose knee sits at AI_IRV = AI_IRR * VLEN/ELEN.

Kernel-registry imports are deliberately lazy (function-local): the
registry attaches spaces from :mod:`repro.tuning.spaces` at import time,
and a module-level import here would cycle.
"""

from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core import hw
from repro.core.roofline import adapted_roofline
from repro.tuning.records import (
    TuningRecord,
    load_record,
    resolve_store,
    save_record,
    tuning_fingerprint,
)
from repro.tuning.space import (
    TuningSpace,
    canonical_dtype,
    predicted_config_time_s,
)

#: Process-wide count of candidate-timing invocations.  The cross-process
#: acceptance test asserts this stays 0 when every record is a store hit.
TIMING_RUNS = 0
_TIMING_LOCK = threading.Lock()  # tune_kernels(jobs>1) increments concurrently


def timing_runs() -> int:
    """Process-wide candidate-timing invocation count.

    Accessor rather than attribute because ``repro.tuning.tune`` names the
    *function* on the package (the submodule is shadowed by the re-export).
    """
    return TIMING_RUNS

_DTYPE_TO_JNP = {"fp32": "float32", "bf16": "bfloat16", "fp16": "float16"}


def _resolve_ops(kernel: Any):
    if isinstance(kernel, str):
        from repro.kernels.registry import get_kernel

        return get_kernel(kernel)
    return kernel


def _example_args(ops: Any) -> Tuple:
    """Default problem: the kernel's registered ``kernel/<name>`` workload."""
    from repro.analysis.workload import get_workload

    try:
        wl = get_workload(f"kernel/{ops.name}")
    except KeyError:
        raise ValueError(
            f"kernel {ops.name!r} has no registered example workload; "
            "pass args=... explicitly"
        ) from None
    return wl.example_args()


def _infer_dtype(args: Tuple) -> str:
    for a in args:
        dt = getattr(a, "dtype", None)
        if dt is not None:
            return canonical_dtype(dt)
    return "fp32"


def _cast_args(args: Tuple, dtype: str) -> Tuple:
    """Cast floating-point operands to the ELEN candidate (ints untouched)."""
    jnp_name = _DTYPE_TO_JNP.get(dtype)
    if jnp_name is None:
        return args
    import jax.numpy as jnp

    target = getattr(jnp, jnp_name)
    out = []
    for a in args:
        dt = getattr(a, "dtype", None)
        if dt is not None and jnp.issubdtype(dt, jnp.floating):
            out.append(a.astype(target))
        else:
            out.append(a)
    return tuple(out)


def _time_config(
    ops: Any,
    args: Tuple,
    config: Dict[str, Any],
    *,
    fixed: Dict[str, Any],
    mode: str,
    repeats: int,
    min_time_s: float,
) -> float:
    """Best-of-repeats seconds for one candidate (warmup outside the ROI)."""
    global TIMING_RUNS
    with _TIMING_LOCK:
        TIMING_RUNS += 1
    from repro.core.profiler import time_fn

    kw = {**fixed, **config, "interpret": mode != "compiled"}
    return time_fn(ops, *args, repeats=repeats, min_time_s=min_time_s, **kw)


# ---------------------------------------------------------------------------
# Analytic pruning
# ---------------------------------------------------------------------------


def prune(
    space: TuningSpace,
    args: Tuple,
    chip: hw.ChipSpec,
    dtype: str,
    *,
    keep: int = 4,
) -> Tuple[List[Tuple[Dict[str, Any], float]], int]:
    """Roofline-scored survivors: ``([(config, predicted_s), ...], pruned)``.

    Candidates are clamped/deduplicated/VMEM-filtered by the space, then
    stably sorted by predicted time (enumeration order breaks ties), and
    all but the first ``keep`` are pruned.  The score is monotone in
    predicted traffic and FLOPs, so pruning never discards a config the
    model considers faster than a survivor.
    """
    roofline = adapted_roofline(chip, dtype)
    cands = space.candidates(args)
    scored = [
        (cfg, predicted_config_time_s(space, cfg, args, roofline))
        for cfg in cands
    ]
    scored.sort(key=lambda cs: cs[1])  # stable: ties keep enumeration order
    survivors = scored[: max(int(keep), 1)]
    return survivors, len(cands) - len(survivors)


def _default_config(space: TuningSpace, args: Tuple) -> Dict[str, Any]:
    """The kernel's hard-coded defaults, clamped to the problem (the
    baseline every record's speedup is measured against)."""
    cfg = space.validate(dict(space.default), args)
    if cfg is not None:
        return cfg
    if space.clamp is not None:
        return dict(space.clamp(dict(space.default), args))
    return dict(space.default)


# ---------------------------------------------------------------------------
# The driver
# ---------------------------------------------------------------------------


def tune(
    kernel: Any,
    args: Optional[Tuple] = None,
    *,
    chip: hw.ChipSpec = hw.GRACE_CORE,
    dtype: Optional[str] = None,
    space: Optional[TuningSpace] = None,
    store: Any = "default",
    mode: str = "interpret",
    keep: int = 4,
    repeats: int = 2,
    min_time_s: float = 0.0,
    force: bool = False,
    apply: bool = True,
) -> TuningRecord:
    """Tune one kernel on one (chip, dtype); returns the (possibly cached)
    best-known :class:`TuningRecord`.

    * ``kernel`` — registry name or :class:`~repro.kernels.registry.
      KernelOps`; ``args`` defaults to the kernel's example workload.
    * ``dtype`` — the ELEN axis: operands are cast, the roofline re-kneed.
    * ``store`` — ``"default"`` (persistent, ``$REPRO_ARTIFACT_DIR``-aware),
      a directory path, an ``ArtifactStore``, or ``None`` (never persist).
      On a store hit the record returns with ``cached=True`` and **no
      timing runs are performed** (pass ``force=True`` to re-tune).
    * ``apply`` — install the winning config on the KernelOps so subsequent
      calls resolve it automatically (explicit kwargs still win).
    """
    ops = _resolve_ops(kernel)
    space = space or getattr(ops, "tuning_space", None)
    if space is None:
        raise ValueError(f"kernel {ops.name!r} has no TuningSpace")
    args = tuple(args) if args is not None else _example_args(ops)
    dtype = dtype or _infer_dtype(args)
    if dtype != _infer_dtype(args):
        args = _cast_args(args, dtype)

    store_obj = resolve_store(store)
    fp = tuning_fingerprint(ops.name, ops.raw, args, chip.name, dtype, space)
    if store_obj is not None and not force:
        rec = load_record(store_obj, fp)
        if rec is not None:
            if apply:
                ops.set_tuned(rec.config, chip=chip.name, dtype=dtype)
            return rec

    survivors, pruned = prune(space, args, chip, dtype, keep=keep)
    if not survivors:
        raise ValueError(
            f"{ops.name}: no valid candidate in the tuning space for "
            f"args with shapes {[getattr(a, 'shape', None) for a in args]}"
        )
    fixed = dict(space.fixed)
    timed: List[Tuple[Dict[str, Any], float, float]] = []
    for cfg, predicted_s in survivors:
        t = _time_config(
            ops, args, cfg, fixed=fixed, mode=mode,
            repeats=repeats, min_time_s=min_time_s,
        )
        timed.append((cfg, t, predicted_s))

    roofline = adapted_roofline(chip, dtype)
    best_cfg, best_t, best_pred = min(timed, key=lambda cts: cts[1])
    default_cfg = space.validate(dict(space.default), args)
    default_timed = False
    if default_cfg is None:
        # the kernel's hard-coded default does not fit this problem (it
        # would trip the kernel's divisibility assert): the best survivor
        # doubles as the baseline — never time an invalid config
        default_cfg, default_t, default_pred = best_cfg, best_t, best_pred
    else:
        default_pred = predicted_config_time_s(space, default_cfg, args, roofline)
        default_t = None
        for cfg, t, _ in timed:
            if cfg == default_cfg:
                default_t = t
                break
        if default_t is None:
            default_timed = True
            default_t = _time_config(
                ops, args, default_cfg, fixed=fixed, mode=mode,
                repeats=repeats, min_time_s=min_time_s,
            )
        if default_t < best_t:  # never ship a config slower than the default
            best_cfg, best_t, best_pred = default_cfg, default_t, default_pred

    record = TuningRecord(
        kernel=ops.name,
        chip=chip.name,
        dtype=dtype,
        fingerprint=fp,
        config=best_cfg,
        default_config=default_cfg,
        best_time_s=best_t,
        default_time_s=default_t,
        predicted_best_s=best_pred,
        predicted_default_s=default_pred,
        space_size=space.size(),
        candidates=len(survivors) + pruned,
        pruned=pruned,
        timed=len(timed) + (1 if default_timed else 0),
        mode=mode,
        problem="x".join(
            str(tuple(getattr(a, "shape", ()))) for a in args[:2]
        ),
    )
    if store_obj is not None:
        save_record(store_obj, record)
    if apply:
        ops.set_tuned(record.config, chip=chip.name, dtype=dtype)
    return record


def load_tuned(
    kernel: Any,
    *,
    chip: hw.ChipSpec = hw.GRACE_CORE,
    dtype: Optional[str] = None,
    args: Optional[Tuple] = None,
    store: Any = "default",
    apply: bool = True,
) -> Optional[TuningRecord]:
    """Pick up a persisted record without ever timing (None on store miss).

    The cross-process half of the zero-re-tune story: process A ``tune()``s
    and persists; process B ``load_tuned()``s and its KernelOps resolves the
    stored config at call time.
    """
    ops = _resolve_ops(kernel)
    space = getattr(ops, "tuning_space", None)
    store_obj = resolve_store(store)
    if space is None or store_obj is None:
        return None
    args = tuple(args) if args is not None else _example_args(ops)
    dtype = dtype or _infer_dtype(args)
    if dtype != _infer_dtype(args):
        args = _cast_args(args, dtype)
    fp = tuning_fingerprint(ops.name, ops.raw, args, chip.name, dtype, space)
    rec = load_record(store_obj, fp)
    if rec is not None and apply:
        ops.set_tuned(rec.config, chip=chip.name, dtype=dtype)
    return rec


# ---------------------------------------------------------------------------
# The analyze() hook: tuned-vs-default outlook, no timing
# ---------------------------------------------------------------------------


def outlook(
    ops: Any,
    args: Tuple,
    chip: hw.ChipSpec,
    *,
    dtype: str = "fp32",
    store: Any = "default",
) -> Optional[Dict[str, Any]]:
    """Analytic tuned-vs-default report for ``SVEAnalysis.tuning``.

    Pure model + store lookup — never compiles, never times.  ``record`` is
    the persisted best config when one exists (the zero-re-tune pickup),
    else None.
    """
    space = getattr(ops, "tuning_space", None)
    if space is None:
        return None
    if dtype != _infer_dtype(args):
        # mirror tune(): the ELEN axis casts operands, and both the record
        # fingerprint and the VMEM/traffic models see the cast shapes
        args = _cast_args(args, dtype)
    roofline = adapted_roofline(chip, dtype)
    survivors, pruned = prune(space, args, chip, dtype, keep=1)
    if not survivors:
        return None
    best_cfg, best_pred = survivors[0]
    default_cfg = _default_config(space, args)
    default_pred = predicted_config_time_s(space, default_cfg, args, roofline)
    rec = None
    store_obj = resolve_store(store)
    if store_obj is not None:
        fp = tuning_fingerprint(ops.name, ops.raw, args, chip.name, dtype, space)
        rec = load_record(store_obj, fp)
    return {
        "kernel": ops.name,
        "chip": chip.name,
        "dtype": dtype,
        "default_config": default_cfg,
        "best_config": best_cfg,
        "predicted_default_s": default_pred,
        "predicted_best_s": best_pred,
        "predicted_speedup": (
            max(default_pred / best_pred, 1.0) if best_pred > 0 else 1.0
        ),
        "candidates": len(survivors) + pruned,
        "record": rec.config if rec is not None else None,
        "record_time_s": rec.best_time_s if rec is not None else None,
    }


# ---------------------------------------------------------------------------
# Sweeps (the --tune / CLI entry)
# ---------------------------------------------------------------------------


def tunable_kernels() -> List[str]:
    """Registry kernels with both a TuningSpace and an example workload."""
    from repro.analysis.workload import list_workloads
    from repro.kernels.registry import KERNELS

    workloads = set(list_workloads(tags=("kernel",)))
    return sorted(
        name
        for name, ops in KERNELS.items()
        if ops.tuning_space is not None and f"kernel/{name}" in workloads
    )


def tune_kernels(
    kernels: Optional[Sequence[str]] = None,
    *,
    chip: hw.ChipSpec = hw.GRACE_CORE,
    dtypes: Optional[Sequence[str]] = None,
    jobs: int = 1,
    cap: Optional[int] = None,
    store: Any = "default",
    mode: str = "interpret",
    keep: int = 4,
    repeats: int = 2,
    min_time_s: float = 0.0,
    force: bool = False,
    apply: bool = True,
) -> List[TuningRecord]:
    """Tune a set of kernels over the ELEN axis; returns records in
    deterministic (kernel, dtype) order.

    ``dtypes=None`` tunes each kernel's base dtype only; ``dtypes=()`` (or
    ``["space"]``) sweeps each space's own ELEN candidates.  ``cap`` takes
    the first N values of every axis (the CI tiny-space knob).  ``jobs``
    fans (kernel, dtype) cells over a thread pool — store hits are
    timing-free so this is safe for cached sweeps; live timing under heavy
    concurrency will show scheduler noise.
    """
    names = list(kernels) if kernels else tunable_kernels()
    cells: List[Tuple[str, TuningSpace, Optional[str]]] = []
    for name in names:
        ops = _resolve_ops(name)
        space = getattr(ops, "tuning_space", None)
        if space is None:
            raise ValueError(f"kernel {name!r} has no TuningSpace")
        if cap is not None:
            space = space.subset(cap)
        if dtypes is None:
            cell_dtypes: Sequence[Optional[str]] = (None,)
        elif len(dtypes) == 0 or list(dtypes) == ["space"]:
            cell_dtypes = space.dtypes or (None,)
        else:
            cell_dtypes = dtypes
        for dt in cell_dtypes:
            cells.append((name, space, dt))

    def run_cell(cell: Tuple[str, TuningSpace, Optional[str]]) -> TuningRecord:
        name, cell_space, dt = cell
        return tune(
            name, chip=chip, dtype=dt, space=cell_space, store=store,
            mode=mode, keep=keep, repeats=repeats, min_time_s=min_time_s,
            force=force, apply=apply,
        )

    if jobs <= 1 or len(cells) <= 1:
        return [run_cell(c) for c in cells]
    with ThreadPoolExecutor(max_workers=jobs) as pool:
        return list(pool.map(run_cell, cells))


def report_dict(records: Sequence[TuningRecord], *, wall_s: float = 0.0) -> Dict:
    """Machine-readable ``tuning.json`` payload."""
    return {
        "kind": "tuning_report",
        "records": [r.to_dict() for r in records],
        "stats": {
            "tuned": len(records),
            "cached": sum(1 for r in records if r.cached),
            "timing_runs": TIMING_RUNS,
            "wall_s": round(wall_s, 3),
        },
    }


def format_records(records: Sequence[TuningRecord]) -> str:
    """Fixed-width table over ``TuningRecord.row()`` projections."""
    rows = [r.row() for r in records]
    if not rows:
        return "(no tuning records)"
    keys = list(rows[0].keys())
    widths = {k: max(len(k), *(len(str(r[k])) for r in rows)) for k in keys}
    lines = ["  ".join(k.ljust(widths[k]) for k in keys)]
    for r in rows:
        lines.append("  ".join(str(r[k]).ljust(widths[k]) for k in keys))
    return "\n".join(lines)
