import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and record cost/memory/collective analysis for §Roofline.

MUST be run as its own process (the two lines above must execute before any
jax import anywhere — including ``from repro...``).  Smoke tests and benches
never import this module, so they see 1 device.

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--jobs 4] [--baseline]
    python -m repro.launch.dryrun --list
"""

import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

OUT_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def run_one(arch: str, shape: str, mesh_kind: str, baseline: bool, out_dir: str) -> dict:
    import jax  # noqa: E402  (after XLA_FLAGS)

    from repro.launch import cells as cells_mod
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    cell = cells_mod.build_cell(arch, shape, mesh, baseline=baseline)
    lowered, compiled = cells_mod.lower_cell(cell, mesh)
    t1 = time.time()
    print(compiled.memory_analysis())  # proves it fits
    print({k: v for k, v in (compiled.cost_analysis() or {}).items()
           if k in ("flops", "bytes accessed")})
    result = cells_mod.analyze_cell(cell, mesh, compiled)
    result["compile_s"] = t1 - t0
    result["baseline"] = baseline
    os.makedirs(out_dir, exist_ok=True)
    # atomic write: --skip-existing trusts file existence, so an interrupted
    # dump must never leave a truncated artifact behind
    path = _cell_artifact(out_dir, arch, shape, mesh_kind, baseline)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(result, f, indent=1)
    os.replace(tmp, path)
    print(f"[ok] {arch} x {shape} x {mesh_kind} "
          f"compile={result['compile_s']:.1f}s "
          f"dominant={result['roofline']['dominant']} "
          f"bound={result['roofline']['bound_s']:.4g}s "
          f"mem/dev={result['memory_per_device']['total_gb']:.2f}GB "
          f"class={result['sve']['perf_class']}"
          f"({result['sve']['perf_class_name']})")
    return result


def all_cells():
    # import here so --list works without jax device init side effects
    from repro.configs import cells as cfg_cells

    out = []
    for arch, shape in cfg_cells(include_paper_arch=False):
        for mesh_kind in ("single", "multi"):
            out.append((arch, shape, mesh_kind))
    return out


def _cell_artifact(out_dir: str, arch: str, shape: str, mesh_kind: str,
                   baseline: bool) -> str:
    tag = "base" if baseline else "opt"
    fname = f"{arch}__{shape}__{mesh_kind}__{tag}.json".replace("/", "_")
    return os.path.join(out_dir, fname)


def drive_all(jobs: int, baseline: bool, out_dir: str, mesh_filter=None,
              skip_existing: bool = False) -> int:
    todo = [c for c in all_cells() if mesh_filter is None or c[2] == mesh_filter]
    if skip_existing:
        kept = []
        for c in todo:
            path = _cell_artifact(out_dir, c[0], c[1], c[2], baseline)
            if os.path.exists(path):
                print(f"[skip] {c}: artifact exists ({path})")
            else:
                kept.append(c)
        todo = kept
    procs = {}
    failed, done = [], 0
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    while todo or procs:
        while todo and len(procs) < jobs:
            arch, shape, mesh_kind = todo.pop(0)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                   "--out-dir", out_dir]
            if baseline:
                cmd.append("--baseline")
            logname = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.log")
            os.makedirs(out_dir, exist_ok=True)
            logf = open(logname, "w")
            p = subprocess.Popen(cmd, stdout=logf, stderr=subprocess.STDOUT, env=env)
            procs[p.pid] = (p, (arch, shape, mesh_kind), logf)
        time.sleep(2)
        for pid in list(procs):
            p, cellid, logf = procs[pid]
            if p.poll() is not None:
                logf.close()
                del procs[pid]
                done += 1
                status = "ok" if p.returncode == 0 else "FAIL"
                if p.returncode != 0:
                    failed.append(cellid)
                print(f"[{done}] {status}: {cellid}", flush=True)
    if failed:
        print(f"{len(failed)} FAILED cells: {failed}")
        return 1
    print(f"all {done} cells compiled clean")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful baseline RunConfig instead of optimized")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--jobs", type=int, default=4,
                    help="concurrent compile subprocesses for --all")
    ap.add_argument("--skip-existing", action="store_true",
                    help="with --all: skip cells whose analysis JSON already "
                         "exists in --out-dir (persistent artifact reuse)")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args()

    if args.list:
        for c in all_cells():
            print(*c)
        return 0
    if args.all:
        return drive_all(args.jobs, args.baseline, args.out_dir,
                         skip_existing=args.skip_existing)
    try:
        run_one(args.arch, args.shape, args.mesh, args.baseline, args.out_dir)
        return 0
    except Exception:
        traceback.print_exc()
        return 1


if __name__ == "__main__":
    sys.exit(main())
