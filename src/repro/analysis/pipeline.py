"""One-call SVE analysis pipeline: Workload -> SVEAnalysis.

Implements the paper's end-to-end method (Sec. 3): ``analyze(workload)``
chains PMU-analogue event extraction (``core.counters``, paper Sec. 3.1 /
Table 1), Eq. 1 metrics (VB, R_ins, AI — Sec. 3.3), the adapted roofline
(Eq. 2) and the Fig. 8 decision tree into a single call that returns a
typed, serializable report.  Callers never wire counters / metrics /
roofline / decision_tree by hand again.  Kernel workloads additionally
carry the autotuner's tuned-vs-default outlook (``SVEAnalysis.tuning``,
see :mod:`repro.tuning`).

Event sources (``source=``):

* ``"analytic"`` — the workload's Sec.-3.3-style flops/bytes model;
* ``"compiled"`` — lower + compile the workload's callable and extract
  events from the XLA artifact (``counters.events_from_compiled``);
* ``"auto"`` (default) — analytic when the model is present, else compiled.

``analyze_sweep`` amortizes compilation: compiled artifacts are
chip-independent (events are GLOBAL quantities), so a multi-chip /
multi-ELEN sweep compiles each workload exactly once via ``ArtifactCache``
— and, backed by the persistent :class:`~repro.analysis.store.ArtifactStore`,
at most once across *processes*.  ``analyze_sweep(..., jobs=N)`` fans the
(workload x chip x dtype) cells over a thread pool; single-flight
deduplication in the cache guarantees concurrent cells of the same workload
wait on one compile rather than racing.
"""

from __future__ import annotations

import dataclasses
import json
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

from repro.core import hw, metrics
from repro.core.counters import Events, events_from_analytic, events_from_compiled
from repro.core.decision_tree import Decision, PerfClass, classify
from repro.core.metrics import VectorizationReport
from repro.core.roofline import AdaptedRoofline, adapted_roofline
from repro.analysis.store import ArtifactStore, default_store, workload_fingerprint
from repro.analysis.workload import Workload, get_workload, list_workloads

WorkloadLike = Union[str, Workload]

#: Sentinel: resolve ``store.default_store()`` lazily, at first use (so the
#: ``$REPRO_ARTIFACT_DIR`` override is honored even for module-level caches).
DEFAULT_STORE = "default"


# ---------------------------------------------------------------------------
# Compiled-artifact cache (the sweep's compile-once guarantee)
# ---------------------------------------------------------------------------


class ArtifactCache:
    """In-memory + optionally disk-backed cache of per-workload Events.

    Events are chip-independent (global flops/bytes/collective quantities),
    so one compile serves every (chip, dtype) cell of a sweep.  Lookups are
    **single-flight**: under a parallel sweep, concurrent cells for the same
    workload block on one leader's compile instead of compiling N times.

    ``store`` adds a persistent layer keyed by workload fingerprint (see
    :mod:`repro.analysis.store`): pass an :class:`ArtifactStore`, the
    :data:`DEFAULT_STORE` sentinel for the shared default directory, any
    other string as a cache-directory path, or ``None`` (default) for a
    process-local, memory-only cache.

    ``compiles`` / ``hits`` / ``store_hits`` are exposed for tests and cost
    accounting (``hits`` counts in-memory hits only).
    """

    def __init__(self, store: Union[ArtifactStore, str, None] = None) -> None:
        # keyed by workload fingerprint (content address), NOT object
        # identity: two distinct workloads sharing a name but differing in
        # shapes/dtypes/body get different keys, while the cache never pins
        # request Workloads (and their example arrays) for the process
        # lifetime — a long-lived AnalysisService stays bounded by the
        # small Events payloads
        self._events: Dict[str, Events] = {}
        self._store = store
        self._lock = threading.Lock()
        self._inflight: Dict[str, threading.Event] = {}
        self.compiles = 0
        self.hits = 0
        self.store_hits = 0

    @property
    def store(self) -> Optional[ArtifactStore]:
        if isinstance(self._store, str):
            if self._store == DEFAULT_STORE:
                return default_store()
            # any other string is a cache directory (one store per dir)
            from repro.analysis.store import _store_for

            return _store_for(self._store)
        return self._store

    def events_for(self, wl: Workload) -> Events:
        if wl.fn is None:
            raise ValueError(f"{wl.name}: no callable to compile")
        key = workload_fingerprint(wl)
        while True:
            with self._lock:
                if key in self._events:
                    self.hits += 1
                    return self._events[key]
                flight = self._inflight.get(key)
                if flight is None:
                    # become the leader for this workload
                    self._inflight[key] = threading.Event()
                    break
            # another thread is compiling this workload: wait, then re-check
            # (if the leader failed, the loop elects a new leader)
            flight.wait()
        try:
            ev = self._load_or_compile(wl, key)
            with self._lock:
                self._events[key] = ev
            return ev
        finally:
            with self._lock:
                self._inflight.pop(key).set()

    def _load_or_compile(self, wl: Workload, fingerprint: str) -> Events:
        store = self.store
        if store is not None:
            ev = store.get(fingerprint)
            if ev is not None:
                with self._lock:
                    self.store_hits += 1
                return ev
        with self._lock:
            self.compiles += 1
        # already-jitted callables (and KernelOps) expose .lower — use it
        # rather than re-wrapping, which would re-trace static arguments
        lower = getattr(wl.fn, "lower", None)
        if lower is None:
            import jax

            lower = jax.jit(wl.fn).lower
        compiled = lower(*wl.example_args()).compile()
        ev = events_from_compiled(compiled, n_devices=wl.n_devices)
        if store is not None:
            store.put(fingerprint, ev, workload=wl.name)
        return ev

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.compiles = 0
            self.hits = 0
            self.store_hits = 0


#: Module-level default cache shared by bare ``analyze`` calls — persistent
#: across processes via the default ArtifactStore.
DEFAULT_CACHE = ArtifactCache(store=DEFAULT_STORE)


# ---------------------------------------------------------------------------
# The typed report
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SVEAnalysis:
    """Everything the paper derives about one workload on one chip model.

    ``tuning`` (kernel workloads only) is the autotuner's analytic outlook:
    the default vs roofline-best block config, the predicted tuned-vs-
    default speedup, and — when the tuning store already holds a record for
    this (kernel, chip, dtype) — the persisted winning config.
    """

    workload: str
    chip: str
    dtype: str
    source: str  # "analytic" | "compiled"
    events: Events
    report: VectorizationReport
    roofline: AdaptedRoofline
    decision: Decision
    wall_s: Optional[float] = None
    tuning: Optional[Dict[str, Any]] = None

    # -- the paper's headline quantities, flattened -------------------------
    @property
    def vb(self) -> float:
        return self.roofline.vb

    @property
    def r_ins(self) -> float:
        return self.report.r_ins

    @property
    def ai(self) -> float:
        return self.report.ai

    @property
    def ai_inflection(self) -> float:
        return self.decision.ai_inflection

    @property
    def perf_class(self) -> PerfClass:
        return self.decision.perf_class

    @property
    def bound(self) -> str:
        """Adapted-roofline region: "memory-bound" or "compute-bound"."""
        return self.roofline.region(self.ai)

    @property
    def predicted_speedup(self) -> float:
        return self.roofline.predicted_speedup(self.ai)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "workload": self.workload,
            "chip": self.chip,
            "dtype": self.dtype,
            "source": self.source,
            "vb": self.vb,
            "r_ins": self.r_ins,
            "ai": self.ai,
            "ai_inflection": self.ai_inflection,
            "bound": self.bound,
            "predicted_speedup": self.predicted_speedup,
            "perf_class": int(self.perf_class),
            "perf_class_name": self.perf_class.name,
            "rationale": self.decision.rationale,
            "gather_fraction": self.report.gather_fraction,
            "vectorizable_fraction": self.report.vectorizable_fraction,
            "flops": self.report.flops,
            "hbm_bytes": self.report.hbm_bytes,
            "wall_s": self.wall_s,
            "events": self.events.to_dict(),
            "roofline": dataclasses.asdict(self.roofline),
            "tuning": self.tuning,
        }

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), **kw)

    def row(self) -> Dict[str, Any]:
        """One flat table row (the CSV/pretty-print projection)."""
        return {
            "workload": self.workload,
            "chip": self.chip,
            "dtype": self.dtype,
            "vb": f"{self.vb:.0f}",
            "r_ins": f"{self.r_ins:.3g}",
            "ai": f"{self.ai:.4g}",
            "knee": f"{self.ai_inflection:.4g}",
            "bound": self.bound,
            "class": f"{int(self.perf_class)} {self.perf_class.name}",
            "speedup_pred": f"{self.predicted_speedup:.3g}",
            "tuned": (
                "" if not self.tuning
                else f"{self.tuning['predicted_speedup']:.3g}x"
            ),
            "wall_s": "" if self.wall_s is None else f"{self.wall_s:.5f}",
        }

    def table(self) -> str:
        return format_table([self])

    def __str__(self) -> str:
        return (
            f"[{self.workload} @ {self.chip}/{self.dtype}] "
            f"VB={self.vb:.0f} R_ins={self.r_ins:.3g} AI={self.ai:.4g} "
            f"({self.bound}) Class {int(self.perf_class)} "
            f"({self.perf_class.describe()})"
        )


def format_table(results: Sequence[SVEAnalysis]) -> str:
    """Pretty fixed-width table over ``SVEAnalysis.row()`` projections."""
    rows = [r.row() for r in results]
    if not rows:
        return "(no results)"
    keys = list(rows[0].keys())
    widths = {k: max(len(k), *(len(str(r[k])) for r in rows)) for k in keys}
    lines = ["  ".join(k.ljust(widths[k]) for k in keys)]
    for r in rows:
        lines.append("  ".join(str(r[k]).ljust(widths[k]) for k in keys))
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# The pipeline
# ---------------------------------------------------------------------------


def _resolve(wl: WorkloadLike) -> Workload:
    return get_workload(wl) if isinstance(wl, str) else wl


def _report_from_events(
    name: str, dtype: str, ev: Events, chip: hw.ChipSpec
) -> VectorizationReport:
    """Eq.-1 report from artifact events: scalar baseline = one element per
    issue slot; effective R_ins = Amdahl over the vectorizable FLOP share."""
    vb = metrics.vectorization_bound(chip, dtype)
    r_eff = metrics.amdahl_r_ins(vb, ev.vectorizable_fraction)
    ins_scalar = ev.flops / 2.0
    return VectorizationReport(
        name=name,
        dtype=dtype,
        flops=ev.flops,
        hbm_bytes=ev.bytes_accessed,
        gather_bytes=ev.gather_bytes,
        ins_scalar=ins_scalar,
        ins_vec=ins_scalar / max(r_eff, 1e-30),
        vectorizable_fraction=ev.vectorizable_fraction,
        collective_bytes=ev.collective_bytes,
    )


def _tuning_outlook(
    wl: Workload, chip: hw.ChipSpec, dtype: str
) -> Optional[Dict[str, Any]]:
    """Autotuner outlook for kernel workloads (model + store lookup only —
    never compiles or times; never raises into the analysis)."""
    if not wl.name.startswith("kernel/"):
        return None
    try:
        from repro.kernels.registry import KERNELS

        ops = KERNELS.get(wl.name[len("kernel/"):])
        if ops is None or ops.tuning_space is None:
            return None
        from repro.tuning import outlook

        return outlook(ops, wl.example_args(), chip, dtype=dtype)
    except Exception:  # noqa: BLE001 — the outlook is advisory, not load-bearing
        return None


def _time_roi(wl: Workload) -> Optional[float]:
    """ROI wall time through the paper's profiler API (Sec. 3.1)."""
    if wl.fn is None:
        return None
    import jax

    from repro.core.profiler import Profiler

    args = wl.example_args()
    prof = Profiler()
    prof.configure_measure()
    jax.block_until_ready(wl.fn(*args))  # warmup/compile outside the ROI
    prof.start_measure()
    jax.block_until_ready(wl.fn(*args))
    prof.stop_measure()
    return prof.mean_roi_s()


def analyze(
    wl: WorkloadLike,
    chip: hw.ChipSpec = hw.GRACE_CORE,
    *,
    dtype: Optional[str] = None,
    source: str = "auto",
    time_roi: bool = False,
    cache: Optional[ArtifactCache] = None,
    store: Union[ArtifactStore, str, None] = None,
) -> SVEAnalysis:
    """Run the paper's full method on one workload, on one chip model.

    Chains compile/lower (cached) -> event extraction -> Eq. 1 metrics ->
    adapted roofline (Eq. 2) -> Fig. 8 decision tree, plus an optional
    profiler-timed ROI, and returns the typed :class:`SVEAnalysis`.  For
    registry kernels with a TuningSpace the result also reports the
    roofline-predicted tuned-vs-default speedup and any persisted tuned
    config (``result.tuning``).

    Without ``cache``, events come from the module-level ``DEFAULT_CACHE``
    (persistent via the default ArtifactStore, so repeat processes skip
    compilation); pass ``store`` to persist under a specific store instead,
    or an explicit memory-only ``ArtifactCache()`` to bypass persistence.
    """
    wl = _resolve(wl)
    if cache is None:
        cache = ArtifactCache(store=store) if store is not None else DEFAULT_CACHE
    dtype = dtype or wl.dtype
    if source not in ("auto", "analytic", "compiled"):
        raise ValueError(f"source must be auto|analytic|compiled, got {source!r}")
    if source == "auto":
        source = "analytic" if wl.has_analytic_model else "compiled"

    if source == "analytic":
        if not wl.has_analytic_model:
            raise ValueError(f"{wl.name}: no analytic model for source='analytic'")
        ev = events_from_analytic(
            flops=wl.flops,
            hbm_bytes=wl.hbm_bytes,
            gather_bytes=wl.gather_bytes,
            collective_bytes=wl.collective_bytes,
            n_devices=wl.n_devices,
        )
        ev.nonvec_flops = wl.flops * (1.0 - wl.vectorizable_fraction)
        report = wl.report(chip, dtype=dtype)
    else:
        ev = cache.events_for(wl)
        report = _report_from_events(wl.name, dtype, ev, chip)

    rl = adapted_roofline(chip, dtype)
    decision = classify(report, chip, roofline=rl)
    wall = _time_roi(wl) if time_roi else None
    return SVEAnalysis(
        workload=wl.name,
        chip=chip.name,
        dtype=dtype,
        source=source,
        events=ev,
        report=report,
        roofline=rl,
        decision=decision,
        wall_s=wall,
        tuning=_tuning_outlook(wl, chip, dtype),
    )


def analyze_events(
    name: str,
    events: Events,
    chip: hw.ChipSpec = hw.GRACE_CORE,
    *,
    dtype: str = "fp32",
) -> SVEAnalysis:
    """The pipeline's tail for callers that already hold Events (e.g. the
    dry-run, which post-processes events with its analytic traffic model)."""
    report = _report_from_events(name, dtype, events, chip)
    rl = adapted_roofline(chip, dtype)
    return SVEAnalysis(
        workload=name,
        chip=chip.name,
        dtype=dtype,
        source="compiled",
        events=events,
        report=report,
        roofline=rl,
        decision=classify(report, chip, roofline=rl),
    )


def analyze_compiled(
    name: str,
    compiled: Any,
    chip: hw.ChipSpec = hw.GRACE_CORE,
    *,
    dtype: str = "fp32",
    n_devices: Optional[int] = None,
) -> SVEAnalysis:
    """Analyze an already-compiled ``jax.stages.Compiled`` artifact."""
    ev = events_from_compiled(compiled, n_devices=n_devices)
    return analyze_events(name, ev, chip, dtype=dtype)


def analyze_sweep(
    workloads: Optional[Iterable[WorkloadLike]] = None,
    chips: Sequence[hw.ChipSpec] = (hw.GRACE_CORE, hw.TPU_V5E),
    *,
    dtypes: Optional[Sequence[str]] = None,
    source: str = "auto",
    time_roi: bool = False,
    cache: Optional[ArtifactCache] = None,
    store: Union[ArtifactStore, str, None] = None,
    jobs: int = 1,
) -> List[SVEAnalysis]:
    """``analyze`` over a (workload x chip x dtype) grid, compiling each
    workload at most once (events are chip-independent; see ArtifactCache).

    ``workloads`` defaults to every registered workload; ``dtypes`` defaults
    to each workload's own dtype.  Without an explicit ``cache``, the sweep
    is backed by the persistent default ArtifactStore (or ``store``), so a
    repeat sweep in a fresh process performs zero compiles.

    ``jobs > 1`` fans the cells over a thread pool.  Results are returned in
    the same deterministic (workload, chip, dtype) order as the serial path,
    and the cache's single-flight guarantee keeps the compile count at one
    per unique workload regardless of concurrency.
    """
    if cache is None:
        cache = ArtifactCache(store=store if store is not None else DEFAULT_STORE)
    names = list(workloads) if workloads is not None else list_workloads()
    cells: List[tuple] = []
    for w in names:
        wl = _resolve(w)
        for chip in chips:
            for dtype in dtypes or (wl.dtype,):
                cells.append((wl, chip, dtype))

    def run_cell(cell: tuple) -> SVEAnalysis:
        wl, chip, dtype = cell
        return analyze(
            wl, chip, dtype=dtype, source=source, time_roi=time_roi, cache=cache
        )

    if jobs <= 1 or len(cells) <= 1:
        return [run_cell(c) for c in cells]
    with ThreadPoolExecutor(max_workers=jobs) as pool:
        return list(pool.map(run_cell, cells))
