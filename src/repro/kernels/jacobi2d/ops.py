"""Jacobi2D kernel call surface (served by the kernel registry) + the
multi-sweep driver."""

from __future__ import annotations

import functools

import jax

from repro.kernels.registry import JACOBI_STEP as jacobi_step
from repro.kernels.jacobi2d.kernel import jacobi_step as _step

__all__ = ["jacobi_step", "jacobi"]


@functools.partial(jax.jit, static_argnames=("sweeps", "block_rows", "interpret"))
def jacobi(u, *, sweeps: int = 10, block_rows: int = 128, interpret: bool = True):
    def body(u, _):
        return _step(u, block_rows=block_rows, interpret=interpret), None

    u, _ = jax.lax.scan(body, u, None, length=sweeps)
    return u
