"""State-vector RX-gate kernel — the paper's quantum-circuit simulator core.

An RX(theta) on qubit q of an n-qubit state mixes amplitude pairs whose
indices differ in bit q:

    |a'> = cos(t/2)|a> - i sin(t/2)|b>,   |b'> = cos(t/2)|b> - i sin(t/2)|a>

TPU adaptation: complex64 is not a vector-unit-native type, so the state is
stored as separate (re, im) fp32 planes (structure-of-arrays — the same
trick SVE ports of QC simulators use to keep lanes dense), reshaped to
(outer, 2, inner) with inner = 2**q so the pair partner is a fixed stride.
The kernel tiles the OUTER axis with BlockSpecs; each program applies the
rotation to a (bo, 2, inner) tile in VMEM.  AI ~ 6 flops / 16 bytes per
amplitude — memory-bound for large n (paper Fig. 5: speedup collapses once
the socket's bandwidth saturates at ~8 threads).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rx_kernel(re_ref, im_ref, ore_ref, oim_ref, *, cos: float, sin: float):
    re = re_ref[...]  # (bo, 2, inner)
    im = im_ref[...]
    re0, re1 = re[:, 0], re[:, 1]
    im0, im1 = im[:, 0], im[:, 1]
    # (cos - i sin X) rotation: a' = c*a - i s*b ; b' = c*b - i s*a
    ore0 = cos * re0 + sin * im1
    oim0 = cos * im0 - sin * re1
    ore1 = cos * re1 + sin * im0
    oim1 = cos * im1 - sin * re0
    ore_ref[...] = jnp.stack([ore0, ore1], axis=1)
    oim_ref[...] = jnp.stack([oim0, oim1], axis=1)


def rx_gate(
    re: jax.Array,
    im: jax.Array,
    qubit: int,
    theta: float,
    *,
    block_outer: int = 256,
    interpret: bool = True,
):
    """Apply RX(theta) on ``qubit`` to the state (re, im), both (2**n,)."""
    import math

    n_amp = re.shape[0]
    inner = 1 << qubit
    outer = n_amp // (2 * inner)
    assert outer * 2 * inner == n_amp, (n_amp, qubit)
    c, s = math.cos(theta / 2), math.sin(theta / 2)
    re3 = re.reshape(outer, 2, inner)
    im3 = im.reshape(outer, 2, inner)
    bo = min(block_outer, outer)
    assert outer % bo == 0
    kernel = functools.partial(_rx_kernel, cos=c, sin=s)
    ore, oim = pl.pallas_call(
        kernel,
        grid=(outer // bo,),
        in_specs=[
            pl.BlockSpec((bo, 2, inner), lambda i: (i, 0, 0)),
            pl.BlockSpec((bo, 2, inner), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bo, 2, inner), lambda i: (i, 0, 0)),
            pl.BlockSpec((bo, 2, inner), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((outer, 2, inner), re.dtype),
            jax.ShapeDtypeStruct((outer, 2, inner), im.dtype),
        ],
        interpret=interpret,
    )(re3, im3)
    return ore.reshape(n_amp), oim.reshape(n_amp)
