"""MXU-tiled GEMM — the paper's DGEMM/SGEMM on TPU.

Classic Pallas TPU matmul schedule: grid (M/bm, N/bn, K/bk) with the K axis
innermost ("arbitrary" = sequential), accumulating into an fp32 VMEM scratch
tile; the output tile is written once on the last K step.  Block shapes are
MXU-aligned (multiples of 128 on the matmul dims in production; tests sweep
smaller aligned tiles).

The paper's ELEN axis maps to the dtype sweep: fp32 ("double" stand-in on
TPU — the MXU has no fp64), bf16 (native), and the accumulate-in-fp32 rule
plays the role of SVE's widening arithmetic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """x: (M, K) @ y: (K, N) -> (M, N); fp32 accumulation in VMEM scratch."""
    M, K = x.shape
    K2, N = y.shape
    assert K == K2, (x.shape, y.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"({M},{N},{K}) not divisible by tile ({bm},{bn},{bk})"
    )
    nk = K // bk
    kernel = functools.partial(_gemm_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[_vmem_scratch(bm, bn)],
        interpret=interpret,
    )(x, y)


def _vmem_scratch(bm: int, bn: int):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM((bm, bn), jnp.float32)
