"""Oracle + analytic terms for the STREAM kernels (McCalpin semantics)."""

from __future__ import annotations

import jax.numpy as jnp


def copy_ref(a):
    return a + 0  # force a materialized copy


def scale_ref(a, q):
    return a * jnp.asarray(q, a.dtype)


def add_ref(a, b):
    return a + b


def triad_ref(a, b, q):
    return a + jnp.asarray(q, a.dtype) * b


def flops_bytes(kind: str, n_elements: int, dtype_bytes: int) -> dict:
    """McCalpin counting: copy/scale move 2N words, add/triad 3N."""
    words = {"copy": 2, "scale": 2, "add": 3, "triad": 3}[kind]
    flops = {"copy": 0, "scale": 1, "add": 1, "triad": 2}[kind] * n_elements
    bytes_ = words * n_elements * dtype_bytes
    return {"flops": float(flops), "bytes": float(bytes_),
            "ai": flops / bytes_ if bytes_ else 0.0}
