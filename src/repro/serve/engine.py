"""Slot-level continuously-batched serving engine over a paged KV cache.

The production serve path: a fixed set of ``max_batch`` slots advances
through one fused :func:`~repro.models.transformer.decode_step_paged` per
token, and every slot carries its OWN cache position.  When a request
finishes (EOS or token budget) its slot is refilled from the queue on the
very next step and its cache blocks return to a shared pool — finished
slots are masked out and reassigned, never waited on.  This is the paper's
predication insight (Eq. 1: keep the lanes busy) executed at the serving
layer, where a fused decode step is the vector issue and the batch slots
are its lanes; :func:`repro.core.metrics.slot_utilization` reports the
resulting busy-lane fraction.

The KV cache is PAGED: attention caches live in a physical block pool
addressed through per-slot block tables (``block_size`` tokens per block,
block 0 reserved as the null block idle slots write into), so a slot's
logical cache never moves when requests of different lengths come and go,
and blocks freed by one request are immediately reused by the next.
Scheduling state — positions, block tables, the free list — is host-side
numpy ("slot accounting"); only the pools live on device, and the fused
step is compiled exactly once per engine.

``scheduler="wave"`` keeps the legacy lockstep behavior (admit a wave,
run every slot to the wave's horizon) as the golden-equivalence baseline:
both schedulers feed identical per-request token sequences, so greedy
outputs must match token-for-token while the continuous scheduler spends
strictly fewer fused steps on ragged workloads.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerKind, ModelConfig
from repro.core import metrics as core_metrics
from repro.models import transformer

SCHEDULERS = ("continuous", "wave")


class RequestTooLong(ValueError):
    """Raised at submit() time when prompt + budget exceed one slot's cache.

    Typed and early on purpose: under the old in-wave ``assert`` a single
    oversized request crashed the whole wave it was batched into.
    """


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never stops early

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        self.generated: List[int] = []
        self.done = False
        self.submitted_s: Optional[float] = None
        self.started_s: Optional[float] = None
        self.finished_s: Optional[float] = None

    @property
    def latency_s(self) -> Optional[float]:
        """Submit -> finish wall time (includes queue wait — the quantity
        continuous batching exists to shrink)."""
        if self.submitted_s is None or self.finished_s is None:
            return None
        return self.finished_s - self.submitted_s


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 256, scheduler: str = "continuous",
                 block_size: int = 16):
        if scheduler not in SCHEDULERS:
            raise ValueError(f"scheduler must be one of {SCHEDULERS}, "
                             f"got {scheduler!r}")
        if scheduler == "continuous" and max_len % block_size:
            # wave mode uses the dense cache and never touches the pool
            raise ValueError(f"max_len {max_len} must be a multiple of "
                             f"block_size {block_size}")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.scheduler = scheduler
        self.block_size = block_size
        self.queue: Deque[Request] = deque()
        self.completed: Dict[int, Request] = {}
        # slot accounting (Eq. 1 analogue): fused steps are vector issues,
        # slots are lanes, busy_slot_steps counts the useful lane-steps
        self.steps = 0
        self.busy_slot_steps = 0
        self.wall_s = 0.0
        #: uid -> physical block ids the request occupied, in allocation
        #: order (pool-reuse introspection; continuous scheduler only)
        self.block_history: Dict[int, List[int]] = {}
        self._decode = jax.jit(
            lambda p, t, c: transformer.decode_step(p, cfg, t, c)
        )
        self._decode_paged = jax.jit(
            lambda p, t, c, pos, bt: transformer.decode_step_paged(
                p, cfg, t, c, pos, bt, block_size=block_size
            )
        )
        self._reset_slots = jax.jit(transformer.reset_paged_slots)
        self._has_state = any(k != LayerKind.ATTN for k in cfg.superblock)

    # -- bookkeeping -----------------------------------------------------------

    @property
    def total_slot_steps(self) -> int:
        return self.steps * self.max_batch

    @property
    def slot_utilization(self) -> float:
        return core_metrics.slot_utilization(
            self.busy_slot_steps, self.steps, self.max_batch
        )

    def submit(self, req: Request) -> None:
        horizon = len(req.prompt) + req.max_new_tokens
        if horizon > self.max_len:
            raise RequestTooLong(
                f"request {req.uid}: prompt[{len(req.prompt)}] + "
                f"max_new_tokens[{req.max_new_tokens}] = {horizon} exceeds "
                f"the per-slot cache ({self.max_len} tokens)"
            )
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.uid}: empty prompt")
        req.submitted_s = time.time()
        self.queue.append(req)

    def _finish(self, req: Request) -> None:
        req.done = True
        if req.finished_s is None:
            req.finished_s = time.time()
        self.completed[req.uid] = req

    # -- wave scheduler (legacy lockstep, golden baseline) ---------------------

    def _run_wave(self, wave: List[Request]) -> None:
        B = self.max_batch
        cache = transformer.init_cache(self.cfg, B, self.max_len)
        prompt_len = np.array(
            [len(r.prompt) for r in wave] + [1] * (B - len(wave)), np.int32
        )
        horizon = int(max(
            len(r.prompt) + r.max_new_tokens for r in wave
        ))
        if horizon > self.max_len:  # unreachable: submit() already rejects
            raise RequestTooLong(f"wave horizon {horizon} > {self.max_len}")
        tokens = np.zeros((B, 1), np.int32)
        for s, r in enumerate(wave):
            tokens[s, 0] = r.prompt[0]
            r.started_s = time.time()

        for t in range(horizon - 1):
            self.busy_slot_steps += sum(1 for r in wave if not r.done)
            logits, cache = self._decode(self.params, jnp.asarray(tokens), cache)
            self.steps += 1
            nxt = np.asarray(jnp.argmax(logits[:, 0, : self.cfg.vocab], axis=-1))
            for s, r in enumerate(wave):
                if r.done:
                    continue
                if t + 1 < prompt_len[s]:
                    tokens[s, 0] = r.prompt[t + 1]  # still consuming prompt
                else:
                    tok = int(nxt[s])
                    r.generated.append(tok)
                    tokens[s, 0] = tok
                    if (len(r.generated) >= r.max_new_tokens or tok == r.eos_id):
                        r.done = True
                        r.finished_s = time.time()
            if all(r.done for r in wave):
                break
        for r in wave:
            self._finish(r)

    def _drain_waves(self, max_waves: int) -> None:
        waves = 0
        while self.queue:
            if waves >= max_waves:
                raise RuntimeError("serve loop did not drain")
            wave = [self.queue.popleft()
                    for _ in range(min(self.max_batch, len(self.queue)))]
            self._run_wave(wave)
            waves += 1

    # -- continuous scheduler (per-slot positions, paged blocks) ---------------

    def _drain_continuous(self, max_steps: Optional[int]) -> None:
        B, bs = self.max_batch, self.block_size
        nb_slot = self.max_len // bs
        if max_steps is None:
            # exact occupancy bound: a request holds its slot for at most
            # prompt + max_new - 1 steps, so total work is a hard cap
            max_steps = sum(
                len(r.prompt) + r.max_new_tokens for r in self.queue
            ) + B
        cache = transformer.init_paged_cache(self.cfg, B, self.max_len, bs)
        positions = np.zeros(B, np.int32)
        block_tables = np.zeros((B, nb_slot), np.int32)  # 0 = null block
        free: Deque[int] = deque(range(1, 1 + B * nb_slot))
        slot_req: List[Optional[Request]] = [None] * B
        tokens = np.zeros((B, 1), np.int32)
        reset_mask = np.zeros(B, bool)

        while True:
            # refill: finished slots take the next queued request NOW —
            # the lane is re-predicated, not idled until a wave drains
            for b in range(B):
                if slot_req[b] is None and self.queue:
                    r = self.queue.popleft()
                    slot_req[b] = r
                    r.started_s = time.time()
                    positions[b] = 0
                    block_tables[b] = 0
                    tokens[b, 0] = r.prompt[0]
                    reset_mask[b] = True
            if all(r is None for r in slot_req):
                break
            if self.steps >= max_steps:
                raise RuntimeError("serve loop did not drain")
            # allocate the write block for any slot whose position entered
            # an unmapped logical block (covers fresh admissions at 0 too)
            for b, r in enumerate(slot_req):
                if r is not None:
                    j = positions[b] // bs
                    if block_tables[b, j] == 0:
                        blk = free.popleft()
                        block_tables[b, j] = blk
                        self.block_history.setdefault(r.uid, []).append(blk)
            if self._has_state and reset_mask.any():
                cache = self._reset_slots(cache, jnp.asarray(reset_mask))
            reset_mask[:] = False

            self.busy_slot_steps += sum(1 for r in slot_req if r is not None)
            logits, cache = self._decode_paged(
                self.params, jnp.asarray(tokens), cache,
                jnp.asarray(positions), jnp.asarray(block_tables),
            )
            self.steps += 1
            nxt = np.asarray(jnp.argmax(logits[:, 0, : self.cfg.vocab], axis=-1))
            for b, r in enumerate(slot_req):
                if r is None:
                    continue
                t = int(positions[b])
                positions[b] = t + 1
                if t + 1 < len(r.prompt):
                    tokens[b, 0] = r.prompt[t + 1]  # still consuming prompt
                    continue
                tok = int(nxt[b])
                r.generated.append(tok)
                tokens[b, 0] = tok
                if (len(r.generated) >= r.max_new_tokens or tok == r.eos_id):
                    self._finish(r)
                    # free the slot's blocks back to the pool (LIFO: the
                    # next admission reuses this request's blocks first)
                    for j in range(nb_slot):
                        if block_tables[b, j] != 0:
                            free.appendleft(int(block_tables[b, j]))
                    block_tables[b] = 0
                    positions[b] = 0
                    tokens[b, 0] = 0
                    slot_req[b] = None

    # -- public ----------------------------------------------------------------

    def run_until_drained(
        self, max_waves: int = 1000, *, max_steps: Optional[int] = None
    ) -> Dict[int, Request]:
        t0 = time.time()
        if self.scheduler == "wave":
            self._drain_waves(max_waves)
        else:
            self._drain_continuous(max_steps)
        self.wall_s += time.time() - t0
        return self.completed

    def stats(self) -> Dict[str, Any]:
        """Serving metrics in the perf-ledger schema (see
        :func:`repro.perf.ledger.metrics_from_serving`)."""
        lat = sorted(
            r.latency_s for r in self.completed.values()
            if r.latency_s is not None
        )
        new_tokens = sum(len(r.generated) for r in self.completed.values())
        return {
            "scheduler": self.scheduler,
            "requests": len(self.completed),
            "new_tokens": new_tokens,
            "fused_steps": self.steps,
            "busy_slot_steps": self.busy_slot_steps,
            "slot_steps": self.total_slot_steps,
            "slot_utilization": self.slot_utilization,
            "wall_s": self.wall_s,
            "tok_s": new_tokens / self.wall_s if self.wall_s > 0 else 0.0,
            "p50_latency_s": float(np.percentile(lat, 50)) if lat else 0.0,
            "p95_latency_s": float(np.percentile(lat, 95)) if lat else 0.0,
        }
