"""Quickstart: build a model, take a train step, and run the paper's
vectorization analysis on the compiled step — the 60-second tour.

The analysis is ONE call now: wrap the step in a ``Workload`` and
``analyze`` it; counters -> Eq. 1 metrics -> adapted roofline (Eq. 2) ->
Fig. 8 decision tree all run inside the pipeline.  Extracted events persist
in the content-addressed artifact store, so a second run of this script
performs zero analysis compiles.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.analysis import DEFAULT_CACHE, Workload, analyze
from repro.configs.base import ShapeConfig
from repro.core import hw
from repro.data import pipeline
from repro.optim import adamw
from repro.train import steps as steps_mod


def main():
    # 1. pick an architecture (all 10 assigned archs are selectable by name)
    cfg = configs.get_smoke_config("qwen3-1.7b")
    print(f"arch={cfg.name}  family={cfg.family}  params~{cfg.param_count()/1e6:.1f}M")

    # 2. one training step
    run = steps_mod.RunConfig(remat="none", zero=False)
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_opt_state(params, run.opt)
    shape = ShapeConfig("quickstart", 64, 4, "train")
    batch = {k: jnp.asarray(v) for k, v in
             pipeline.global_batch(cfg, shape, pipeline.DataConfig(), 0).items()}
    train_step = jax.jit(steps_mod.make_train_step(cfg, run))
    params, opt, metrics = train_step(params, opt, batch)
    print(f"step 0: loss={float(metrics['loss']):.4f} "
          f"grad_norm={float(metrics['grad_norm']):.3f}")

    # 3. the paper's analysis, in one call on the TPU target model
    wl = Workload(name="train_step", fn=train_step, args=(params, opt, batch),
                  dtype="bf16")
    result = analyze(wl, chip=hw.TPU_V5E)

    ev = result.events
    print(f"\ncompiled-step events (while-aware structural model):")
    print(f"  flops={ev.flops:.3e}  mxu_share={ev.vectorizable_fraction:.2%}  "
          f"hlo_traffic={ev.bytes_accessed:.3e}B")
    rl = result.roofline
    print(f"\nadapted roofline on {result.chip} (paper Eq. 2):")
    print(f"  VB={result.vb:.0f}  AI_IRR={rl.ai_irr:.1f}  "
          f"AI_IRV={rl.ai_irv:.1f} flop/B  AI={result.ai:.3g} ({result.bound})")
    print(f"\ndecision tree (paper Fig. 8): Class {int(result.perf_class)} "
          f"— {result.perf_class.describe()}")
    print(f"  {result.decision.rationale}")
    print("\n" + result.table())

    # 4. events persisted by fingerprint: a re-run of this script loads them
    # from the artifact store instead of recompiling the step
    store = DEFAULT_CACHE.store
    print(f"\n[analysis: {DEFAULT_CACHE.compiles} compiles, "
          f"{DEFAULT_CACHE.store_hits} store hits; store at {store.cache_dir}]")


if __name__ == "__main__":
    main()
