"""Flash-decode kernel call surface (served by the kernel registry).

``flash_decode`` is the registry-managed contiguous-cache op, and
``flash_prefill`` the registry-managed chunked-prefill op (its tuning
space covers the chunk-tile x KV-tile x ELEN axes).  The paged decode
variant (block-table indirection via scalar prefetch, the continuous-
batching serve path) is exported directly from the kernel module — its
block-pool calling convention doesn't fit the registry's
same-shaped-ref contract for event capture.
"""

from __future__ import annotations

from repro.kernels.flash_decode.kernel import (
    flash_decode_paged,
    flash_decode_paged_sharded,
    flash_prefill_paged,
)
from repro.kernels.registry import FLASH_DECODE as flash_decode
from repro.kernels.registry import FLASH_PREFILL as flash_prefill

__all__ = [
    "flash_decode",
    "flash_decode_paged",
    "flash_decode_paged_sharded",
    "flash_prefill",
    "flash_prefill_paged",
]
