"""Seeded traffic sampling: one cell -> one reproducible request trace.

All randomness flows from the cell's derived seed through a single
``numpy`` generator with a FIXED draw order (arrivals first, then per-uid
length -> tokens -> stop cap), so the trace is a pure function of the
spec — rerunning a matrix anywhere regenerates byte-identical traffic,
and a faulted cell's golden twin (same seed, fault excluded from the
seed derivation) serves exactly the same requests.

Arrival times are measured in **fused decode steps** — the engine's own
clock — not wall seconds: step-time varies by machine, and a
wall-clock arrival process would make the admission pattern (hence slot
scheduling, hence utilization) machine-dependent.
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.scenarios.matrix import ArrivalSpec, EosSpec, PromptSpec, Scenario


@dataclasses.dataclass
class RequestSpec:
    """One sampled request: everything the engine's submit() needs, plus
    the arrival step the feeder honors.  ``malformed`` marks requests a
    fault plan injected expressly to be rejected ('' = well-formed)."""

    uid: int
    arrive_step: int
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: int = -1
    malformed: str = ""


def _arrival_steps(spec: ArrivalSpec, n: int, rng: np.random.Generator) -> List[int]:
    if spec.kind == "poisson":
        gaps = rng.exponential(scale=1.0 / spec.rate, size=n)
        return [int(t) for t in np.floor(np.cumsum(gaps) - gaps[0])]
    if spec.kind == "bursty":
        return [(i // spec.burst) * spec.gap for i in range(n)]
    # replay: cycle the explicit offsets over the request count
    steps = sorted(spec.steps[i % len(spec.steps)] for i in range(n))
    return [int(s) for s in steps]


def _prompt_len(spec: PromptSpec, rng: np.random.Generator) -> int:
    if spec.kind == "uniform":
        return int(rng.integers(spec.lo, spec.hi + 1))
    if spec.kind == "fixed":
        return spec.n
    return spec.long if rng.random() < spec.p_long else spec.short


def _stop_cap(spec: EosSpec, max_new: int, rng: np.random.Generator) -> int:
    """Token budget under stochastic early stop: Geometric(p) capped at
    the cell budget.  p_early == 0 -> always the full budget.  The draw
    happens even at p == 0?  No — skipping it would shift later draws
    between eos=0 and eos>0 cells, but eos is part of the traffic key, so
    each eos choice is its own seeded stream and the order stays fixed
    *within* a cell."""
    if spec.p_early <= 0.0:
        return max_new
    return min(max_new, int(rng.geometric(spec.p_early)))


#: Prefix pools per shared-prefix cell and the unique-tail length bounds:
#: bimodal traffic — every request takes one of two long shared prefixes
#: and appends a short unique tail, the shape prefix caching feeds on.
_SHARED_GROUPS = 2
_TAIL_LO, _TAIL_HI = 1, 2


def _shared_prompts(cell: Scenario, vocab: int,
                    rng: np.random.Generator) -> List[np.ndarray]:
    """Per-uid prompts for a shared-prefix cell, FIXED draw order: group
    prefixes first (one per pool), then per-uid (group, tail length, tail
    tokens).  Prefix lengths come from the cell's prompt distribution,
    clamped so prefix + longest tail + budget always fits the slot cache;
    tails are unique per uid, so streams diverge right where copy-on-write
    must fork the last shared block."""
    room = cell.max_len - cell.max_new - _TAIL_HI
    groups = []
    for _ in range(_SHARED_GROUPS):
        plen = max(1, min(_prompt_len(cell.prompt, rng), room))
        groups.append(rng.integers(0, vocab, size=plen).astype(np.int32))
    prompts = []
    for _ in range(cell.requests):
        g = int(rng.integers(0, len(groups)))
        tail_len = int(rng.integers(_TAIL_LO, _TAIL_HI + 1))
        tail = rng.integers(0, vocab, size=tail_len).astype(np.int32)
        prompts.append(np.concatenate([groups[g], tail]))
    return prompts


def sample_trace(cell: Scenario, vocab: int) -> List[RequestSpec]:
    """The cell's reproducible request trace, sorted by arrival step.

    Prompt lengths are clamped so prompt + budget always fits the
    per-slot cache — well-formed by construction; the *malformed* fault
    plan injects its violations explicitly on top.  Shared-prefix cells
    (``prompt_sharing != "none"``) draw bimodal shared-prefix prompts —
    identical between "shared" and "shared-off" (the sharing MODE is
    outside the traffic key), so the COW engine and its baseline twin
    serve the same bytes.
    """
    rng = np.random.default_rng(cell.seed)
    arrivals = _arrival_steps(cell.arrival, cell.requests, rng)
    shared = (getattr(cell, "prompt_sharing", "none") != "none")
    prompts = _shared_prompts(cell, vocab, rng) if shared else None
    out: List[RequestSpec] = []
    for uid in range(cell.requests):
        if shared:
            prompt = prompts[uid]
        else:
            plen = _prompt_len(cell.prompt, rng)
            plen = max(1, min(plen, cell.max_len - cell.max_new))
            prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        out.append(RequestSpec(
            uid=uid,
            arrive_step=int(arrivals[uid]),
            prompt=prompt,
            max_new_tokens=_stop_cap(cell.eos, cell.max_new, rng),
        ))
    out.sort(key=lambda r: (r.arrive_step, r.uid))
    return out
