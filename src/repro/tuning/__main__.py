"""CLI for the roofline-guided autotuner.

    python -m repro.tuning                          # tune every tunable kernel
    python -m repro.tuning --kernels gemm jacobi2d  # a subset
    python -m repro.tuning --dtypes space           # sweep each ELEN axis
    python -m repro.tuning --cap 2 --keep 2 --jobs 4 --out tuning.json

Emits a table on stderr and a machine-readable ``tuning.json`` report
(``--out``; default stdout).  Records persist in the tuning store
(``$REPRO_ARTIFACT_DIR``/tuning), so a second invocation reports
``cached: true`` per record and performs zero timing runs.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Optional, Sequence

from repro.core import hw


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tuning",
        description="Roofline-guided kernel autotuner; emits tuning.json.",
    )
    ap.add_argument("--kernels", nargs="+", default=None,
                    help="kernel names (default: every tunable kernel)")
    ap.add_argument("--chip", default="grace-core", choices=sorted(hw.CHIPS),
                    help="chip model the roofline prunes against")
    ap.add_argument("--dtypes", nargs="+", default=None,
                    help="ELEN axis: explicit dtypes, or 'space' to sweep "
                         "each kernel space's own candidates")
    ap.add_argument("--mode", default="interpret",
                    choices=["interpret", "compiled"],
                    help="timing mode for survivors")
    ap.add_argument("--keep", type=int, default=4,
                    help="survivors timed after roofline pruning")
    ap.add_argument("--cap", type=int, default=None,
                    help="take only the first N values per axis (tiny spaces)")
    ap.add_argument("--repeats", type=int, default=2,
                    help="timing repeats per survivor (best-of)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="thread-pool width over (kernel, dtype) cells")
    ap.add_argument("--force", action="store_true",
                    help="re-tune even on a store hit")
    ap.add_argument("--store-dir", default=None,
                    help="tuning store directory (default: "
                         "$REPRO_ARTIFACT_DIR/tuning)")
    ap.add_argument("--no-store", action="store_true",
                    help="never read/write the persistent store")
    ap.add_argument("--out", default=None,
                    help="write tuning.json here (default: stdout)")
    ap.add_argument("--list", action="store_true",
                    help="list tunable kernels and exit")
    ap.add_argument("--records", action="store_true",
                    help="list persisted tuning records and exit")
    args = ap.parse_args(argv)

    if args.records:
        # enumerate through the store's listing surface (iter_json) rather
        # than globbing its files — same path the perf gate's staleness
        # check walks
        from repro.tuning.records import TUNING_VERSION, resolve_store

        store = resolve_store(args.store_dir or "default")
        n = 0
        for fp, payload in store.iter_json():
            if payload.get("tuning_version") != TUNING_VERSION:
                continue
            r = payload.get("record") or {}
            cfg = " ".join(f"{k}={v}" for k, v in sorted((r.get("config") or {}).items()))
            print(f"{fp}  {r.get('kernel')}@{r.get('chip')}/{r.get('dtype')}  "
                  f"[{cfg}]  best={r.get('best_time_s', 0):.3g}s")
            n += 1
        print(f"[{n} persisted records in {store.cache_dir}]", file=sys.stderr)
        return 0

    from repro.tuning import (
        format_records,
        report_dict,
        tunable_kernels,
        tune_kernels,
    )

    if args.list:
        for name in tunable_kernels():
            print(name)
        return 0

    known = set(tunable_kernels())
    names = args.kernels or sorted(known)
    unknown = [n for n in names if n not in known]
    if unknown:
        print(f"error: not tunable {unknown}; see --list", file=sys.stderr)
        return 2

    store = None if args.no_store else (args.store_dir or "default")
    t0 = time.perf_counter()
    records = tune_kernels(
        names,
        chip=hw.get_chip(args.chip),
        dtypes=args.dtypes,
        jobs=args.jobs,
        cap=args.cap,
        store=store,
        mode=args.mode,
        keep=args.keep,
        repeats=args.repeats,
        force=args.force,
    )
    wall = time.perf_counter() - t0

    print(format_records(records), file=sys.stderr)
    cached = sum(1 for r in records if r.cached)
    print(
        f"[{len(records)} records ({cached} cached) in {wall:.2f}s]",
        file=sys.stderr,
    )
    payload = json.dumps(report_dict(records, wall_s=wall), indent=1)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(payload)
        print(f"tuning report -> {args.out}", file=sys.stderr)
    else:
        print(payload)
    return 0


if __name__ == "__main__":
    sys.exit(main())
