"""Step functions: train_step / prefill_step / decode_step for every family.

``make_train_step(cfg, run)`` closes over the model family (dense LM, VLM
stub, encoder-decoder) and the run options (remat policy, microbatching,
MoE aux weight) and returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with donated params/opt_state.

Gradient accumulation: with ``run.microbatches > 1`` the global batch is
split on the leading axis and a ``lax.scan`` accumulates fp32 gradients —
the collective-optimization lever that trades memory-term for step latency.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer, whisper
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class RunConfig:
    remat: str = "dots"  # none | dots | full
    microbatches: int = 1
    moe_aux_weight: float = 0.01
    zero: bool = True  # ZeRO-shard optimizer state over data axes
    opt: adamw.AdamWConfig = dataclasses.field(default_factory=adamw.AdamWConfig)
    kv_cache_dtype: str = "bfloat16"  # reserved: int8 quantized decode cache (next §Perf lever)


BASELINE_RUN = RunConfig(remat="full", microbatches=1, zero=False)
# `full` remat is the default: `dots` saves every projection output
# (~1 GB/layer/device at 4k x 256) and blows the 16 GB HBM budget on most
# cells; where it fits it is a §Perf lever (see EXPERIMENTS.md).
OPTIMIZED_RUN = RunConfig(remat="full", microbatches=1, zero=True)


# --------------------------------------------------------------------------


def init_model(key, cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return whisper.init_whisper(key, cfg)
    return transformer.init_lm(key, cfg)


def model_forward(params, cfg: ModelConfig, batch: Dict[str, Any], *, remat: str):
    """Returns (logits, aux, labels) aligned per family."""
    if cfg.is_encoder_decoder:
        logits, aux = whisper.forward(
            params, cfg, batch["enc_frames"], batch["tokens"], remat=remat
        )
        return logits, aux, batch.get("labels")
    if "img_embeds" in batch:
        logits, aux = transformer.forward(
            params, cfg, batch["tokens"], img_embeds=batch["img_embeds"], remat=remat
        )
        # image prefix positions carry no labels
        n_img = batch["img_embeds"].shape[1]
        logits = logits[:, n_img:, :]
        return logits, aux, batch.get("labels")
    logits, aux = transformer.forward(params, cfg, batch["tokens"], remat=remat)
    return logits, aux, batch.get("labels")


def loss_fn(params, cfg: ModelConfig, batch, run: RunConfig):
    logits, aux, labels = model_forward(params, cfg, batch, remat=run.remat)
    loss = transformer.lm_loss(logits, labels, real_vocab=cfg.vocab)
    total = loss + run.moe_aux_weight * aux
    return total, {"loss": loss, "moe_aux": aux}


def make_train_step(cfg: ModelConfig, run: RunConfig):
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (total, metrics), grads = grad_fn(params, cfg, batch, run)
        return grads, metrics

    def accumulated(params, batch):
        n = run.microbatches

        def resplit(x):
            b = x.shape[0]
            assert b % n == 0, f"batch {b} % microbatches {n}"
            return x.reshape(n, b // n, *x.shape[1:])

        mb = jax.tree.map(resplit, batch)
        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

        def body(carry, mb_i):
            gacc, macc = carry
            grads, metrics = single(params, mb_i)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / n, gacc, grads)
            macc = jax.tree.map(lambda a, m: a + m / n, macc, metrics)
            return (gacc, macc), None

        m0 = {"loss": jnp.zeros((), jnp.float32), "moe_aux": jnp.zeros((), jnp.float32)}
        (grads, metrics), _ = jax.lax.scan(body, (g0, m0), mb)
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
        return grads, metrics

    def train_step(params, opt_state, batch):
        if run.microbatches > 1:
            grads, metrics = accumulated(params, batch)
        else:
            grads, metrics = single(params, batch)
        params, opt_state, stats = adamw.apply_update(params, grads, opt_state, run.opt)
        metrics = dict(metrics)
        metrics.update(stats)
        return params, opt_state, metrics

    return train_step


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, run: RunConfig = OPTIMIZED_RUN):
    if cfg.is_encoder_decoder:

        def prefill_step(params, *, enc_frames, tokens):
            return whisper.prefill(params, cfg, enc_frames, tokens, remat=run.remat)

        return prefill_step

    def prefill_step(params, *, tokens, img_embeds=None):
        return transformer.prefill(
            params, cfg, tokens, img_embeds=img_embeds, remat=run.remat
        )

    return prefill_step


def make_decode_step(cfg: ModelConfig, run: RunConfig = OPTIMIZED_RUN):
    if cfg.is_encoder_decoder:

        def decode_fn(params, *, tokens, cache):
            return whisper.decode_step(params, cfg, tokens, cache)

        return decode_fn

    def decode_fn(params, *, tokens, cache):
        return transformer.decode_step(params, cfg, tokens, cache)

    return decode_fn
