"""STREAM (copy/scale/add/triad) as Pallas kernels — the paper's bandwidth
probe.  Pure streaming: one VMEM tile in, one out, zero reuse; the roofline
memory term IS the runtime, so the kernel's only job is to keep tiles
hardware-aligned ((8, 128) sublane x lane multiples) and let the DMA pipeline
run.  The ELEN sweep (fp32/bf16/fp16) reproduces the paper's Sec. 4.2 STREAM
experiment: instruction count drops with element size, runtime does not.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy_kernel(a_ref, c_ref):
    c_ref[...] = a_ref[...]


def _scale_kernel(a_ref, c_ref, *, q):
    c_ref[...] = a_ref[...] * q


def _add_kernel(a_ref, b_ref, c_ref):
    c_ref[...] = a_ref[...] + b_ref[...]


def _triad_kernel(a_ref, b_ref, c_ref, *, q):
    c_ref[...] = a_ref[...] + q * b_ref[...]


def _call(kernel, arrays, *, block_rows: int, interpret: bool):
    rows, width = arrays[0].shape
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)
    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, width), lambda i: (i, 0)) for _ in arrays],
        out_specs=pl.BlockSpec((br, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, width), arrays[0].dtype),
        interpret=interpret,
    )(*arrays)


def stream_copy(a, *, block_rows: int = 256, interpret: bool = True):
    return _call(_copy_kernel, (a,), block_rows=block_rows, interpret=interpret)


def stream_scale(a, q: float, *, block_rows: int = 256, interpret: bool = True):
    # q is a compile-time scalar (embedded in the kernel), not an operand
    k = functools.partial(_scale_kernel, q=float(q))
    return _call(k, (a,), block_rows=block_rows, interpret=interpret)


def stream_add(a, b, *, block_rows: int = 256, interpret: bool = True):
    return _call(_add_kernel, (a, b), block_rows=block_rows, interpret=interpret)


def stream_triad(a, b, q: float, *, block_rows: int = 256, interpret: bool = True):
    k = functools.partial(_triad_kernel, q=float(q))
    return _call(k, (a, b), block_rows=block_rows, interpret=interpret)
