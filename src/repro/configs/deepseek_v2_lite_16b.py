"""DeepSeek-V2-Lite (16B) — MLA (kv_lora=512) + fine-grained MoE top-6.

[arXiv:2405.04434; hf]  27L, d_model=2048, 16H, expert d_ff=1408,
vocab=102400.  NOTE (DESIGN.md §4): the assignment bracket says "64e top-6"
while the prose says "160 routed"; HF's V2-Lite has 64 routed experts — we
use 64 + 2 shared.  MLA dims per the paper: kv_lora_rank=512, qk_nope=128,
qk_rope=64, v_head=128.  First layer dense (d_ff=10944).
"""

from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,
    vocab=102400,
    moe=MoEConfig(
        n_routed=64, top_k=6, d_ff_expert=1408, n_shared=2, first_dense=True
    ),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    moe=MoEConfig(n_routed=8, top_k=2, d_ff_expert=32, n_shared=1, first_dense=True),
    mla=MLAConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
    param_dtype="float32",
    compute_dtype="float32",
)
