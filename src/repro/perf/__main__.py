"""CLI for the perf trajectory ledger + regression gate.

    python -m repro.perf record --summary experiments/bench/summary.json \\
        --tuning experiments/bench/tuning.json
    python -m repro.perf record --serving experiments/bench/serve.json
    python -m repro.perf compare --baseline latest
    python -m repro.perf gate --baseline pinned:abc123 --tol-wall 2.0
    python -m repro.perf report --out experiments/bench/perf
    python -m repro.perf list

``record`` appends one BenchRun from any mix of ``summary.json`` /
``tuning.json`` / analysis-service reports / ``launch.serve`` serve
reports.  ``gate`` exits non-zero on
confirmed regressions and prints each one's decision-tree triage.
``report`` emits the markdown trajectory plus one machine-readable
``BENCH_<seq>.json`` per run.  The ledger lives in
``$REPRO_ARTIFACT_DIR/perf`` unless ``--store-dir`` overrides it.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence

from repro.perf.baseline import resolve_baseline, validate_policy
from repro.perf.compare import compare_runs
from repro.perf.gate import export_trajectory, format_markdown, gate_run
from repro.perf.ledger import Ledger, capture_env


def _ledger(args: argparse.Namespace) -> Ledger:
    return Ledger(args.store_dir)


def _resolve_run(ledger: Ledger, ref: Optional[str], series: Optional[str]):
    if ref:
        run = ledger.get(ref)
        if run is None:
            print(f"error: no unique run matching {ref!r}", file=sys.stderr)
            return None
        return run
    run = ledger.latest(series)
    if run is None:
        print("error: ledger is empty; record a run first", file=sys.stderr)
    return run


def cmd_record(args: argparse.Namespace) -> int:
    def load(path: Optional[str]):
        if path is None:
            return None
        with open(path) as f:
            return json.load(f)

    summary = load(args.summary)
    tuning = load(args.tuning)
    analyses = load(args.analysis)
    serving = load(args.serving)
    if summary is None and tuning is None and analyses is None and serving is None:
        print("error: pass at least one of "
              "--summary/--tuning/--analysis/--serving", file=sys.stderr)
        return 2
    # a summary stamped by benchmarks.run carries its own RunEnv — honor it
    # (record never re-derives environment); capture only when absent
    env = None
    if summary is None or not summary.get("env"):
        env = capture_env(chip=args.chip, dtype=args.dtype)
    ledger = _ledger(args)
    run = ledger.record_sources(
        summary=summary, tuning=tuning, analyses=analyses, serving=serving,
        env=env, meta={"note": args.note} if args.note else None,
    )
    print(f"recorded run {run.run_id} (seq {run.seq}, series "
          f"{run.env.series_key()}, {len(run.metrics)} workloads) "
          f"-> {ledger.root}")
    return 0


def cmd_compare(args: argparse.Namespace) -> int:
    ledger = _ledger(args)
    run = _resolve_run(ledger, args.run, args.series)
    if run is None:
        return 2
    baseline = resolve_baseline(
        ledger, args.baseline, series=run.env.series_key(),
        exclude=(run.run_id,),
    )
    if baseline is None:
        print(f"no baseline under policy {args.baseline!r}", file=sys.stderr)
        return 2
    cmp_ = compare_runs(baseline, run, wall_tol_scale=args.tol_wall)
    for d in cmp_.deltas:
        flag = "REG" if d.regressed else ("imp" if d.improved else "   ")
        print(f"{flag}  {d.key:44s} {d.metric:24s} "
              f"{d.before!s:>14s} -> {d.after!s:<14s} {d.rel_delta:+.1%}")
    print(f"\n[{len(cmp_.deltas)} deltas, {len(cmp_.regressions)} regressions, "
          f"{len(cmp_.improvements)} improvements vs {baseline.run_id[:12]}]")
    return 0


def cmd_gate(args: argparse.Namespace) -> int:
    ledger = _ledger(args)
    run = _resolve_run(ledger, args.run, args.series)
    if run is None:
        return 2
    result = gate_run(
        run, ledger, policy=args.baseline, wall_tol_scale=args.tol_wall,
        tuning_store=None if args.no_tuning_store else "default",
    )
    print(result.describe())
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result.to_dict(), f, indent=1)
        print(f"gate result -> {args.out}", file=sys.stderr)
    return result.exit_code


def cmd_report(args: argparse.Namespace) -> int:
    ledger = _ledger(args)
    gate = None
    if args.gate:
        run = ledger.latest(args.series)
        if run is not None:
            gate = gate_run(run, ledger, policy=args.baseline,
                            wall_tol_scale=args.tol_wall)
    md = format_markdown(ledger, series=args.series, gate=gate)
    if args.out:
        import os

        os.makedirs(args.out, exist_ok=True)
        md_path = os.path.join(args.out, "report.md")
        with open(md_path, "w") as f:
            f.write(md)
        paths = export_trajectory(ledger, args.out, series=args.series)
        print(f"report -> {md_path} (+ {len(paths)} BENCH_<seq>.json)")
    else:
        print(md)
    return 0


def cmd_list(args: argparse.Namespace) -> int:
    ledger = _ledger(args)
    runs = ledger.runs(args.series)
    if not runs:
        print("(empty ledger)")
        return 0
    for r in runs:
        print(f"{r.seq:4d}  {r.run_id}  {r.env.series_key():20s} "
              f"git={r.env.git_sha}  workloads={len(r.metrics)}")
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.perf",
        description="Perf trajectory ledger + decision-tree regression gate.",
    )
    ap.add_argument("--store-dir", default=None,
                    help="ledger directory (default: $REPRO_ARTIFACT_DIR/perf)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("record", help="append a BenchRun from artifacts")
    p.add_argument("--summary", default=None, help="benchmarks summary.json")
    p.add_argument("--tuning", default=None, help="autotuner tuning.json")
    p.add_argument("--analysis", default=None,
                   help="analysis service report JSON")
    p.add_argument("--serving", default=None,
                   help="launch.serve serve-report JSON")
    p.add_argument("--chip", default="grace-core")
    p.add_argument("--dtype", default="fp32")
    p.add_argument("--note", default=None, help="free-form run annotation")
    p.set_defaults(fn=cmd_record)

    for name, fn in (("compare", cmd_compare), ("gate", cmd_gate)):
        p = sub.add_parser(name, help=f"{name} a run against a baseline")
        p.add_argument("--run", default=None,
                       help="run id prefix (default: latest)")
        p.add_argument("--baseline", default="latest", type=validate_policy,
                       help="latest | pinned:<prefix> | median:<K>")
        p.add_argument("--series", default=None,
                       help="restrict to one chip/dtype series")
        p.add_argument("--tol-wall", type=float, default=1.0,
                       help="scale noisy (timing) tolerances")
        if name == "gate":
            p.add_argument("--out", default=None,
                           help="write the gate result JSON here")
            p.add_argument("--no-tuning-store", action="store_true",
                           help="skip the TuningRecord staleness check")
        p.set_defaults(fn=fn)

    p = sub.add_parser("report",
                       help="markdown trajectory + BENCH_<seq>.json export")
    p.add_argument("--out", default=None,
                   help="directory for report.md + BENCH_<seq>.json "
                        "(default: print markdown)")
    p.add_argument("--series", default=None)
    p.add_argument("--gate", action="store_true",
                   help="include a gate of the latest run")
    p.add_argument("--baseline", default="latest", type=validate_policy)
    p.add_argument("--tol-wall", type=float, default=1.0)
    p.set_defaults(fn=cmd_report)

    p = sub.add_parser("list", help="list recorded runs")
    p.add_argument("--series", default=None)
    p.set_defaults(fn=cmd_list)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
