"""Predicated block-ELL SpMV — the paper's SVE-predication showcase on TPU.

SVE handles ragged sparse rows with predicate registers; the TPU analogue is
per-tile masking: rows are grouped into (8, 128)-aligned tiles and each
lane's contribution is gated by ``lane < row_nnz`` (a predicate computed from
``broadcasted_iota``), so a row occupies only ceil(nnz/128) lanes-issues
instead of the fixed-width max over all rows.  The kernel also implements
the paper's synthetic repeat-K loop (Sec. 3.2) as a ``fori_loop`` with a
loop-carried accumulator (their `#pragma unroll(1)` + no-DCE trick — the
carried dependency stops XLA from folding the K FMAs).

Grid: one program per row-block.  VMEM per step: the (8, width) value/index
tiles + the dense x (gathered); x stays resident across programs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(values_ref, col_ref, nnz_ref, x_ref, y_ref, *, repeat: int):
    vals = values_ref[0]  # (rb, width)
    cols = col_ref[0]
    nnz = nnz_ref[0]  # (rb,)
    rb, width = vals.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (rb, width), 1)
    pred = lane < nnz[:, None]  # predicate register analogue
    x = x_ref[...]
    gathered = jnp.take(x, cols, axis=0)  # (rb, width) gather from VMEM
    contrib = jnp.where(pred, vals * gathered, 0.0)
    inv = jnp.asarray(1.0 / repeat, vals.dtype)

    def body(_, acc):
        # loop-carried FMA: repeat x the arithmetic intensity, same result
        return acc + contrib.sum(axis=-1) * inv

    acc0 = jnp.zeros((rb,), vals.dtype)
    y_ref[0] = jax.lax.fori_loop(0, repeat, body, acc0)


def spmv_blockell(values, col_idx, row_nnz, x, *, repeat: int = 1,
                  interpret: bool = True):
    """y = A @ x for block-ELL A.  values/col_idx: (nb, rb, width);
    row_nnz: (nb, rb); x: (n_cols,).  Returns (nb*rb,)."""
    nb, rb, width = values.shape
    n_cols = x.shape[0]
    kernel = functools.partial(_spmv_kernel, repeat=repeat)
    y = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, rb, width), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, rb, width), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, rb), lambda i: (i, 0)),
            pl.BlockSpec((n_cols,), lambda i: (0,)),  # x resident in VMEM
        ],
        out_specs=pl.BlockSpec((1, rb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, rb), values.dtype),
        interpret=interpret,
    )(values, col_idx, row_nnz, x)
    return y.reshape(nb * rb)


def spmv_fixed_width(values, col_idx, row_nnz, x, *, interpret: bool = True):
    """The fixed-width-SIMD strawman: no predication — every row is padded
    to the full tile width and all lanes issue (the paper's ASIMD 1.0x
    case).  Numerically identical (padding values are zero); the cost model
    differs (see kernels.spmv.ops.issue_counts)."""
    nb, rb, width = values.shape
    n_cols = x.shape[0]

    def kernel(values_ref, col_ref, x_ref, y_ref):
        vals = values_ref[0]
        cols = col_ref[0]
        x_ = x_ref[...]
        y_ref[0] = (vals * jnp.take(x_, cols, axis=0)).sum(axis=-1)

    y = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, rb, width), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, rb, width), lambda i: (i, 0, 0)),
            pl.BlockSpec((n_cols,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, rb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, rb), values.dtype),
        interpret=interpret,
    )(values, col_idx, x)
    return y.reshape(nb * rb)
