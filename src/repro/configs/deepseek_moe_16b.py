"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed, top-6.

[arXiv:2401.06066; hf]  28L, d_model=2048, 16H (kv=16), expert d_ff=1408,
vocab=102400.  First layer uses a dense FFN (d_ff=10944, per the paper);
remaining 27 layers are MoE.
"""

from repro.configs.base import MoEConfig, ModelConfig

FULL = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,  # dense FFN of the first layer
    vocab=102400,
    moe=MoEConfig(
        n_routed=64, top_k=6, d_ff_expert=1408, n_shared=2, first_dense=True
    ),
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    moe=MoEConfig(n_routed=8, top_k=2, d_ff_expert=32, n_shared=1, first_dense=True),
    param_dtype="float32",
    compute_dtype="float32",
)
