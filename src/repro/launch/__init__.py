"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers.

NOTE: ``repro.launch.dryrun`` must only ever run as a standalone process —
it sets XLA_FLAGS (512 host devices) at import.  Import ``mesh``/``cells``
freely.
"""
