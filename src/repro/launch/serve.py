"""Serving driver: load (or init) a model, run batched requests.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-124m --smoke \
        --requests 8 --max-new 12
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

import repro.configs as configs
from repro.serve.engine import Request, ServeEngine
from repro.train import steps as steps_mod


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-124m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    params = steps_mod.init_model(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(cfg, params, max_batch=args.max_batch,
                         max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    for uid in range(args.requests):
        plen = int(rng.integers(4, 17))
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, size=plen).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    t0 = time.time()
    done = engine.run_until_drained()
    dt = time.time() - t0
    total_new = sum(len(r.generated) for r in done.values())
    print(f"served {len(done)} requests, {total_new} tokens, "
          f"{engine.steps} fused steps in {dt:.2f}s "
          f"({total_new/max(dt,1e-9):.1f} tok/s)")
    for uid in sorted(done):
        r = done[uid]
        print(f"  req {uid}: prompt[{len(r.prompt)}] -> {r.generated}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
