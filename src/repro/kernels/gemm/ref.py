"""Pure-jnp oracle + analytic roofline terms for the GEMM kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def flops_bytes(M: int, N: int, K: int, dtype_bytes: int = 4) -> dict:
    """Analytic kernel cost: 2MNK FLOPs; cold traffic A+B+C."""
    flops = 2.0 * M * N * K
    bytes_ = (M * K + K * N + M * N) * dtype_bytes
    return {"flops": flops, "bytes": bytes_, "ai": flops / bytes_}
