"""The perf trajectory ledger + decision-tree regression gate (repro.perf).

ISSUE-4 contracts: the ledger is append-only (a second ``record`` — in this
process or another — appends, never rewrites); baselines resolve by policy
(latest / pinned / rolling-median-of-K); comparison is noise-aware per
metric spec; triage maps synthetic before/after Events deltas onto all four
Fig.-8 PerfClass outcomes with the Eq. 2 quantities (AI vs AI_IRV) that
justify them; and the end-to-end gate contract holds: record -> perturb ->
gate exits non-zero naming the class transition; an unperturbed re-run
exits zero and performs zero recompiles (store-backed).
"""

import dataclasses
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import analyze
from repro.analysis.pipeline import ArtifactCache, analyze_events
from repro.analysis.store import ArtifactStore
from repro.analysis.workload import get_workload
from repro.core import hw
from repro.core.counters import Events
from repro.core.decision_tree import PerfClass
from repro.core.roofline import adapted_roofline
from repro.perf import (
    BenchRun,
    Ledger,
    RunEnv,
    capture_env,
    compare_runs,
    gate_run,
    metrics_from_analysis,
    metrics_from_serving,
    metrics_from_summary,
    metrics_from_tuning,
    resolve_baseline,
    triage_regressions,
)
from repro.perf.gate import export_trajectory, format_markdown
from repro.perf.triage import split_key

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ENV = RunEnv(chip="grace-core", dtype="fp32", git_sha="aaaa111",
             jax_version="0", tuned_hash="", host="t")


def _run(ledger, metrics, env=ENV):
    return ledger.record(metrics, env=env)


def _summary(wall_s=2.0, rows=13, ok=True):
    return {
        "kind": "benchmarks_summary",
        "benchmarks": [
            {"name": "fig3_vectorization", "ok": ok, "rows": rows,
             "wall_s": wall_s, "error": None}
        ],
    }


# ---------------------------------------------------------------------------
# Ledger: ingestion + append-only trajectory
# ---------------------------------------------------------------------------


def test_metrics_from_all_three_sources(tmp_path):
    ledger = Ledger(str(tmp_path))
    summary = _summary()
    tuning = {"records": [{
        "kernel": "gemm", "chip": "grace-core", "dtype": "fp32",
        "config": {"bm": 256, "bk": 128}, "best_time_s": 1e-3,
        "default_time_s": 2e-3, "speedup_vs_default": 2.0,
        "predicted_speedup": 1.5,
    }]}
    analysis = analyze("kernel/gemm", hw.GRACE_CORE)
    run = ledger.record_sources(
        summary=summary, tuning=tuning, analyses=[analysis], env=ENV
    )
    assert set(run.metrics) == {
        "bench/fig3_vectorization",
        "tuning/gemm@grace-core/fp32",
        "kernel/gemm@grace-core/fp32",
    }
    assert run.metric("bench/fig3_vectorization", "rows") == 13
    # sorted-key config token: ledger ingestion never re-derives it
    assert run.metric("tuning/gemm@grace-core/fp32", "config") == "bk=128 bm=256"
    m = run.metrics["kernel/gemm@grace-core/fp32"]
    assert m["perf_class"] == int(analysis.perf_class)
    assert m["ai"] == pytest.approx(analysis.ai)
    # everything the triage needs to re-run Fig. 8 is self-contained
    for name in ("flops", "hbm_bytes", "gather_bytes", "r_ins",
                 "vectorizable_fraction"):
        assert name in m


def _serve_report(scheduler="continuous", slot_utilization=0.9,
                  fused_steps=48, tok_s=50.0, p95=0.8):
    return {
        "kind": "serve_report",
        "arch": "gpt2-124m",
        "scheduler": scheduler,
        "stats": {
            "scheduler": scheduler,
            "requests": 6, "new_tokens": 31, "fused_steps": fused_steps,
            "busy_slot_steps": 86, "slot_steps": fused_steps * 2,
            "slot_utilization": slot_utilization, "wall_s": 0.62,
            "tok_s": tok_s, "p50_latency_s": 0.3, "p95_latency_s": p95,
        },
    }


def test_metrics_from_serving_keyed_by_arch_and_scheduler(tmp_path):
    ledger = Ledger(str(tmp_path))
    run = ledger.record_sources(serving=_serve_report(), env=ENV)
    assert set(run.metrics) == {"serve/gpt2-124m@continuous"}
    assert run.meta["sources"] == ["serving"]
    m = run.metrics["serve/gpt2-124m@continuous"]
    # everything launch.serve's stats() emits lands in the ledger row
    for name in ("tok_s", "p50_latency_s", "p95_latency_s",
                 "slot_utilization", "fused_steps", "requests", "new_tokens"):
        assert name in m, name
    assert m["slot_utilization"] == pytest.approx(0.9)
    # wave and continuous runs are distinct trajectory keys
    assert set(metrics_from_serving(_serve_report("wave"))) == {
        "serve/gpt2-124m@wave"
    }


def test_serving_regressions_gate(tmp_path):
    """Slot utilization dropping or fused steps growing regresses the
    serve path; wall-noisy tok/s movement inside tolerance does not."""
    ledger = Ledger(str(tmp_path))
    base = ledger.record_sources(serving=_serve_report(), env=ENV)
    worse = ledger.record_sources(
        serving=_serve_report(slot_utilization=0.7, fused_steps=60,
                              tok_s=47.0), env=ENV,
    )
    cmp_ = compare_runs(base, worse)
    regressed = {(r.key, r.metric) for r in cmp_.regressions}
    assert ("serve/gpt2-124m@continuous", "slot_utilization") in regressed
    assert ("serve/gpt2-124m@continuous", "fused_steps") in regressed
    assert ("serve/gpt2-124m@continuous", "tok_s") not in regressed  # -6%, noisy
    result = gate_run(worse, ledger, policy="latest")
    assert not result.ok and result.exit_code == 1
    # a same-metrics re-record passes
    again = ledger.record_sources(serving=_serve_report(), env=ENV)
    assert gate_run(again, ledger, policy="pinned:" + base.run_id[:10]).ok


def test_summary_env_stamp_is_honored(tmp_path):
    summary = {**_summary(), "env": dataclasses.asdict(
        dataclasses.replace(ENV, git_sha="stamped99"))}
    run = Ledger(str(tmp_path)).record_sources(summary=summary)
    assert run.env.git_sha == "stamped99"  # never re-derived


def test_ledger_appends_never_rewrites(tmp_path):
    ledger = Ledger(str(tmp_path))
    r1 = _run(ledger, metrics_from_summary(_summary()))
    r2 = _run(ledger, metrics_from_summary(_summary()))  # identical payload
    assert r1.run_id != r2.run_id  # timestamp+seq are part of the address
    assert [r.seq for r in ledger.runs()] == [1, 2]
    # the first entry's bytes are untouched by the second record
    p1 = ledger.store.path_for(r1.run_id)
    with open(p1) as f:
        assert json.load(f)["run"]["run_id"] == r1.run_id


def test_ledger_series_filter_and_lookup(tmp_path):
    ledger = Ledger(str(tmp_path))
    r1 = _run(ledger, metrics_from_summary(_summary()))
    r2 = _run(ledger, metrics_from_summary(_summary()),
              env=dataclasses.replace(ENV, dtype="bf16"))
    assert ledger.series() == ["grace-core/bf16", "grace-core/fp32"]
    assert [r.run_id for r in ledger.runs("grace-core/fp32")] == [r1.run_id]
    assert ledger.get(r1.run_id[:10]).run_id == r1.run_id  # prefix lookup
    assert ledger.latest("grace-core/bf16").run_id == r2.run_id


def test_ledger_refuses_empty_and_skips_corrupt(tmp_path):
    ledger = Ledger(str(tmp_path))
    with pytest.raises(ValueError):
        ledger.record({})
    r1 = _run(ledger, metrics_from_summary(_summary()))
    (tmp_path / "zz.json").write_text("{not json")
    assert [r.run_id for r in ledger.runs()] == [r1.run_id]  # skip, not raise
    assert (tmp_path / "zz.json").exists()  # enumeration never deletes


# ---------------------------------------------------------------------------
# Baseline policies
# ---------------------------------------------------------------------------


def test_baseline_latest_excludes_run_under_test(tmp_path):
    ledger = Ledger(str(tmp_path))
    r1 = _run(ledger, metrics_from_summary(_summary(wall_s=1.0)))
    r2 = _run(ledger, metrics_from_summary(_summary(wall_s=2.0)))
    assert resolve_baseline(ledger, "latest").run_id == r2.run_id
    assert resolve_baseline(
        ledger, "latest", exclude=(r2.run_id,)
    ).run_id == r1.run_id


def test_baseline_pinned_by_run_id_and_git_sha(tmp_path):
    ledger = Ledger(str(tmp_path))
    r1 = _run(ledger, metrics_from_summary(_summary()),
              env=dataclasses.replace(ENV, git_sha="feedbeef1234"))
    _run(ledger, metrics_from_summary(_summary()))
    assert resolve_baseline(ledger, f"pinned:{r1.run_id[:8]}").run_id == r1.run_id
    assert resolve_baseline(ledger, "pinned:feedbeef").run_id == r1.run_id
    assert resolve_baseline(ledger, "pinned:nope") is None


def test_baseline_median_absorbs_an_outlier(tmp_path):
    ledger = Ledger(str(tmp_path))
    for wall in (1.0, 1.1, 30.0):  # one noisy spike
        _run(ledger, metrics_from_summary(_summary(wall_s=wall)))
    base = resolve_baseline(ledger, "median:3")
    assert base.metric("bench/fig3_vectorization", "wall_s") == 1.1
    assert base.metric("bench/fig3_vectorization", "rows") == 13
    assert base.meta["synthetic"] == "median:3"


def test_baseline_unknown_policy_raises(tmp_path):
    ledger = Ledger(str(tmp_path))
    _run(ledger, metrics_from_summary(_summary()))
    with pytest.raises(ValueError):
        resolve_baseline(ledger, "newest")
    with pytest.raises(ValueError):
        resolve_baseline(ledger, "median:x")


# ---------------------------------------------------------------------------
# Noise-aware comparison
# ---------------------------------------------------------------------------


def test_wall_noise_within_tolerance_is_not_a_regression(tmp_path):
    ledger = Ledger(str(tmp_path))
    base = _run(ledger, metrics_from_summary(_summary(wall_s=1.00)))
    ok = _run(ledger, metrics_from_summary(_summary(wall_s=1.05)))  # +5% < 10%
    bad = _run(ledger, metrics_from_summary(_summary(wall_s=1.50)))  # +50%
    assert compare_runs(base, ok).ok
    cmp_bad = compare_runs(base, bad)
    assert [r.metric for r in cmp_bad.regressions] == ["wall_s"]
    # the CI knob: scaling noisy tolerances absorbs shared-runner noise
    assert compare_runs(base, bad, wall_tol_scale=6.0).ok


def test_pass_fail_and_rows_are_deterministic_gates(tmp_path):
    ledger = Ledger(str(tmp_path))
    base = _run(ledger, metrics_from_summary(_summary()))
    broke = _run(ledger, metrics_from_summary(_summary(ok=False, rows=0)))
    got = {r.metric for r in compare_runs(base, broke).regressions}
    assert got == {"ok", "rows"}


def test_zero_baseline_movement_is_informational_not_astronomical(tmp_path):
    """A 0.000-rounded baseline wall time must not turn epsilon-nonzero
    into a +1e29% regression; the delta is reported, never gated, and its
    JSON form stays strict (no Infinity)."""
    ledger = Ledger(str(tmp_path))
    base = _run(ledger, {"bench/x": {"wall_s": 0.0}})
    run = _run(ledger, {"bench/x": {"wall_s": 0.001}})
    cmp_ = compare_runs(base, run)
    assert cmp_.ok
    (d,) = cmp_.deltas
    assert d.rel_delta == float("inf") and not d.regressed
    assert json.loads(json.dumps(cmp_.to_dict()))["deltas"][0]["rel_delta"] is None


def test_record_sources_propagates_summary_failure_count(tmp_path):
    """`repro.perf record --summary` of an aborted run must mark the run
    unhealthy, or baseline resolution would anchor on its truncated walls."""
    ledger = Ledger(str(tmp_path))
    aborted = {**_summary(wall_s=0.1, ok=False), "failed": 1}
    bad = ledger.record_sources(summary=aborted, env=ENV)
    assert bad.meta["failed"] == 1
    assert resolve_baseline(ledger, "latest", exclude=()) is None  # filtered


def test_disjoint_keys_report_but_never_gate(tmp_path):
    ledger = Ledger(str(tmp_path))
    base = _run(ledger, {"bench/a": {"wall_s": 1.0}})
    run = _run(ledger, {"bench/b": {"wall_s": 9.0}})
    cmp_ = compare_runs(base, run)
    assert cmp_.ok
    assert cmp_.new_keys == ["bench/b"] and cmp_.missing_keys == ["bench/a"]


# ---------------------------------------------------------------------------
# Golden triage: synthetic Events deltas -> all four PerfClass outcomes
# ---------------------------------------------------------------------------


def _point(name, flops, bytes_, gather=0.0, nonvec=0.0):
    """One trajectory point derived from synthetic artifact Events."""
    ev = Events()
    ev.flops = flops
    ev.bytes_accessed = bytes_
    ev.hbm_read_bytes = bytes_ / 2
    ev.gather_bytes = gather
    ev.nonvec_flops = nonvec
    return analyze_events(name, ev, hw.GRACE_CORE, dtype="fp32")


# before: a healthy compute-bound kernel (Class 4, AI = 1000)
_BEFORE = ("k", 1e9, 1e6)
# after-deltas chosen to land on each Fig. 8 leaf
_GOLDEN = [
    # vectorizable share collapses (threading-runtime/serial growth): Class 1
    (("k", 1e9, 1e6, 0.0, 0.95e9), PerfClass.NOT_VECTORIZED),
    # streaming traffic blows up, AI falls left of the knee: Class 2
    (("k", 1e9, 4e9), PerfClass.MEMORY_BANDWIDTH_BOUND),
    # same blow-up but pointer-chasing (gather share > ELEN/line): Class 3
    (("k", 1e9, 4e9, 1.5e9), PerfClass.MEMORY_LATENCY_BOUND),
    # stays compute-bound but does 2x the FLOPs (redundant work): Class 4
    (("k", 2e9, 1e6), PerfClass.SPEEDUP),
]


@pytest.mark.parametrize("after_args,expect_class", _GOLDEN)
def test_triage_maps_events_deltas_onto_each_perf_class(
    tmp_path, after_args, expect_class
):
    ledger = Ledger(str(tmp_path))
    before = _point(*_BEFORE)
    assert before.perf_class == PerfClass.SPEEDUP  # the healthy baseline
    after = _point(*after_args)
    assert after.perf_class == expect_class  # the synthetic delta lands
    base = _run(ledger, metrics_from_analysis([before]))
    run = _run(ledger, metrics_from_analysis([after]))
    cmp_ = compare_runs(base, run)
    assert not cmp_.ok
    triages = triage_regressions(cmp_, base, run, tuning_store=None)
    assert len(triages) == 1
    t = triages[0]
    # triage re-derives the same classes the pipeline computed
    assert t.class_before == PerfClass.SPEEDUP
    assert t.class_after == expect_class
    # ... and justifies them with the Eq. 2 quantities
    rl = adapted_roofline(hw.GRACE_CORE, "fp32")
    assert t.ai_irv == pytest.approx(rl.ai_irv)
    assert t.ai_irr == pytest.approx(rl.ai_irr)
    assert "AI" in t.narrative and "AI_IRV" in t.narrative
    if expect_class != PerfClass.SPEEDUP:
        assert f"Class {int(expect_class)}" in t.narrative
        assert "slipped from Class 4" in t.narrative


def test_triage_flags_stale_tuning_record(tmp_path):
    """A run recorded under one config while the tuning store's best is
    another must name the stale TuningRecord as a suspect."""
    from repro.tuning import TuningRecord, save_record

    store = ArtifactStore(str(tmp_path / "tuning"))
    save_record(store, TuningRecord(
        kernel="gemm", chip="grace-core", dtype="fp32", fingerprint="ff" * 16,
        config={"bm": 256, "bn": 256, "bk": 256},
        default_config={"bm": 128, "bn": 128, "bk": 128},
        best_time_s=1e-3, default_time_s=2e-3,
    ))
    ledger = Ledger(str(tmp_path / "perf"))
    before = metrics_from_analysis([_point("kernel/gemm", 1e9, 1e6)])
    after = metrics_from_analysis([_point("kernel/gemm", 1e9, 4e9)])
    after["kernel/gemm@grace-core/fp32"]["config"] = "bk=128 bm=128 bn=128"
    base, run = _run(ledger, before), _run(ledger, after)
    cmp_ = compare_runs(base, run)
    (t,) = triage_regressions(cmp_, base, run, tuning_store=store)
    assert any("stale TuningRecord" in s for s in t.suspects)

    # multiple persisted records per (kernel, chip, dtype) are normal
    # (capped CI spaces, other problem shapes): a run whose config matches
    # ANY of them is NOT stale — no false re-tune chase
    save_record(store, TuningRecord(
        kernel="gemm", chip="grace-core", dtype="fp32", fingerprint="ee" * 16,
        config={"bm": 128, "bn": 128, "bk": 128},
        default_config={"bm": 128, "bn": 128, "bk": 128},
        best_time_s=1e-3, default_time_s=1e-3,
    ))
    (t2,) = triage_regressions(cmp_, base, run, tuning_store=store)
    assert not any("stale TuningRecord" in s for s in t2.suspects)


def test_triage_wall_only_regression_suspects_noise(tmp_path):
    ledger = Ledger(str(tmp_path))
    base = _run(ledger, {"bench/x": {"wall_s": 1.0, "rows": 5}})
    run = _run(ledger, {"bench/x": {"wall_s": 2.0, "rows": 5}})
    cmp_ = compare_runs(base, run)
    (t,) = triage_regressions(cmp_, base, run, tuning_store=None)
    assert t.class_before is None  # no counters to re-classify
    assert any("noise" in s for s in t.suspects)


def test_split_key():
    assert split_key("kernel/gemm@grace-core/fp32") == (
        "kernel/gemm", "grace-core", "fp32")
    assert split_key("bench/fig3") == ("bench/fig3", None, None)


# ---------------------------------------------------------------------------
# The gate
# ---------------------------------------------------------------------------


def test_gate_first_run_passes_trivially(tmp_path):
    ledger = Ledger(str(tmp_path))
    r1 = _run(ledger, metrics_from_summary(_summary()))
    g = gate_run(r1, ledger)
    assert g.ok and g.exit_code == 0 and g.baseline_id is None
    assert "no baseline" in g.note


def test_gate_latest_walks_back_to_a_comparable_run(tmp_path):
    """A heterogeneous ledger (benchmark runs + service reports) must not
    turn the gate vacuous: 'latest' falls back past a disjoint record to
    the newest run that shares metrics with the run under test."""
    ledger = Ledger(str(tmp_path))
    _run(ledger, metrics_from_summary(_summary(wall_s=1.0)))  # comparable
    _run(ledger, metrics_from_analysis([_point("k", 1e9, 1e6)]))  # disjoint
    slow = _run(ledger, metrics_from_summary(_summary(wall_s=9.0)))
    g = gate_run(slow, ledger, tuning_store=None)
    assert not g.ok  # the +800% wall regression was NOT masked
    assert "fell back" in g.note


def test_gate_with_fully_disjoint_baseline_is_loudly_vacuous(tmp_path):
    ledger = Ledger(str(tmp_path))
    _run(ledger, metrics_from_analysis([_point("k", 1e9, 1e6)]))
    other = _run(ledger, {"bench/other": {"wall_s": 1.0}})
    g = gate_run(other, ledger, tuning_store=None)
    assert g.ok and "VACUOUS" in g.note and "VACUOUS" in g.describe()


def test_failed_runs_never_become_latest_or_median_baselines(tmp_path):
    """An aborted benchmark run (meta['failed']) records a truncated wall
    time; anchoring on it would fail the next healthy run spuriously."""
    ledger = Ledger(str(tmp_path))
    good = _run(ledger, metrics_from_summary(_summary(wall_s=5.0)))
    ledger.record(metrics_from_summary(_summary(wall_s=0.1, ok=False)),
                  env=ENV, meta={"failed": 1})
    healthy = _run(ledger, metrics_from_summary(_summary(wall_s=5.2)))
    assert resolve_baseline(
        ledger, "latest", exclude=(healthy.run_id,)
    ).run_id == good.run_id
    base = resolve_baseline(ledger, "median:3", exclude=(healthy.run_id,))
    assert base.metric("bench/fig3_vectorization", "wall_s") == 5.0
    assert gate_run(healthy, ledger, tuning_store=None).ok
    # pinned: stays the operator's explicit (unfiltered) choice
    runs = ledger.runs()
    assert resolve_baseline(
        ledger, f"pinned:{runs[1].run_id[:12]}"
    ).run_id == runs[1].run_id


def test_trajectory_export_disambiguates_seq_collisions(tmp_path):
    """Two concurrent recorders landing on one seq both keep an export."""
    ledger = Ledger(str(tmp_path / "perf"))
    r1 = _run(ledger, metrics_from_summary(_summary()))
    clash = dataclasses.replace(r1, run_id="ff" * 16, timestamp=r1.timestamp + 1)
    ledger.store.put_json(clash.run_id, {
        "kind": "perf_run", "perf_version": 1, "run": clash.to_dict(),
    })
    paths = export_trajectory(ledger, str(tmp_path / "export"))
    names = [os.path.basename(p) for p in paths]
    assert names == ["BENCH_1.json", "BENCH_1_ffffffff.json"]


def test_gate_is_series_scoped(tmp_path):
    """A bf16 run never gates against an fp32 baseline (the trajectory is
    keyed by (chip, dtype) — Stephens et al.'s moving-target axis)."""
    ledger = Ledger(str(tmp_path))
    _run(ledger, metrics_from_summary(_summary(wall_s=1.0)))
    slow16 = _run(ledger, metrics_from_summary(_summary(wall_s=9.0)),
                  env=dataclasses.replace(ENV, dtype="bf16"))
    assert gate_run(slow16, ledger).ok  # first bf16 point: nothing to regress


def test_gate_unresolved_pin_fails_instead_of_going_green(tmp_path):
    """A typo'd/garbage-collected pin must be an error, not a trivial pass
    — otherwise the gate silently checks nothing forever."""
    ledger = Ledger(str(tmp_path))
    r1 = _run(ledger, metrics_from_summary(_summary()))
    g = gate_run(r1, ledger, policy="pinned:deadbee")
    assert not g.ok and g.exit_code == 1
    assert "did not resolve" in g.note and "FAIL" in g.describe()


def test_malformed_policy_fails_fast(tmp_path):
    from repro.perf.baseline import validate_policy

    for bad in ("median:x", "median:0", "pinned:", "newest"):
        with pytest.raises(ValueError):
            validate_policy(bad)
    # the perf CLI rejects it at argparse time (exit 2), ledger untouched
    from repro.perf.__main__ import main

    with pytest.raises(SystemExit) as exc:
        main(["--store-dir", str(tmp_path), "gate", "--baseline", "median:x"])
    assert exc.value.code == 2
    # benchmarks.run validates BEFORE running any benchmark
    from benchmarks.run import main as bench_main

    assert bench_main(["--gate", "--baseline", "newest"]) == 2


def test_vanished_metric_is_reported_in_comparison_and_note(tmp_path):
    ledger = Ledger(str(tmp_path))
    base = _run(ledger, {"bench/x": {"wall_s": 1.0, "rows": 5}})
    run = _run(ledger, {"bench/x": {"rows": 5, "extra": 1.0}})
    cmp_ = compare_runs(base, run)
    assert cmp_.missing_metrics == ["bench/x.wall_s"]
    assert cmp_.new_metrics == ["bench/x.extra"]
    g = gate_run(run, ledger, tuning_store=None)
    assert g.ok and "metrics vanished" in g.note  # loud, but not a verdict flip


def test_gate_result_round_trips_to_json(tmp_path):
    ledger = Ledger(str(tmp_path))
    base = _run(ledger, metrics_from_analysis([_point("k", 1e9, 1e6)]))
    run = _run(ledger, metrics_from_analysis([_point("k", 1e9, 4e9)]))
    g = gate_run(run, ledger, tuning_store=None)
    payload = json.loads(json.dumps(g.to_dict()))
    assert payload["ok"] is False and payload["exit_code"] == 1
    assert payload["baseline_id"] == base.run_id
    assert payload["triage"][0]["class_transition"].startswith("Class 4")


def test_markdown_and_trajectory_export(tmp_path):
    ledger = Ledger(str(tmp_path / "perf"))
    _run(ledger, metrics_from_summary(_summary(wall_s=1.0)))
    r2 = _run(ledger, metrics_from_summary(_summary(wall_s=5.0)))
    g = gate_run(r2, ledger, tuning_store=None)
    md = format_markdown(ledger, gate=g)
    assert "# Performance trajectory" in md and "FAIL" in md
    assert r2.run_id[:12] in md
    out = str(tmp_path / "export")
    paths = export_trajectory(ledger, out)
    assert [os.path.basename(p) for p in paths] == ["BENCH_1.json", "BENCH_2.json"]
    point = json.load(open(paths[1]))
    assert point["kind"] == "perf_trajectory_point"
    assert BenchRun.from_dict(point["run"]).run_id == r2.run_id


# ---------------------------------------------------------------------------
# End-to-end gate contract (the ISSUE-4 acceptance)
# ---------------------------------------------------------------------------


def test_end_to_end_gate_contract(tmp_path):
    """record -> perturb a kernel's traffic -> gate exits non-zero naming
    the PerfClass transition with AI vs AI_IRV; the unperturbed re-run
    exits zero AND performs zero recompiles (store-backed)."""
    events_store = str(tmp_path / "events")
    ledger = Ledger(str(tmp_path / "perf"))
    wl = get_workload("kernel/gemm")

    # -- run 1: the healthy baseline, compiled through a persistent store
    cache1 = ArtifactCache(store=events_store)
    a1 = analyze(wl, hw.GRACE_CORE, source="compiled", cache=cache1)
    assert cache1.compiles == 1
    run1 = ledger.record(metrics_from_analysis([a1]), env=ENV)
    assert gate_run(run1, ledger, tuning_store=None).ok  # nothing to regress

    # -- run 2: a perturbed config re-streams operands (the stale-tile
    # failure mode): same workload name/chip/dtype, 64x the HBM traffic
    bad = dataclasses.replace(
        wl, flops=a1.events.flops, hbm_bytes=a1.events.bytes_accessed * 64,
    )
    a2 = analyze(bad, hw.GRACE_CORE, source="analytic")
    run2 = ledger.record(metrics_from_analysis([a2]), env=ENV)
    g2 = gate_run(run2, ledger, tuning_store=None)
    assert not g2.ok and g2.exit_code != 0
    (t,) = [t for t in g2.triages if t.key.startswith("kernel/gemm")]
    rl = adapted_roofline(hw.GRACE_CORE, "fp32")
    assert t.class_before == PerfClass.SPEEDUP
    assert t.class_after in (PerfClass.MEMORY_BANDWIDTH_BOUND,
                             PerfClass.MEMORY_LATENCY_BOUND)
    # the Eq. 2 justification: AI crossed the scalar knee (the Fig. 8
    # stage-2 threshold), and both inflection points are reported
    assert t.ai_after < rl.ai_irr <= t.ai_before
    assert t.ai_irv == pytest.approx(rl.ai_irv)
    assert "AI_IRV" in t.narrative and "Class" in t.narrative

    # -- run 3: unperturbed re-run in a fresh cache (= a fresh process):
    # store hit, ZERO compiles, and the gate against the healthy baseline
    # exits zero
    cache2 = ArtifactCache(store=events_store)
    a3 = analyze(wl, hw.GRACE_CORE, source="compiled", cache=cache2)
    assert cache2.compiles == 0 and cache2.store_hits == 1
    run3 = ledger.record(metrics_from_analysis([a3]), env=ENV)
    g3 = gate_run(run3, ledger, policy=f"pinned:{run1.run_id[:12]}",
                  tuning_store=None)
    assert g3.ok and g3.exit_code == 0


# ---------------------------------------------------------------------------
# Cross-process: a second `record` run appends, never rewrites
# ---------------------------------------------------------------------------


def test_second_record_process_appends_never_rewrites(tmp_path):
    summary_path = tmp_path / "summary.json"
    summary_path.write_text(json.dumps({**_summary(), "env": ENV.to_dict()}))
    env = {**os.environ, "PYTHONPATH": "src",
           "REPRO_ARTIFACT_DIR": str(tmp_path / "artifacts")}
    for _ in range(2):
        subprocess.run(
            [sys.executable, "-m", "repro.perf", "record",
             "--summary", str(summary_path)],
            capture_output=True, text=True, env=env, cwd=REPO_ROOT,
            check=True, timeout=120,
        )
        if _ == 0:
            ledger = Ledger(str(tmp_path / "artifacts" / "perf"))
            (first,) = ledger.runs()
            first_path = ledger.store.path_for(first.run_id)
            first_bytes = open(first_path, "rb").read()
    runs = Ledger(str(tmp_path / "artifacts" / "perf")).runs()
    assert [r.seq for r in runs] == [1, 2]
    assert runs[0].run_id != runs[1].run_id
    # byte-identical first entry: append-only held across processes
    assert open(first_path, "rb").read() == first_bytes


def test_gate_cli_exit_codes(tmp_path):
    """`python -m repro.perf gate` exits 0 on pass, 1 on regression."""
    from repro.perf.__main__ import main

    root = str(tmp_path / "perf")
    ledger = Ledger(root)
    _run(ledger, metrics_from_analysis([_point("k", 1e9, 1e6)]))
    assert main(["--store-dir", root, "gate", "--no-tuning-store"]) == 0
    _run(ledger, metrics_from_analysis([_point("k", 1e9, 4e9)]))
    out = str(tmp_path / "gate.json")
    assert main(["--store-dir", root, "gate", "--no-tuning-store",
                 "--out", out]) == 1
    payload = json.load(open(out))
    assert payload["ok"] is False
    assert payload["triage"][0]["class_transition"] is not None


# ---------------------------------------------------------------------------
# chunked-prefill serving trajectories (ISSUE-7)
# ---------------------------------------------------------------------------


def _chunked_serve_report(chunk=8, ttft_p95_steps=30.0, ttft_p50_steps=12.0,
                          fused_steps=40):
    rep = _serve_report(fused_steps=fused_steps)
    rep["prefill_chunk"] = chunk
    rep["prefill_budget"] = chunk
    rep["stats"].update({
        "prefill_chunk": chunk, "prefill_budget": chunk,
        "ttft_p50_s": 0.1, "ttft_p95_s": 0.2,
        "ttft_p50_steps": ttft_p50_steps,
        "ttft_p95_steps": ttft_p95_steps,
    })
    return rep


def test_metrics_from_serving_chunked_variant_key(tmp_path):
    """prefill_chunk > 1 forks the trajectory key: the chunked and
    token-by-token runs must never share a baseline."""
    m = metrics_from_serving(_chunked_serve_report(chunk=8))
    (key, row), = m.items()
    assert key == "serve/gpt2-124m@continuous+prefill8"
    assert row["prefill_chunk"] == 8 and isinstance(row["prefill_chunk"], int)
    assert row["ttft_p95_steps"] == 30.0
    # chunk 1 (or absent) keeps the legacy key byte-for-byte
    plain = _serve_report()
    assert set(metrics_from_serving(plain)) == {"serve/gpt2-124m@continuous"}
    # both variants can land in one run as disjoint trajectories
    run = Ledger(str(tmp_path)).record(
        {**metrics_from_serving(plain),
         **metrics_from_serving(_chunked_serve_report())}, env=ENV)
    assert len(run.metrics) == 2


def test_ttft_steps_regression_gates_exactly(tmp_path):
    """The step-clock TTFT is deterministic given the trace, so ANY growth
    on the chunked trajectory regresses — while the noisy wall TTFT needs
    its 20% headroom."""
    ledger = Ledger(str(tmp_path))
    base = ledger.record(
        metrics_from_serving(_chunked_serve_report(ttft_p95_steps=30.0)),
        env=ENV)
    worse = ledger.record(
        metrics_from_serving(_chunked_serve_report(ttft_p95_steps=31.0)),
        env=ENV)
    cmp_ = compare_runs(base, worse)
    key = "serve/gpt2-124m@continuous+prefill8"
    assert (key, "ttft_p95_steps") in {
        (r.key, r.metric) for r in cmp_.regressions}
    assert not gate_run(worse, ledger, policy="latest").ok
    # improvement direction never trips
    better = ledger.record(
        metrics_from_serving(_chunked_serve_report(ttft_p95_steps=29.0,
                                                   fused_steps=39)),
        env=ENV)
    assert gate_run(better, ledger,
                    policy="pinned:" + base.run_id[:10]).ok


def test_ttft_regression_triages_to_scheduling_not_noise(tmp_path):
    """Triage on a chunked-serve TTFT regression names the scheduler
    counters as the suspect — never 'machine noise', which is the verdict
    reserved for wall-only movement."""
    ledger = Ledger(str(tmp_path))
    base = _run(ledger, metrics_from_serving(_chunked_serve_report()))
    worse = _run(ledger, metrics_from_serving(
        _chunked_serve_report(ttft_p95_steps=65.0, ttft_p50_steps=30.0,
                              fused_steps=48)))
    cmp_ = compare_runs(base, worse)
    assert not cmp_.ok
    (t,) = triage_regressions(cmp_, base, worse, tuning_store=None)
    assert t.key == "serve/gpt2-124m@continuous+prefill8"
    assert {"ttft_p95_steps", "ttft_p50_steps", "fused_steps"} <= set(
        t.metrics)
    assert any("admission/chunking/budget" in s for s in t.suspects)
    assert not any("wall-time regression" in s for s in t.suspects)
    assert "ttft_p95_steps" in t.narrative


def test_prefill_chunk_drop_regresses(tmp_path):
    """A run that silently serves with a narrower chunk than its baseline
    (same trajectory key, e.g. a config override bug) regresses on the
    exact prefill_chunk counter."""
    ledger = Ledger(str(tmp_path))
    base = _run(ledger, {"serve/gpt2-124m@continuous+prefill8": {
        "prefill_chunk": 8, "fused_steps": 40}})
    worse = _run(ledger, {"serve/gpt2-124m@continuous+prefill8": {
        "prefill_chunk": 4, "fused_steps": 40}})
    cmp_ = compare_runs(base, worse)
    assert [(r.key, r.metric) for r in cmp_.regressions] == [
        ("serve/gpt2-124m@continuous+prefill8", "prefill_chunk")]
