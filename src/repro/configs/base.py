"""Model/run configuration dataclasses shared by models, configs, launch.

Every assigned architecture is expressed as a ``ModelConfig``.  Layer
heterogeneity (Jamba's 1:7 attn:mamba interleave, DeepSeek's first-dense-then-
MoE) is expressed via a *superblock*: the smallest repeating group of layers.
The transformer core scans over superblocks so HLO size is O(1) in depth.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple


class LayerKind(str, enum.Enum):
    ATTN = "attn"  # self-attention + FFN (dense or MoE per moe_every)
    MAMBA = "mamba"  # Mamba-2 (SSD) mixer + FFN


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    # apply MoE FFN on layers where (layer_index % moe_every == moe_offset)
    moe_every: int = 1
    moe_offset: int = 0
    first_dense: bool = False  # first layer uses dense FFN (DeepSeek)
    router_dtype: str = "float32"

    @property
    def n_active_experts(self) -> int:
        return self.top_k + self.n_shared


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # norms
    nonparam_ln: bool = False  # OLMo: non-parametric LayerNorm
    rms_norm: bool = True
    norm_eps: float = 1e-5
    # act / ffn
    tie_embeddings: bool = False
    # heterogeneity: one superblock = this many layers, scanned n_layers/len
    layer_pattern: Tuple[LayerKind, ...] = (LayerKind.ATTN,)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (Whisper)
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    # VLM stub frontend
    n_img_tokens: int = 0
    # positional
    max_position: int = 1 << 20
    # dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # embedding tables are padded so the vocab dim shards over `model`
    # (MaxText-style); loss masks the padded logits.
    vocab_pad_multiple: int = 256
    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    # sub-quadratic? (for long_500k eligibility)
    @property
    def sub_quadratic(self) -> bool:
        return any(k == LayerKind.MAMBA for k in self.layer_pattern)

    @property
    def superblock(self) -> Tuple[LayerKind, ...]:
        return self.layer_pattern

    @property
    def n_superblocks(self) -> int:
        main = self.n_layers - self.enc_layers
        if self.moe is not None and self.moe.first_dense:
            main -= 1
        assert main % len(self.layer_pattern) == 0, (
            f"{self.name}: {main} layers not divisible by superblock "
            f"{len(self.layer_pattern)}"
        )
        return main // len(self.layer_pattern)

    def param_count(self) -> float:
        """Total parameters (embedding included), analytic."""
        d, v = self.d_model, self.vocab
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        attn = self._attn_params()
        ffn_dense = 3 * d * self.d_ff  # SwiGLU
        mamba = self._mamba_params()
        n_attn = sum(1 for k in self._full_pattern() if k == LayerKind.ATTN)
        n_mamba = sum(1 for k in self._full_pattern() if k == LayerKind.MAMBA)
        total += n_attn * attn + n_mamba * mamba
        # FFN per layer: MoE or dense
        for i, _ in enumerate(self._full_pattern()):
            if self._is_moe_layer(i):
                m = self.moe
                total += (m.n_routed + m.n_shared) * 3 * d * m.d_ff_expert
                total += d * m.n_routed  # router
            else:
                total += ffn_dense
        # norms (2 per layer) negligible but count
        total += len(self._full_pattern()) * 2 * d + d
        if self.is_encoder_decoder:
            # encoder layers: attn + dense ffn; decoder cross-attn extra
            total += self.enc_layers * (attn + ffn_dense + 2 * d)
            total += (self.n_layers - self.enc_layers) * attn  # cross-attn
        return float(total)

    def active_param_count(self) -> float:
        """Activated parameters per token (MoE-aware), analytic."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        total = self.param_count()
        # subtract inactive routed experts on MoE layers
        n_moe_layers = sum(
            1 for i, _ in enumerate(self._full_pattern()) if self._is_moe_layer(i)
        )
        inactive = (m.n_routed - m.top_k) * 3 * d * m.d_ff_expert
        return float(total - n_moe_layers * inactive)

    def _full_pattern(self):
        main = self.n_layers - self.enc_layers
        pat = []
        if self.moe is not None and self.moe.first_dense:
            pat.append(LayerKind.ATTN)
            main -= 1
        reps = main // len(self.layer_pattern)
        pat.extend(list(self.layer_pattern) * reps)
        return pat

    def _is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        if self.moe.first_dense and i == 0:
            return False
        return (i % self.moe.moe_every) == self.moe.moe_offset

    def _attn_params(self) -> float:
        d = self.d_model
        if self.mla is not None:
            ml = self.mla
            qd = self.n_heads * (ml.qk_nope_dim + ml.qk_rope_dim)
            return (
                d * qd  # q proj
                + d * (ml.kv_lora_rank + ml.qk_rope_dim)  # kv down
                + ml.kv_lora_rank * self.n_heads * (ml.qk_nope_dim + ml.v_head_dim)
                + self.n_heads * ml.v_head_dim * d  # o proj
            )
        hd = self.head_dim
        return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d

    def _mamba_params(self) -> float:
        if self.ssm is None:
            return 0.0
        d = self.d_model
        s = self.ssm
        di = s.d_inner(d)
        nh = s.n_heads(d)
        conv_ch = di + 2 * s.n_groups * s.d_state
        in_proj = d * (2 * di + 2 * s.n_groups * s.d_state + nh)
        return in_proj + conv_ch * s.d_conv + 2 * nh + di + di * d


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
