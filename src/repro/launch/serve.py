"""Serving driver: load (or init) a model, run batched requests.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-124m \\
        --scheduler continuous --requests 8 --max-new 12

``--scheduler wave`` runs the legacy lockstep scheduler (the golden
baseline); the default continuous scheduler refills slots mid-flight over
the paged KV cache.  ``--prefill-chunk N`` commits up to N prompt tokens
per fused step (chunked prefill) and ``--prefill-budget`` caps the total
prefill tokens admitted per step so decode never stalls behind a long
prompt — both land in the report and the ledger key, so chunked and
token-by-token trajectories stay separate.  ``--kv-dtype bf16|int8``
stores the paged KV pool quantized (per-row fp32 scales for int8) and
``--share-prefixes`` deduplicates identical prompt prefixes onto shared
pool blocks with copy-on-write (``--shared-prefix-len N`` samples traffic
that exercises it); both fork the ledger key (``+kv<dtype>`` /
``+shared``).  ``--draft <arch> --spec-k N`` turns on speculative
decoding (the draft model proposes up to N tokens per slot, one fused
target step verifies them; ledger key gains ``+spec<N>``), and
``--temperature/--top-k/--sample-seed`` select real sampling with
per-request PRNG streams (temperature 0 = greedy, bit-identical to the
pre-sampling engine).  ``--record`` appends the serving metrics (tok/s,
p50/p95 request latency, slot utilization, block dedup ratio) to the perf
trajectory ledger, where ``python -m repro.perf report`` renders them;
``--out`` writes the full machine-readable serve report.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

import repro.configs as configs
from repro.serve.engine import SCHEDULERS, Request, RequestTooLong, ServeEngine
from repro.train import steps as steps_mod


def build_report(args: argparse.Namespace, engine: ServeEngine,
                 rejections: list = ()) -> dict:
    """Machine-readable serve report (the ledger's serving source)."""
    return {
        "kind": "serve_report",
        "arch": args.arch,
        "scheduler": engine.scheduler,
        "max_batch": engine.max_batch,
        "max_len": engine.max_len,
        "block_size": engine.block_size,
        "prefill_chunk": engine.prefill_chunk,
        "prefill_budget": engine.prefill_budget,
        "kv_dtype": engine.kv_dtype,
        "share_prefixes": engine.share_prefixes,
        "draft": getattr(args, "draft", None),
        "spec_k": engine.spec_k,
        "spec_adaptive": engine.spec_adaptive,
        "mesh": engine.mesh_shape,
        "temperature": engine.temperature,
        "top_k": engine.top_k,
        "sample_seed": engine.sample_seed,
        "rejected": len(rejections),
        "rejections": [{"uid": u, "reason": reason} for u, reason in rejections],
        "stats": engine.stats(),
        "requests": [
            {
                "uid": r.uid,
                "prompt_len": int(len(r.prompt)),
                "new_tokens": len(r.generated),
                "tokens": [int(t) for t in r.generated],
                "latency_s": r.latency_s,
                "ttft_s": r.ttft_s,
            }
            for _, r in sorted(engine.completed.items())
        ],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-124m")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="smoke-sized config (--no-smoke for the real one)")
    ap.add_argument("--scheduler", choices=list(SCHEDULERS),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--prompt-lo", type=int, default=4,
                    help="minimum sampled prompt length")
    ap.add_argument("--prompt-hi", type=int, default=16,
                    help="maximum sampled prompt length (inclusive)")
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help="commit up to N prompt tokens per fused step "
                         "(1 = token-by-token; continuous scheduler only)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="cap total prefill tokens admitted per step so "
                         "decode slots never stall behind long prompts")
    ap.add_argument("--kv-dtype", choices=["f32", "bf16", "int8"],
                    default="f32",
                    help="paged KV pool storage dtype (quantized paging; "
                         "continuous scheduler only for bf16/int8)")
    ap.add_argument("--share-prefixes", action="store_true",
                    help="deduplicate identical prompt prefixes onto "
                         "shared pool blocks with copy-on-write "
                         "(continuous scheduler only)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="sample all prompts with a common prefix of this "
                         "length (exercises --share-prefixes; 0 = fully "
                         "random prompts)")
    ap.add_argument("--draft", default=None,
                    help="draft-model arch for speculative decoding "
                         "(e.g. gpt2-124m); requires --spec-k >= 1")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="draft tokens proposed per slot per fused target "
                         "step (0 = speculation off)")
    ap.add_argument("--spec-adaptive", action="store_true",
                    help="adapt the per-slot draft width from the trailing "
                         "acceptance EMA, clamped to [0, --spec-k]")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve tensor-parallel over a data-x-model device "
                         "mesh, e.g. 2x2 (use XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N to fake "
                         "N host devices; continuous scheduler only)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k highest-probability "
                         "tokens (0 = full vocab; needs --temperature > 0)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="base seed of the per-request sampling streams")
    ap.add_argument("--warmup", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="compile the fused step before serving so TTFT "
                         "measures scheduling, not XLA compilation")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the serve report JSON here")
    ap.add_argument("--record", action="store_true",
                    help="append serving metrics to the perf ledger")
    args = ap.parse_args(argv)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import (MeshShapeError, make_serve_mesh,
                                       parse_mesh)

        try:
            mesh = make_serve_mesh(*parse_mesh(args.mesh))
        except MeshShapeError as e:
            ap.error(str(e))

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    params = steps_mod.init_model(jax.random.PRNGKey(args.seed), cfg)
    draft_cfg = draft_params = None
    if args.spec_k > 0:
        if not args.draft:
            ap.error("--spec-k requires --draft <arch>")
        draft_cfg = (configs.get_smoke_config(args.draft) if args.smoke
                     else configs.get_config(args.draft))
        # same init seed as the target: --draft <same arch> gives exact
        # self-speculation (acceptance 1.0 at temperature 0), the
        # acceptance-friendly setup CI uses for the fewer-steps assert
        draft_params = steps_mod.init_model(
            jax.random.PRNGKey(args.seed), draft_cfg
        )
    engine = ServeEngine(cfg, params, max_batch=args.max_batch,
                         max_len=args.max_len, scheduler=args.scheduler,
                         block_size=args.block_size,
                         prefill_chunk=args.prefill_chunk,
                         prefill_budget=args.prefill_budget,
                         kv_dtype=args.kv_dtype,
                         share_prefixes=args.share_prefixes,
                         temperature=args.temperature, top_k=args.top_k,
                         sample_seed=args.sample_seed, spec_k=args.spec_k,
                         draft_cfg=draft_cfg, draft_params=draft_params,
                         spec_adaptive=args.spec_adaptive, mesh=mesh)
    if args.warmup:
        engine.warmup()

    rng = np.random.default_rng(args.seed)
    shared_prefix = (
        rng.integers(0, cfg.vocab,
                     size=args.shared_prefix_len).astype(np.int32)
        if args.shared_prefix_len > 0 else None)
    rejections: list = []
    for uid in range(args.requests):
        plen = int(rng.integers(args.prompt_lo, args.prompt_hi + 1))
        prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        if shared_prefix is not None:
            prompt = np.concatenate([shared_prefix, prompt])
        try:
            engine.submit(Request(
                uid=uid,
                prompt=prompt,
                max_new_tokens=args.max_new,
            ))
        except RequestTooLong as e:
            # an oversized submission is a counted rejection, not a crash:
            # the remaining requests still get served and reported
            rejections.append((uid, str(e)))
    done = engine.run_until_drained()
    stats = engine.stats()
    print(f"[{args.scheduler}] served {stats['requests']} requests, "
          f"{stats['new_tokens']} tokens, {stats['fused_steps']} fused steps "
          f"in {stats['wall_s']:.2f}s ({stats['tok_s']:.1f} tok/s)")
    print(f"  slot utilization {stats['slot_utilization']:.3f} "
          f"({stats['busy_slot_steps']}/{stats['slot_steps']} slot-steps), "
          f"latency p50 {stats['p50_latency_s']:.3f}s "
          f"p95 {stats['p95_latency_s']:.3f}s, "
          f"ttft p50 {stats['ttft_p50_s']:.3f}s "
          f"p95 {stats['ttft_p95_s']:.3f}s"
          + (f" [prefill chunk {engine.prefill_chunk}"
             + (f", budget {engine.prefill_budget}"
                if engine.prefill_budget else "") + "]"
             if engine.prefill_chunk > 1 else ""))
    if engine.kv_dtype != "f32" or engine.share_prefixes:
        print(f"  kv_dtype {stats['kv_dtype']}, "
              f"prefix sharing {'on' if stats['share_prefixes'] else 'off'}: "
              f"{stats['logical_blocks']} logical / "
              f"{stats['physical_blocks']} physical blocks "
              f"({stats['shared_block_hits']} shared hits, "
              f"{stats['cow_copies']} COW copies, "
              f"dedup {stats['block_dedup_ratio']:.3f})")
    if engine.mesh is not None:
        print(f"  mesh {stats['mesh']} ({stats['mesh_devices']} devices), "
              f"device lane utilization "
              f"{stats['device_lane_utilization']:.3f}")
    if engine.spec_k > 0:
        print(f"  speculative: draft {args.draft} k={engine.spec_k}"
              + (" (adaptive width)" if engine.spec_adaptive else "") + ", "
              f"acceptance {stats['acceptance_rate']:.3f} "
              f"({stats['accepted_tokens']}/{stats['drafted_tokens']} "
              f"drafts accepted, {stats['draft_steps']} draft steps, "
              f"{stats['target_steps']} target steps)")
    if rejections:
        print(f"  rejected {len(rejections)} oversized request(s) at submit:")
        for uid, reason in rejections:
            print(f"    req {uid}: {reason}")
    for uid in sorted(done):
        r = done[uid]
        lat = f"{r.latency_s:.3f}s" if r.latency_s is not None else "n/a"
        print(f"  req {uid}: prompt[{len(r.prompt)}] latency {lat} "
              f"-> {r.generated}")

    report = build_report(args, engine, rejections)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"serve report -> {args.out}")
    if args.record:
        from repro.perf.ledger import default_ledger

        run = default_ledger().record_sources(
            serving=report, meta={"argv": " ".join(argv or [])} if argv else None,
        )
        print(f"recorded serving run {run.run_id} (seq {run.seq}) "
              f"-> perf ledger")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
