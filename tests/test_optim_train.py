"""Optimizer behaviour + end-to-end training integration (loss goes down,
microbatch accumulation equivalence, checkpoint-resume bitwise replay)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import ShapeConfig
from repro.data import pipeline
from repro.optim import adamw
from repro.train import steps as steps_mod

SMOKE = ShapeConfig("smoke", 32, 4, "train")


# ---------------------------------------------------------------------------
# AdamW unit behaviour
# ---------------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                            weight_decay=0.0, grad_clip=1e9)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw.init_opt_state(params, cfg)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adamw.apply_update(params, g, state, cfg)

    for _ in range(150):
        params, state, _ = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=0.05)


def test_grad_clip_caps_update_norm():
    cfg = adamw.AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init_opt_state(params, cfg)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, stats = adamw.apply_update(params, huge, state, cfg)
    assert float(stats["grad_norm"]) == pytest.approx(2e6, rel=1e-5)


def test_lr_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, rel=0.02)
    assert lrs[-1] == pytest.approx(0.1, rel=0.05)


def test_bf16_state_halves_memory():
    params = {"w": jnp.zeros((128, 128), jnp.bfloat16)}
    full = adamw.init_opt_state(params, adamw.AdamWConfig())
    lean = adamw.init_opt_state(
        params, adamw.AdamWConfig(state_dtype="bfloat16", master_weights=False)
    )
    b_full = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(full))
    b_lean = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(lean))
    assert b_lean < 0.35 * b_full


def test_master_weights_carry_precision():
    """bf16 params + fp32 master accumulate tiny updates that bf16 alone loses."""
    cfg = adamw.AdamWConfig(lr=1e-4, warmup_steps=0, weight_decay=0.0)
    params = {"w": jnp.ones(8, jnp.bfloat16)}
    state = adamw.init_opt_state(params, cfg)
    g = {"w": jnp.full(8, 1e-3, jnp.bfloat16)}
    for _ in range(3):
        params, state, _ = adamw.apply_update(params, g, state, cfg)
    master = np.asarray(state["master"]["w"])
    assert np.all(master < 1.0)
    assert not np.allclose(master, np.asarray(params["w"], np.float32))


# ---------------------------------------------------------------------------
# integration
# ---------------------------------------------------------------------------


def _setup(arch="gpt2-124m", microbatches=1, **run_kw):
    cfg = configs.get_smoke_config(arch)
    run = steps_mod.RunConfig(remat="none", zero=False,
                              microbatches=microbatches, **run_kw)
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_opt_state(params, run.opt)
    ts = jax.jit(steps_mod.make_train_step(cfg, run))
    return cfg, run, params, opt, ts


def test_loss_decreases_over_20_steps():
    cfg = configs.get_smoke_config("gpt2-124m")
    # test-speed optimizer: no warmup damping
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=0, total_steps=1000)
    run = steps_mod.RunConfig(remat="none", zero=False, opt=opt_cfg)
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_opt_state(params, run.opt)
    ts = jax.jit(steps_mod.make_train_step(cfg, run))
    dc = pipeline.DataConfig(seed=0)
    losses = []
    batch = pipeline.global_batch(cfg, SMOKE, dc, 0)  # fixed batch: memorize
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    for step in range(20):
        params, opt, m = ts(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatch_accumulation_matches_single_shot():
    cfg1, _, p1, o1, ts1 = _setup(microbatches=1)
    cfg4, _, p4, o4, ts4 = _setup(microbatches=4)
    batch = pipeline.global_batch(cfg1, SMOKE, pipeline.DataConfig(), 0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    p1n, _, m1 = ts1(p1, o1, batch)
    p4n, _, m4 = ts4(p4, o4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1n), jax.tree.leaves(p4n)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-4, atol=1e-5
        )


def test_checkpoint_resume_is_exact_replay(tmp_path):
    """Steps 0..5 straight vs crash-after-3 + resume must agree exactly
    (stateless data pipeline + step-indexed batches)."""
    from repro.checkpoint import CheckpointStore

    def run_steps(params, opt, ts, cfg, lo, hi):
        dc = pipeline.DataConfig(seed=9)
        for step in range(lo, hi):
            batch = pipeline.global_batch(cfg, SMOKE, dc, step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, _ = ts(params, opt, batch)
        return params, opt

    cfg, run, params, opt, ts = _setup()
    p_straight, _ = run_steps(params, opt, ts, cfg, 0, 6)

    cfg, run, params, opt, ts = _setup()
    store = CheckpointStore(str(tmp_path))
    p3, o3 = run_steps(params, opt, ts, cfg, 0, 3)
    store.save(3, {"params": p3, "opt": o3})
    _, restored, _ = store.restore({"params": p3, "opt": o3})
    p_resumed, _ = run_steps(restored["params"], restored["opt"], ts, cfg, 3, 6)

    for a, b in zip(jax.tree.leaves(p_straight), jax.tree.leaves(p_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_step_works_under_host_mesh():
    """pjit path on the real (single-device) mesh with the production
    sharding rules — the same code path the 512-way dry-run exercises."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed import sharding as shard_rules
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    cfg = configs.get_smoke_config("qwen3-1.7b")
    run = steps_mod.RunConfig(remat="none", zero=True)
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    p_sh = shard_rules.param_shardings(params, mesh)
    params = jax.device_put(params, p_sh)
    opt = adamw.init_opt_state(params, run.opt)
    batch = pipeline.global_batch(cfg, SMOKE, pipeline.DataConfig(), 0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    ts = jax.jit(steps_mod.make_train_step(cfg, run))
    with mesh:
        p2, o2, m = ts(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
