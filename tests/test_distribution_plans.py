"""Mesh-plan context + optimized distribution paths (shard_map EP MoE,
sequence-parallel constraints): numerics must be identical to the plain path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as configs
from repro.configs.base import ShapeConfig
from repro.data import pipeline
from repro.distributed import context as mesh_ctx
from repro.models import moe
from repro.optim import adamw
from repro.train import steps as steps_mod

SMOKE = ShapeConfig("smoke", 32, 2, "train")


def test_default_plan_is_inactive():
    plan = mesh_ctx.current()
    assert not plan.active
    assert plan.moe_impl == "global"
    # shard_seq is a no-op without a plan
    x = jnp.ones((2, 8, 4))
    assert mesh_ctx.shard_seq(x, plan) is x


def test_use_plan_scopes_correctly():
    plan = mesh_ctx.MeshPlan(n_data=4, data_axes=("data",), model_axis="model")
    with mesh_ctx.use_plan(plan):
        assert mesh_ctx.current().n_data == 4
    assert mesh_ctx.current().n_data == 1


def test_plan_for_mesh_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = mesh_ctx.plan_for_mesh(mesh, seq_parallel=True, moe_impl="shard_map")
    assert plan.data_axes == ("data",)
    assert plan.model_axis == "model"
    assert plan.seq_parallel and plan.moe_impl == "shard_map"
    assert plan.mesh is mesh


def _moe_setup():
    cfg = configs.get_smoke_config("deepseek-moe-16b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0)
    )
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model), jnp.float32)
    return cfg, params, x


def test_shard_map_moe_matches_global():
    cfg, params, x = _moe_setup()
    y_ref, aux_ref = moe.moe_ffn(params, cfg, x)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = mesh_ctx.plan_for_mesh(mesh, moe_impl="shard_map")
    with mesh_ctx.use_plan(plan), mesh:
        y_sm, aux_sm = jax.jit(lambda p, x: moe.moe_ffn(p, cfg, x))(params, x)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sm),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_ref), float(aux_sm), rtol=1e-4)


def test_hierarchical_moe_matches_global():
    cfg, params, x = _moe_setup()
    y_ref, _ = moe.moe_ffn(params, cfg, x)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = mesh_ctx.plan_for_mesh(mesh, moe_impl="hierarchical")
    with mesh_ctx.use_plan(plan), mesh:
        y_h, _ = jax.jit(lambda p, x: moe.moe_ffn(p, cfg, x))(params, x)
    # n_data == 1 -> falls back to global; just assert identical
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_h),
                               rtol=2e-4, atol=2e-4)


def test_shard_map_moe_gradients_flow():
    cfg, params, x = _moe_setup()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = mesh_ctx.plan_for_mesh(mesh, moe_impl="shard_map")

    def loss(p):
        y, aux = moe.moe_ffn(p, cfg, x)
        return jnp.sum(jnp.square(y)) + aux

    with mesh_ctx.use_plan(plan), mesh:
        g = jax.jit(jax.grad(loss))(params)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert np.all(np.isfinite(np.asarray(leaf))), path
    assert float(jnp.max(jnp.abs(g["wi_gate"]))) > 0


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-moe-16b"])
def test_train_step_under_optimized_plan_matches_plain(arch):
    """The optimized plan (SP constraints / shard_map EP) must not change
    the loss value — distribution is semantics-preserving."""
    cfg = configs.get_smoke_config(arch)
    run = steps_mod.RunConfig(remat="none", zero=False)
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in
             pipeline.global_batch(cfg, SMOKE, pipeline.DataConfig(), 0).items()}

    loss_plain, _ = steps_mod.loss_fn(params, cfg, batch, run)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = mesh_ctx.plan_for_mesh(
        mesh, seq_parallel=(cfg.moe is None), moe_impl="shard_map"
    )
    with mesh_ctx.use_plan(plan), mesh:
        loss_opt, _ = jax.jit(
            lambda p, b: steps_mod.loss_fn(p, cfg, b, run)
        )(params, batch)
    np.testing.assert_allclose(float(loss_plain), float(loss_opt),
                               rtol=2e-4, atol=1e-5)
