"""OLMo-1B — dense, non-parametric LayerNorm, MHA.

[arXiv:2402.00838; hf]  16L, d_model=2048, 16H (kv=16 -> MHA), d_ff=8192,
vocab=50304.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab=50304,
    nonparam_ln=True,
    rms_norm=False,
)

SMOKE = ModelConfig(
    name="olmo-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    nonparam_ln=True,
    rms_norm=False,
    param_dtype="float32",
    compute_dtype="float32",
)
