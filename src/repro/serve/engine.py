"""Batched serving engine: iteration-level batched greedy decoding over a
fixed-size KV cache, fed from a request queue.

Requests are admitted in waves of up to ``max_batch``; a wave advances in
LOCKSTEP — at global position t each slot consumes its own prompt token (if
its prompt is longer than t) or its last generated token.  This keeps the
scalar cache position uniform across the batch (correct by construction
with the one-commit-per-step cache layout) while still exercising the real
serving shape: one fused ``decode_step`` for the whole batch per token, the
decode_* dry-run cell.  Ragged prompts are handled by per-slot switchover
masking — the predication idea at the serving layer.

A slot-level continuously-batched engine (per-slot write indices + scatter
commits + paged cache blocks) is the production extension; the fused-step /
fixed-slot structure here is its inner loop.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never stops early

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        self.generated: List[int] = []
        self.done = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.queue: deque = deque()
        self.completed: Dict[int, Request] = {}
        self.steps = 0
        self._decode = jax.jit(
            lambda p, t, c: transformer.decode_step(p, cfg, t, c)
        )

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # -- one wave -------------------------------------------------------------

    def _run_wave(self, wave: List[Request]) -> None:
        B = self.max_batch
        cache = transformer.init_cache(self.cfg, B, self.max_len)
        prompt_len = np.array(
            [len(r.prompt) for r in wave] + [1] * (B - len(wave)), np.int32
        )
        horizon = int(max(
            len(r.prompt) + r.max_new_tokens for r in wave
        ))
        assert horizon <= self.max_len, "wave exceeds cache"
        tokens = np.zeros((B, 1), np.int32)
        for s, r in enumerate(wave):
            tokens[s, 0] = r.prompt[0]

        for t in range(horizon - 1):
            logits, cache = self._decode(self.params, jnp.asarray(tokens), cache)
            self.steps += 1
            nxt = np.asarray(jnp.argmax(logits[:, 0, : self.cfg.vocab], axis=-1))
            for s, r in enumerate(wave):
                if r.done:
                    continue
                if t + 1 < prompt_len[s]:
                    tokens[s, 0] = r.prompt[t + 1]  # still consuming prompt
                else:
                    tok = int(nxt[s])
                    r.generated.append(tok)
                    tokens[s, 0] = tok
                    if (len(r.generated) >= r.max_new_tokens or tok == r.eos_id):
                        r.done = True
            if all(r.done for r in wave):
                break
        for r in wave:
            r.done = True
            self.completed[r.uid] = r

    # -- public ----------------------------------------------------------------

    def run_until_drained(self, max_waves: int = 1000) -> Dict[int, Request]:
        waves = 0
        while self.queue:
            wave = [self.queue.popleft()
                    for _ in range(min(self.max_batch, len(self.queue)))]
            self._run_wave(wave)
            waves += 1
            if waves > max_waves:
                raise RuntimeError("serve loop did not drain")
        return self.completed
