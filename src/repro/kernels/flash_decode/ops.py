"""Flash-decode kernel call surface (served by the kernel registry)."""

from __future__ import annotations

from repro.kernels.registry import FLASH_DECODE as flash_decode

__all__ = ["flash_decode"]
