"""Vectorization-effectiveness metrics (paper Eq. 1 and Sec. 3.3).

Implements, for an arbitrary hardware model (``ChipSpec``):

* ``vectorization_bound`` — VB = VLEN / ELEN (paper Eq. 1, left).
* ``instruction_reduction`` — R_ins_reduction = Ins_nonvec / Ins_vec
  (paper Eq. 1, right).
* ``arithmetic_intensity`` — AI = FLOPs / bytes-from-memory; the decision tree
  uses the LLC-read-miss approximation FP_op / LLC_read_miss (paper Sec. 5),
  which on TPU becomes FLOPs / HBM-read-bytes.
* ``vector_issues`` — the TPU instruction-count model: how many vector issue
  slots a given element count occupies at a given element width, including the
  predication (masking) efficiency for ragged extents.

The paper measures Ins_nonvec by compiling with vectorization disabled.  XLA
has no such switch, so the scalar baseline is *defined* as one element per
issue slot — exactly the denominator's semantics in the paper (instructions to
a solution with no data-parallel packing).  This makes R_ins measurable from
an op census of the lowered HLO (see counters.py) and analytically equal to
VB x utilization for fully-vectorizable kernels, which is the quantity the
paper's Fig. 3a plots against the VB dashed lines.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

from repro.core import hw


def vectorization_bound(chip: hw.ChipSpec, dtype: str) -> float:
    """VB = VLEN / ELEN (paper Eq. 1).

    On Grace: VB(fp64)=2, VB(fp32)=4.  On TPU the VPU issue is 8x128 32-bit
    lanes, with sub-32-bit types packed 2x/4x — the same ELEN scaling the
    paper studies, at a longer base vector.
    """
    return chip.vlen_bits / hw.elen_bits(dtype)


def packing_factor(dtype: str, base_bits: int = 32) -> float:
    """Relative element packing vs a 32-bit lane (TPU-native comparison).

    bf16 -> 2.0, fp32 -> 1.0, int8 -> 4.0, fp64 -> 0.5.  This is the ratio the
    paper sweeps by changing ELEN at fixed VLEN.
    """
    return base_bits / hw.elen_bits(dtype)


def vector_issues(
    elements: float,
    dtype: str,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    *,
    ragged_extents: Sequence[int] | None = None,
    tile: int | None = None,
) -> float:
    """Number of vector issue slots to process ``elements`` elements.

    ``ragged_extents`` models the paper's SpMV case: each row of length
    ``r`` occupies ``ceil(r / tile)`` tiles under predication (SVE/VLA
    analogue: masked Pallas tiles), instead of ``ceil(max_r / tile)`` under
    fixed-width padding.  ``tile`` defaults to the chip's full vector issue
    width in elements.
    """
    lanes = chip.vlen_bits / hw.elen_bits(dtype)
    t = tile if tile is not None else lanes
    if ragged_extents is None:
        return math.ceil(elements / t) if elements else 0.0
    return float(sum(math.ceil(max(r, 0) / t) for r in ragged_extents))


def scalar_issues(elements: float) -> float:
    """Scalar baseline: one element per retired instruction."""
    return float(elements)


def instruction_reduction(ins_nonvec: float, ins_vec: float) -> float:
    """R_ins_reduction = Ins_nonvec / Ins_vec (paper Eq. 1)."""
    if ins_vec <= 0:
        return float("inf") if ins_nonvec > 0 else 1.0
    return ins_nonvec / ins_vec


def lane_utilization(
    useful_elements: float, issues: float, dtype: str, chip: hw.ChipSpec
) -> float:
    """Fraction of vector lanes doing useful work (predication efficiency)."""
    lanes = chip.vlen_bits / hw.elen_bits(dtype)
    if issues <= 0:
        return 0.0
    return min(1.0, useful_elements / (issues * lanes))


def slot_utilization(
    busy_slot_steps: float, steps: float, slots: int
) -> float:
    """Fraction of serving slots doing useful work across a batch of fused
    decode steps — Eq. 1's lane utilization lifted to the serving layer.

    A fused decode step is a vector issue whose "lanes" are the batch
    slots; a slot is busy when it carries a live request (consuming prompt
    or generating) and idle when it is drained, finished-but-waiting
    (lockstep waves), or unfilled.  ``busy_slot_steps`` counts busy
    (slot, step) pairs; the denominator is ``steps * slots``, exactly as
    :func:`lane_utilization` divides useful elements by issues x lanes.
    Continuous batching is to this metric what predicated loops are to
    lane utilization: finished slots are refilled (masked and reassigned)
    instead of waited on.
    """
    if steps <= 0 or slots <= 0:
        return 0.0
    return min(1.0, busy_slot_steps / (steps * slots))


def acceptance_rate(accepted: float, drafted: float) -> float:
    """Accepted draft tokens per token drafted — Eq. 1's active-lane
    fraction, lifted to speculative decoding.

    A k-wide verification step is a vector issue whose "lanes" are the k
    drafted positions: every lane's work is executed (the fused target
    step scores all k tokens regardless), but only the accepted prefix
    retires useful results — the rejected suffix is masked off by the
    position rewind, exactly as a predicated-out SVE lane burns an issue
    slot without contributing elements.  1.0 means every draft survived
    verification (all lanes active); low values mean the draft model
    disagrees with the target and speculation is mostly rewound work.
    Degenerate inputs (nothing drafted — e.g. speculation disabled)
    report 0.0.
    """
    if drafted <= 0:
        return 0.0
    return min(1.0, accepted / drafted)


def block_dedup_ratio(bytes_served: float, bytes_stored: float) -> float:
    """KV-cache bytes served per byte physically stored — Eq. 1's lane
    utilization as a *memory* metric.

    Prefix sharing maps identical prompt prefixes onto the same physical
    blocks, so one stored block can back several slots' logical caches:
    ``bytes_served`` sums every slot's logical block-spans, while
    ``bytes_stored`` counts distinct physical allocations (copy-on-write
    copies included).  1.0 means no sharing (every logical byte has its
    own physical byte, the fixed-width baseline); > 1.0 is the dedup win,
    exactly as lane utilization > the scalar baseline is the predication
    win.  Degenerate inputs (nothing stored yet) report the no-sharing
    baseline rather than dividing by zero.
    """
    if bytes_stored <= 0:
        return 1.0
    return bytes_served / bytes_stored


def device_lane_utilization(
    busy_lane_steps: Sequence[float], steps: float, lanes_per_device: int
) -> float:
    """Busy-lane fraction of the *worst* device shard — Eq. 1 one level up.

    A mesh of devices is the vector-lane question at the next scale: each
    fused step issues once across every device, and a device's "lanes" are
    the batch slots its data shard owns.  ``busy_lane_steps[i]`` counts busy
    (slot, step) pairs on shard ``i``; each shard's utilization is its busy
    count over ``steps * lanes_per_device``, and the reported figure is the
    minimum over shards — the straggler lane that bounds the whole issue,
    exactly as one predicated-out SVE lane still burns its issue slot.  On
    a single shard (1x1 mesh, or no mesh) this degenerates to
    :func:`slot_utilization`.  Deterministic (pure slot accounting), so the
    perf ledger gates it at tol 0.
    """
    counts = list(busy_lane_steps)
    if steps <= 0 or lanes_per_device <= 0 or not counts:
        return 0.0
    return min(
        min(1.0, b / (steps * lanes_per_device)) for b in counts
    )


def expert_imbalance(expert_loads: Sequence[float]) -> float:
    """Max-over-mean load across expert-parallel shards — the EP variant of
    :func:`device_lane_utilization`.

    Under expert parallelism each device owns ``E / model`` experts, and a
    fused MoE step finishes only when the most-loaded shard drains — the
    straggler factor is ``max(load) / mean(load)``.  1.0 is a perfectly
    balanced router (every "lane" equally busy, Eq. 1's utilization = 1);
    ``n_shards`` is the pathological one-hot router where one device does
    all the work while the rest idle through the issue.  Degenerate input
    (no load observed) reports the balanced baseline 1.0.
    """
    loads = [max(0.0, float(x)) for x in expert_loads]
    total = sum(loads)
    if not loads or total <= 0:
        return 1.0
    return max(loads) * len(loads) / total


def arithmetic_intensity(flops: float, hbm_bytes: float) -> float:
    """AI = FLOPs / bytes moved from main memory (paper Sec. 3.3)."""
    if hbm_bytes <= 0:
        return float("inf") if flops > 0 else 0.0
    return flops / hbm_bytes


@dataclasses.dataclass(frozen=True)
class VectorizationReport:
    """Everything the decision tree needs about one kernel/application run."""

    name: str
    dtype: str
    flops: float
    hbm_bytes: float
    gather_bytes: float  # pointer-chasing traffic (latency-bound signal)
    ins_scalar: float  # scalar-equivalent retired instructions
    ins_vec: float  # vector-issue count of the vectorized version
    vectorizable_fraction: float  # share of FLOPs in vector/matrix-eligible ops
    collective_bytes: float = 0.0

    @property
    def r_ins(self) -> float:
        return instruction_reduction(self.ins_scalar, self.ins_vec)

    @property
    def ai(self) -> float:
        return arithmetic_intensity(self.flops, self.hbm_bytes)

    @property
    def gather_fraction(self) -> float:
        if self.hbm_bytes <= 0:
            return 0.0
        return self.gather_bytes / self.hbm_bytes


def amdahl_r_ins(vb: float, vectorizable_fraction: float) -> float:
    """Analytic R_ins for a partially vectorizable instruction stream.

    The paper observes (Sec. 4.1) that when non-vectorized instructions grow
    (e.g. threading runtime), R_ins collapses even though kernels vectorize.
    Amdahl over the instruction stream: R = 1 / ((1-f) + f/VB).
    """
    f = min(max(vectorizable_fraction, 0.0), 1.0)
    return 1.0 / ((1.0 - f) + f / max(vb, 1e-30))
