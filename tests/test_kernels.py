"""Per-kernel shape/dtype sweeps vs pure-jnp/numpy oracles (interpret mode).

Every Pallas kernel in src/repro/kernels is asserted allclose against its
ref.py for a sweep of shapes, dtypes, and tilings — the assignment's
kernel-validation contract.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import kernel as fdk, ref as fdr
from repro.kernels.gemm import kernel as gk, ops as gops, ref as gr
from repro.kernels.jacobi2d import kernel as jk, ops as jops, ref as jr
from repro.kernels.qc_gate import kernel as qk, ops as qops, ref as qr
from repro.kernels.stream import kernel as sk, ops as sops, ref as sr

# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------

GEMM_CASES = [
    # M, N, K, bm, bn, bk, dtype
    (32, 32, 32, 32, 32, 32, jnp.float32),
    (64, 32, 96, 32, 16, 24, jnp.float32),
    (128, 128, 64, 64, 64, 32, jnp.float32),
    (48, 80, 56, 16, 16, 8, jnp.float32),
    (64, 64, 64, 32, 32, 32, jnp.bfloat16),
    (64, 64, 128, 64, 64, 128, jnp.bfloat16),  # single k step
]


@pytest.mark.parametrize("M,N,K,bm,bn,bk,dtype", GEMM_CASES)
def test_gemm_sweep(M, N, K, bm, bn, bk, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K), dtype)
    y = jax.random.normal(jax.random.PRNGKey(1), (K, N), dtype)
    out = gk.gemm(x, y, bm=bm, bn=bn, bk=bk)
    ref = gr.gemm_ref(x, y)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )
    assert out.dtype == dtype


def test_gemm_tiling_is_invisible():
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 64), jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(3), (64, 64), jnp.float32)
    outs = [np.asarray(gk.gemm(x, y, bm=bm, bn=bn, bk=bk))
            for bm, bn, bk in [(64, 64, 64), (32, 32, 16), (16, 64, 32)]]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-5)


def test_gemm_tile_picker_respects_vmem():
    bm, bn, bk = gops.pick_tiles(4096, 4096, 4096, vmem_budget=4 * 2**20)
    assert gops.vmem_bytes(bm, bn, bk) <= 4 * 2**20
    assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0


def test_gemm_ai_grows_with_size():
    small = gr.flops_bytes(128, 128, 128)
    big = gr.flops_bytes(4096, 4096, 4096)
    assert big["ai"] > 10 * small["ai"]


# ---------------------------------------------------------------------------
# STREAM
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("rows,br", [(64, 16), (64, 64), (256, 32)])
def test_stream_sweep(dtype, rows, br):
    a = jax.random.normal(jax.random.PRNGKey(0), (rows, 128), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (rows, 128), dtype)
    tol = dict(rtol=1e-5, atol=1e-5) if dtype == jnp.float32 else dict(rtol=2e-2, atol=2e-2)
    for name, got, want in [
        ("copy", sk.stream_copy(a, block_rows=br), sr.copy_ref(a)),
        ("scale", sk.stream_scale(a, 2.5, block_rows=br), sr.scale_ref(a, 2.5)),
        ("add", sk.stream_add(a, b, block_rows=br), sr.add_ref(a, b)),
        ("triad", sk.stream_triad(a, b, 3.0, block_rows=br), sr.triad_ref(a, b, 3.0)),
    ]:
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            err_msg=name, **tol,
        )


def test_stream_elen_issue_model():
    """Paper Sec. 4.2 (GCC column): R_ins 2x/4x/8x for fp64->fp16 at VLEN=128."""
    n = 1 << 20
    assert sops.issue_counts(n, 64)["r_ins"] == pytest.approx(2.0)
    assert sops.issue_counts(n, 32)["r_ins"] == pytest.approx(4.0)
    assert sops.issue_counts(n, 16)["r_ins"] == pytest.approx(8.0)


# ---------------------------------------------------------------------------
# Jacobi2D
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("H,W,br", [(32, 128, 8), (32, 128, 32), (64, 256, 16),
                                    (16, 128, 4)])
def test_jacobi_sweep(H, W, br):
    u = jax.random.normal(jax.random.PRNGKey(2), (H, W), jnp.float32)
    out = jk.jacobi_step(u, block_rows=br)
    ref = jr.jacobi_ref(u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=1e-5)


def test_jacobi_boundary_passthrough():
    u = jax.random.normal(jax.random.PRNGKey(3), (16, 128), jnp.float32)
    out = np.asarray(jk.jacobi_step(u, block_rows=8))
    np.testing.assert_array_equal(out[0], np.asarray(u[0]))
    np.testing.assert_array_equal(out[-1], np.asarray(u[-1]))
    np.testing.assert_array_equal(out[:, 0], np.asarray(u[:, 0]))
    np.testing.assert_array_equal(out[:, -1], np.asarray(u[:, -1]))


def test_jacobi_multi_sweep_converges():
    """Repeated sweeps smooth toward the boundary-harmonic solution."""
    u = jnp.zeros((16, 128), jnp.float32).at[8, 64].set(100.0)
    out = jops.jacobi(u, sweeps=50, block_rows=8)
    assert float(jnp.max(jnp.abs(out[1:-1, 1:-1]))) < 100.0
    assert np.all(np.isfinite(np.asarray(out)))


def test_jacobi_is_memory_bound_in_model():
    from repro.core import hw
    from repro.core.roofline import adapted_roofline

    fb = jr.flops_bytes(4096, 4096, dtype_bytes=8)
    rl = adapted_roofline(hw.GRACE_CORE, "fp64")
    assert fb["ai"] < rl.ai_irr  # left of the scalar knee: Class 2 territory


# ---------------------------------------------------------------------------
# flash-decode
# ---------------------------------------------------------------------------

FD_CASES = [
    # B, KV, G, D, S, bs
    (1, 1, 1, 16, 32, 8),
    (2, 2, 3, 16, 64, 16),
    (2, 4, 2, 32, 128, 32),
    (3, 2, 4, 16, 64, 64),  # single block
]


@pytest.mark.parametrize("B,KV,G,D,S,bs", FD_CASES)
def test_flash_decode_sweep(B, KV, G, D, S, bs):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, KV, G, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    valid = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = fdk.flash_decode(q, k, v, valid, block_s=bs)
    ref = fdr.decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


def test_flash_decode_masked_tail_is_inert():
    B, KV, G, D, S = 1, 2, 2, 16, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, KV, G, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    valid = jnp.asarray([40], jnp.int32)
    out1 = fdk.flash_decode(q, k, v, valid, block_s=16)
    out2 = fdk.flash_decode(
        q, k.at[:, 40:].set(99.0), v.at[:, 40:].set(-99.0), valid, block_s=16
    )
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6, atol=1e-6)


def test_flash_decode_issue_model():
    c = fdr.issue_counts([100, 512, 30], S=512, block_s=64)
    assert c["predicated"] == 2 + 8 + 1
    assert c["fixed"] == 3 * 8
    assert c["r_issue"] > 2.0


def _paged_setup(B, KV, D, bs, nb, seed=0):
    """Random pool + shuffled non-contiguous block tables (block 0 = null)."""
    n_blocks = 1 + B * nb
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    k_pool = jax.random.normal(ks[0], (n_blocks, bs, KV, D), jnp.float32)
    v_pool = jax.random.normal(ks[1], (n_blocks, bs, KV, D), jnp.float32)
    perm = np.random.default_rng(seed).permutation(np.arange(1, n_blocks))
    bt = jnp.asarray(perm[: B * nb].reshape(B, nb).astype(np.int32))
    return k_pool, v_pool, bt, ks[2]


@pytest.mark.parametrize("B,KV,G,D,bs,nb", [
    (1, 1, 1, 16, 8, 4),
    (3, 2, 2, 16, 4, 6),
    (2, 4, 2, 32, 16, 2),
])
def test_flash_decode_paged_matches_ref(B, KV, G, D, bs, nb):
    k_pool, v_pool, bt, kq = _paged_setup(B, KV, D, bs, nb)
    q = jax.random.normal(kq, (B, KV, G, D), jnp.float32)
    valid = jax.random.randint(jax.random.PRNGKey(7), (B,), 1, nb * bs + 1)
    out = fdk.flash_decode_paged(q, k_pool, v_pool, bt, valid)
    ref = fdr.decode_paged_ref(q, k_pool, v_pool, bt, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_flash_decode_paged_matches_contiguous():
    """A paged cache with an identity block table must reproduce the
    contiguous kernel bit-for-bit: paging changes placement, not math."""
    B, KV, G, D, bs, nb = 2, 2, 2, 16, 8, 4
    S = nb * bs
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (B, KV, G, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    valid = jnp.asarray([13, 27], jnp.int32)
    # lay each sequence's blocks out contiguously after the null block
    k_pool = jnp.concatenate(
        [jnp.zeros((1, bs, KV, D), jnp.float32),
         k.reshape(B * nb, bs, KV, D)])
    v_pool = jnp.concatenate(
        [jnp.zeros((1, bs, KV, D), jnp.float32),
         v.reshape(B * nb, bs, KV, D)])
    bt = jnp.arange(1, 1 + B * nb, dtype=jnp.int32).reshape(B, nb)
    paged = fdk.flash_decode_paged(q, k_pool, v_pool, bt, valid)
    dense = fdk.flash_decode(q, k, v, valid, block_s=bs)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               rtol=1e-6, atol=1e-6)


def test_flash_decode_paged_stale_blocks_are_inert():
    """Garbage in recycled / never-allocated blocks past valid_len cannot
    leak into the output — per-slot length predication in the kernel."""
    B, KV, G, D, bs, nb = 2, 2, 2, 16, 4, 4
    k_pool, v_pool, bt, kq = _paged_setup(B, KV, D, bs, nb, seed=5)
    q = jax.random.normal(kq, (B, KV, G, D), jnp.float32)
    valid = jnp.asarray([6, 11], jnp.int32)
    out1 = fdk.flash_decode_paged(q, k_pool, v_pool, bt, valid)
    # poison every pool row belonging to a logical position >= valid
    kp, vp = np.asarray(k_pool).copy(), np.asarray(v_pool).copy()
    for b in range(B):
        for j in range(nb):
            for o in range(bs):
                if j * bs + o >= int(valid[b]):
                    kp[int(bt[b, j]), o] = 99.0
                    vp[int(bt[b, j]), o] = -99.0
    out2 = fdk.flash_decode_paged(
        q, jnp.asarray(kp), jnp.asarray(vp), bt, valid)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# quantized paging (the ELEN axis): int8 / bf16 pools vs the f32 oracle
# ---------------------------------------------------------------------------


def _quantized_paged_setup(B, KV, D, bs, nb, seed=0):
    """f32 pools + their per-row int8 quantization (kernel commit formula)."""
    k_pool, v_pool, bt, kq = _paged_setup(B, KV, D, bs, nb, seed=seed)
    kq8, ks = fdr.quantize_rows(k_pool)
    vq8, vs = fdr.quantize_rows(v_pool)
    return k_pool, v_pool, kq8, vq8, ks, vs, bt, kq


@pytest.mark.parametrize("B,KV,G,D,bs,nb", [
    (1, 1, 1, 16, 8, 4),
    (3, 2, 2, 16, 4, 6),
    (2, 4, 2, 32, 16, 2),
])
def test_flash_decode_paged_int8_matches_ref(B, KV, G, D, bs, nb):
    """Kernel-side per-tile dequant == whole-array ref dequant (tight),
    and both stay within quantization error of the f32 pools (loose)."""
    k_pool, v_pool, kq8, vq8, ks, vs, bt, kq = _quantized_paged_setup(
        B, KV, D, bs, nb)
    q = jax.random.normal(kq, (B, KV, G, D), jnp.float32)
    valid = jax.random.randint(jax.random.PRNGKey(7), (B,), 1, nb * bs + 1)
    out = fdk.flash_decode_paged(q, kq8, vq8, bt, valid,
                                 k_scale=ks, v_scale=vs)
    ref = fdr.decode_paged_ref(q, kq8, vq8, bt, valid,
                               k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
    f32 = fdr.decode_paged_ref(q, k_pool, v_pool, bt, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(f32), atol=0.08)


def test_flash_decode_paged_bf16_matches_ref():
    """bf16 pools (no scales) widen in VMEM; output within bf16 error of
    the f32 oracle."""
    B, KV, G, D, bs, nb = 2, 2, 2, 16, 8, 4
    k_pool, v_pool, bt, kq = _paged_setup(B, KV, D, bs, nb, seed=2)
    q = jax.random.normal(kq, (B, KV, G, D), jnp.float32)
    valid = jnp.asarray([9, 25], jnp.int32)
    out = fdk.flash_decode_paged(q, k_pool.astype(jnp.bfloat16),
                                 v_pool.astype(jnp.bfloat16), bt, valid)
    f32 = fdr.decode_paged_ref(q, k_pool, v_pool, bt, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(f32), atol=0.03)


def test_flash_decode_paged_int8_rejects_lone_scale():
    k_pool, v_pool, kq8, vq8, ks, vs, bt, kq = _quantized_paged_setup(
        1, 1, 16, 8, 4)
    q = jax.random.normal(kq, (1, 1, 1, 16), jnp.float32)
    valid = jnp.asarray([5], jnp.int32)
    with pytest.raises(ValueError):
        fdk.flash_decode_paged(q, kq8, vq8, bt, valid, k_scale=ks)


def test_flash_decode_paged_int8_stale_blocks_are_inert():
    """The f32 stale-block sweep, on quantized pools: poisoning int8 rows
    AND their scales past valid_len cannot change the output — length
    predication must mask before dequantization, not after."""
    B, KV, G, D, bs, nb = 2, 2, 2, 16, 4, 4
    _, _, kq8, vq8, ks, vs, bt, kq = _quantized_paged_setup(
        B, KV, D, bs, nb, seed=5)
    q = jax.random.normal(kq, (B, KV, G, D), jnp.float32)
    valid = jnp.asarray([6, 11], jnp.int32)
    out1 = fdk.flash_decode_paged(q, kq8, vq8, bt, valid,
                                  k_scale=ks, v_scale=vs)
    kp, vp = np.asarray(kq8).copy(), np.asarray(vq8).copy()
    ksp, vsp = np.asarray(ks).copy(), np.asarray(vs).copy()
    for b in range(B):
        for j in range(nb):
            for o in range(bs):
                if j * bs + o >= int(valid[b]):
                    kp[int(bt[b, j]), o] = 127
                    vp[int(bt[b, j]), o] = -127
                    ksp[int(bt[b, j]), o] = 99.0
                    vsp[int(bt[b, j]), o] = 99.0
    out2 = fdk.flash_decode_paged(
        q, jnp.asarray(kp), jnp.asarray(vp), bt, valid,
        k_scale=jnp.asarray(ksp), v_scale=jnp.asarray(vsp))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("B,C,KV,G,D,bs,nb,bc,bks", [
    (1, 8, 1, 1, 16, 8, 4, 8, 0),
    (2, 8, 2, 2, 16, 8, 6, 4, 8),
    (3, 4, 1, 2, 16, 4, 8, 2, 4),
])
def test_flash_prefill_paged_int8_matches_ref(B, C, KV, G, D, bs, nb,
                                              bc, bks):
    """Quantized chunked prefill: the commit kernel's int8 rows and
    scales must be BIT-identical to the ref formula (same quantizer), and
    the attended output must match the dequantizing oracle."""
    n_blocks = 1 + B * nb
    keys = jax.random.split(jax.random.PRNGKey(11), 5)
    kq8, ks = fdr.quantize_rows(
        jax.random.normal(keys[0], (n_blocks, bs, KV, D), jnp.float32))
    vq8, vs = fdr.quantize_rows(
        jax.random.normal(keys[1], (n_blocks, bs, KV, D), jnp.float32))
    perm = np.random.default_rng(11).permutation(np.arange(1, n_blocks))
    bt = jnp.asarray(perm[: B * nb].reshape(B, nb).astype(np.int32))
    k_new = jax.random.normal(keys[2], (B, C, KV, D), jnp.float32)
    v_new = jax.random.normal(keys[3], (B, C, KV, D), jnp.float32)
    starts = np.random.default_rng(12).integers(0, nb * bs - C + 1, B)
    q_start = jnp.asarray(starts.astype(np.int32))
    q = jax.random.normal(keys[4], (B, C, KV, G, D), jnp.float32)
    q_len = jax.random.randint(jax.random.PRNGKey(9), (B,), 1, C + 1)

    out, kp2, vp2, ks2, vs2 = fdk.flash_prefill_paged(
        q, k_new, v_new, kq8, vq8, bt, q_start, q_len,
        k_scale=ks, v_scale=vs, block_c=bc, block_s=bks)
    rout, rkp, rvp, rks, rvs = fdr.prefill_paged_ref(
        q, k_new, v_new, kq8, vq8, bt, q_start, q_len,
        k_scale=ks, v_scale=vs)
    # compare through the block tables: unreferenced blocks are undefined
    for b in range(B):
        for j in range(nb):
            blk = int(bt[b, j])
            np.testing.assert_array_equal(
                np.asarray(kp2)[blk], np.asarray(rkp)[blk],
                err_msg=f"k block {blk}")
            np.testing.assert_array_equal(
                np.asarray(vp2)[blk], np.asarray(rvp)[blk],
                err_msg=f"v block {blk}")
            np.testing.assert_allclose(
                np.asarray(ks2)[blk], np.asarray(rks)[blk], rtol=1e-6,
                err_msg=f"k scale {blk}")
            np.testing.assert_allclose(
                np.asarray(vs2)[blk], np.asarray(rvs)[blk], rtol=1e-6,
                err_msg=f"v scale {blk}")
    for b in range(B):
        n = int(q_len[b])
        np.testing.assert_allclose(
            np.asarray(out)[b, :n], np.asarray(rout)[b, :n],
            rtol=3e-5, atol=3e-5, err_msg=f"slot {b}")


# ---------------------------------------------------------------------------
# QC RX gate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_qubits,qubit", [(8, 0), (8, 4), (8, 7), (12, 6)])
def test_rx_gate_sweep(n_qubits, qubit):
    n = 1 << n_qubits
    re = jax.random.normal(jax.random.PRNGKey(4), (n,), jnp.float32)
    im = jax.random.normal(jax.random.PRNGKey(5), (n,), jnp.float32)
    o_re, o_im = qk.rx_gate(re, im, qubit, 1.1, block_outer=2)
    r_re, r_im = qr.rx_ref(re, im, qubit, 1.1)
    np.testing.assert_allclose(np.asarray(o_re), r_re, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(o_im), r_im, rtol=1e-5, atol=1e-5)


def test_rx_preserves_norm():
    """Unitarity: ||psi|| is invariant under RX."""
    re, im = qops.zero_state(10)
    re = jax.random.normal(jax.random.PRNGKey(6), re.shape, jnp.float32)
    im = jax.random.normal(jax.random.PRNGKey(7), im.shape, jnp.float32)
    norm0 = float(jnp.sum(re**2 + im**2))
    o_re, o_im = qops.rx_layer(re, im, n_qubits=10, theta=0.3)
    norm1 = float(jnp.sum(o_re**2 + o_im**2))
    np.testing.assert_allclose(norm0, norm1, rtol=1e-5)


def test_rx_two_pi_is_minus_identity():
    """RX(2pi) = -I (spin-1/2 phase)."""
    import math

    re, im = qops.zero_state(6)
    o_re, o_im = qk.rx_gate(re, im, 3, 2 * math.pi, block_outer=2)
    np.testing.assert_allclose(np.asarray(o_re), -np.asarray(re), atol=1e-5)
    np.testing.assert_allclose(np.asarray(o_im), -np.asarray(im), atol=1e-5)
