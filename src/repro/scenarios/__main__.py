"""CLI for the scenario-matrix traffic harness.

    python -m repro.scenarios list  [--matrix smoke|full|spec.json] [--only GLOB]
    python -m repro.scenarios run   [--matrix ...] [--only GLOB] [--jobs N]
                                    [--record] [--out report.json]
                                    [--report-md matrix.md] [--no-twin]
    python -m repro.scenarios gate  [--matrix ...] [--only GLOB] [--jobs N]
                                    [--record] [--out ...] [--report-md ...]

``list`` expands the matrix and prints one cell id per line (what
``--only`` globs against).  ``run`` executes every selected cell —
faulted cells also run their fault-free golden twin and diff the served
token streams — checks per-cell SLOs, and with ``--record`` appends one
BenchRun per cell (key ``scenario/<cell_id>``) to the perf ledger so
``python -m repro.perf gate`` enforces the trajectory.  ``run`` exits
non-zero only on cell *errors*; ``gate`` additionally fails on any
golden-twin divergence or SLO violation — the CI contract.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence

from repro.scenarios.matrix import MATRICES, load_matrix
from repro.scenarios.runner import (
    format_matrix_markdown,
    run_matrix,
)


def _add_select(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--matrix", default="smoke",
                    help=f"named matrix ({'/'.join(sorted(MATRICES))}) or a "
                         "JSON MatrixSpec file")
    ap.add_argument("--only", default=None,
                    help="fnmatch glob over cell ids (e.g. '*device-loss')")


def _add_run(ap: argparse.ArgumentParser) -> None:
    _add_select(ap)
    ap.add_argument("--jobs", type=int, default=1,
                    help="cells run concurrently (threads; compiled steps "
                         "are shared per config)")
    ap.add_argument("--record", action="store_true",
                    help="append one BenchRun per cell to the perf ledger")
    ap.add_argument("--no-twin", action="store_true",
                    help="skip golden-twin execution/diffing (faster, "
                         "forfeits the equivalence check)")
    ap.add_argument("--out", default=None,
                    help="write the full matrix report JSON here")
    ap.add_argument("--report-md", default=None,
                    help="write the markdown matrix table here")


def _print_summary(results) -> None:
    for r in results:
        if r.error:
            line = f"ERROR {r.error}"
        else:
            bits = [f"{r.stats.get('tok_s', 0.0):.1f} tok/s",
                    f"util {r.stats.get('slot_utilization', 0.0):.3f}"]
            if r.golden_checked:
                bits.append("twin=" + ("ok" if r.golden_ok else "DIFF"))
            if r.slo_failures:
                bits.append("SLO: " + "; ".join(r.slo_failures))
            line = ", ".join(bits)
        mark = "ok " if r.ok else "FAIL"
        print(f"  [{mark}] {r.cell.cell_id}: {line}")
    print(f"{sum(r.ok for r in results)}/{len(results)} cells ok")


def _run(args: argparse.Namespace, *, strict: bool) -> int:
    spec = load_matrix(args.matrix)
    results = run_matrix(
        spec, only=args.only, jobs=args.jobs,
        check_twin=not args.no_twin, record=args.record,
    )
    if not results:
        print(f"error: no cells match --only {args.only!r}", file=sys.stderr)
        return 2
    _print_summary(results)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"kind": "scenario_matrix",
                       "matrix": args.matrix,
                       "cells": [r.report() for r in results]}, f, indent=1)
        print(f"matrix report -> {args.out}")
    if args.report_md:
        with open(args.report_md, "w") as f:
            f.write(format_matrix_markdown(results))
        print(f"matrix markdown -> {args.report_md}")
    if strict:
        bad = [r for r in results if not r.ok]
        if bad:
            print(f"scenario gate: {len(bad)} failing cell(s)",
                  file=sys.stderr)
            return 1
        print("scenario gate: all cells ok")
        return 0
    return 1 if any(r.error for r in results) else 0


def cmd_list(args: argparse.Namespace) -> int:
    import fnmatch

    spec = load_matrix(args.matrix)
    cells = spec.cells()
    if args.only:
        cells = [c for c in cells if fnmatch.fnmatch(c.cell_id, args.only)]
    for c in cells:
        print(c.cell_id)
    print(f"{len(cells)} cells", file=sys.stderr)
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.scenarios")
    sub = ap.add_subparsers(dest="cmd", required=True)
    _add_select(sub.add_parser("list", help="print matching cell ids"))
    _add_run(sub.add_parser("run", help="run the matrix"))
    _add_run(sub.add_parser(
        "gate", help="run the matrix; fail on twin/SLO/error"))
    args = ap.parse_args(argv)
    if args.cmd == "list":
        return cmd_list(args)
    return _run(args, strict=args.cmd == "gate")


if __name__ == "__main__":
    raise SystemExit(main())
