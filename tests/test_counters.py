"""HLO-artifact counter extraction: collective parsing, shape arithmetic,
MXU flop census — on synthetic HLO text and on a real compiled module."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import counters

SYNTHETIC_HLO = """
ENTRY %main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %p1 = bf16[64,64]{1,0} parameter(1)
  %ag = f32[512,256]{1,0} all-gather(f32[128,256]{1,0} %p0), replica_groups={}, dimensions={0}
  %ar = bf16[64,64]{1,0} all-reduce(bf16[64,64]{1,0} %p1), to_apply=%add
  %rs = f32[32,256]{1,0} reduce-scatter(f32[128,256]{1,0} %p0), dimensions={0}
  %a2a = f32[128,256]{1,0} all-to-all(f32[128,256]{1,0} %p0), dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(f32[128,256]{1,0} %p0), source_target_pairs={{0,1}}
  %ags = (f32[128,256]{1,0}, f32[512,256]{1,0}) all-gather-start(f32[128,256]{1,0} %p0), dimensions={0}
  %dot = f32[128,64]{1,0} dot(f32[128,256]{1,0} %p0, f32[256,64]{1,0} %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_shape_bytes():
    assert counters.shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert counters.shape_bytes("bf16[64,64]") == 64 * 64 * 2
    assert counters.shape_bytes("s32[10]") == 40
    assert counters.shape_bytes("pred[8]") == 8
    # tuples: sum of parts
    assert counters.shape_bytes("(f32[4], bf16[4])") == 16 + 8


def test_parse_collectives_by_kind():
    stats = counters.parse_collectives(SYNTHETIC_HLO)
    f32_row = 128 * 256 * 4
    assert stats.bytes_by_kind["all-reduce"] == 64 * 64 * 2
    assert stats.bytes_by_kind["reduce-scatter"] == f32_row
    assert stats.bytes_by_kind["all-to-all"] == f32_row
    assert stats.bytes_by_kind["collective-permute"] == f32_row
    # all-gather counted once per op (sync + async-start), operand-sized
    assert stats.count_by_kind["all-gather"] == 2
    assert stats.total_count == 6
    assert stats.total_bytes > 0


def test_parse_collectives_ignores_non_collectives():
    stats = counters.parse_collectives("%dot = f32[4,4] dot(f32[4,4] %a, f32[4,4] %b)")
    assert stats.total_count == 0


def test_parse_mxu_flops_dot():
    flops = counters.parse_mxu_flops(SYNTHETIC_HLO)
    # dot: out 128x64, contracted k=256 -> 2*128*64*256
    assert flops == 2 * 128 * 64 * 256


def test_events_from_real_compiled_module():
    """End-to-end on a real XLA:CPU artifact: flops/bytes populated, dot
    census counted; no collectives on a single device."""

    def f(a, b):
        return jnp.tanh(a @ b).sum()

    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    ev = counters.events_from_compiled(compiled, n_devices=1)
    assert ev.flops >= 2 * 256 * 512 * 128 * 0.9
    assert ev.bytes_accessed >= (256 * 512 + 512 * 128) * 4
    assert ev.collective_bytes == 0
    assert ev.census.get("dot", 0) + ev.census.get("fusion", 0) >= 1


def test_vectorizable_fraction():
    ev = counters.Events()
    ev.flops = 100.0
    ev.mxu_flops = 80.0
    assert ev.mxu_fraction == pytest.approx(0.8)
    assert ev.vectorizable_fraction == 1.0  # no serial (fft/sort) flops
    ev.nonvec_flops = 25.0
    assert ev.vectorizable_fraction == pytest.approx(0.75)
    ev.nonvec_flops = 200.0  # overshoot clamps at 0
    assert ev.vectorizable_fraction == 0.0


def test_events_global_normalization():
    """cost_analysis is per-device; Events must be global (x n_devices)."""

    def f(a):
        return a * 2.0

    a = jax.ShapeDtypeStruct((1024,), jnp.float32)
    compiled = jax.jit(f).lower(a).compile()
    ev1 = counters.events_from_compiled(compiled, n_devices=1)
    ev4 = counters.events_from_compiled(compiled, n_devices=4)
    assert ev4.bytes_accessed == pytest.approx(4 * ev1.bytes_accessed)


def test_operand_region_nested_parens():
    line = "%x = f32[8]{0} all-reduce(f32[8]{0} add(f32[8] %a, f32[8] %b)), to_apply=%s"
    m = counters._COLLECTIVE_RE.search(line)
    region = counters._operand_region(line, m.end() - 1)
    assert "f32[8]" in region
    stats = counters.parse_collectives(line)
    assert stats.count_by_kind["all-reduce"] == 1
