"""Prefix-sharing COW engine + quantized paging: the serving contract.

Two independent memory levers, one invariant — neither may change bytes
where it promises not to:

* **Prefix sharing (COW)** changes PLACEMENT only: on bimodal
  shared-prefix traffic the sharing engine must serve token streams
  byte-identical to the sharing-disabled engine across every serve
  architecture, while allocating strictly fewer physical blocks
  (``block_dedup_ratio > 1``).  Preempting one of two sharing slots
  must decref — not free — the shared blocks, leaving the survivor's
  stream untouched (the regression this PR's engine fix pins).
* **Quantized KV (ELEN axis)** changes PRECISION only, and by a bounded
  amount: teacher-forced decode under ``kv_dtype="bf16"/"int8"`` stays
  within a per-arch logit tolerance of the f32 cache (calibrated ~3x
  above measured drift), and a pure-SSM model — which pages no
  attention KV at all — is bit-exact under every kv_dtype.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine
from repro.train import steps as steps_mod

SERVE_ARCHS = (
    "gpt2-124m", "qwen3-1.7b", "mamba2-370m", "deepseek-v2-lite-16b",
    "deepseek-moe-16b", "jamba-1.5-large-398b",
)

_MODELS = {}


def _model(arch):
    if arch not in _MODELS:
        cfg = configs.get_smoke_config(arch)
        _MODELS[arch] = (cfg, steps_mod.init_model(jax.random.PRNGKey(0), cfg))
    return _MODELS[arch]


def _bimodal_prompts(cfg, rng, n=4, prefix_lens=(17, 9), tail_hi=2):
    """Bimodal shared-prefix traffic: two long system prompts, short
    unique tails — the shape prefix caching feeds on."""
    groups = [rng.integers(0, cfg.vocab, size=p).astype(np.int32)
              for p in prefix_lens]
    prompts = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab,
                            size=int(rng.integers(1, tail_hi + 1)))
        prompts.append(np.concatenate([groups[i % len(groups)],
                                       tail.astype(np.int32)]))
    return prompts


def _serve(arch, prompts, *, share, max_new=4, max_batch=4, max_len=64,
           bs=8, hook=None):
    cfg, params = _model(arch)
    eng = ServeEngine(cfg, params, max_batch=max_batch, max_len=max_len,
                      scheduler="continuous", block_size=bs,
                      share_prefixes=share)
    if hook is not None:
        eng.add_step_hook(hook)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=p.copy(), max_new_tokens=max_new))
    eng.run_until_drained()
    return eng


# ---------------------------------------------------------------------------
# differential: COW sharing is byte-invisible across every architecture
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", SERVE_ARCHS)
def test_sharing_streams_identical_fewer_blocks(arch):
    """The COW engine vs the sharing-disabled engine on the same bimodal
    shared-prefix traffic: byte-identical streams, strictly fewer
    physical blocks, dedup ratio > 1 — on dense, GQA, MLA, MoE, SSM and
    hybrid serve paths alike."""
    cfg, _ = _model(arch)
    rng = np.random.default_rng(31)
    prompts = _bimodal_prompts(cfg, rng)
    base = _serve(arch, prompts, share=False)
    shared = _serve(arch, prompts, share=True)
    for uid in range(len(prompts)):
        assert shared.completed[uid].generated == \
            base.completed[uid].generated, f"{arch} req {uid}"
    sb, ss = base.stats(), shared.stats()
    assert ss["physical_blocks"] < sb["physical_blocks"], (
        arch, ss["physical_blocks"], sb["physical_blocks"])
    assert ss["logical_blocks"] == sb["logical_blocks"], arch
    assert ss["shared_block_hits"] > 0 and ss["block_dedup_ratio"] > 1.0
    # the baseline never shares and never forks
    assert sb["shared_block_hits"] == 0 and sb["cow_copies"] == 0
    assert sb["block_dedup_ratio"] == 1.0


def test_identical_prompts_cow_at_first_generated_token():
    """Two byte-identical prompts share EVERY prompt span; the first
    generated token lands in the ragged shared block, so exactly that
    divergence forces COW copies — and the streams still match an
    unshared run."""
    cfg, _ = _model("gpt2-124m")
    rng = np.random.default_rng(32)
    prompt = rng.integers(0, cfg.vocab, size=13).astype(np.int32)
    prompts = [prompt, prompt.copy()]
    base = _serve("gpt2-124m", prompts, share=False, max_new=6)
    shared = _serve("gpt2-124m", prompts, share=True, max_new=6)
    for uid in (0, 1):
        assert shared.completed[uid].generated == \
            base.completed[uid].generated, uid
    s = shared.stats()
    # both slots acquire ceil(13/8)=2 spans; all 4 served, 2 stored...
    assert s["shared_block_hits"] == 2
    # ...until generation diverges the ragged block for one of the twins
    assert s["cow_copies"] >= 1
    assert s["physical_blocks"] < base.stats()["physical_blocks"]


def test_dedup_accounting_flows_to_stats_and_report():
    """stats() exposes the exact counters the ledger ingests, and the
    byte-denominated ratio equals the block-granular one."""
    cfg, _ = _model("gpt2-124m")
    rng = np.random.default_rng(33)
    eng = _serve("gpt2-124m", _bimodal_prompts(cfg, rng), share=True)
    s = eng.stats()
    assert s["share_prefixes"] is True and s["kv_dtype"] == "f32"
    assert s["kv_bytes_served"] > s["kv_bytes_stored"] > 0
    assert s["block_dedup_ratio"] == pytest.approx(
        s["kv_bytes_served"] / s["kv_bytes_stored"])
    assert s["block_dedup_ratio"] == pytest.approx(
        s["logical_blocks"] / s["physical_blocks"])


# ---------------------------------------------------------------------------
# regression: preempting a sharing slot decrefs, never frees
# ---------------------------------------------------------------------------


def test_preempt_shared_slot_leaves_survivor_bit_identical():
    """Preempt one of two slots sharing prefix blocks mid-decode: the
    shared blocks must survive (decref, not free), the survivor's stream
    stays bit-identical, and the preempted request replays identically.
    Before the fix, preempt() freed shared blocks outright and the
    survivor read recycled bytes."""
    cfg, _ = _model("gpt2-124m")
    rng = np.random.default_rng(34)
    prompt = rng.integers(0, cfg.vocab, size=12).astype(np.int32)
    prompts = [prompt, prompt.copy()]
    base = _serve("gpt2-124m", prompts, share=True, max_new=6)

    fired = []

    def hook(engine, busy):
        live = engine._live
        for b, r in enumerate(live["slot_req"]):
            if (not fired and r is not None and r.uid == 1
                    and len(r.generated) >= 2):
                fired.append(engine.preempt(uid=1))
        return False

    faulted = _serve("gpt2-124m", prompts, share=True, max_new=6, hook=hook)
    assert faulted.preemptions == 1 and fired == [1]
    for uid in (0, 1):
        assert faulted.completed[uid].generated == \
            base.completed[uid].generated, uid
    # the replay re-shares the evicted prefix blocks, so dedup persists
    assert faulted.stats()["shared_block_hits"] >= base.stats()[
        "shared_block_hits"]
    assert faulted.stats()["block_dedup_ratio"] > 1.0


def test_stale_partial_tail_after_preempt_serves_clean_streams():
    """THE partial-tail soundness regression, end-to-end: request 0
    registers a 3-token ragged tail, request 1 joins with a 1-token
    strict prefix of it, and request 0 is preempted on the exact step
    request 1 writes its first generated token — so that write lands IN
    PLACE (sole owner, no COW) in rows request 0's registry key still
    claims.  Request 0's replay then presents the very prompt that key
    matches: before the engine trimmed stale keys, the replay aliased
    the diverged block and its prompt write-through overwrote request
    1's live generated rows.  Both streams must stay byte-identical to
    the sharing-disabled fault-free baseline, with zero COWs (nothing in
    this trace legitimately diverges a still-shared block)."""
    cfg, params = _model("gpt2-124m")
    rng = np.random.default_rng(37)
    chain = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    tail = rng.integers(0, cfg.vocab, size=3).astype(np.int32)
    prompts = [np.concatenate([chain, tail]),      # registers rows 0-2
               np.concatenate([chain, tail[:1]])]  # strict-prefix tail
    max_new = {0: 4, 1: 16}

    def run(share, hook=None):
        eng = ServeEngine(cfg, params, max_batch=2, max_len=32,
                          scheduler="continuous", block_size=8,
                          share_prefixes=share)
        if hook is not None:
            eng.add_step_hook(hook)
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=p.copy(),
                               max_new_tokens=max_new[uid]))
        eng.run_until_drained()
        return eng

    fired = []

    def hook(engine, busy):
        live = engine._live
        if live is None or fired:
            return False
        for b, r in enumerate(live["slot_req"]):
            # request 1 at position 9 == its first generated write into
            # row 1 of the shared ragged block, this very step: the
            # preemption decrefs request 0 away first, so the write goes
            # in place under the stale 3-row key
            if r is not None and r.uid == 1 and live["positions"][b] == 9:
                fired.append(engine.preempt(uid=0))
        return False

    base = run(share=False)
    faulted = run(share=True, hook=hook)
    assert fired == [0] and faulted.preemptions == 1
    for uid in (0, 1):
        assert faulted.completed[uid].generated == \
            base.completed[uid].generated, uid
    s = faulted.stats()
    # the full first span re-shares on replay; the diverged ragged claim
    # was trimmed, so the replay allocates fresh instead of COWing a
    # block it was never entitled to share
    assert s["shared_block_hits"] > 0
    assert s["cow_copies"] == 0, (
        "replay aliased a diverged block via a stale partial key"
    )


# ---------------------------------------------------------------------------
# quantized KV: teacher-forced accuracy against the f32 cache
# ---------------------------------------------------------------------------

#: max |logit diff| vs the f32 cache over 14 teacher-forced steps,
#: calibrated ~3x above the measured drift at this exact configuration.
#: mamba2 pages no attention KV, so every kv_dtype must be bit-exact.
_KV_TOL = {
    "gpt2-124m":            {"bf16": 0.02,  "int8": 0.06},
    "qwen3-1.7b":           {"bf16": 0.03,  "int8": 0.11},
    "mamba2-370m":          {"bf16": 0.0,   "int8": 0.0},
    "deepseek-v2-lite-16b": {"bf16": 0.035, "int8": 0.13},
    "deepseek-moe-16b":     {"bf16": 0.035, "int8": 0.12},
    "jamba-1.5-large-398b": {"bf16": 0.01,  "int8": 0.02},
}


def _teacher_forced_logits(arch, kv_dtype, T=14):
    """Decode T forced tokens through a paged cache of the given storage
    dtype; the token stream is FIXED (no argmax feedback), so any
    divergence is pure quantization error, never compounding token
    flips."""
    cfg, params = _model(arch)
    B, max_len, bs = 2, 32, 8
    rng = np.random.default_rng(13)
    toks = rng.integers(0, cfg.vocab, (B, T)).astype(np.int32)
    nb = max_len // bs
    bt = jnp.asarray(
        np.arange(1, 1 + B * nb, dtype=np.int32).reshape(B, nb))
    cache = transformer.init_paged_cache(cfg, B, max_len, bs,
                                         kv_dtype=kv_dtype)
    out = []
    for t in range(T):
        logits, cache = transformer.decode_step_paged(
            params, cfg, jnp.asarray(toks[:, t:t + 1]), cache,
            jnp.full((B,), t, jnp.int32), bt, block_size=bs,
            kv_dtype=kv_dtype)
        out.append(np.asarray(logits))
    return np.stack(out)


@pytest.mark.parametrize("arch", SERVE_ARCHS)
def test_quantized_kv_teacher_forced_within_tolerance(arch):
    f32 = _teacher_forced_logits(arch, "f32")
    for kd in ("bf16", "int8"):
        got = _teacher_forced_logits(arch, kd)
        tol = _KV_TOL[arch][kd]
        if tol == 0.0:
            np.testing.assert_array_equal(got, f32,
                                          err_msg=f"{arch} {kd}")
        else:
            diff = float(np.abs(got - f32).max())
            assert diff <= tol, f"{arch} {kd}: |diff| {diff} > {tol}"


def test_quantized_engine_serves_and_reports_kv_dtype():
    """End-to-end: quantized-paged engines drain real traffic and
    stats() carries the dtype the ledger forks on.  Quantization — unlike
    sharing — is ALLOWED to flip a greedy argmax (that is the ELEN
    trade), so only the bounded claims are pinned: every request drains
    in full, every FIRST token matches f32 (it depends on one prompt
    commit, where the per-row scales are exact to ~1e-2 logits), and
    bf16 tracks f32 token-for-token on this trace."""
    cfg, _ = _model("gpt2-124m")
    rng = np.random.default_rng(35)
    prompts = [rng.integers(0, cfg.vocab, size=int(n)).astype(np.int32)
               for n in (11, 5, 9)]

    def run(kd):
        cfg, params = _model("gpt2-124m")
        eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                          scheduler="continuous", block_size=8,
                          kv_dtype=kd)
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=p.copy(), max_new_tokens=5))
        eng.run_until_drained()
        return eng

    runs = {kd: run(kd) for kd in ("f32", "bf16", "int8")}
    for kd, eng in runs.items():
        assert eng.stats()["kv_dtype"] == kd
        for uid in range(len(prompts)):
            got = eng.completed[uid].generated
            assert len(got) == 5, (kd, uid)
            assert got[0] == runs["f32"].completed[uid].generated[0], (
                kd, uid)
    for uid in range(len(prompts)):  # bf16 drift never flips this trace
        assert runs["bf16"].completed[uid].generated == \
            runs["f32"].completed[uid].generated, uid


def test_quantized_sharing_compose():
    """The two levers compose: int8 pool + prefix sharing still serves
    the exact streams of the f32 unshared baseline on shared traffic."""
    cfg, params = _model("gpt2-124m")
    rng = np.random.default_rng(36)
    prompts = _bimodal_prompts(cfg, rng)

    def run(kd, share):
        eng = ServeEngine(cfg, params, max_batch=4, max_len=64,
                          scheduler="continuous", block_size=8,
                          kv_dtype=kd, share_prefixes=share)
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=p.copy(), max_new_tokens=4))
        eng.run_until_drained()
        return eng

    base = run("f32", False)
    both = run("int8", True)
    for uid in range(len(prompts)):
        assert both.completed[uid].generated == \
            base.completed[uid].generated, uid
    assert both.stats()["block_dedup_ratio"] > 1.0
    assert both.stats()["kv_dtype"] == "int8"
