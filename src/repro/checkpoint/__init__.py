from repro.checkpoint.store import CheckpointStore  # noqa: F401
