"""Serving-path correctness: prefill and token-by-token decode must reproduce
the teacher-forced forward pass for every architecture family (the KV cache,
compressed MLA cache, SSM state handoff, and conv-window handoff are all
exercised by this).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import transformer, whisper as whisper_mod
from repro.train import steps as steps_mod
from tests.conftest import dropless

B, S = 2, 12

DECODER_ARCHS = [a for a in configs.ALL_ARCHS
                 if not configs.get_smoke_config(a).is_encoder_decoder
                 and configs.get_smoke_config(a).family != "vlm"]


def _tol(cfg):
    return dict(rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_prefill_matches_forward(arch):
    cfg = dropless(configs.get_smoke_config(arch))
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full_logits, _ = transformer.forward(params, cfg, tok)
    last_logits, cache = transformer.prefill(params, cfg, tok)
    np.testing.assert_allclose(
        np.asarray(full_logits[:, -1]), np.asarray(last_logits[:, 0]), **_tol(cfg)
    )
    assert int(cache["pos"]) == S


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_decode_loop_matches_forward(arch):
    cfg = dropless(configs.get_smoke_config(arch))
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full_logits, _ = transformer.forward(params, cfg, tok)
    cache = transformer.init_cache(cfg, B, S)
    dec = jax.jit(lambda p, t, c: transformer.decode_step(p, cfg, t, c))
    logits_steps = []
    for i in range(S):
        logits, cache = dec(params, tok[:, i:i + 1], cache)
        logits_steps.append(logits[:, 0])
    # every position must match the teacher-forced logits, not just the last
    dec_logits = jnp.stack(logits_steps, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_logits), np.asarray(dec_logits), **_tol(cfg)
    )


def test_whisper_decode_matches_forward():
    cfg = configs.get_smoke_config("whisper-large-v3")
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    s_enc = 8
    frames = jax.random.normal(
        jax.random.PRNGKey(2), (B, s_enc, cfg.d_model), jnp.dtype(cfg.compute_dtype)
    )
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full_logits, _ = whisper_mod.forward(params, cfg, frames, tok)
    # prefill on the prompt prefix, then decode the last token
    _, cache = whisper_mod.prefill(params, cfg, frames, tok[:, :S - 1])
    logits, cache = whisper_mod.decode_step(params, cfg, tok[:, S - 1:S], cache)
    np.testing.assert_allclose(
        np.asarray(full_logits[:, -1]), np.asarray(logits[:, 0]), rtol=2e-3, atol=2e-3
    )
    assert int(cache["pos"]) == S


def test_vlm_prefill_matches_forward():
    cfg = configs.get_smoke_config("internvl2-76b")
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    img = jax.random.normal(
        jax.random.PRNGKey(2), (B, cfg.n_img_tokens, cfg.d_model),
        jnp.dtype(cfg.compute_dtype),
    )
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full_logits, _ = transformer.forward(params, cfg, tok, img_embeds=img)
    last_logits, cache = transformer.prefill(params, cfg, tok, img_embeds=img)
    np.testing.assert_allclose(
        np.asarray(full_logits[:, -1]), np.asarray(last_logits[:, 0]),
        rtol=2e-3, atol=2e-3,
    )
    assert int(cache["pos"]) == S + cfg.n_img_tokens


def test_decode_cache_dtype_matches_config():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    cache = transformer.init_cache(cfg, B, S)
    k = cache["blocks"]["slot0"]["k"]
    assert k.dtype == jnp.dtype(cfg.compute_dtype)
    assert k.shape == (cfg.n_superblocks, B, S, cfg.n_kv_heads, cfg.head_dim)


def test_mamba_state_is_fp32():
    cfg = configs.get_smoke_config("mamba2-370m")
    cache = transformer.init_cache(cfg, B, S)
    assert cache["blocks"]["slot0"]["ssm_state"].dtype == jnp.float32


def test_moe_capacity_drops_are_the_only_forward_decode_gap():
    """With ample capacity the MoE archs match exactly; with tight capacity
    the gap is real token dropping (documents the semantics)."""
    arch = "deepseek-moe-16b"
    cfg_tight = configs.get_smoke_config(arch)
    cfg_loose = dropless(cfg_tight)
    assert cfg_loose.moe.capacity_factor > cfg_tight.moe.capacity_factor
