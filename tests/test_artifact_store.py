"""The persistent artifact store (repro.analysis.store) + parallel sweeps.

ISSUE-2 contracts: fingerprints are stable across processes (same workload
-> store hit; changed shape/dtype/body -> miss), corrupt cache files are
recovered from (dropped + recompiled, never raised), and a parallel
``analyze_sweep(jobs>1)`` performs exactly one compile per unique workload
while returning results identical to the serial path.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    ArtifactCache,
    ArtifactStore,
    Workload,
    analyze,
    analyze_sweep,
    workload_fingerprint,
)
from repro.analysis.store import fn_token
from repro.core import hw
from repro.core.counters import Events, events_from_analytic

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mm_workload(shape=(64, 64), dtype=jnp.float32, name="store-mm"):
    a = jnp.ones(shape, dtype)
    return Workload(name=name, fn=lambda x: x @ x, args=(a,), dtype="fp32")


# ---------------------------------------------------------------------------
# fingerprints
# ---------------------------------------------------------------------------


def test_fingerprint_stable_for_identical_workloads():
    assert workload_fingerprint(_mm_workload()) == workload_fingerprint(_mm_workload())


def test_fingerprint_changes_with_shape_dtype_body_and_defaults():
    base = workload_fingerprint(_mm_workload())
    assert workload_fingerprint(_mm_workload(shape=(32, 64))) != base
    assert workload_fingerprint(_mm_workload(dtype=jnp.bfloat16)) != base
    a = jnp.ones((64, 64), jnp.float32)
    other_body = Workload(name="store-mm", fn=lambda x: x + x, args=(a,))
    assert workload_fingerprint(other_body) != base
    # default-argument values are behavior too
    d1 = Workload(name="store-mm", fn=lambda x, k=1.0: x * k, args=(a,))
    d2 = Workload(name="store-mm", fn=lambda x, k=2.0: x * k, args=(a,))
    assert workload_fingerprint(d1) != workload_fingerprint(d2)


def test_fn_token_sees_through_jit_and_closures():
    def make(scale):
        return lambda x: x * scale

    assert fn_token(make(2.0)) != fn_token(make(3.0))  # closure value differs
    f = lambda x: x + 1  # noqa: E731
    assert fn_token(jax.jit(f)) == fn_token(jax.jit(f))  # __wrapped__ path


def test_fingerprint_sees_captured_array_shape_and_dtype():
    """Large-array reprs elide shape/dtype, so captured arrays must token
    by abstract signature — different captures must not share events."""
    a = jnp.ones((16,), jnp.float32)

    def make(w):
        return Workload(name="cap", fn=lambda x: x + w, args=(a,))

    base = workload_fingerprint(make(jnp.zeros((2000,), jnp.float32)))
    assert workload_fingerprint(make(jnp.zeros((4000,), jnp.float32))) != base
    assert workload_fingerprint(make(jnp.zeros((2000,), jnp.bfloat16))) != base
    assert workload_fingerprint(make(jnp.zeros((2000,), jnp.float32))) == base


def test_fingerprint_of_partial_bound_callables_and_arrays():
    """functools.partial args route through value tokens: bound callables
    must not embed memory addresses, bound arrays must carry shape/dtype."""
    import functools

    a = jnp.ones((16,), jnp.float32)

    def step(op, x):
        return op(x)

    def double(x):
        return x * 2

    def triple(x):
        return x * 3

    wl_d = Workload(name="part", fn=functools.partial(step, double), args=(a,))
    wl_t = Workload(name="part", fn=functools.partial(step, triple), args=(a,))
    assert workload_fingerprint(wl_d) != workload_fingerprint(wl_t)
    # same bound callable -> stable (no process-local id in the token)
    wl_d2 = Workload(name="part", fn=functools.partial(step, double), args=(a,))
    assert workload_fingerprint(wl_d) == workload_fingerprint(wl_d2)

    def scale(w, x):
        return x * w.sum()

    p1 = Workload(name="part", fn=functools.partial(scale, jnp.zeros((2000,))),
                  args=(a,))
    p2 = Workload(name="part", fn=functools.partial(scale, jnp.zeros((4000,))),
                  args=(a,))
    assert workload_fingerprint(p1) != workload_fingerprint(p2)


def test_cache_memory_keyed_by_content_not_object_identity():
    """Two equal-content Workload objects share one in-memory entry (and
    the cache never pins the request objects themselves)."""
    cache = ArtifactCache()
    analyze(_mm_workload(), hw.GRACE_CORE, cache=cache)
    analyze(_mm_workload(), hw.GRACE_CORE, cache=cache)  # fresh object
    assert cache.compiles == 1 and cache.hits == 1


def test_fingerprint_cross_process_stability(tmp_path):
    """The same source in a fresh interpreter yields the same fingerprint."""
    script = (
        "import jax.numpy as jnp\n"
        "from repro.analysis import Workload, workload_fingerprint\n"
        "a = jnp.ones((64, 64), jnp.float32)\n"
        "wl = Workload(name='store-mm', fn=lambda x: x @ x, args=(a,), dtype='fp32')\n"
        "print(workload_fingerprint(wl))\n"
    )
    env = {**os.environ, "PYTHONPATH": "src"}
    fps = [
        subprocess.run(
            [sys.executable, "-c", script], capture_output=True, text=True,
            env=env, cwd=REPO_ROOT, check=True,
        ).stdout.strip()
        for _ in range(2)
    ]
    assert fps[0] == fps[1]
    assert fps[0] == workload_fingerprint(_mm_workload())


# ---------------------------------------------------------------------------
# store round-trip + corrupt recovery
# ---------------------------------------------------------------------------


def test_events_json_round_trip():
    ev = events_from_analytic(flops=1e9, hbm_bytes=1e6, gather_bytes=3e4,
                              collective_bytes=2e5, n_devices=4)
    ev.nonvec_flops = 1e8
    ev.census = {"dot": 3}
    ev.while_trip_counts = [8, 8]
    back = Events.from_dict(json.loads(json.dumps(ev.to_dict())))
    assert back.to_dict() == ev.to_dict()
    assert back.vectorizable_fraction == ev.vectorizable_fraction


def test_store_put_get_and_stats(tmp_path):
    store = ArtifactStore(str(tmp_path))
    ev = events_from_analytic(flops=2.0, hbm_bytes=4.0)
    assert store.get("feedface") is None and store.misses == 1
    path = store.put("feedface", ev, workload="w")
    assert os.path.exists(path)
    got = store.get("feedface")
    assert got is not None and got.flops == 2.0
    assert store.hits == 1 and store.puts == 1
    assert store.entries() == {"feedface": "w"}
    assert store.clear() == 1


def test_iter_json_enumerates_without_globbing_internals(tmp_path):
    """The listing surface (perf ledger, `repro.tuning --records`): every
    readable entry in deterministic order, corrupt/stale files skipped —
    and never deleted, unlike get_json's self-healing path."""
    store = ArtifactStore(str(tmp_path))
    store.put_json("bb" * 16, {"workload": "b", "x": 2})
    store.put_json("aa" * 16, {"workload": "a", "x": 1})
    (tmp_path / "zz.json").write_text("{truncated")
    (tmp_path / "stale.json").write_text('{"version": 99, "fingerprint": "s"}')
    (tmp_path / "notes.txt").write_text("ignored")
    got = list(store.iter_json())
    assert [fp for fp, _ in got] == ["aa" * 16, "bb" * 16]  # filename-sorted
    assert [p["x"] for _, p in got] == [1, 2]
    assert (tmp_path / "zz.json").exists()  # skip-only: no deletion
    assert store.dropped_corrupt == 0 and store.misses == 0


def test_iter_json_namespace_selects_subdirectory(tmp_path):
    """A root store can list a typed layer's subdirectory (e.g. tuning/)."""
    root = ArtifactStore(str(tmp_path))
    sub = ArtifactStore(str(tmp_path / "tuning"))
    sub.put_json("cc" * 16, {"workload": "gemm", "kind": "tuning"})
    assert list(root.iter_json()) == []
    ((fp, payload),) = list(root.iter_json("tuning"))
    assert fp == "cc" * 16 and payload["workload"] == "gemm"
    assert list(root.iter_json("missing-dir")) == []  # empty, never raises


@pytest.mark.parametrize("garbage", ["{not json", '{"version": 99}', ""])
def test_corrupt_cache_file_recovered(tmp_path, garbage):
    """A corrupt/truncated/stale entry is dropped and recompiled, not raised."""
    store = ArtifactStore(str(tmp_path))
    wl = _mm_workload()
    fp = workload_fingerprint(wl)
    os.makedirs(str(tmp_path), exist_ok=True)
    with open(store.path_for(fp), "w") as f:
        f.write(garbage)
    cache = ArtifactCache(store=store)
    result = analyze(wl, hw.GRACE_CORE, cache=cache)
    assert result.events.flops >= 2 * 64**3  # recompiled, correct events
    assert cache.compiles == 1 and store.dropped_corrupt == 1
    # ... and the recompile healed the store for the next reader
    fresh = ArtifactCache(store=ArtifactStore(str(tmp_path)))
    analyze(_mm_workload(), hw.GRACE_CORE, cache=fresh)
    assert fresh.compiles == 0 and fresh.store_hits == 1


def test_cache_accepts_directory_path_string_as_store(tmp_path):
    """ArtifactCache(store=<str>) means a cache directory, like --store-dir."""
    first = ArtifactCache(store=str(tmp_path))
    analyze(_mm_workload(), hw.GRACE_CORE, cache=first)
    assert first.compiles == 1 and len(first.store.entries()) == 1
    again = ArtifactCache(store=str(tmp_path))
    analyze(_mm_workload(), hw.GRACE_CORE, cache=again)
    assert again.compiles == 0 and again.store_hits == 1


def test_store_hit_matches_compiled_events(tmp_path):
    store = ArtifactStore(str(tmp_path))
    first = ArtifactCache(store=store)
    r1 = analyze(_mm_workload(), hw.GRACE_CORE, cache=first)
    second = ArtifactCache(store=store)
    r2 = analyze(_mm_workload(), hw.GRACE_CORE, cache=second)
    assert second.compiles == 0 and second.store_hits == 1
    assert r2.to_dict() == r1.to_dict()


# ---------------------------------------------------------------------------
# cross-process: second analyze_sweep performs zero compiles
# ---------------------------------------------------------------------------


_SWEEP_SCRIPT = """
import json
from repro.analysis import ArtifactCache, analyze_sweep
from repro.core import hw
cache = ArtifactCache(store="default")
results = analyze_sweep(["kernel/gemm", "kernel/stream-triad"],
                        chips=(hw.GRACE_CORE, hw.TPU_V5E),
                        source="compiled", cache=cache)
print(json.dumps({"compiles": cache.compiles, "store_hits": cache.store_hits,
                  "cells": len(results),
                  "classes": [int(r.perf_class) for r in results]}))
"""


def test_second_sweep_process_performs_zero_compiles(tmp_path):
    """The headline acceptance: a fresh process over the kernel workloads
    gets every artifact from the store."""
    env = {**os.environ, "PYTHONPATH": "src",
           "REPRO_ARTIFACT_DIR": str(tmp_path)}
    runs = []
    for _ in range(2):
        proc = subprocess.run(
            [sys.executable, "-c", _SWEEP_SCRIPT], capture_output=True,
            text=True, env=env, cwd=REPO_ROOT, check=True, timeout=300,
        )
        runs.append(json.loads(proc.stdout.strip().splitlines()[-1]))
    assert runs[0]["compiles"] == 2 and runs[0]["store_hits"] == 0
    assert runs[1]["compiles"] == 0 and runs[1]["store_hits"] == 2
    assert runs[0]["cells"] == runs[1]["cells"] == 4
    assert runs[0]["classes"] == runs[1]["classes"]  # store hit == recompute


# ---------------------------------------------------------------------------
# parallel sweeps
# ---------------------------------------------------------------------------


def _parallel_workloads(n=3):
    a = jnp.ones((48, 48), jnp.float32)
    return [
        Workload(name=f"par-{i}", fn=lambda x, k=float(i): x @ x + k, args=(a,))
        for i in range(n)
    ]


def test_parallel_sweep_compiles_once_per_unique_workload():
    """jobs=4 over 3 workloads x 2 chips: single-flight keeps compiles == 3."""
    wls = _parallel_workloads()
    cache = ArtifactCache()  # memory-only: isolates the single-flight claim
    results = analyze_sweep(
        wls, chips=(hw.GRACE_CORE, hw.TPU_V5E), source="compiled",
        cache=cache, jobs=4,
    )
    assert len(results) == 6
    assert cache.compiles == len(wls)
    assert cache.compiles + cache.hits == 6


def test_parallel_sweep_matches_serial_results():
    wls = _parallel_workloads()
    serial = analyze_sweep(wls, chips=(hw.GRACE_CORE, hw.GRACE_SOCKET),
                           source="compiled", cache=ArtifactCache())
    parallel = analyze_sweep(wls, chips=(hw.GRACE_CORE, hw.GRACE_SOCKET),
                             source="compiled", cache=ArtifactCache(), jobs=4)
    assert [r.to_dict() for r in parallel] == [r.to_dict() for r in serial]


def test_parallel_sweep_with_store_still_single_flight(tmp_path):
    wls = _parallel_workloads()
    cache = ArtifactCache(store=ArtifactStore(str(tmp_path)))
    analyze_sweep(wls, chips=(hw.GRACE_CORE, hw.TPU_V5E), source="compiled",
                  cache=cache, jobs=4)
    assert cache.compiles == len(wls)
    assert len(cache.store.entries()) == len(wls)  # one JSON per workload
