"""System-level behaviour: cell construction, AOT lowering on the host mesh,
artifact analysis, the profiler API, and cell applicability rules."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import SHAPES, ShapeConfig
from repro.core.profiler import Profiler, time_fn
from repro.launch import cells as cells_mod
from repro.launch.mesh import make_host_mesh
from repro.train import steps as steps_mod


def test_cell_matrix_applicability():
    cells = configs.cells()
    names = {(a, s) for a, s in cells}
    # long_500k only for sub-quadratic archs
    assert ("mamba2-370m", "long_500k") in names
    assert ("jamba-1.5-large-398b", "long_500k") in names
    assert ("qwen3-32b", "long_500k") not in names
    assert ("whisper-large-v3", "long_500k") not in names
    # every arch has the other three shapes
    for a in configs.ASSIGNED_ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert (a, s) in names
    assert len(cells) == 10 * 3 + 2


def test_input_specs_are_abstract():
    cfg = configs.get_config("qwen3-32b")
    specs = configs.input_specs(cfg, SHAPES["decode_32k"])
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_inapplicable_cell_raises():
    cfg = configs.get_config("qwen3-32b")
    with pytest.raises(ValueError):
        configs.input_specs(cfg, SHAPES["long_500k"])


@pytest.mark.parametrize(
    "shape_name,kind", [("train_4k", "train"), ("prefill_32k", "prefill"),
                        ("decode_32k", "decode")]
)
def test_build_and_lower_smoke_cell_on_host_mesh(shape_name, kind, monkeypatch):
    """The full build→lower→compile→analyze path, shrunk to the host mesh and
    a smoke config (structurally identical to the 512-device dry-run)."""
    mesh = make_host_mesh()
    arch = "qwen3-1.7b"
    smoke_cfg = configs.get_smoke_config(arch)
    small = ShapeConfig(shape_name, 32, 2, kind)
    monkeypatch.setitem(cells_mod.SHAPES, shape_name, small)
    monkeypatch.setattr(cells_mod.configs, "get_config", lambda a: smoke_cfg)
    cell = cells_mod.build_cell(arch, shape_name, mesh)
    lowered, compiled = cells_mod.lower_cell(cell, mesh)
    result = cells_mod.analyze_cell(cell, mesh, compiled)
    rl = result["roofline"]
    assert rl["flops"] > 0
    assert rl["hbm_bytes"] > 0
    assert rl["dominant"] in ("compute", "memory", "collective")
    assert result["memory_per_device"]["total_gb"] >= 0
    assert result["events"]["n_devices"] == mesh.size
    assert cell.model_flops > 0


def test_run_config_baseline_vs_optimized():
    shape = SHAPES["train_4k"]
    base = cells_mod.run_config_for("qwen3-32b", shape, baseline=True)
    opt = cells_mod.run_config_for("qwen3-32b", shape, baseline=False)
    assert not base.zero and opt.zero
    big = cells_mod.run_config_for("jamba-1.5-large-398b", shape)
    assert big.opt.state_dtype == "bfloat16" and not big.opt.master_weights


def test_profiler_api_roundtrip():
    from repro.core.counters import events_from_analytic

    prof = Profiler()
    prof.configure_measure()
    prof.start_measure()
    _ = float(jnp.sum(jnp.ones((256, 256)) @ jnp.ones((256, 256))))
    prof.stop_measure()
    ev = events_from_analytic(flops=2 * 256**3, hbm_bytes=3 * 256 * 256 * 4)
    m = prof.record("gemm-roi", ev)
    assert m.wall_s > 0
    out = prof.print_results()
    assert "gemm-roi" in out and "VFP_SPEC" in out


def test_profiler_event_group_limit():
    with pytest.raises(ValueError):
        Profiler(events=tuple(f"E{i}" for i in range(7)))


def test_time_fn_meets_paper_methodology():
    calls = []

    def f(x):
        calls.append(1)
        return x * 2

    t = time_fn(f, jnp.ones(16), repeats=5, min_time_s=0.0)
    assert t >= 0
    assert len(calls) >= 6  # warmup + 5 repeats


def test_make_host_mesh_axes():
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.size == len(jax.devices())
