"""Core library: the paper's contribution as composable JAX-side modules.

- hw:            hardware models (Grace SVE-128, TPU v5e/v5p)
- counters:      PMU-analogue events from lowered/compiled XLA artifacts
- metrics:       VB, R_ins_reduction, AI, lane utilization (paper Eq. 1)
- roofline:      adapted roofline (paper Eq. 2) + three-term TPU roofline
- decision_tree: the paper's Fig. 8 four-class classifier
- profiler:      configure/start/stop/print ROI API (paper Sec. 3.1)
"""

from repro.core import hw, counters, metrics, roofline, decision_tree, profiler  # noqa: F401
from repro.core.decision_tree import PerfClass, classify  # noqa: F401
from repro.core.metrics import (  # noqa: F401
    VectorizationReport,
    arithmetic_intensity,
    instruction_reduction,
    vectorization_bound,
)
from repro.core.roofline import adapted_roofline, three_term  # noqa: F401
