"""Qwen2.5-14B — dense, GQA kv=8, QKV bias.

[hf:Qwen/Qwen2.5-0.5B family; hf]  48L, d_model=5120, 40H (GQA kv=8),
d_ff=13824, vocab=152064.  NOTE: 40 heads is NOT divisible by the 16-way
``model`` mesh axis — the baseline sharding pads heads 40->48 under GSPMD
(recorded waste; a hillclimb target, see EXPERIMENTS.md §Perf).
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    qkv_bias=True,
    param_dtype="float32",
    compute_dtype="float32",
)
