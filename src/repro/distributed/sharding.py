"""Divisibility-aware sharding rules: param pytrees -> NamedSharding pytrees.

Megatron-style tensor parallelism over the ``model`` axis:
  * embeddings / lm_head: vocab over ``model``
  * attention q/k/v projections: output (head) dim over ``model``
  * attention output proj / FFN down proj: input dim over ``model``  (row)
  * FFN up/gate: output dim over ``model``  (column)
  * MoE expert stacks (E, d, f): expert dim over ``model``  (EP)
  * Mamba z/x/dt projections + conv + out_proj: d_inner over ``model``
  * everything else (norms, scalars, routers, B/C projections): replicated

A dim is sharded on an axis only if divisible; otherwise the rule falls back
to the next candidate dim or replication (e.g. whisper's 20-head projections
keep the fused output dim sharded because 20*64=1280 divides 16 even though
20 heads alone would not).

Batch ("data"-parallel) sharding of activations uses all of (pod, data);
ZeRO-style optimizer-state sharding adds those axes to the first divisible
replicated dim of each state tensor.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, data_axes

# (path-regex, candidate specs tried in order; first fully-divisible wins).
# Specs name logical roles; `model` is the TP axis.  Regexes match the
# "/"-joined param path, e.g. "blocks/slot0/attn/wq/w".
_RULES = [
    # attention / mla / dense projections  — column-parallel
    (r"(wq|wk|wv|w_uk|w_uv|wz|wx|wdt|lm_head)/w$", [P(None, "model"), P(None, None)]),
    (r"(wq|wk|wv|wz|wx|wdt)/b$", [P("model"), P(None)]),
    # row-parallel (contracting dim sharded)
    (r"(wo|out_proj)/w$", [P("model", None), P(None, None)]),
    (r"(wo|out_proj)/b$", [P(None)]),
    # embeddings: vocab over model
    (r"embed(ding)?s?/embedding$", [P("model", None), P(None, None)]),
    # MoE expert stacks (E, d, f) / (E, f, d): expert-parallel
    (r"moe/(wi_gate|wi_up|wo)$", [P("model", None, None), P(None, None, None)]),
    (r"moe/router$", [P(None, None)]),
    # dense / shared-expert SwiGLU FFN (raw arrays, not {w,b} dicts)
    (r"(ffn|shared)/(wi_gate|wi_up)$", [P(None, "model"), P(None, None)]),
    (r"(ffn|shared)/wo$", [P("model", None), P(None, None)]),
    # mamba conv + small projections
    (r"conv_x_[wb]$", [P(None, "model"), P(None)]),
    (r"conv_BC_[wb]$", [P(None, None), P(None)]),
    (r"wBC/w$", [P(None, None)]),
    (r"wBC/b$", [P(None)]),
    (r"(A_log|D|dt_bias)$", [P(None)]),
    # kv-down (MLA) small projection
    (r"w_dkv/w$", [P(None, None)]),
    # norms and leftovers: replicate
    (r".*", [P(None)]),
]


def _fits(spec: P, shape, mesh: Mesh) -> bool:
    if len(spec) > len(shape):
        return False
    for dim, axes in zip(shape[-len(spec):] if spec else (), spec):
        if axes is None:
            continue
        names = axes if isinstance(axes, tuple) else (axes,)
        size = 1
        for n in names:
            if n not in mesh.axis_names:
                return False
            size *= axis_size(mesh, n)
        if dim % size != 0:
            return False
    return True


def _pad_spec(spec: P, rank: int) -> P:
    """Left-pad with None for stacked leading axes (scan-over-layers)."""
    pad = rank - len(spec)
    return P(*([None] * pad + list(spec)))


def spec_for_path(path: str, shape, mesh: Mesh) -> P:
    for pattern, candidates in _RULES:
        if re.search(pattern, path):
            for cand in candidates:
                if _fits(cand, shape, mesh):
                    return _pad_spec(cand, len(shape))
            return P(*([None] * len(shape)))
    return P(*([None] * len(shape)))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(params: Any, mesh: Mesh):
    """NamedSharding pytree for a model param pytree."""

    def f(path, leaf):
        spec = spec_for_path(_path_str(path), leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(f, params)


def serve_param_shardings(params: Any, mesh: Mesh):
    """:func:`param_shardings` with the serve-path mamba exception.

    On a 2-D mesh (data AND model axes both > 1), model-sharded mamba
    block leaves are partially replicated across the data axis — a layout
    the CPU SPMD partitioner miscompiles inside the selective-scan ops
    (mesh_check caught 2x2 mamba streams diverging where every
    single-axis mesh is byte-exact).  Serving therefore replicates leaves
    under a ``mamba`` path segment on 2-D meshes; attention and MoE
    leaves keep their Megatron split (verified exact at 2x2), and
    single-axis meshes keep full mamba sharding.
    """
    sh = param_shardings(params, mesh)
    m = axis_size(mesh, "model")
    if m <= 1 or mesh.devices.size == m:
        return sh
    rep = NamedSharding(mesh, P())

    def f(path, s):
        return rep if "mamba" in _path_str(path).split("/") else s

    return jax.tree_util.tree_map_with_path(f, sh)


# --------------------------------------------------------------------------
# activations / inputs
# --------------------------------------------------------------------------


def batch_spec(mesh: Mesh, batch: int, rank: int, *, seq_axis: Optional[int] = None,
               seq_len: int = 0) -> P:
    """Shard dim0 (batch) over the data axes; if batch is too small, fall
    back to sharding the sequence dim (long-context decode, batch=1)."""
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= axis_size(mesh, a)
    dims = [None] * rank
    if batch % dp_size == 0:
        dims[0] = dp if len(dp) > 1 else dp[0]
    elif seq_axis is not None and seq_len % dp_size == 0:
        dims[seq_axis] = dp if len(dp) > 1 else dp[0]
    return P(*dims)


def input_shardings(specs: Any, mesh: Mesh, *, batch: int):
    """Shardings for the input_specs pytree (tokens, labels, stubs, caches).

    Caches: batch dim is index 1 (stacked layers lead); when batch doesn't
    divide the data axes (long_500k, B=1), the sequence dim shards instead,
    and SSM states shard their head dim over ``model``.
    """

    def f(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        if "cache" in pstr or "ssm_state" in pstr or "conv_state" in pstr or (
            len(shape) >= 4
        ):
            return NamedSharding(mesh, _cache_spec(pstr, shape, mesh, batch))
        # flat inputs: tokens/labels (B, S), stubs (B, S, d)
        return NamedSharding(mesh, batch_spec(mesh, batch, len(shape)))

    return jax.tree_util.tree_map_with_path(f, specs)


def _cache_spec(pstr: str, shape, mesh: Mesh, batch: int) -> P:
    dp = data_axes(mesh)
    dp_axes = dp if len(dp) > 1 else (dp[0] if dp else None)
    dp_size = 1
    for a in dp:
        dp_size *= axis_size(mesh, a)
    m = axis_size(mesh, "model")
    dims = [None] * len(shape)
    if len(shape) == 0 or "pos" in pstr:
        return P()
    # identify batch axis: stacked caches are (nsb, B, ...), whisper too;
    # non-stacked (first_block) are (B, ...)
    b_axis = 1 if (len(shape) >= 2 and shape[0] != batch and shape[1] == batch) else 0
    if batch % dp_size == 0 and shape[b_axis] == batch:
        dims[b_axis] = dp_axes
        if "ssm_state" in pstr or "conv_state" in pstr:
            # shard heads (ssm) / channels (conv) over model when divisible
            ax = b_axis + 1 if "ssm_state" in pstr else len(shape) - 1
            if shape[ax] % m == 0:
                dims[ax] = "model"
            return P(*dims)
        # attention caches (.., B, S, ...): ALSO shard the long seq dim over
        # `model` — a 549 GB 32k-prefill cache must spread over all chips.
        seq_axis = b_axis + 1
        if len(shape) > seq_axis + 1 and shape[seq_axis] % m == 0:
            dims[seq_axis] = "model"
        return P(*dims)
    # batch too small (long_500k, B=1): shard heads/channels over model for
    # SSM state; shard the seq dim over (data x model) for attention caches
    if "ssm_state" in pstr:
        if shape[b_axis + 1] % m == 0:
            dims[b_axis + 1] = "model"
        return P(*dims)
    if "conv_state" in pstr:
        if shape[-1] % m == 0:
            dims[-1] = "model"
        return P(*dims)
    seq_axis = b_axis + 1
    if len(shape) > seq_axis:
        full = tuple(dp) + ("model",)
        if shape[seq_axis] % (dp_size * m) == 0:
            dims[seq_axis] = full
        elif shape[seq_axis] % dp_size == 0:
            dims[seq_axis] = dp_axes
    return P(*dims)


# --------------------------------------------------------------------------
# paged serve caches (ServeEngine block pools; also the speculative draft's)
# --------------------------------------------------------------------------


def paged_cache_spec(key: str, shape, mesh: Mesh) -> P:
    """PartitionSpec for one leaf of a paged serve cache (by leaf name).

    The block pool is sharded along the *head* axis over ``model`` — the
    block axis stays replicated so any slot's block table can point at any
    physical block without cross-device gathers.  Per-leaf rules:

      * ``k``/``v`` pools ``(nsb, n_blocks, bs, KV, hd)``: KV heads over
        ``model`` when divisible (matches column-parallel wk/wv, so commits
        scatter locally).
      * ``*_scale`` int8 pools: replicated — every device holds the full
        per-row fp32 scale pool (scales are per token row, not per head, so
        each head shard needs all of them; a few bytes/row).
      * MLA ``c``/``k_rope`` latent pools: replicated — the latent cache is
        per-token, not per-head; the head split lives in the absorbed
        w_uk/w_uv projections, which the param rules already shard.
      * ``ssm_state`` ``(nsb, B, H, d_state, hd)``: heads over ``model``,
        slots over the data axes (the recurrence is elementwise per slot).
      * ``conv_state`` ``(nsb, B, d_conv-1, ch)``: channels over ``model``
        (aligned with the column-parallel conv_x/wx), slots over data.

    Every rule is divisibility-gated with replication as the fallback, so
    sharding is pure placement — never semantics.
    """
    m = axis_size(mesh, "model")
    dp = data_axes(mesh)
    dp_axes = dp if len(dp) > 1 else (dp[0] if dp else None)
    dp_size = 1
    for a in dp:
        dp_size *= axis_size(mesh, a)
    dims = [None] * len(shape)
    if key.endswith("_scale"):
        return P(*dims)
    if key in ("k", "v") and len(shape) >= 4:
        head_ax = len(shape) - 2  # (..., n_blocks, bs, KV, hd)
        if shape[head_ax] % m == 0:
            dims[head_ax] = "model"
        return P(*dims)
    # SSM leaves shard only on a SINGLE-axis mesh: partially-replicated
    # mamba scan operands (a leaf sharded on one axis of a 2-D mesh,
    # replicated on the other) miscompile under the CPU SPMD partitioner
    # — mesh_check caught 2x2 streams diverging where 2x1/1x2 were exact —
    # so on 2-D meshes the recurrent state stays replicated (placement
    # only; the attention pools still split).
    flat = mesh.devices.size
    if key == "ssm_state" and len(shape) == 5:
        if m > 1 and flat == m and shape[2] % m == 0:
            dims[2] = "model"
        elif dp and flat == dp_size and shape[1] % dp_size == 0:
            dims[1] = dp_axes
        return P(*dims)
    if key == "conv_state" and len(shape) == 4:
        if m > 1 and flat == m and shape[-1] % m == 0:
            dims[-1] = "model"
        elif dp and flat == dp_size and shape[1] % dp_size == 0:
            dims[1] = dp_axes
        return P(*dims)
    return P(*dims)  # MLA latent pools and anything unrecognized: replicate


def paged_cache_shardings(cache: Any, mesh: Mesh):
    """NamedSharding pytree for a `transformer.init_paged_cache` pytree.

    Applies equally to the target cache and the speculative draft's cache
    (the draft is attention-only, so only the k/v + scale rules fire).
    """

    def f(path, leaf):
        key = _path_str(path).split("/")[-1]
        return NamedSharding(mesh, paged_cache_spec(key, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(f, cache)


def replicated(mesh: Mesh) -> NamedSharding:
    """The replicated sharding — host-side slot accounting (positions,
    block tables, free list, sampler inputs) lives identically on every
    device; only pools and params split."""
    return NamedSharding(mesh, P())


# --------------------------------------------------------------------------
# ZeRO optimizer-state sharding
# --------------------------------------------------------------------------


def zero_shard_spec(param_spec: P, shape, mesh: Mesh) -> P:
    """Add the data axes to the first unsharded, divisible dim (ZeRO-1/3)."""
    dp = data_axes(mesh)
    if not dp:
        return param_spec
    dp_size = 1
    for a in dp:
        dp_size *= axis_size(mesh, a)
    dims = list(param_spec) + [None] * (len(shape) - len(param_spec))
    for i, (d, s) in enumerate(zip(shape, dims)):
        if s is None and d % dp_size == 0 and d > 0:
            dims[i] = dp if len(dp) > 1 else dp[0]
            return P(*dims)
    return P(*dims)


def opt_state_shardings(params, p_shardings, mesh: Mesh, *, zero: bool = True):
    """Shardings for AdamW state (m, v, master) mirroring param shapes."""

    def f(p_leaf, s_leaf):
        if not zero:
            return s_leaf
        spec = zero_shard_spec(s_leaf.spec, p_leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(f, params, p_shardings)
