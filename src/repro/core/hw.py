"""Hardware models for the adapted roofline / vectorization-bound analysis.

The paper (ARM SVE Unleashed) parameterizes its analysis by three hardware
quantities: the vector length VLEN, the peak compute throughput, and the peak
memory bandwidth.  We keep that parameterization but provide two concrete
machine models:

* ``GRACE`` — the paper's platform (Neoverse V2, 128-bit SVE), used by the
  paper-validation benchmarks so the analytic reproduction matches the paper's
  own numbers.
* ``TPU_V5E`` — the target platform for the framework.  The TPU has two
  data-parallel engines: the VPU (8x128 lanes of 32-bit) and the MXU (128x128
  systolic array, bf16-native).  "Vector length" on TPU is per-issue lane
  count x element bits; element-size packing (fp32 -> bf16 -> int8) plays the
  role the paper assigns to ELEN.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip hardware model used by the roofline and VB metrics."""

    name: str
    # Peak dense compute throughput per chip, FLOP/s, keyed by element type.
    peak_flops: Mapping[str, float]
    # Peak HBM/DRAM bandwidth per chip, bytes/s.
    hbm_bw: float
    # Inter-chip interconnect bandwidth per link, bytes/s (0 for single-socket).
    ici_bw_per_link: float
    # Number of ICI links per chip that can be driven concurrently.
    ici_links: int
    # Native vector width in bits for the vector (non-matrix) engine.
    vlen_bits: int
    # Memory transaction granule in bytes (cache line / HBM burst).
    transaction_bytes: int
    # MXU dims (0 if no matrix engine).
    mxu_dim: int = 0

    def peak(self, dtype: str = "bf16") -> float:
        if dtype not in self.peak_flops:
            raise KeyError(
                f"{self.name}: no peak for dtype {dtype!r}; "
                f"have {sorted(self.peak_flops)}"
            )
        return self.peak_flops[dtype]

    def ici_bw(self) -> float:
        return self.ici_bw_per_link * max(self.ici_links, 1)


#: Element sizes in bits for the dominant data formats (paper's ELEN).
ELEN_BITS: Mapping[str, int] = {
    "fp64": 64,
    "f64": 64,
    "float64": 64,
    "fp32": 32,
    "f32": 32,
    "float32": 32,
    "tf32": 32,
    "bf16": 16,
    "fp16": 16,
    "f16": 16,
    "float16": 16,
    "bfloat16": 16,
    "int8": 8,
    "s8": 8,
    "fp8": 8,
    "int4": 4,
}


def elen_bits(dtype: str) -> int:
    key = str(dtype).lower()
    if key not in ELEN_BITS:
        raise KeyError(f"unknown element type {dtype!r}")
    return ELEN_BITS[key]


# --- The paper's platform: Nvidia Grace (Neoverse V2), 128-bit SVE -----------
# Peak FP64/chip-core: 4 FPU pipes x 2 FLOP (FMA) x 2 lanes (128b/64b) x 3.447GHz.
# We model a single core (the paper's single-thread analysis) and the full
# 72-core socket; STREAM-measured bandwidth per the paper: 30 GB/s @1T,
# 250 GB/s @72T.
_GRACE_CORE_FP64_SCALAR = 4 * 2 * 3.447e9  # 4 pipes, FMA, scalar (1 elem)

GRACE_CORE = ChipSpec(
    name="grace-core",
    peak_flops={
        # scalar baseline (vectorization disabled) — 1 element per issue
        "scalar_fp64": _GRACE_CORE_FP64_SCALAR,
        "scalar_fp32": _GRACE_CORE_FP64_SCALAR,
        # vectorized peaks = scalar x VB
        "fp64": _GRACE_CORE_FP64_SCALAR * 2,
        "fp32": _GRACE_CORE_FP64_SCALAR * 4,
        "fp16": _GRACE_CORE_FP64_SCALAR * 8,
        # Neoverse V2 SVE carries the BF16 extension (BFDOT/BFMMLA); same
        # 16-bit lane packing as fp16 — needed by the ELEN-packing tuning axis
        "bf16": _GRACE_CORE_FP64_SCALAR * 8,
    },
    hbm_bw=30e9,  # single-thread STREAM triad (paper Sec. 3)
    ici_bw_per_link=0.0,
    ici_links=0,
    vlen_bits=128,
    transaction_bytes=64,  # LLC line (paper Sec. 5: 64-byte line)
)

GRACE_SOCKET = dataclasses.replace(
    GRACE_CORE,
    name="grace-socket-72c",
    peak_flops={k: v * 72 for k, v in GRACE_CORE.peak_flops.items()},
    hbm_bw=250e9,  # 72-thread STREAM triad (paper Sec. 3)
)


# --- Target platform: TPU v5e ------------------------------------------------
# Constants fixed by the assignment: 197 TFLOP/s bf16/chip, 819 GB/s HBM,
# ~50 GB/s/link ICI.  fp32 matmul runs the MXU in passes -> 1/2 bf16; int8 2x.
# The VPU is (8 sublanes x 128 lanes) of 32-bit elements per issue.
TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops={
        "bf16": 197e12,
        "fp32": 98.5e12,
        "int8": 394e12,
        # scalar-equivalent baseline: one element per issue slot at VPU clock.
        # 197e12 / (2 flop/MAC) / (128*128 MACs) ~= 6.0e9 issue slots/s; the
        # scalar model charges 2 FLOP per slot.
        "scalar": 197e12 / (128 * 128),
    },
    hbm_bw=819e9,
    ici_bw_per_link=50e9,
    ici_links=4,
    vlen_bits=8 * 128 * 32,  # one VPU vreg issue: 8x128 lanes x 32-bit
    transaction_bytes=512,
    mxu_dim=128,
)

TPU_V5P = ChipSpec(
    name="tpu-v5p",
    peak_flops={
        "bf16": 459e12,
        "fp32": 229.5e12,
        "int8": 918e12,
        "scalar": 459e12 / (128 * 128),
    },
    hbm_bw=2765e9,
    ici_bw_per_link=100e9,
    ici_links=6,
    vlen_bits=8 * 128 * 32,
    transaction_bytes=512,
    mxu_dim=128,
)

DEFAULT_CHIP = TPU_V5E

CHIPS: Mapping[str, ChipSpec] = {
    "grace-core": GRACE_CORE,
    "grace-socket": GRACE_SOCKET,
    "tpu-v5e": TPU_V5E,
    "tpu-v5p": TPU_V5P,
}


def get_chip(name: str) -> ChipSpec:
    if name not in CHIPS:
        raise KeyError(f"unknown chip {name!r}; have {sorted(CHIPS)}")
    return CHIPS[name]
