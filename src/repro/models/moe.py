"""Mixture-of-Experts FFN: shared + fine-grained routed experts (DeepSeekMoE).

Dispatch is GShard-style with a capacity factor: tokens are scattered into an
(E, C, d) expert buffer (position = rank of the token among the expert's
assignments, computed with an exclusive cumsum over the one-hot assignment
matrix), processed with batched expert GEMMs, and gathered back weighted by
the normalized router gates.  Overflow beyond capacity is dropped (standard
for capacity-based MoE).

Two distribution layouts (selected by the active MeshPlan):

* **global** (paper-faithful baseline): one (E, C, d) buffer over the GLOBAL
  token set.  Under pjit the scatter crosses the data sharding of tokens and
  the model sharding of experts, so GSPMD materializes and all-reduces the
  whole buffer — measured 237 TB/step of all-reduce on
  deepseek-moe-16b@train_4k (EXPERIMENTS.md §Perf).

* **hierarchical** (optimized): tokens are first split (Z, T/Z, d) with Z =
  the data-axis size, constrained so dim 0 lies on the data axes; dispatch
  runs per shard (vmapped) into a (Z, E, C_local, d) buffer.  Expert GEMMs
  batch over Z (data-sharded) x E (model-sharded) with a LOCAL contraction —
  the scatter never crosses a sharding boundary, and the only cross-shard
  movement left is the return-path combine (a TP-sized all-reduce).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import context as mesh_ctx
from repro.models import layers


def init_moe(key, cfg, dtype) -> dict:
    m, d = cfg.moe, cfg.d_model
    k_router, k_routed, k_shared = jax.random.split(key, 3)
    ks = jax.random.split(k_routed, 3)
    e, f = m.n_routed, m.d_ff_expert
    p = {
        "router": layers.truncated_normal(k_router, (d, e), 1.0, jnp.float32),
        "wi_gate": layers.truncated_normal(ks[0], (e, d, f), 1.0, dtype),
        "wi_up": layers.truncated_normal(ks[1], (e, d, f), 1.0, dtype),
        "wo": layers.truncated_normal(ks[2], (e, f, d), 1.0, dtype),
    }
    if m.n_shared > 0:
        p["shared"] = layers.swiglu_init(k_shared, d, m.n_shared * f, dtype)
    return p


def _capacity(m, T: int) -> int:
    C = int(math.ceil(m.top_k * T / m.n_routed * m.capacity_factor))
    return max(8, -(-C // 8) * 8)  # round up to sublane multiple


def _route(params, m, xf):
    """(T, d) -> gates (T,K), idx (T,K), aux scalar."""
    E, K = m.n_routed, m.top_k
    T = xf.shape[0]
    logits = xf.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gates, idx = jax.lax.top_k(probs, K)  # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance aux (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)
    return gates, idx, aux


def _dispatch(m, xf, idx, C: int):
    """Scatter tokens into the (E, C, d) buffer; returns (buf, slot, keep)."""
    E, K = m.n_routed, m.top_k
    T, d = xf.shape
    e_flat = idx.reshape(T * K)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)  # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # exclusive cumsum
    pos = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]  # (T*K,)
    keep = pos < C
    slot = jnp.where(keep, e_flat * C + pos, E * C)  # OOB -> dropped
    x_rep = jnp.broadcast_to(xf[:, None, :], (T, K, d)).reshape(T * K, d)
    buf = jnp.zeros((E * C, d), xf.dtype).at[slot].add(
        jnp.where(keep[:, None], x_rep, 0), mode="drop"
    )
    return buf.reshape(E, C, d), slot, keep


def _combine(out_buf, slot, keep, gates, T: int, K: int, d: int, dtype):
    """Gather expert outputs back to token order, gate-weighted."""
    E_C = out_buf.shape[0] * out_buf.shape[1]
    y_rep = jnp.take(
        out_buf.reshape(E_C, d), jnp.minimum(slot, E_C - 1), axis=0
    )
    y_rep = jnp.where(keep[:, None], y_rep, 0)
    w = gates.reshape(T * K).astype(dtype)
    return (y_rep * w[:, None]).reshape(T, K, d).sum(axis=1)


def _expert_gemms(params, buf, dtype):
    """Batched expert SwiGLU; buf (..., E, C, d) -> (..., E, C, d)."""
    g = jnp.einsum("...ecd,edf->...ecf", buf, params["wi_gate"].astype(dtype))
    u = jnp.einsum("...ecd,edf->...ecf", buf, params["wi_up"].astype(dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...ecf,efd->...ecd", h, params["wo"].astype(dtype))


def moe_ffn(params, cfg, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).  aux = Switch-style load-balance loss."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    plan = mesh_ctx.current()
    if (plan.moe_impl == "shard_map" and plan.mesh is not None
            and B % max(plan.n_data, 1) == 0
            and m.n_routed % max(plan.n_model, 1) == 0):
        return _moe_ffn_shard_map(params, cfg, x, plan)
    Z = plan.n_data if plan.moe_hierarchical else 1
    # B % Z: token shards must coincide with the batch sharding, otherwise
    # the (Z, T/Z) split would cut across sequences on other data shards
    if Z > 1 and B % Z == 0 and (T // Z) >= m.top_k:
        return _moe_ffn_hierarchical(params, cfg, x, plan)

    xf = x.reshape(T, d)
    gates, idx, aux = _route(params, m, xf)
    C = _capacity(m, T)
    buf, slot, keep = _dispatch(m, xf, idx, C)
    out_buf = _expert_gemms(params, buf, x.dtype)
    y = _combine(out_buf, slot, keep, gates, T, m.top_k, d, x.dtype)
    if m.n_shared > 0:
        y = y + layers.swiglu(params["shared"], xf)
    return y.reshape(B, S, d), aux


def _moe_ffn_hierarchical(params, cfg, x, plan) -> Tuple[jax.Array, jax.Array]:
    """Per-data-shard dispatch: (Z, T_local, d) buffers, local scatters,
    (Z x E)-batched expert GEMMs.  See module docstring."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    Z = plan.n_data
    Tl = T // Z
    xf = x.reshape(Z, Tl, d)
    xf = mesh_ctx.constrain(xf, P(plan.dp, None, None))

    gates, idx, aux = jax.vmap(lambda xs: _route(params, m, xs))(xf)
    C = _capacity(m, Tl)
    buf, slot, keep = jax.vmap(lambda xs, ix: _dispatch(m, xs, ix, C))(xf, idx)
    # buf (Z, E, C, d): Z on the data axes, E on the model axis; the GEMM
    # contraction (d) is fully local on every shard.
    buf = mesh_ctx.constrain(buf, P(plan.dp, plan.model_axis, None, None))
    out_buf = _expert_gemms(params, buf, x.dtype)
    out_buf = mesh_ctx.constrain(out_buf, P(plan.dp, plan.model_axis, None, None))
    y = jax.vmap(
        lambda ob, sl, kp, gt: _combine(ob, sl, kp, gt, Tl, m.top_k, d, x.dtype)
    )(out_buf, slot, keep, gates)
    y = mesh_ctx.constrain(y, P(plan.dp, None, None))
    if m.n_shared > 0:
        y = y + jax.vmap(lambda xs: layers.swiglu(params["shared"], xs))(xf)
    return y.reshape(B, S, d), aux.mean()


def _moe_ffn_shard_map(params, cfg, x, plan) -> Tuple[jax.Array, jax.Array]:
    """Expert parallelism under shard_map (iteration 3, EXPERIMENTS.md §Perf).

    Per device: tokens are data-sharded and model-replicated, so every model
    rank REDUNDANTLY computes routing + the full (E, C_local, d) scatter
    (cheap elementwise work), then slices only ITS E/n_model experts — zero
    communication for dispatch.  Each rank K-sums the combine for its local
    experts and ONE psum over the model axis crosses the EP boundary:
    (T_local, d) bf16 per layer, vs the (T_local*K, d) fp32 all-reduces
    GSPMD emits for the global layout (measured 98 TB -> ~8 TB per step on
    deepseek-moe-16b@train_4k).
    """
    from jax.experimental.shard_map import shard_map

    m = cfg.moe
    B, S, d = x.shape
    E, K = m.n_routed, m.top_k
    n_model = plan.n_model
    E_loc = E // n_model
    dp = plan.dp
    model = plan.model_axis

    def per_device(wi_gate, wi_up, wo, router, xs):
        Bl = xs.shape[0]
        Tl = Bl * S
        xf = xs.reshape(Tl, d)
        p_local = {"router": router}
        gates, idx, aux = _route(p_local, m, xf)
        C = _capacity(m, Tl)
        buf, slot, keep = _dispatch(m, xf, idx, C)  # (E, C, d), local
        # my expert shard: dynamic slice at my model coordinate (free:
        # buf is computed model-replicated)
        e0 = jax.lax.axis_index(model) * E_loc if model else 0
        buf_loc = jax.lax.dynamic_slice_in_dim(buf, e0, E_loc, axis=0)
        p_exp = {"wi_gate": wi_gate, "wi_up": wi_up, "wo": wo}
        out_loc = _expert_gemms(p_exp, buf_loc, xs.dtype)  # (E_loc, C, d)
        # local combine: keep only assignments routed to MY experts
        mine = keep & (slot >= e0 * C) & (slot < (e0 + E_loc) * C)
        y_rep = jnp.take(
            out_loc.reshape(E_loc * C, d),
            jnp.clip(slot - e0 * C, 0, E_loc * C - 1), axis=0,
        )
        y_rep = jnp.where(mine[:, None], y_rep, 0)
        w = gates.reshape(Tl * K).astype(xs.dtype)
        y_part = (y_rep * w[:, None]).reshape(Tl, K, d).sum(axis=1)
        # the ONLY cross-device step: EP combine, bf16 (T_local, d)
        y = jax.lax.psum(y_part, model) if model else y_part
        if plan.data_axes:
            aux = jax.lax.pmean(aux, plan.data_axes)
        return y.reshape(Bl, S, d), aux

    specs_in = (
        P(model, None, None),  # wi_gate (E, d, f) -> E over model
        P(model, None, None),
        P(model, None, None),
        P(None, None),         # router replicated
        P(dp, None, None),     # x: batch over data axes
    )
    fn = shard_map(
        per_device, mesh=plan.mesh,
        in_specs=specs_in,
        out_specs=(P(dp, None, None), P()),
        check_rep=False,
    )
    y, aux = fn(params["wi_gate"], params["wi_up"], params["wo"],
                params["router"], x)
    if m.n_shared > 0:
        # shared expert OUTSIDE the shard_map: its wi/wo are TP-sharded by
        # the param rules, so GSPMD column/row-parallelizes it — inside the
        # shard_map it would run model-replicated (measured 16x redundant
        # compute, the dominant term of iteration 3a)
        y = y + layers.swiglu(params["shared"], x.reshape(B * S, d)).reshape(B, S, d)
    return y, aux
