"""Roofline-guided kernel autotuning with persistent tuning records.

The paper's analytic machinery (Eq. 1 vectorization bound, Eq. 2 adapted
roofline) applied as a *search pruner*: instead of hand-picked static block
shapes, every registry kernel carries a :class:`TuningSpace` (block/tile
axes + the ELEN-packing dtype axis), :func:`tune` discards candidates the
roofline + VMEM models already rule out, times only the survivors, and
persists the winner as a content-addressed :class:`TuningRecord` — so
repeat processes re-tune **zero** times, mirroring the analysis pipeline's
zero-recompile artifact store.

    from repro.tuning import tune

    record = tune("gemm")            # prune -> time -> persist -> apply
    record = tune("gemm")            # store hit: cached=True, no timing

    from repro.kernels.registry import get_kernel
    get_kernel("gemm")               # repr shows the active tuned config

CLI: ``python -m repro.tuning --help`` (writes a machine-readable
``tuning.json``); ``python -m benchmarks.run --tune`` runs the same sweep
before the benchmark suite.  See ``docs/TUNING.md`` for the executable
guide.
"""

from repro.tuning.space import (  # noqa: F401
    TuningSpace,
    predicted_config_time_s,
    predicted_time_s,
)
from repro.tuning.records import (  # noqa: F401
    TUNING_VERSION,
    TuningRecord,
    default_tuning_dir,
    default_tuning_store,
    load_record,
    save_record,
    tuning_fingerprint,
)
from repro.tuning.tune import (  # noqa: F401
    format_records,
    load_tuned,
    outlook,
    prune,
    report_dict,
    timing_runs,
    tunable_kernels,
    tune,
    tune_kernels,
)
