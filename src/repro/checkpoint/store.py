"""Step-atomic sharded checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json        — step, flat param paths, shapes/dtypes, data step
            arrays.npz           — one entry per flat path (this host's arrays)
            _COMMITTED           — written last; restore ignores dirs without it

* **Atomicity**: the commit marker makes a half-written checkpoint (node
  failure mid-save) invisible to restore — restart picks the newest
  committed step.
* **Elastic restore**: arrays are loaded host-side and ``jax.device_put``
  against *target* shardings, so a run checkpointed on a 16x16 mesh restores
  onto 2x16x16 (or a single CPU) unchanged — resharding happens at placement.
* **Async**: ``save(..., blocking=False)`` hands the host arrays to a writer
  thread; training continues while the previous step serializes.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def _unflatten_like(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(
        self,
        step: int,
        state: Dict[str, Any],
        *,
        extra: Optional[Dict[str, Any]] = None,
        blocking: bool = True,
    ) -> str:
        self.wait()
        host_arrays = {
            k: np.asarray(jax.device_get(v)) for k, v in _flatten(state).items()
        }
        manifest = {
            "step": int(step),
            "extra": extra or {},
            "arrays": {
                k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                for k, a in host_arrays.items()
            },
        }
        path = os.path.join(self.dir, f"step_{step:08d}")

        def write():
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host_arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)
            with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
                f.write("ok")
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return path

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def committed_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "_COMMITTED")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        template: Any,
        *,
        step: Optional[int] = None,
        shardings: Any = None,
    ) -> Tuple[int, Any, Dict[str, Any]]:
        """Load a checkpoint into ``template``'s structure.

        ``shardings`` (optional pytree of NamedSharding) triggers elastic
        placement onto the *current* mesh regardless of the saving mesh.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten_like(template, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda a, s: jax.device_put(a, s), state, shardings
            )
        else:
            state = jax.tree.map(jax.numpy.asarray, state)
        return manifest["step"], state, manifest.get("extra", {})
