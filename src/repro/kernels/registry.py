"""Kernel registry: one jit-wrapper factory for every Pallas kernel.

This is the paper's Sec. 3.2 micro-benchmark suite (GEMM, STREAM, SpMV,
Jacobi2D, the QC RX gate, flash-decode) behind one registration surface.
Each ``kernels/<pkg>/ops.py`` used to hand-roll the same
``functools.partial(jax.jit, static_argnames=(..., "interpret"))`` wrapper.
:func:`register_kernel` replaces those six copies with one factory that
returns a :class:`KernelOps` exposing the call surfaces:

* ``op(*args)``        — default call (interpret-mode Pallas, CPU-safe);
* ``op.kernel(*args)`` — compiled Pallas path (``interpret=False``);
* ``op.interpret(*args)`` — explicit interpret-mode path;
* ``op.ref(*args)``    — the pure-jnp/numpy oracle.

Registration also auto-registers the kernel as a :class:`~repro.analysis.
workload.Workload` (name ``kernel/<name>``) with a small example problem
and the ref module's analytic flops/bytes model (paper Sec. 3.3), so every
kernel is reachable through ``repro.analysis.analyze`` with zero extra
wiring — and, when a :class:`~repro.tuning.space.TuningSpace` is attached,
through the roofline-guided autotuner (``repro.tuning``): after a
``tune()`` the ops object resolves its best-known block config at call
time, with explicit keyword arguments always winning.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.analysis.workload import Workload, register_lazy
from repro.tuning import spaces as _spaces
from repro.tuning.space import TuningSpace, canonical_dtype


class KernelOps:
    """Call surface for one registered kernel (ref / kernel / interpret).

    When a :class:`TuningSpace` is attached and a tuned config is active
    (installed by ``repro.tuning.tune``/``load_tuned``), calls resolve the
    tuned static arguments automatically: the config is validated against
    the actual call arguments (clamp + divisibility) and merged only for
    keywords the caller did not pass — explicit kwargs always win.
    """

    def __init__(
        self,
        name: str,
        kernel_fn: Callable,
        ref_fn: Optional[Callable] = None,
        *,
        static_argnums: Tuple[int, ...] = (),
        static_argnames: Tuple[str, ...] = (),
        tuning_space: Optional[TuningSpace] = None,
    ) -> None:
        self.name = name
        self.raw = kernel_fn
        self._ref = ref_fn
        self.tuning_space = tuning_space
        self._tuned: Dict[Tuple[str, str], Dict[str, Any]] = {}
        self._active: Optional[Tuple[str, str]] = None
        names = tuple(static_argnames)
        if "interpret" not in names:
            names = names + ("interpret",)
        self._jit = jax.jit(
            kernel_fn, static_argnums=static_argnums or None, static_argnames=names
        )
        functools.update_wrapper(self, kernel_fn, updated=())

    # -- tuned-config state --------------------------------------------------

    def set_tuned(
        self,
        config: Dict[str, Any],
        *,
        chip: str = "",
        dtype: str = "",
        activate: bool = True,
    ) -> None:
        """Install a best-known config for (chip, dtype); ``activate`` makes
        it the one calls resolve (most-recent-tune-wins semantics)."""
        key = (chip, dtype)
        self._tuned[key] = dict(config)
        if activate:
            self._active = key

    def tuned_config(
        self, chip: Optional[str] = None, dtype: Optional[str] = None
    ) -> Optional[Dict[str, Any]]:
        """The active tuned config (no args), or the one for (chip, dtype)."""
        if chip is None and dtype is None:
            if self._active is None:
                return None
            return dict(self._tuned[self._active])
        cfg = self._tuned.get((chip or "", dtype or ""))
        return dict(cfg) if cfg is not None else None

    def clear_tuned(self) -> None:
        self._tuned.clear()
        self._active = None

    def load_tuned(self, **kw: Any):
        """Pick up a persisted TuningRecord for this kernel (zero timing);
        see :func:`repro.tuning.load_tuned` for the keyword surface."""
        from repro.tuning import load_tuned

        return load_tuned(self, **kw)

    @property
    def fingerprint_extra(self) -> str:
        """Behavioral state the artifact fingerprint must see: an active
        tuned config changes what a call lowers to."""
        if self._active is None:
            return ""
        cfg = self._tuned.get(self._active)
        return f"tuned:{sorted(cfg.items())!r}" if cfg else ""

    def _resolve_active(self, args: Tuple) -> Optional[Dict[str, Any]]:
        """The config to resolve for THIS call: prefer the entry tuned for
        the call's element type (a multi-dtype sweep leaves one config per
        dtype), falling back to the most recently activated one."""
        if self._active is None:
            return None
        chip, _ = self._active
        for a in args:
            dt = getattr(a, "dtype", None)
            if dt is not None:
                cfg = self._tuned.get((chip, canonical_dtype(dt)))
                if cfg is not None:
                    return cfg
                break
        return self._tuned.get(self._active)

    def _tuned_kwargs(self, args: Tuple, kw: Dict[str, Any]) -> Dict[str, Any]:
        """Merge the active tuned config into ``kw`` for keys the caller
        did not pass, after re-validating it against these arguments.

        Validation sees the call as it would actually execute: caller-passed
        axis values override the tuned ones (explicit kwargs win), and only
        the surviving tuned keys are merged.
        """
        cfg = self._resolve_active(args)
        if not cfg:
            return kw
        space = self.tuning_space
        if space is not None:
            view = {**cfg, **{k: v for k, v in kw.items() if k in space.axes}}
            extra = {
                k: v for k, v in kw.items()
                if k != "interpret" and k not in space.axes
            }
            try:
                valid = space.validate(view, args, extra=extra)
            except Exception:
                valid = None
            if valid is None:  # the call's config does not fit: fall back
                return kw
            cfg = valid
        for k, v in cfg.items():
            kw.setdefault(k, v)
        return kw

    # -- call surfaces -------------------------------------------------------

    def __call__(self, *args: Any, **kw: Any):
        kw.setdefault("interpret", True)
        kw = self._tuned_kwargs(args, kw)
        return self._jit(*args, **kw)

    def kernel(self, *args: Any, **kw: Any):
        kw["interpret"] = False
        kw = self._tuned_kwargs(args, kw)
        return self._jit(*args, **kw)

    def interpret(self, *args: Any, **kw: Any):
        kw["interpret"] = True
        kw = self._tuned_kwargs(args, kw)
        return self._jit(*args, **kw)

    def lower(self, *args: Any, **kw: Any):
        """AOT-lower the (interpret-mode by default) jitted kernel.

        Exposing ``lower`` lets the analysis pipeline compile a kernel
        workload directly instead of re-wrapping it in ``jax.jit`` — which
        would turn the static arguments into tracers.  The active tuned
        config is resolved here too (``fingerprint_extra`` keeps the
        artifact store's content addresses distinct per config).
        """
        kw.setdefault("interpret", True)
        kw = self._tuned_kwargs(args, kw)
        return self._jit.lower(*args, **kw)

    def ref(self, *args: Any, **kw: Any):
        if self._ref is None:
            raise NotImplementedError(f"kernel {self.name!r} has no ref oracle")
        return self._ref(*args, **kw)

    def __repr__(self) -> str:
        if self._active is not None and self._tuned.get(self._active):
            chip, dtype = self._active
            cfg = " ".join(
                f"{k}={v}" for k, v in sorted(self._tuned[self._active].items())
            )
            where = f" @ {chip}/{dtype}" if (chip or dtype) else ""
            return f"KernelOps({self.name!r}, tuned[{cfg}]{where})"
        return f"KernelOps({self.name!r})"


KERNELS: Dict[str, KernelOps] = {}

# kernel workload builders, kept so registration can be re-applied after
# repro.analysis.clear_registry() (module import side effects only run once)
_WORKLOAD_BUILDERS: Dict[str, Callable[[], Workload]] = {}


def register_builtin_workloads() -> None:
    """(Re-)register every kernel workload; idempotent discovery hook."""
    for wl_name, builder in _WORKLOAD_BUILDERS.items():
        register_lazy(wl_name, builder, tags=("kernel",), replace=True)


def register_kernel(
    name: str,
    kernel: Optional[Callable] = None,
    *,
    ref: Optional[Callable] = None,
    static_argnums: Tuple[int, ...] = (),
    static_argnames: Tuple[str, ...] = (),
    workload: Optional[Callable[[], Workload]] = None,
    tuning_space: Optional[TuningSpace] = None,
):
    """Register a kernel entry point; usable directly or as a decorator.

    ``workload`` is a zero-arg builder returning the kernel's example
    Workload; it is registered lazily as ``kernel/<name>`` so importing the
    registry never constructs example arrays.  ``tuning_space`` declares
    the kernel's tunable static arguments for ``repro.tuning``.
    """

    def _do(fn: Callable) -> KernelOps:
        if name in KERNELS:
            raise ValueError(f"kernel {name!r} already registered")
        ops = KernelOps(
            name,
            fn,
            ref,
            static_argnums=static_argnums,
            static_argnames=static_argnames,
            tuning_space=tuning_space,
        )
        KERNELS[name] = ops
        if workload is not None:
            _WORKLOAD_BUILDERS[f"kernel/{name}"] = workload
            register_lazy(f"kernel/{name}", workload, tags=("kernel",),
                          replace=True)
        return ops

    if kernel is not None:
        return _do(kernel)
    return _do


def get_kernel(name: str) -> KernelOps:
    if name not in KERNELS:
        raise KeyError(f"unknown kernel {name!r}; have {sorted(KERNELS)}")
    return KERNELS[name]


def list_kernels() -> list:
    return sorted(KERNELS)


# ---------------------------------------------------------------------------
# The six kernel packages
# ---------------------------------------------------------------------------

from repro.kernels.flash_decode import kernel as _fd_k, ref as _fd_r  # noqa: E402
from repro.kernels.gemm import kernel as _gemm_k, ref as _gemm_r  # noqa: E402
from repro.kernels.jacobi2d import kernel as _jac_k, ref as _jac_r  # noqa: E402
from repro.kernels.qc_gate import kernel as _qc_k, ref as _qc_r  # noqa: E402
from repro.kernels.spmv import kernel as _spmv_k, ref as _spmv_r  # noqa: E402
from repro.kernels.stream import kernel as _stream_k, ref as _stream_r  # noqa: E402


def _gemm_workload() -> Workload:
    import jax.numpy as jnp

    n = 256
    fb = _gemm_r.flops_bytes(n, n, n, 4)

    def args():
        x = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
        y = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
        return (x, y)

    return Workload(
        name="kernel/gemm", fn=GEMM, args=args, dtype="fp32",
        flops=fb["flops"], hbm_bytes=fb["bytes"],
        problem=f"{n}^2", tags=("kernel",),
        notes="MXU-tiled Pallas GEMM; compute-bound Class 4",
    )


def _stream_workload() -> Workload:
    import jax.numpy as jnp

    rows, cols = 2048, 128
    fb = _stream_r.flops_bytes("triad", rows * cols, 4)

    def args():
        a = jnp.ones((rows, cols), jnp.float32)
        b = jnp.ones((rows, cols), jnp.float32)
        return (a, b, 3.0)

    return Workload(
        name="kernel/stream-triad", fn=STREAM_TRIAD, args=args, dtype="fp32",
        flops=fb["flops"], hbm_bytes=fb["bytes"],
        problem=f"{rows}x{cols}", tags=("kernel",),
        notes="McCalpin triad; streaming memory-bandwidth-bound Class 2",
    )


def _spmv_workload() -> Workload:
    import numpy as np

    n = 512

    def args():
        vals, cols, nnz = _spmv_r.make_problem(
            jax.random.PRNGKey(0), n, n, row_block=8, max_nnz=64, width_pad=128
        )
        x = jax.random.normal(jax.random.PRNGKey(1), (n,), vals.dtype)
        return (vals, cols, nnz, x)

    # per-nnz accounting (same model as spmv/ops.flops_bytes): 2 FLOPs per
    # nonzero; traffic = val + colidx + gathered x, the x reads being the
    # latency-bound pointer-chasing share
    nnz_np = np.asarray(
        _spmv_r.make_problem(
            jax.random.PRNGKey(0), n, n, row_block=8, max_nnz=64, width_pad=128
        )[2]
    )
    total_nnz = float(nnz_np.sum())
    return Workload(
        name="kernel/spmv", fn=SPMV, args=args, dtype="fp32",
        flops=2.0 * total_nnz, hbm_bytes=total_nnz * (4 + 4 + 4),
        gather_bytes=total_nnz * 4,
        problem=f"{n}^2 zipf", tags=("kernel",),
        notes="predicated block-ELL SpMV; pointer-chasing Class 3",
    )


def _jacobi_workload() -> Workload:
    import jax.numpy as jnp

    n = 256
    fb = _jac_r.flops_bytes(n, n, 4)

    def args():
        return (jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32),)

    return Workload(
        name="kernel/jacobi2d", fn=JACOBI_STEP, args=args, dtype="fp32",
        flops=fb["flops"], hbm_bytes=fb["bytes"],
        problem=f"{n}^2", tags=("kernel",),
        notes="5-point stencil sweep; memory-bound Class 2",
    )


def _qc_workload() -> Workload:
    import jax.numpy as jnp

    n_qubits = 14
    fb = _qc_r.flops_bytes(n_qubits, 4)

    def args():
        n_amp = 1 << n_qubits
        re = jnp.zeros((n_amp,), jnp.float32).at[0].set(1.0)
        im = jnp.zeros((n_amp,), jnp.float32)
        return (re, im)

    def one_gate(re, im):
        return RX_GATE(re, im, qubit=0, theta=0.25)

    return Workload(
        name="kernel/qc-gate", fn=one_gate, args=args, dtype="fp32",
        flops=fb["flops"], hbm_bytes=fb["bytes"],
        problem=f"{n_qubits} qubits", tags=("kernel",),
        notes="single RX gate over the state vector; streaming Class 2",
    )


def _flash_prefill_workload() -> Workload:
    import jax.numpy as jnp
    import numpy as np

    B, C, KV, G, D = 2, 16, 2, 4, 16
    bs, nb = 8, 8  # 64-token view per slot
    q_start = (24, 0)
    fb = _fd_r.prefill_flops_bytes(B, C, KV, G, D, q_start, dtype_bytes=4)

    def args():
        n_blocks = 1 + B * nb
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        q = jax.random.normal(ks[0], (B, C, KV, G, D), jnp.float32)
        kn = jax.random.normal(ks[1], (B, C, KV, D), jnp.float32)
        vn = jax.random.normal(ks[2], (B, C, KV, D), jnp.float32)
        kp = jax.random.normal(ks[3], (n_blocks, bs, KV, D), jnp.float32)
        vp = jax.random.normal(ks[4], (n_blocks, bs, KV, D), jnp.float32)
        bt = 1 + np.arange(B * nb, dtype=np.int32).reshape(B, nb)
        return (q, kn, vn, kp, vp, jnp.asarray(bt),
                jnp.asarray(q_start, jnp.int32))

    def one_chunk(q, kn, vn, kp, vp, bt, qs):
        return FLASH_PREFILL(q, kn, vn, kp, vp, bt, qs, block_c=8)[0]

    return Workload(
        name="kernel/flash-prefill", fn=one_chunk, args=args, dtype="fp32",
        flops=fb["flops"], hbm_bytes=fb["bytes"],
        problem=f"B{B} C{C} KV{KV} G{G} D{D} bs{bs}", tags=("kernel",),
        notes="chunked causal prefill committing K/V into paged blocks",
    )


def _flash_decode_workload() -> Workload:
    import jax.numpy as jnp

    B, KV, G, D, S = 2, 2, 4, 16, 64
    valid = (40, 64)
    fb = _fd_r.flops_bytes(B, KV, G, D, valid, dtype_bytes=4)

    def args():
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, KV, G, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
        vl = jnp.asarray(valid, jnp.int32)
        return (q, k, v, vl)

    def one_step(q, k, v, vl):
        return FLASH_DECODE(q, k, v, vl, block_s=16)

    return Workload(
        name="kernel/flash-decode", fn=one_step, args=args, dtype="fp32",
        flops=fb["flops"], hbm_bytes=fb["bytes"],
        problem=f"B{B} KV{KV} G{G} D{D} S{S}", tags=("kernel",),
        notes="predicated KV-cache attention decode; GQA reuse lifts AI",
    )


GEMM = register_kernel(
    "gemm", _gemm_k.gemm,
    ref=_gemm_r.gemm_ref,
    static_argnames=("bm", "bn", "bk"),
    workload=_gemm_workload,
    tuning_space=_spaces.gemm_space(),
)

STREAM_COPY = register_kernel(
    "stream-copy", _stream_k.stream_copy,
    ref=_stream_r.copy_ref,
    static_argnames=("block_rows",),
    tuning_space=_spaces.stream_space(n_arrays=1, flops_per_elem=0.0),
)
STREAM_SCALE = register_kernel(
    "stream-scale", _stream_k.stream_scale,
    ref=_stream_r.scale_ref,
    static_argnums=(1,), static_argnames=("block_rows",),
    tuning_space=_spaces.stream_space(n_arrays=1, flops_per_elem=1.0),
)
STREAM_ADD = register_kernel(
    "stream-add", _stream_k.stream_add,
    ref=_stream_r.add_ref,
    static_argnames=("block_rows",),
    tuning_space=_spaces.stream_space(n_arrays=2, flops_per_elem=1.0),
)
STREAM_TRIAD = register_kernel(
    "stream-triad", _stream_k.stream_triad,
    ref=_stream_r.triad_ref,
    static_argnums=(2,), static_argnames=("block_rows",),
    workload=_stream_workload,
    tuning_space=_spaces.stream_space(n_arrays=2, flops_per_elem=2.0),
)

SPMV = register_kernel(
    "spmv", _spmv_k.spmv_blockell,
    ref=_spmv_r.spmv_ref,
    static_argnames=("repeat",),
    workload=_spmv_workload,
)
SPMV_FIXED = register_kernel(
    "spmv-fixed-width", _spmv_k.spmv_fixed_width,
    ref=_spmv_r.spmv_ref,
)

JACOBI_STEP = register_kernel(
    "jacobi2d", _jac_k.jacobi_step,
    ref=_jac_r.jacobi_ref,
    static_argnames=("block_rows",),
    workload=_jacobi_workload,
    tuning_space=_spaces.jacobi2d_space(),
)

RX_GATE = register_kernel(
    "qc-gate", _qc_k.rx_gate,
    static_argnames=("qubit", "theta", "block_outer"),
    workload=_qc_workload,
    tuning_space=_spaces.qc_gate_space(),
)

FLASH_DECODE = register_kernel(
    "flash-decode", _fd_k.flash_decode,
    ref=_fd_r.decode_ref,
    static_argnames=("block_s",),
    workload=_flash_decode_workload,
    tuning_space=_spaces.flash_decode_space(),
)

FLASH_PREFILL = register_kernel(
    "flash-prefill", _fd_k.flash_prefill_paged,
    ref=_fd_r.prefill_paged_ref,
    static_argnames=("block_c", "block_s"),
    workload=_flash_prefill_workload,
    tuning_space=_spaces.flash_prefill_space(),
)
