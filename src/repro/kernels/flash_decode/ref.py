"""Oracle + analytic terms for the flash-decode kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_ref(q, k, v, valid_len):
    """q (B,KV,G,D); k/v (B,S,KV,D); valid_len (B,) -> (B,KV,G,D)."""
    B, KV, G, D = q.shape
    S = k.shape[1]
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(D)
    mask = jnp.arange(S)[None, :] < valid_len[:, None]  # (B, S)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_paged_ref(q, k_pool, v_pool, block_tables, valid_len):
    """Paged oracle: gather each slot's logical view, then run the dense
    reference.  q (B,KV,G,D); k/v_pool (n_blocks, bs, KV, D); block_tables
    (B, nb); valid_len (B,) with every live slot >= 1."""
    B = q.shape[0]
    nb = block_tables.shape[1]
    bs = k_pool.shape[1]
    k = k_pool[block_tables].reshape(B, nb * bs, *k_pool.shape[2:])
    v = v_pool[block_tables].reshape(B, nb * bs, *v_pool.shape[2:])
    return decode_ref(q, k, v, valid_len)


def flops_bytes(B, KV, G, D, valid_len, dtype_bytes: int = 2) -> dict:
    """Per decode step: 2*2*H*D flops per live cache token; traffic = live
    K+V reads (the q/output traffic is negligible)."""
    live = float(sum(int(v) for v in valid_len))
    flops = 4.0 * KV * G * D * live
    bytes_ = 2.0 * KV * D * dtype_bytes * live
    return {"flops": flops, "bytes": bytes_, "ai": flops / bytes_ if bytes_ else 0}


def issue_counts(valid_len, S: int, block_s: int) -> dict:
    """Predicated vs fixed-width block issues (the SVE lesson at token level)."""
    import math as m

    pred = sum(m.ceil(max(int(v), 1) / block_s) for v in valid_len)
    fixed = len(valid_len) * (S // block_s)
    return {"predicated": pred, "fixed": fixed,
            "r_issue": fixed / pred if pred else 0.0}
