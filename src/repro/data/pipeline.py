"""Synthetic, deterministic, *stateless* data pipeline.

Batches are a pure function of (seed, step): restart/elastic-resume needs to
checkpoint only the integer step — no iterator state, no host-local files.
Each host materializes only its shard of the global batch (``host_slice``),
which is how the pipeline scales to thousands of nodes: the global batch is
never resident on any single host.

Token streams are Zipf-distributed (more realistic router/vocab pressure for
MoE than uniform); modality stubs (audio frames / image patch embeddings)
are unit-Gaussian, matching ``input_specs``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2  # token distribution skew


def _rng(cfg: DataConfig, step: int, role: str) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, hash(role) % (2**31)])
    )


def _tokens(rng, shape, vocab: int, a: float) -> np.ndarray:
    z = rng.zipf(a, size=shape).astype(np.int64)
    return ((z - 1) % vocab).astype(np.int32)


def global_batch(
    model_cfg: ModelConfig,
    shape: ShapeConfig,
    data_cfg: DataConfig,
    step: int,
    *,
    host_slice: Optional[slice] = None,
) -> Dict[str, Any]:
    """Materialize (a host's slice of) the training batch for ``step``."""
    B, S = shape.global_batch, shape.seq_len
    sl = host_slice or slice(0, B)
    nb = sl.stop - sl.start
    out: Dict[str, Any] = {}
    rng = _rng(data_cfg, step, "tokens")
    if model_cfg.is_encoder_decoder:
        s_enc = max(S // 4, 8)
        frames_rng = _rng(data_cfg, step, "frames")
        all_tokens = _tokens(rng, (B, S + 1), model_cfg.vocab, data_cfg.zipf_a)
        out["enc_frames"] = frames_rng.standard_normal(
            (nb, s_enc, model_cfg.d_model), dtype=np.float32
        )
        out["tokens"] = all_tokens[sl, :-1]
        out["labels"] = all_tokens[sl, 1:]
        return out
    if model_cfg.family == "vlm":
        n_img = model_cfg.n_img_tokens
        s_text = S - n_img
        img_rng = _rng(data_cfg, step, "img")
        all_tokens = _tokens(rng, (B, s_text + 1), model_cfg.vocab, data_cfg.zipf_a)
        out["img_embeds"] = img_rng.standard_normal(
            (nb, n_img, model_cfg.d_model), dtype=np.float32
        )
        out["tokens"] = all_tokens[sl, :-1]
        out["labels"] = all_tokens[sl, 1:]
        return out
    all_tokens = _tokens(rng, (B, S + 1), model_cfg.vocab, data_cfg.zipf_a)
    out["tokens"] = all_tokens[sl, :-1]
    out["labels"] = all_tokens[sl, 1:]
    return out


def host_slice_for(process_index: int, process_count: int, global_batch_size: int) -> slice:
    per = global_batch_size // process_count
    return slice(process_index * per, (process_index + 1) * per)
