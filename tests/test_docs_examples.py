"""Execute every ```python block in the user-facing docs.

The ISSUE-2 contract: documented snippets cannot drift from the API.  Each
doc's blocks run top-to-bottom in one shared namespace (so later blocks may
use names defined earlier, exactly as a reader would paste them).  Blocks
fenced as ```bash (or any non-python language) are ignored; a block
preceded by an HTML comment containing ``no-doctest`` is skipped.
"""

import pathlib
import re

import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

DOCS = [
    "README.md",
    "docs/METHOD.md",
    "docs/ARCHITECTURE.md",
    "docs/TUNING.md",
    "docs/PERF.md",
    "docs/SERVING.md",
    "docs/SCENARIOS.md",
]

_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_SKIP_MARK = "no-doctest"


def python_blocks(text: str):
    """(start_line, source) for every executable ```python block."""
    out = []
    for m in _BLOCK_RE.finditer(text):
        preceding = text[: m.start()].rstrip().rsplit("\n", 1)[-1]
        if _SKIP_MARK in preceding and preceding.lstrip().startswith("<!--"):
            continue
        line = text[: m.start()].count("\n") + 2  # first line inside fence
        out.append((line, m.group(1)))
    return out


@pytest.mark.parametrize("doc", DOCS)
def test_doc_python_blocks_execute(doc):
    path = REPO_ROOT / doc
    assert path.exists(), f"{doc} missing"
    blocks = python_blocks(path.read_text())
    assert blocks, f"{doc} has no ```python blocks to verify"
    ns: dict = {"__name__": f"doctest_{path.stem}"}
    for line, src in blocks:
        code = compile(src, f"{doc}:{line}", "exec")
        try:
            exec(code, ns)  # noqa: S102 — executing our own documentation
        except Exception as e:
            pytest.fail(f"{doc} block at line {line} failed: {e!r}")


def test_readme_links_docs():
    """README's repo map must point at the method/architecture/tuning docs."""
    readme = (REPO_ROOT / "README.md").read_text()
    assert "docs/METHOD.md" in readme
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/TUNING.md" in readme
    assert "docs/PERF.md" in readme
    assert "docs/SERVING.md" in readme
    assert "docs/SCENARIOS.md" in readme
