"""Per-slot token selection for the serving engine: greedy or sampled.

One helper replaces the three argmax sites the schedulers used to carry
separately.  :meth:`SlotSampler.select` takes the fused step's logits
``(B, W, vocab_padded)`` plus the slot->request map and returns host
token ids ``(B, W)`` in ONE device transfer — greedy at ``temperature
== 0`` (bit-identical to the old ``jnp.argmax`` sites), temperature /
top-k sampling otherwise.

Sampling is *canonical-stream*: the PRNG key for a token is derived
solely from ``(seed, request.uid, generation_index)`` — never from the
slot, the step count, or the scheduler.  A request therefore owns one
reproducible token stream: re-running the same traffic through a
different scheduler, after a preemption replay, or under speculative
decoding reads the same keys at the same generation indices and (given
bit-identical logits) emits the same tokens.  Speculative decoding
leans on this hardest — the draft model proposes with the SAME keys the
target uses to verify, so at 100% logit agreement every proposal is
accepted, and any rejection re-samples the same index from the same key
on the next step.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=None)
def _jit_greedy(vocab: int):
    """Argmax over the unpadded vocab for every logit row — exactly the
    expression the schedulers used inline, so temp=0 streams are bitwise
    unchanged by the refactor."""
    return jax.jit(lambda rows: jnp.argmax(rows[..., :vocab], axis=-1))


@functools.lru_cache(maxsize=None)
def _jit_sample(vocab: int, temperature: float, top_k: int, seed: int):
    """Temperature/top-k categorical sampling with per-(uid, index) keys.

    Row ``(b, i)`` is sampled with key ``fold_in(fold_in(key(seed),
    uids[b]), idx0[b] + i)`` — position ``i`` inside the fed window maps
    to generation index ``idx0[b] + i``, which is what makes multi-token
    (speculative) windows read the same stream as one-token decode.
    """
    def fn(rows, uids, idx0):
        B, W, _ = rows.shape
        logits = rows[..., :vocab].astype(jnp.float32) / temperature
        if 0 < top_k < vocab:
            kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
            logits = jnp.where(logits < kth, -jnp.inf, logits)
        base = jax.random.PRNGKey(seed)
        flat_u = jnp.repeat(uids, W)
        flat_i = (
            idx0[:, None] + jnp.arange(W, dtype=jnp.uint32)[None, :]
        ).reshape(-1)
        keys = jax.vmap(
            lambda u, i: jax.random.fold_in(jax.random.fold_in(base, u), i)
        )(flat_u, flat_i)
        toks = jax.vmap(jax.random.categorical)(
            keys, logits.reshape(B * W, vocab)
        )
        return toks.reshape(B, W)

    return jax.jit(fn)


class SlotSampler:
    """Token selection policy for one engine: vocab + temperature +
    top-k + seed, with the compiled select function shared across
    engines via the module-level ``lru_cache`` factories."""

    def __init__(self, vocab: int, *, temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0):
        if vocab < 1:
            raise ValueError(f"vocab must be >= 1, got {vocab}")
        if temperature < 0.0:
            raise ValueError(
                f"temperature must be >= 0 (0 = greedy), got {temperature}"
            )
        if top_k < 0:
            raise ValueError(
                f"top_k must be >= 0 (0 = full vocab), got {top_k}"
            )
        self.vocab = int(vocab)
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.seed = int(seed)
        #: greedy engines skip the uid/index plumbing entirely
        self.greedy = self.temperature == 0.0
        if self.greedy:
            self._fn = _jit_greedy(self.vocab)
        else:
            self._fn = _jit_sample(
                self.vocab, self.temperature, self.top_k, self.seed
            )

    def select(self, rows: jax.Array, reqs: Sequence[Optional[object]] = (),
               *, offset: int = 0) -> np.ndarray:
        """Pick one token per logit row — ``rows`` is ``(B, W, >=vocab)``
        from the fused step, ``reqs`` maps slot -> request (``None`` for
        idle slots; any object with ``.uid`` and ``.generated`` works).

        Row ``(b, i)`` is treated as generation index
        ``len(reqs[b].generated) + offset + i`` of request ``reqs[b]``
        (``offset`` shifts the whole window — draft round ``i`` of
        speculative decoding proposes index ``gi + i`` before anything
        is appended).  Rows of idle/irrelevant slots are selected too
        and simply discarded by the caller; their keys can never collide
        with a live stream's.  Returns ``(B, W)`` int64 host tokens via
        a single device transfer.
        """
        if self.greedy:
            return np.asarray(self._fn(rows))
        uids = np.array(
            [0 if r is None else int(r.uid) for r in reqs], np.int64
        ).astype(np.uint32)
        idx0 = np.array(
            [0 if r is None else len(r.generated) + offset for r in reqs],
            np.int64,
        ).astype(np.uint32)
        return np.asarray(
            self._fn(rows, jnp.asarray(uids), jnp.asarray(idx0))
        )
