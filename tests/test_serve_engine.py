"""Serving engine contract.

* Both schedulers must match single-request greedy decoding token-for-token.
* On ragged prompts with early EOS the continuous scheduler must produce
  IDENTICAL per-request tokens to ``scheduler="wave"`` while spending
  strictly fewer fused decode steps at strictly higher slot utilization
  (the Eq. 1 predication win at the serving layer).
* Finished slots refill mid-flight and their paged-cache blocks are
  recycled across requests.
* Oversized requests fail typed at submit(); the drain-loop cap is exact.
"""

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.core import metrics as core_metrics
from repro.serve.engine import Request, RequestTooLong, ServeEngine
from repro.train import steps as steps_mod


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("gpt2-124m")
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_single(cfg, params, prompt, max_new):
    """Reference: unbatched greedy decode."""
    engine = ServeEngine(cfg, params, max_batch=1, max_len=96)
    engine.submit(Request(uid=0, prompt=prompt, max_new_tokens=max_new))
    return engine.run_until_drained()[0].generated


@pytest.mark.parametrize("scheduler", ["continuous", "wave"])
def test_batched_matches_single(setup, scheduler):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(3, 12)))
               .astype(np.int32) for _ in range(3)]
    singles = [_greedy_single(cfg, params, p, 6) for p in prompts]

    engine = ServeEngine(cfg, params, max_batch=3, max_len=96,
                         scheduler=scheduler)
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, prompt=p, max_new_tokens=6))
    done = engine.run_until_drained()
    for uid in range(3):
        assert done[uid].generated == singles[uid], (
            f"req {uid}: batched {done[uid].generated} != single {singles[uid]}"
        )


def test_queue_drains_multiple_waves(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    engine = ServeEngine(cfg, params, max_batch=2, max_len=64,
                         scheduler="wave")
    for uid in range(5):
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, size=4).astype(np.int32),
            max_new_tokens=3,
        ))
    done = engine.run_until_drained()
    assert len(done) == 5
    assert all(len(r.generated) == 3 for r in done.values())


def test_eos_stops_generation(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
    # find what greedy emits first, then set that token as EOS
    first = _greedy_single(cfg, params, prompt, 1)[0]
    engine = ServeEngine(cfg, params, max_batch=1, max_len=64)
    engine.submit(Request(uid=0, prompt=prompt, max_new_tokens=8, eos_id=first))
    done = engine.run_until_drained()
    assert done[0].generated == [first]


# ---------------------------------------------------------------------------
# continuous vs wave: the golden-equivalence + predication-win contract
# ---------------------------------------------------------------------------


def test_continuous_matches_wave_with_fewer_steps(setup):
    """Ragged prompts (4-17 tokens) + one early-EOS request: identical
    per-request tokens, strictly fewer fused steps, strictly higher slot
    utilization under the continuous scheduler."""
    cfg, params = setup
    # seed 9 -> prompt lengths [9, 15, 16, 7, 5, 11]: FIFO waves of 2 pair
    # short with long, so lockstep idles finished slots badly
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(4, 17)))
               .astype(np.int32) for _ in range(6)]
    # request 0 hits EOS on its very first generated token
    eos0 = _greedy_single(cfg, params, prompts[0], 1)[0]

    engines = {}
    for sched in ("wave", "continuous"):
        eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                          scheduler=sched, block_size=8)
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=p.copy(), max_new_tokens=6,
                               eos_id=eos0 if uid == 0 else -1))
        eng.run_until_drained()
        engines[sched] = eng

    wave, cont = engines["wave"], engines["continuous"]
    assert len(cont.completed) == len(wave.completed) == 6
    for uid in range(6):
        assert cont.completed[uid].generated == wave.completed[uid].generated, (
            f"req {uid}: continuous {cont.completed[uid].generated} "
            f"!= wave {wave.completed[uid].generated}"
        )
    assert cont.completed[0].generated == [eos0]  # the early-EOS request
    assert cont.steps < wave.steps, (cont.steps, wave.steps)
    assert cont.slot_utilization > wave.slot_utilization, (
        cont.slot_utilization, wave.slot_utilization
    )
    # the stats() schema the perf ledger ingests
    stats = cont.stats()
    assert stats["fused_steps"] == cont.steps
    assert stats["requests"] == 6
    assert 0.0 < stats["slot_utilization"] <= 1.0
    assert stats["p95_latency_s"] >= stats["p50_latency_s"] > 0.0


def test_early_eos_refills_slot_mid_flight(setup):
    """A slot freed by early EOS admits the next queued request while the
    other slot is still decoding — no wave barrier."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 12, 6)]
    eos0 = _greedy_single(cfg, params, prompts[0], 1)[0]
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32,
                      scheduler="continuous", block_size=8)
    eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=8, eos_id=eos0))
    eng.submit(Request(uid=1, prompt=prompts[1], max_new_tokens=8))
    eng.submit(Request(uid=2, prompt=prompts[2], max_new_tokens=8))
    done = eng.run_until_drained()
    assert len(done) == 3 and done[0].generated == [eos0]
    # uid=2 was admitted into uid=0's freed slot before uid=1 finished
    assert done[2].started_s < done[1].finished_s
    # ... and recycled at least one of uid=0's physical cache blocks
    assert set(eng.block_history[2]) & set(eng.block_history[0])


def test_paged_blocks_reused_across_requests(setup):
    """Sequential requests through one slot recycle pool blocks."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    eng = ServeEngine(cfg, params, max_batch=1, max_len=32,
                      scheduler="continuous", block_size=8)
    for uid in range(3):
        eng.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, size=10).astype(np.int32),
            max_new_tokens=4,
        ))
    eng.run_until_drained()
    # each request spans ceil((10+4-1)/8) = 2 blocks from a 4-block pool;
    # LIFO freeing means every later request reuses its predecessor's blocks
    assert all(len(blocks) == 2 for blocks in eng.block_history.values())
    assert set(eng.block_history[1]) == set(eng.block_history[0])
    assert set(eng.block_history[2]) == set(eng.block_history[0])


# ---------------------------------------------------------------------------
# slot accounting + typed failure modes
# ---------------------------------------------------------------------------


def test_slot_utilization_pinned_trace():
    """Hand-computed trace: 2 slots, prompts of 3 and 5 tokens, max_new=2,
    lockstep wave.  Horizon = max(5, 7) = 7 -> 6 fused steps; slot 0 is
    busy for its own 3+2-1 = 4 steps, slot 1 for all 6; utilization is
    (4 + 6) / (6 * 2) = 10/12."""
    assert core_metrics.slot_utilization(10, 6, 2) == pytest.approx(10 / 12)
    # degenerate inputs clamp instead of exploding
    assert core_metrics.slot_utilization(0, 0, 2) == 0.0
    assert core_metrics.slot_utilization(99, 2, 2) == 1.0


def test_wave_slot_accounting_matches_pinned_trace(setup):
    """The wave engine reproduces the hand trace above exactly."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, scheduler="wave")
    for uid, plen in enumerate((3, 5)):
        eng.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, size=plen).astype(np.int32),
            max_new_tokens=2,
        ))
    eng.run_until_drained()
    assert eng.steps == 6
    assert eng.busy_slot_steps == 10
    assert eng.slot_utilization == pytest.approx(10 / 12)


def test_request_too_long_rejected_at_submit(setup):
    """An oversized request raises typed at submit() and cannot poison the
    queue (the old in-wave assert crashed whole waves)."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32)
    with pytest.raises(RequestTooLong):
        eng.submit(Request(uid=0, prompt=np.arange(20, dtype=np.int32),
                           max_new_tokens=20))
    assert not eng.queue  # nothing enqueued
    eng.submit(Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=3))
    done = eng.run_until_drained()
    assert list(done) == [1] and len(done[1].generated) == 3


def test_max_waves_cap_is_exact(setup):
    """max_waves admits exactly max_waves waves (the old check ran one
    extra wave before raising)."""
    cfg, params = setup
    rng = np.random.default_rng(7)

    def submit3(eng):
        for uid in range(3):
            eng.submit(Request(
                uid=uid,
                prompt=rng.integers(0, cfg.vocab, size=3).astype(np.int32),
                max_new_tokens=2,
            ))

    eng = ServeEngine(cfg, params, max_batch=1, max_len=32, scheduler="wave")
    submit3(eng)
    with pytest.raises(RuntimeError):
        eng.run_until_drained(max_waves=2)
    assert len(eng.completed) == 2  # exactly two waves ran

    eng = ServeEngine(cfg, params, max_batch=1, max_len=32, scheduler="wave")
    submit3(eng)
    assert len(eng.run_until_drained(max_waves=3)) == 3


# ---------------------------------------------------------------------------
# chunked prefill: budget disaggregation + preemption + typed failures
# ---------------------------------------------------------------------------


def _chunked_engine(cfg, params, *, chunk, budget=None, max_batch=2,
                    max_len=64):
    return ServeEngine(cfg, params, max_batch=max_batch, max_len=max_len,
                       scheduler="continuous", block_size=8,
                       prefill_chunk=chunk, prefill_budget=budget)


def _trace_hook(trace):
    """Step hook recording {uid: position} at the top of every iteration."""
    def hook(engine, busy):
        live = engine._live
        trace.append({r.uid: int(live["positions"][b])
                      for b, r in enumerate(live["slot_req"])
                      if r is not None})
        return False
    return hook


def test_prefill_budget_never_starves_decode(setup):
    """Pinned trace: while a 40-token prompt prefills under an 8-token
    budget, the already-decoding request advances by EXACTLY one token on
    every fused step — decode latency no longer queues behind the prompt
    (the disaggregation contract), and streams stay byte-identical."""
    cfg, params = setup
    rng = np.random.default_rng(21)
    short = rng.integers(0, cfg.vocab, size=4).astype(np.int32)
    long = rng.integers(0, cfg.vocab, size=40).astype(np.int32)

    def run(chunk, budget=None, hook=None):
        eng = _chunked_engine(cfg, params, chunk=chunk, budget=budget)
        if hook is not None:
            eng.add_step_hook(hook)
        eng.submit(Request(uid=0, prompt=short.copy(), max_new_tokens=10))
        eng.submit(Request(uid=1, prompt=long.copy(), max_new_tokens=4))
        eng.run_until_drained()
        return eng

    trace = []
    chunked = run(8, budget=8, hook=_trace_hook(trace))
    base = run(1)
    for uid in (0, 1):
        assert chunked.completed[uid].generated == \
            base.completed[uid].generated, uid

    # steps where the short request is decoding while the long one is
    # still mid-prefill: the decoder must advance +1 on every one of them
    overlap = 0
    for prev, cur in zip(trace, trace[1:]):
        if not (0 in prev and 1 in prev and 0 in cur and 1 in cur):
            continue
        if prev[0] >= len(short) and 0 < prev[1] < len(long):
            assert cur[0] == prev[0] + 1, (prev, cur)
            overlap += 1
        if 0 < prev[1] < len(long):  # prompt admission capped by budget
            assert cur[1] - prev[1] <= 8, (prev, cur)
    assert overlap >= 3, trace  # the overlap actually happened

    # budget held the long prompt back vs an unbudgeted chunked run, yet
    # both serve identical streams
    free_run = run(8)
    for uid in (0, 1):
        assert free_run.completed[uid].generated == \
            base.completed[uid].generated, uid
    assert free_run.completed[1].ttft_steps <= \
        chunked.completed[1].ttft_steps


def test_prefill_budget_one_crawls_but_stays_golden(setup):
    """The degenerate budget=1 serializes prefill to one token per step
    (token-by-token pacing) without perturbing a single served byte."""
    cfg, params = setup
    rng = np.random.default_rng(22)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (11, 6, 17)]

    def run(chunk, budget=None):
        eng = _chunked_engine(cfg, params, chunk=chunk, budget=budget)
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=p.copy(), max_new_tokens=4))
        eng.run_until_drained()
        return eng

    base, crawl = run(1), run(8, budget=1)
    for uid in range(3):
        assert crawl.completed[uid].generated == \
            base.completed[uid].generated, uid
    assert crawl.steps >= base.steps  # budget=1 cannot beat token-by-token


def test_preempt_mid_prefill_replays_identically(setup):
    """Evicting a request in the MIDDLE of its chunked prefill (blocks
    freed, position reset) replays prompt + generated on re-admission and
    serves a bit-identical stream."""
    cfg, params = setup
    rng = np.random.default_rng(23)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 33)]

    def run(chunk, preempt_mid_prefill=False):
        eng = _chunked_engine(cfg, params, chunk=chunk, budget=8)
        if preempt_mid_prefill:
            fired = []

            def hook(engine, busy):
                live = engine._live
                for b, r in enumerate(live["slot_req"]):
                    if (not fired and r is not None and r.uid == 1
                            and 0 < live["positions"][b] < 33):
                        fired.append(engine.preempt(uid=1))
                return False

            eng.add_step_hook(hook)
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=p.copy(), max_new_tokens=5))
        eng.run_until_drained()
        return eng

    base = run(1)
    faulted = run(8, preempt_mid_prefill=True)
    assert faulted.preemptions == 1
    for uid in (0, 1):
        assert faulted.completed[uid].generated == \
            base.completed[uid].generated, uid


def test_ttft_steps_deterministic_across_runs(setup):
    """The step-clock TTFT the ledger gates on is a pure function of the
    trace: two identical runs agree exactly (wall-clock TTFT never can)."""
    cfg, params = setup
    rng = np.random.default_rng(24)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (30, 4, 30, 4)]

    def run():
        eng = _chunked_engine(cfg, params, chunk=8, budget=8)
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=p.copy(), max_new_tokens=3))
        eng.run_until_drained()
        return eng

    a, b = run(), run()
    ttft_a = [a.completed[u].ttft_steps for u in range(4)]
    ttft_b = [b.completed[u].ttft_steps for u in range(4)]
    assert ttft_a == ttft_b
    assert all(t is not None and t >= 1 for t in ttft_a)
    sa, sb = a.stats(), b.stats()
    assert sa["ttft_p95_steps"] == sb["ttft_p95_steps"]
    assert sa["ttft_p50_steps"] == sb["ttft_p50_steps"]


def test_chunked_rejects_oversized_and_bad_config(setup):
    """Typed failures survive the chunked path: oversized requests raise
    at submit(); invalid chunk/budget/scheduler combos raise at __init__."""
    cfg, params = setup
    eng = _chunked_engine(cfg, params, chunk=8, max_len=32)
    with pytest.raises(RequestTooLong):
        eng.submit(Request(uid=0, prompt=np.arange(20, dtype=np.int32),
                           max_new_tokens=20))
    assert not eng.queue
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, max_batch=2, max_len=32,
                    scheduler="wave", prefill_chunk=8)
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, max_batch=2, max_len=32,
                    scheduler="continuous", prefill_chunk=0)
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, max_batch=2, max_len=32,
                    scheduler="continuous", prefill_chunk=8,
                    prefill_budget=0)
