"""Flash-decode: one-token attention over a long KV cache, KV-blocked.

The serve-side hot loop of every decode_* cell: q (B, H, D) attends to a
(B, S, KV, D) cache of which only ``valid_len`` positions are live.  The
kernel streams KV blocks through VMEM keeping a running (max, sum, acc) —
online softmax — and PREDICATES each block on ``pos < valid_len``: ragged
context lengths occupy only ceil(valid/bs) block-issues per head instead of
S/bs, the SVE predication insight applied at the token level (a fixed-width
schedule must process the whole padded cache).

Grid: (B, KV-heads, S/bs) with the KV axis innermost (sequential).  GQA via
G query heads per KV head processed together — the q tile is (G, D), MXU
contractions are (G, D) x (D, bs).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, vl_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, bs: int, ns: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = vl_ref[0]
    q = q_ref[0, 0]  # (G, D)
    k = k_ref[0, 0]  # (bs, D)
    v = v_ref[0, 0]
    D = q.shape[-1]
    scale = 1.0 / math.sqrt(D)

    pos = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    pred = pos < valid  # predicate register analogue

    # skip fully-masked blocks entirely (ragged-length win; on TPU this is
    # the "don't issue the tile" branch)
    @pl.when(si * bs < valid)
    def _work():
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, bs)
        s = jnp.where(pred[None, :], s, NEG_INF)
        m_new = jnp.maximum(m_ref[...], s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_ref[...] - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(
    q: jax.Array,       # (B, KV, G, D)
    k: jax.Array,       # (B, S, KV, D)
    v: jax.Array,       # (B, S, KV, D)
    valid_len: jax.Array,  # (B,) int32 — live cache length per sequence
    *,
    block_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Returns (B, KV, G, D) attention output over the predicated cache."""
    B, KV, G, D = q.shape
    S = k.shape[1]
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    ns = S // bs
    kernel = functools.partial(_decode_kernel, bs=bs, ns=ns)
    from jax.experimental.pallas import tpu as pltpu

    kt = k.transpose(0, 2, 1, 3)  # (B, KV, S, D): head-major streaming
    vt = v.transpose(0, 2, 1, 3)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, ns),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1,), lambda b, h, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, kt, vt, valid_len)


def _decode_kernel_paged(bt_ref, q_ref, k_ref, v_ref, vl_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, bs: int, ns: int):
    """Same online-softmax body as :func:`_decode_kernel`; the KV tile for
    logical block ``si`` of sequence ``b`` is DMA'd from pool block
    ``bt_ref[b, si]`` (scalar-prefetched block table drives the index_map),
    so the kernel streams a non-contiguous paged cache without ever
    materializing a gathered copy."""
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = vl_ref[0]
    q = q_ref[0, 0]  # (G, D)
    k = k_ref[0, 0]  # (bs, D) — one pool block
    v = v_ref[0, 0]
    D = q.shape[-1]
    scale = 1.0 / math.sqrt(D)

    pos = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    pred = pos < valid  # per-slot length predication

    @pl.when(si * bs < valid)
    def _work():
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(pred[None, :], s, NEG_INF)
        m_new = jnp.maximum(m_ref[...], s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_ref[...] - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode_paged(
    q: jax.Array,            # (B, KV, G, D)
    k_pool: jax.Array,       # (n_blocks, block_size, KV, D)
    v_pool: jax.Array,       # (n_blocks, block_size, KV, D)
    block_tables: jax.Array,  # (B, nb) int32 — logical -> pool block map
    valid_len: jax.Array,    # (B,) int32 — live length per slot, >= 1
    *,
    interpret: bool = True,
) -> jax.Array:
    """Flash-decode over a PAGED cache: the continuous-batching serve path.

    Each slot's KV lives in ``valid_len[b] / block_size`` pool blocks named
    by its block-table row; the kernel walks logical blocks, prefetching
    the table so the BlockSpec index_map resolves the indirection at DMA
    time.  Fully-masked logical blocks (beyond the slot's live prefix) are
    never issued — the same predication economics as the contiguous
    kernel, now compounded with block reuse across requests.  Slots with
    ``valid_len == 0`` produce unspecified output (they have no live
    tokens to attend over); the serving engine masks such slots itself.
    """
    B, KV, G, D = q.shape
    bs = k_pool.shape[1]
    nb = block_tables.shape[1]
    kernel = functools.partial(_decode_kernel_paged, bs=bs, ns=nb)
    from jax.experimental.pallas import tpu as pltpu

    kt = k_pool.transpose(0, 2, 1, 3)  # (n_blocks, KV, bs, D): head-major
    vt = v_pool.transpose(0, 2, 1, 3)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, nb),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s, bt: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, s, bt: (bt[b, s], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, s, bt: (bt[b, s], h, 0, 0)),
            pl.BlockSpec((1,), lambda b, h, s, bt: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s, bt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(block_tables, q, kt, vt, valid_len)
