"""Tuning search spaces: the per-kernel block/tile axes + the ELEN axis.

A :class:`TuningSpace` declares, for one registered Pallas kernel, the
static keyword arguments worth searching (block/tile shapes), the dtype
candidates of the paper's ELEN-packing axis (Eq. 1: VB = VLEN/ELEN — a
smaller element type packs more lanes per issue), and the analytic models
the tuner uses to prune before ever timing anything:

* ``vmem_model``    — working-set bytes per grid step; candidates exceeding
  ``vmem_budget`` are discarded outright (they could not be scheduled);
* ``traffic_model`` — HBM bytes as a function of the tile config (tile
  reuse: e.g. a GEMM re-streams each operand once per tile of the other);
* ``flops_model``   — config-independent FLOPs of the problem.

``traffic_model`` + ``flops_model`` feed :func:`predicted_time_s`, the
adapted roofline (paper Eq. 2) read as a time bound — the pruning score of
:func:`repro.tuning.tune.tune`.

Spaces are declarative and free of registry/kernel imports, so the kernel
registry can attach one to each :class:`~repro.kernels.registry.KernelOps`
at registration time without an import cycle.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class TuningSpace:
    """Search space + analytic models for one kernel's static arguments.

    ``axes`` maps each tunable static kwarg to its ordered candidate values
    (the enumeration order is the deterministic tie-break).  ``fixed`` holds
    non-tuned kwargs the kernel needs at tuning time (e.g. the RX gate's
    ``qubit``/``theta``).  ``clamp`` mirrors the kernel's own ``min(block,
    dim)`` clamping so oversized candidates collapse onto their effective
    config (and dedupe); ``constraint`` rejects configs the kernel would
    assert on (divisibility).  All model callables receive the *merged*
    config (fixed + candidate + caller kwargs) and the positional example
    arguments.
    """

    kernel: str
    axes: Mapping[str, Tuple[Any, ...]]
    default: Mapping[str, Any]
    dtypes: Tuple[str, ...] = ()
    fixed: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    clamp: Optional[Callable[[Dict[str, Any], Tuple], Dict[str, Any]]] = None
    constraint: Optional[Callable[[Dict[str, Any], Tuple], bool]] = None
    vmem_model: Optional[Callable[[Dict[str, Any], Tuple, int], float]] = None
    traffic_model: Optional[Callable[[Dict[str, Any], Tuple], float]] = None
    flops_model: Optional[Callable[[Tuple], float]] = None
    vmem_budget: int = 96 * 2**20

    # -- enumeration ---------------------------------------------------------

    def size(self) -> int:
        """Cartesian-product size of the raw (unclamped) space."""
        n = 1
        for values in self.axes.values():
            n *= max(len(values), 1)
        return max(n, 1) * max(len(self.dtypes), 1)

    def configs(self) -> List[Dict[str, Any]]:
        """Every axis combination, in axis-declaration order (bm outermost
        for GEMM — the legacy search-loop order, kept as the tie-break)."""
        keys = list(self.axes)
        if not keys:
            return [{}]
        return [
            dict(zip(keys, values))
            for values in itertools.product(*(self.axes[k] for k in keys))
        ]

    def validate(
        self,
        config: Mapping[str, Any],
        args: Tuple,
        *,
        dtype_bytes: Optional[int] = None,
        extra: Optional[Mapping[str, Any]] = None,
    ) -> Optional[Dict[str, Any]]:
        """Clamp ``config`` to ``args`` and check constraint + VMEM budget.

        Returns the clamped axis-config, or ``None`` if the kernel would
        reject it (failed divisibility) or it cannot fit the VMEM budget.
        ``extra`` carries caller kwargs (they override ``fixed`` in the
        merged view the models see, mirroring a real call).
        """
        cfg = {k: config[k] for k in self.axes if k in config}
        if self.clamp is not None:
            cfg = dict(self.clamp(dict(cfg), args))
        merged = {**self.fixed, **(extra or {}), **cfg}
        if self.constraint is not None and not self.constraint(merged, args):
            return None
        if self.vmem_model is not None:
            if dtype_bytes is None:
                dtype_bytes = _dtype_bytes_of(args)
            if self.vmem_model(merged, args, dtype_bytes) > self.vmem_budget:
                return None
        return cfg

    def candidates(
        self, args: Tuple, *, dtype_bytes: Optional[int] = None
    ) -> List[Dict[str, Any]]:
        """Valid clamped configs, deduplicated, in enumeration order."""
        if dtype_bytes is None:
            dtype_bytes = _dtype_bytes_of(args)
        out: List[Dict[str, Any]] = []
        seen = set()
        for raw in self.configs():
            cfg = self.validate(raw, args, dtype_bytes=dtype_bytes)
            if cfg is None:
                continue
            key = tuple(sorted(cfg.items()))
            if key in seen:
                continue
            seen.add(key)
            out.append(cfg)
        return out

    def subset(self, cap: int) -> "TuningSpace":
        """Space with at most ``cap`` values per axis — the CI "tiny space"
        knob (values keep their order, so the preferred candidates stay)."""
        cap = max(int(cap), 1)
        return dataclasses.replace(
            self,
            axes={k: tuple(v[:cap]) for k, v in self.axes.items()},
            dtypes=tuple(self.dtypes[:cap]),
        )

    # -- identity ------------------------------------------------------------

    def token(self) -> str:
        """Stable content token for fingerprints: the declarative parts of
        the space (axes/defaults/dtypes/fixed/budget).  Model callables are
        deliberately excluded — refining an analytic model reorders pruning
        but does not invalidate a timed record."""
        axes = ",".join(f"{k}={tuple(v)!r}" for k, v in self.axes.items())
        fixed = ",".join(f"{k}={v!r}" for k, v in sorted(self.fixed.items()))
        default = ",".join(f"{k}={v!r}" for k, v in sorted(self.default.items()))
        return (
            f"{self.kernel}|axes[{axes}]|default[{default}]|"
            f"dtypes{tuple(self.dtypes)!r}|fixed[{fixed}]|vmem{self.vmem_budget}"
        )


#: Canonical ELEN names for concrete array dtypes (shared by the tuner and
#: the registry's call-time config resolution).
CANONICAL_DTYPE = {
    "float32": "fp32", "float16": "fp16", "bfloat16": "bf16",
    "float64": "fp64", "int8": "int8", "int32": "int32",
}


def canonical_dtype(dtype: Any) -> str:
    """Paper-style ELEN name ("fp32", "bf16", ...) for an array dtype."""
    key = str(dtype)
    return CANONICAL_DTYPE.get(key, key)


def _dtype_bytes_of(args: Sequence[Any], default: int = 4) -> int:
    """Element size of the first shaped argument (the tile-footprint unit)."""
    for a in args:
        dt = getattr(a, "dtype", None)
        if dt is not None and hasattr(dt, "itemsize"):
            return int(dt.itemsize)
    return default


def predicted_time_s(flops: float, hbm_bytes: float, roofline: Any) -> float:
    """Adapted-roofline (Eq. 2) lower bound read as a time:
    ``max(flops / vector_peak, bytes / bw)``.

    Monotone in both inputs — a candidate that moves more HBM bytes (or
    more FLOPs) is never predicted faster, which is what makes it safe as a
    pruning score (see ``test_tuning.py::test_pruning_monotone``).
    """
    compute_s = flops / max(roofline.vector_peak, 1e-30)
    memory_s = hbm_bytes / max(roofline.bw, 1e-30)
    return max(compute_s, memory_s)


def predicted_config_time_s(
    space: TuningSpace,
    config: Mapping[str, Any],
    args: Tuple,
    roofline: Any,
) -> float:
    """Roofline-predicted time of one candidate config.

    Uses the space's traffic/flops models where present; with neither, all
    candidates score identically and the enumeration order decides (the
    tuner then falls back to timing alone).
    """
    merged = {**space.fixed, **config}
    flops = space.flops_model(args) if space.flops_model is not None else 0.0
    traffic = (
        space.traffic_model(merged, args)
        if space.traffic_model is not None
        else 0.0
    )
    return predicted_time_s(flops, traffic, roofline)
