"""Checkpoint store (atomicity, GC, async, elastic restore) and the
fault-tolerance loop (crash-restart, exact replay, straggler detection)."""

import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.distributed.fault_tolerance import (
    FaultToleranceConfig,
    ResilientLoop,
    StragglerDetector,
)


def _state(x=0.0):
    return {"w": jnp.full((4, 4), x, jnp.float32), "step_f": jnp.asarray(x)}


def test_save_restore_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    s = _state(3.5)
    store.save(7, s)
    step, restored, manifest = store.restore(_state())
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(s["w"]))


def test_uncommitted_checkpoint_invisible(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, _state(1.0))
    # simulate a crash mid-write: a step dir without the commit marker
    crash = tmp_path / "step_00000002"
    crash.mkdir()
    (crash / "arrays.npz").write_bytes(b"garbage")
    assert store.latest_step() == 1
    step, restored, _ = store.restore(_state())
    assert step == 1


def test_gc_keeps_newest_k(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _state(float(s)))
    assert store.committed_steps() == [3, 4]


def test_async_save_then_restore(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(5, _state(5.0), blocking=False)
    store.wait()
    assert store.latest_step() == 5


def test_restore_missing_raises(tmp_path):
    store = CheckpointStore(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        store.restore(_state())


def test_restore_shape_mismatch_raises(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, _state())
    bad_template = {"w": jnp.zeros((2, 2)), "step_f": jnp.asarray(0.0)}
    with pytest.raises(ValueError):
        store.restore(bad_template)


def test_elastic_restore_onto_shardings(tmp_path):
    """Restore re-places arrays against target NamedShardings (1-device mesh
    here; the mechanism is mesh-size agnostic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    store = CheckpointStore(str(tmp_path))
    store.save(2, _state(2.0))
    sh = {
        "w": NamedSharding(mesh, P("data", None)),
        "step_f": NamedSharding(mesh, P()),
    }
    step, restored, _ = store.restore(_state(), shardings=sh)
    assert restored["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


class _Flaky:
    """Step function that crashes at chosen steps, once each."""

    def __init__(self, fail_at):
        self.fail_at = set(fail_at)
        self.calls = 0

    def __call__(self, step, state):
        self.calls += 1
        if step in self.fail_at:
            self.fail_at.remove(step)
            raise RuntimeError(f"injected failure @ {step}")
        return {"w": state["w"] + 1.0, "step_f": state["step_f"]}


def test_resilient_loop_survives_crashes(tmp_path):
    store = CheckpointStore(str(tmp_path))
    cfg = FaultToleranceConfig(checkpoint_every=2, async_save=False, max_restarts=5)
    flaky = _Flaky(fail_at=[3, 7])
    loop = ResilientLoop(store, cfg, flaky, lambda: _state(0.0))
    out = loop.run(total_steps=10)
    assert out["final_step"] == 10
    assert out["restarts"] == 2
    # exact replay: w counts every step exactly once despite the crashes
    np.testing.assert_allclose(np.asarray(out["state"]["w"]), 10.0)


def test_resilient_loop_gives_up_after_max_restarts(tmp_path):
    store = CheckpointStore(str(tmp_path))
    cfg = FaultToleranceConfig(checkpoint_every=100, async_save=False, max_restarts=2)

    def always_fail(step, state):
        raise RuntimeError("dead node")

    loop = ResilientLoop(store, cfg, always_fail, lambda: _state(0.0))
    with pytest.raises(RuntimeError):
        loop.run(total_steps=5)


def test_resilient_loop_resumes_from_disk(tmp_path):
    """A brand-new loop object (fresh process analogue) picks up the latest
    committed checkpoint."""
    store = CheckpointStore(str(tmp_path))
    cfg = FaultToleranceConfig(checkpoint_every=2, async_save=False)
    step_fn = lambda step, st: {"w": st["w"] + 1.0, "step_f": st["step_f"]}  # noqa: E731
    ResilientLoop(store, cfg, step_fn, lambda: _state(0.0)).run(total_steps=4)

    loop2 = ResilientLoop(store, cfg, step_fn, lambda: _state(0.0))
    out = loop2.run(total_steps=8)
    assert out["final_step"] == 8
    np.testing.assert_allclose(np.asarray(out["state"]["w"]), 8.0)


def test_straggler_detector():
    det = StragglerDetector(factor=2.0, window=16)
    for _ in range(10):
        det.observe(0.1)
    assert det.observe(0.5) is True
    assert det.events == 1
    assert det.observe(0.11) is False


# ---------------------------------------------------------------------------
# fault tolerance under the serve path
# ---------------------------------------------------------------------------


def test_serve_restart_resumes_bit_identical_token_stream(tmp_path):
    """Kill the serve drain mid-chunk, restore from the checkpoint store,
    and assert the resumed token stream bit-matches the uninterrupted
    golden run — the serving analogue of exact training replay."""
    import repro.configs as configs
    from repro.serve.engine import Request, ServeEngine
    from repro.train import steps as steps_mod

    cfg = configs.get_smoke_config("gpt2-124m")
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(4, 10)))
               .astype(np.int32) for _ in range(4)]

    def serve_all():
        """Uninterrupted golden run: one engine, all requests."""
        engine = ServeEngine(cfg, params, max_batch=2, max_len=32,
                             scheduler="continuous", block_size=8)
        for uid, p in enumerate(prompts):
            engine.submit(Request(uid=uid, prompt=p, max_new_tokens=4))
        return {u: r.generated for u, r in engine.run_until_drained().items()}

    golden = serve_all()

    # resilient run: 2-request chunks, each chunk one checkpointed step;
    # the second chunk's first attempt dies mid-drain
    chunks = [(0, 1), (2, 3)]
    crashed = {"left": 1}

    def step_fn(chunk_idx, state):
        engine = ServeEngine(cfg, params, max_batch=2, max_len=32,
                             scheduler="continuous", block_size=8)
        for uid in chunks[chunk_idx]:
            engine.submit(Request(uid=uid, prompt=prompts[uid],
                                  max_new_tokens=4))

        def killer(eng, busy):
            if chunk_idx == 1 and crashed["left"] and eng.steps >= 2:
                crashed["left"] -= 1
                raise RuntimeError("simulated device loss mid-drain")
            return False

        engine.add_step_hook(killer)
        done = engine.run_until_drained()
        toks = np.array(state["tokens"])
        for uid, r in done.items():
            toks[uid, : len(r.generated)] = r.generated
        return {"tokens": toks}

    loop = ResilientLoop(
        CheckpointStore(str(tmp_path)),
        FaultToleranceConfig(checkpoint_every=1, async_save=False,
                             max_restarts=3),
        step_fn,
        lambda: {"tokens": np.full((4, 4), -1, np.int32)},
    )
    out = loop.run(total_steps=len(chunks))
    assert out["restarts"] == 1, "the injected death must actually fire"
    resumed = np.asarray(out["state"]["tokens"])
    for uid, toks in golden.items():
        assert resumed[uid].tolist() == toks, (
            f"req {uid}: resumed stream {resumed[uid].tolist()} != "
            f"golden {toks}"
        )
