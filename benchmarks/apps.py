"""The paper's 13-application benchmark suite (Table 2), JAX/TPU-native.

Each app is a :class:`repro.analysis.workload.Workload` (thin ``App``
subclass keeping the paper's "Kernels" column) providing:

  * a jitted callable + inputs (sized to run in this CPU container;
    ``full_problem`` records the paper's original problem size),
  * analytic roofline terms (flops / bytes / gather bytes),
  * an instruction model (scalar vs vector issues -> R_ins), and
  * the dominant ELEN (fp64 stand-ins are fp32 on TPU; noted per app).

All 13 apps register in the global workload registry as ``app/<name>``
(lazily — nothing is built until requested), so the whole suite is
reachable through ``repro.analysis.analyze`` / ``analyze_sweep``.  The
suite feeds every figure/table benchmark: Fig. 3 (R_ins + speedup),
Fig. 4 (thread/chip scaling), Fig. 5 (QC sensitivity), Fig. 6 (synthetic
SpMV), Fig. 7 (roofline placement), Table 3 (decision tree).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.workload import Workload, register_lazy
from repro.kernels.qc_gate import ops as qc_ops, ref as qc_ref
from repro.kernels.gemm import ref as gemm_ref
from repro.kernels.jacobi2d import ref as jacobi_ref
from repro.kernels.spmv import ops as spmv_ops, ref as spmv_ref
from repro.kernels.stream import ref as stream_ref


@dataclasses.dataclass
class App(Workload):
    """A paper-suite application: a Workload + the paper's Kernels column.

    The analytic-model fields (``flops`` / ``hbm_bytes`` / ``gather_bytes``
    / ``vectorizable_fraction``) and the ``issue_model`` / ``report``
    methods now live on :class:`Workload`; ``App`` only adds Table-2
    bookkeeping and survives as a deprecation-friendly alias for callers
    that still construct apps directly.
    """

    kernels: str = ""  # the paper's "Kernels" column


# ---------------------------------------------------------------------------
# app builders (reduced problems; analytic terms per reduced problem)
# ---------------------------------------------------------------------------


def _llm_apps() -> list:
    import repro.configs as configs
    from repro.configs.base import ShapeConfig
    from repro.data import pipeline
    from repro.models import transformer
    from repro.optim import adamw
    from repro.train import steps as steps_mod

    cfg = configs.get_smoke_config("gpt2-124m")
    shape = ShapeConfig("bench", 64, 4, "train")
    run = steps_mod.RunConfig(remat="none", zero=False)
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in
             pipeline.global_batch(cfg, shape, pipeline.DataConfig(), 0).items()}
    opt = adamw.init_opt_state(params, run.opt)
    train = jax.jit(steps_mod.make_train_step(cfg, run))

    n = cfg.param_count()
    T = shape.tokens
    train_app = App(
        name="LLM-training", dtype="fp32", kernels="train", problem=f"{n/1e6:.1f}M@{T}tok",
        full_problem="GPT-2 124M", fn=lambda: train(params, opt, batch), args=(),
        flops=6.0 * n * T, hbm_bytes=34.0 * n * 2 + 10 * T * cfg.d_model * 4,
        vectorizable_fraction=0.95,
        notes="matmul-dominated; fp32 (paper runs FP32 ML workloads)",
    )

    # the paper's inference kernel is `test` = teacher-forced scoring
    # (perplexity eval), i.e. a full forward pass — not incremental decode
    fwd = jax.jit(lambda p, t: transformer.forward(p, cfg, t)[0])
    infer_app = App(
        name="LLM-inference", dtype="fp32", kernels="test",
        problem=f"{n/1e6:.1f}M fwd@{T}tok", full_problem="GPT-2 124M",
        fn=lambda: fwd(params, batch["tokens"]), args=(),
        flops=2.0 * n * T, hbm_bytes=2.0 * n * 2 + 6 * T * cfg.d_model * 4,
        vectorizable_fraction=0.95,
    )
    return [train_app, infer_app]


def _qc_app(n_qubits: int = 16) -> App:
    re, im = qc_ops.zero_state(n_qubits)
    fb = qc_ref.flops_bytes(n_qubits)

    def run():
        return qc_ops.rx_layer(re, im, n_qubits=n_qubits, theta=0.25)

    # The paper's AI estimate is FP_op / LLC_read_miss: a 21-qubit state
    # (33 MB complex128) is RESIDENT in Grace's 117 MB LLC, so DRAM misses
    # are a fraction of streaming traffic — that is what puts QC right of
    # the scalar knee (Class 4 @1T) yet left of the vector knee (the Fig. 7
    # red triangle, and Class 2 once 72 threads saturate bandwidth).
    llc_resident_discount = 0.3125
    return App(
        name="QC-simulator", dtype="fp32", kernels="RX_gate",
        problem=f"{n_qubits} qubits", full_problem="21 qubits",
        fn=run, args=(),
        flops=fb["flops"] * n_qubits,
        hbm_bytes=fb["bytes"] * n_qubits * llc_resident_discount,
        notes="fp64 in paper; fp32 planes on TPU (no fp64 vector unit); "
              "AI uses the paper's LLC-miss estimate (state is LLC-resident)",
    )


def _fft_apps() -> list:
    n1 = 16384
    x1 = jax.random.normal(jax.random.PRNGKey(0), (n1,), jnp.float32)
    fft1 = jax.jit(lambda x: jnp.abs(jnp.fft.fft(x)))
    # FFT flops ~ 5 N log2 N
    f1 = 5.0 * n1 * np.log2(n1)
    app1 = App(
        name="FFT1D", dtype="fp32", kernels="fft1D", problem=str(n1),
        full_problem="16384", fn=lambda: fft1(x1), args=(),
        flops=f1, hbm_bytes=2.0 * n1 * 8,
        vectorizable_fraction=0.05,
        notes="library pre-optimization defeats autovec (paper: FFTW); "
              "XLA lowers to a non-MXU fft HLO — Class 1",
    )
    n2 = 512
    x2 = jax.random.normal(jax.random.PRNGKey(1), (n2, n2), jnp.float32)
    fft2 = jax.jit(lambda x: jnp.abs(jnp.fft.fft2(x)))
    f2 = 5.0 * n2 * n2 * np.log2(n2 * n2)
    app2 = App(
        name="FFT2D", dtype="fp32", kernels="fft2D", problem=f"{n2}x{n2}",
        full_problem="262144", fn=lambda: fft2(x2), args=(),
        flops=f2, hbm_bytes=2.0 * n2 * n2 * 8,
        vectorizable_fraction=0.05,
    )
    return [app1, app2]


def _stream_app(mb: int = 64) -> App:
    rows = mb * 2**20 // (128 * 4)
    a = jnp.ones((rows, 128), jnp.float32)
    b = jnp.ones((rows, 128), jnp.float32)
    triad = jax.jit(lambda a, b: stream_ref.triad_ref(a, b, 3.0))
    n = rows * 128
    fb = stream_ref.flops_bytes("triad", n, 4)
    return App(
        name="STREAM", dtype="fp32", kernels="copy/triad", problem=f"{mb}MB",
        full_problem="1-10G", fn=lambda: triad(a, b), args=(),
        flops=fb["flops"], hbm_bytes=fb["bytes"],
        notes="fp64 in paper; ELEN sweep in fig6/fig3 variants",
    )


def _gemm_apps(n: int = 1024) -> list:
    x = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
    f = jax.jit(lambda a, b: a @ b)
    fb = gemm_ref.flops_bytes(n, n, n, 4)
    dgemm = App(
        name="DGEMM", dtype="fp64", kernels="dgemm (FP64)", problem=f"{n}^2",
        full_problem="12k x 12k", fn=lambda: f(x, y), args=(),
        flops=fb["flops"], hbm_bytes=fb["bytes"],
        notes="fp64 has no MXU path on TPU: runs fp32 with VB=fp64 semantics "
              "for the paper-faithful analysis (DESIGN.md §Adaptation)",
    )
    xb = x.astype(jnp.bfloat16)
    yb = y.astype(jnp.bfloat16)
    fbb = gemm_ref.flops_bytes(n, n, n, 2)
    sgemm = App(
        name="SGEMM", dtype="fp32", kernels="sgemm (FP32)", problem=f"{n}^2",
        full_problem="12k x 12k", fn=lambda: f(xb, yb), args=(),
        flops=fbb["flops"], hbm_bytes=fbb["bytes"],
    )
    return [dgemm, sgemm]


def _spmv_app(n: int = 2048) -> App:
    vals, cols, nnz = spmv_ref.make_problem(
        jax.random.PRNGKey(0), n, n, row_block=8, max_nnz=64, width_pad=128
    )
    x = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
    fb = spmv_ops.flops_bytes(np.asarray(nnz), repeat=1, dtype_bytes=4)
    run = jax.jit(lambda: spmv_ref.spmv_ref(vals, cols, nnz, x))
    return App(
        name="SpMV", dtype="fp64", kernels="spmv_csr", problem=f"{n}^2 zipf",
        full_problem="2048^2", fn=run, args=(),
        flops=fb["flops"], hbm_bytes=fb["bytes"], gather_bytes=fb["gather_bytes"],
        notes="pointer-chasing x[colind[j]]: latency-bound Class 3; "
              "predicated block-ELL Pallas kernel in kernels/spmv",
    )


def _jacobi_app(n: int = 1024) -> App:
    u = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
    fb = jacobi_ref.flops_bytes(n, n, 4)
    run = jax.jit(lambda u: jacobi_ref.jacobi_ref(u))
    return App(
        name="Jacobi2D", dtype="fp64", kernels="sweep", problem=f"{n}^2",
        full_problem="4-32k", fn=lambda: run(u), args=(),
        flops=fb["flops"], hbm_bytes=fb["bytes"],
    )


def _conv_stack(key, channels, img, name, full):
    """Shared builder for the CNN apps (YOLOv3/AlexNet stand-ins)."""
    ks = jax.random.split(key, len(channels))
    kernels = []
    cin = img.shape[-1]
    flops = 0.0
    bytes_ = img.size * 4.0
    h = img.shape[1]
    for i, (cout, ksize, stride) in enumerate(channels):
        w = jax.random.normal(ks[i], (ksize, ksize, cin, cout), jnp.float32) * 0.1
        kernels.append((w, stride))
        h = h // stride
        flops += 2.0 * h * h * cout * ksize * ksize * cin
        bytes_ += h * h * cout * 4.0 + w.size * 4.0
        cin = cout

    @jax.jit
    def run(x):
        for w, stride in kernels:
            x = jax.lax.conv_general_dilated(
                x, w, (stride, stride), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            x = jax.nn.relu(x)
        return x

    return App(
        name=name, dtype="fp32", kernels="detector" if "YOLO" in name else "classifier",
        problem=f"{img.shape[1]}^2x{img.shape[-1]}", full_problem=full,
        fn=lambda: run(img), args=(),
        flops=flops, hbm_bytes=bytes_, vectorizable_fraction=0.97,
    )


def _yolo_app() -> App:
    img = jax.random.normal(jax.random.PRNGKey(0), (1, 128, 128, 3), jnp.float32)
    return _conv_stack(
        jax.random.PRNGKey(1),
        [(32, 3, 1), (64, 3, 2), (128, 3, 2), (256, 3, 2)],
        img, "YOLOv3", "608^2 x 3",
    )


def _alexnet_app() -> App:
    img = jax.random.normal(jax.random.PRNGKey(2), (1, 224, 224, 3), jnp.float32)
    return _conv_stack(
        jax.random.PRNGKey(3),
        [(64, 11, 4), (192, 5, 1), (384, 3, 1)],
        img, "AlexNet", "1k images",
    )


def _autodock_app(n_lig: int = 128, n_rec: int = 2048) -> App:
    """Pairwise Lennard-Jones + Coulomb scoring (the scoring kernel of
    AutoDock): compute-dense elementwise + reduction, Class 4."""
    kl, kr, kq = jax.random.split(jax.random.PRNGKey(4), 3)
    lig = jax.random.normal(kl, (n_lig, 3), jnp.float32)
    rec = jax.random.normal(kr, (n_rec, 3), jnp.float32)
    q = jax.random.normal(kq, (n_lig,), jnp.float32)

    @jax.jit
    def score(lig, rec, q):
        d2 = jnp.sum((lig[:, None, :] - rec[None, :, :]) ** 2, axis=-1) + 1e-6
        inv6 = 1.0 / (d2 * d2 * d2)
        lj = inv6 * inv6 - inv6
        coul = q[:, None] / jnp.sqrt(d2)
        return jnp.sum(lj + coul)

    pairs = n_lig * n_rec
    return App(
        name="AutoDock", dtype="fp64", kernels="scoring",
        problem=f"{n_lig}x{n_rec} pairs", full_problem="1iep complex",
        fn=lambda: score(lig, rec, q), args=(),
        # tiles stay VMEM-resident; charge inputs + ~10% pair spill
        flops=20.0 * pairs, hbm_bytes=(n_lig + n_rec) * 3 * 4.0 + 0.4 * pairs,
        notes="~20 flops/pair on VMEM-resident tiles: high AI, Class 4",
    )


# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def suite() -> Dict[str, App]:
    apps = []
    apps += _llm_apps()
    apps.append(_qc_app())
    apps += _fft_apps()
    apps.append(_stream_app())
    apps += _gemm_apps()
    apps.append(_spmv_app())
    apps.append(_jacobi_app())
    apps.append(_yolo_app())
    apps.append(_alexnet_app())
    apps.append(_autodock_app())
    return {a.name: a for a in apps}


#: Table-2 app names, in suite order (static so registration needs no build).
APP_NAMES = (
    "LLM-training", "LLM-inference", "QC-simulator", "FFT1D", "FFT2D",
    "STREAM", "DGEMM", "SGEMM", "SpMV", "Jacobi2D", "YOLOv3", "AlexNet",
    "AutoDock",
)

def register_app_workloads() -> None:
    """(Re-)register the 13 apps; idempotent discovery hook (also re-run by
    repro.analysis after clear_registry, when import side effects can't)."""
    for _n in APP_NAMES:
        register_lazy(f"app/{_n}", lambda _n=_n: suite()[_n], tags=("app",),
                      replace=True)


register_app_workloads()


def measure(app: App, repeats: int = 5, min_time_s: float = 0.05) -> float:
    """Paper methodology: warmup, >=5 repeats, >=min runtime; best-of."""
    import time

    args = app.example_args()
    out = app.fn(*args)
    jax.block_until_ready(out)
    times = []
    total, i = 0.0, 0
    while i < repeats or total < min_time_s:
        t0 = time.perf_counter()
        out = app.fn(*args)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        times.append(dt)
        total += dt
        i += 1
        if i > 200:
            break
    return min(times)
