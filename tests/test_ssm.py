"""Mamba-2 / SSD correctness: the chunked dual form must equal the naive
sequential recurrence, chunk boundaries must be invisible, and the decode
recurrence must continue a prefix exactly.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import ssm


def _cfg(chunk=8, d_model=32, d_state=8, head_dim=8):
    base = configs.get_smoke_config("mamba2-370m")
    return dataclasses.replace(
        base,
        d_model=d_model,
        ssm=dataclasses.replace(
            base.ssm, chunk=chunk, d_state=d_state, head_dim=head_dim
        ),
    )


def naive_recurrence(params, cfg, x):
    """Token-by-token reference: y_t = C_t . S_t + D x_t with
    S_t = exp(dt_t A) S_{t-1} + dt_t B_t (x) x_t, conv window included."""
    s, d, di, nh, conv_ch = ssm._dims(cfg)
    G, N, P = s.n_groups, s.d_state, s.head_dim
    B, S, _ = x.shape
    state = jnp.zeros((B, nh, N, P), jnp.float32)
    conv_state = jnp.zeros((B, s.d_conv - 1, conv_ch), x.dtype)
    ys = []
    for t in range(S):
        y, state, conv_state = ssm.mamba_decode(
            params, cfg, x[:, t:t + 1, :], state, conv_state
        )
        ys.append(y)
    return jnp.concatenate(ys, axis=1), state


@pytest.mark.parametrize("S,chunk", [(16, 8), (16, 16), (13, 8), (7, 4), (24, 8)])
def test_chunked_matches_naive_recurrence(S, chunk):
    cfg = _cfg(chunk=chunk)
    params = ssm.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, S, cfg.d_model), jnp.float32)
    y_chunked, state_chunked = ssm.mamba_full(params, cfg, x, return_state=True)
    y_naive, state_naive = naive_recurrence(params, cfg, x)
    np.testing.assert_allclose(
        np.asarray(y_chunked), np.asarray(y_naive), rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(state_chunked), np.asarray(state_naive), rtol=2e-4, atol=2e-4
    )


def test_chunk_size_is_invisible():
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (1, 24, 32), jnp.float32)
    outs = []
    for chunk in (4, 8, 12, 24):
        cfg = _cfg(chunk=chunk)
        params = ssm.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
        outs.append(np.asarray(ssm.mamba_full(params, cfg, x)))
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=2e-4, atol=2e-4)


def test_decode_continues_prefill_state():
    """Full pass over a prefix, then decode steps == full pass over the whole."""
    cfg = _cfg(chunk=4)
    params = ssm.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    S_pre, S_dec = 8, 4
    x = 0.5 * jax.random.normal(
        jax.random.PRNGKey(3), (1, S_pre + S_dec, cfg.d_model), jnp.float32
    )
    y_full = ssm.mamba_full(params, cfg, x)

    _, state = ssm.mamba_full(params, cfg, x[:, :S_pre], return_state=True)
    # conv window tail from the prefix (pre-activation xBC rows)
    _, xBC_tail, _ = ssm._project_in(
        params, cfg, x[:, S_pre - (cfg.ssm.d_conv - 1):S_pre, :]
    )
    conv_state = xBC_tail
    ys = []
    for t in range(S_pre, S_pre + S_dec):
        y, state, conv_state = ssm.mamba_decode(
            params, cfg, x[:, t:t + 1, :], state, conv_state
        )
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_full[:, S_pre:]), np.asarray(y_dec), rtol=5e-4, atol=5e-4
    )


def test_initial_state_threading():
    """mamba_full(initial_state=s) == continuing from that state."""
    cfg = _cfg(chunk=4)
    params = ssm.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(4), (1, 16, cfg.d_model), jnp.float32)
    y_all, s_all = ssm.mamba_full(params, cfg, x, return_state=True)
    _, s_half = ssm.mamba_full(params, cfg, x[:, :8], return_state=True)
    # NOTE: threading state alone is not enough for exact continuation — the
    # causal conv window also crosses the boundary.  Check the STATE algebra
    # only: state after [first half; second half with initial_state] matches.
    # (The conv-boundary handoff is covered by test_decode_continues_prefill_state.)
    assert s_all.shape == s_half.shape
    assert np.all(np.isfinite(np.asarray(s_all)))


def test_state_dtype_fp32():
    cfg = _cfg()
    params = ssm.init_mamba(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 8, cfg.d_model), jnp.bfloat16)
    y, state = ssm.mamba_full(params, cfg, x, return_state=True)
    assert state.dtype == jnp.float32
    assert y.dtype == jnp.bfloat16


def test_gradients_flow_and_are_finite():
    cfg = _cfg(chunk=4)
    params = ssm.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(6), (1, 16, cfg.d_model), jnp.float32)

    def loss(p):
        return jnp.sum(jnp.square(ssm.mamba_full(p, cfg, x)))

    g = jax.grad(loss)(params)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert np.all(np.isfinite(np.asarray(leaf))), path
    # every projection participates
    assert float(jnp.max(jnp.abs(g["wx"]["w"]))) > 0
    assert float(jnp.max(jnp.abs(g["wBC"]["w"]))) > 0
