"""Noise-aware per-metric comparison of two BenchRuns.

Every metric the ledger tracks carries a :class:`MetricSpec`: which
direction is *worse*, how much relative movement is tolerated before a
delta becomes a :class:`Regression`, and whether the metric is noisy
(wall-clock times — tolerances scale with ``wall_tol_scale``) or
deterministic (the analytic counters: AI, R_ins, FLOPs, traffic — a
store round-trip reproduces them bit-for-bit, so their tolerances only
absorb float formatting, not measurement noise).

The output is typed all the way down: ``compare_runs`` returns a
:class:`RunComparison` holding every :class:`MetricDelta` plus the
:class:`Regression` subset the gate acts on; the triage layer
(:mod:`repro.perf.triage`) then explains each regression with the
paper's own decision tree.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.perf.ledger import BenchRun


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """How one named metric is judged."""

    name: str
    worse: str  # "higher" | "lower" — the direction that regresses
    rel_tol: float  # relative movement tolerated in the worse direction
    noisy: bool = False  # wall-clock metrics: tolerance scales with the gate knob


#: The gate's metric contract.  Timing metrics are noisy; counter metrics
#: are deterministic (2% covers dtype-cast and model-revision jitter while
#: still catching any real shift); ``perf_class`` regresses on ANY drop —
#: a Fig. 8 class transition is the headline signal, not a percentage.
SPECS: Dict[str, MetricSpec] = {
    s.name: s
    for s in (
        MetricSpec("wall_s", "higher", 0.10, noisy=True),
        MetricSpec("best_time_s", "higher", 0.15, noisy=True),
        MetricSpec("default_time_s", "higher", 0.25, noisy=True),
        MetricSpec("speedup_vs_default", "lower", 0.25, noisy=True),
        MetricSpec("rows", "lower", 0.0),
        MetricSpec("ai", "lower", 0.02),
        MetricSpec("r_ins", "lower", 0.02),
        MetricSpec("flops", "higher", 0.02),
        MetricSpec("hbm_bytes", "higher", 0.02),
        MetricSpec("gather_bytes", "higher", 0.05),
        MetricSpec("vectorizable_fraction", "lower", 0.02),
        MetricSpec("predicted_speedup", "lower", 0.02),
        MetricSpec("perf_class", "lower", 0.0),
        # serving metrics (launch.serve reports): throughput / latency are
        # wall-clock noisy; the scheduler counters are deterministic given
        # the request trace, and slot utilization dropping means the
        # scheduler started idling lanes — the Eq. 1 signal for serving
        MetricSpec("tok_s", "lower", 0.15, noisy=True),
        MetricSpec("p50_latency_s", "higher", 0.15, noisy=True),
        MetricSpec("p95_latency_s", "higher", 0.20, noisy=True),
        MetricSpec("ttft_p50_s", "higher", 0.15, noisy=True),
        MetricSpec("ttft_p95_s", "higher", 0.20, noisy=True),
        # step-clock TTFT (chunked prefill): a pure function of the seeded
        # request trace + scheduler config, so ANY growth regresses — this
        # is the tight signal; the wall TTFTs above absorb machine noise
        MetricSpec("ttft_p50_steps", "higher", 0.0),
        MetricSpec("ttft_p95_steps", "higher", 0.0),
        MetricSpec("prefill_chunk", "lower", 0.0),
        MetricSpec("slot_utilization", "lower", 0.02),
        MetricSpec("fused_steps", "higher", 0.0),
        MetricSpec("requests", "lower", 0.0),
        MetricSpec("new_tokens", "lower", 0.0),
        # scenario-cell counters: given the seeded trace these are exact,
        # so ANY movement regresses — a new rejection, preemption, or
        # restart under the same spec is a behavior change, not noise.
        # (golden_ok / slo_ok are booleans: _judge regresses True -> False
        # before any spec lookup.)
        MetricSpec("rejected", "higher", 0.0),
        MetricSpec("preemptions", "higher", 0.0),
        MetricSpec("restarts", "higher", 0.0),
        # block-pool dedup counters: deterministic given the trace.  The
        # dedup ratio falling (or physical blocks growing) means prefix
        # sharing stopped finding matches or COW started copying more —
        # the memory-side Eq. 1 regression
        MetricSpec("block_dedup_ratio", "lower", 0.0),
        MetricSpec("physical_blocks", "higher", 0.0),
        MetricSpec("logical_blocks", "lower", 0.0),
        MetricSpec("shared_block_hits", "lower", 0.0),
        MetricSpec("cow_copies", "higher", 0.0),
        MetricSpec("kv_bytes_served", "lower", 0.0),
        MetricSpec("kv_bytes_stored", "higher", 0.0),
        # speculative decoding: all exact given the trace, so tol 0.
        # acceptance_rate falling is the Eq. 1 regression (fewer active
        # lanes per k-wide verification issue); more rejected tokens,
        # more draft calls, or more target fused calls for the same
        # traffic all mean speculation got less effective.
        MetricSpec("acceptance_rate", "lower", 0.0),
        MetricSpec("drafted_tokens", "lower", 0.0),
        MetricSpec("accepted_tokens", "lower", 0.0),
        MetricSpec("rejected_tokens", "higher", 0.0),
        MetricSpec("draft_steps", "higher", 0.0),
        MetricSpec("target_steps", "higher", 0.0),
        MetricSpec("spec_k", "lower", 0.0),
        # tensor-parallel serving: both exact given the trace + mesh shape
        # (each +mesh<DxM> fork is its own trajectory).  The worst device
        # shard's busy-lane fraction falling means the mesh started
        # idling a device's lanes — Eq. 1's regression one level up.
        MetricSpec("device_lane_utilization", "lower", 0.0),
        MetricSpec("mesh_devices", "lower", 0.0),
    )
}


@dataclasses.dataclass(frozen=True)
class MetricDelta:
    """One (workload key, metric) movement between baseline and run."""

    key: str
    metric: str
    before: Any
    after: Any
    rel_delta: float  # signed (after - before) / |before|; +-inf from a 0 baseline
    tol: float
    regressed: bool
    improved: bool

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        if isinstance(d["rel_delta"], float) and not math.isfinite(d["rel_delta"]):
            d["rel_delta"] = None  # undefined vs a zero baseline; keep JSON strict
        return d


@dataclasses.dataclass(frozen=True)
class Regression:
    """A delta that moved past tolerance in the worse direction."""

    key: str
    metric: str
    before: Any
    after: Any
    rel_delta: float
    tol: float

    @property
    def severity(self) -> float:
        """How far past tolerance the movement went (>= 0)."""
        return max(0.0, abs(self.rel_delta) - self.tol)

    def describe(self) -> str:
        return (
            f"{self.key}: {self.metric} {self.before} -> {self.after} "
            f"({self.rel_delta:+.1%}, tol {self.tol:.0%})"
        )

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["severity"] = self.severity
        for k in ("rel_delta", "severity"):
            if isinstance(d[k], float) and not math.isfinite(d[k]):
                d[k] = None  # counter sprang from a zero baseline; keep JSON strict
        return d


@dataclasses.dataclass
class RunComparison:
    """Everything ``compare_runs`` derives about (baseline, run)."""

    baseline_id: str
    run_id: str
    deltas: List[MetricDelta]
    regressions: List[Regression]
    improvements: List[MetricDelta]
    new_keys: List[str]
    missing_keys: List[str]
    # per-metric coverage drift within shared keys: "<key>.<metric>" names
    # present only in the baseline (vanished — a gated metric silently
    # disappearing must be visible) or only in the run (new)
    missing_metrics: List[str] = dataclasses.field(default_factory=list)
    new_metrics: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.regressions

    def to_dict(self) -> Dict[str, Any]:
        return {
            "baseline_id": self.baseline_id,
            "run_id": self.run_id,
            "ok": self.ok,
            "regressions": [r.to_dict() for r in self.regressions],
            "improvements": [d.to_dict() for d in self.improvements],
            "deltas": [d.to_dict() for d in self.deltas],
            "new_keys": self.new_keys,
            "missing_keys": self.missing_keys,
            "missing_metrics": self.missing_metrics,
            "new_metrics": self.new_metrics,
        }


def _judge(
    spec: Optional[MetricSpec], before: Any, after: Any, wall_tol_scale: float
) -> Tuple[float, float, bool, bool]:
    """(rel_delta, tol, regressed, improved) for one metric pair."""
    if isinstance(before, bool) or isinstance(after, bool):
        regressed = bool(before) and not bool(after)
        improved = not bool(before) and bool(after)
        return (0.0 if before == after else (1.0 if improved else -1.0),
                0.0, regressed, improved)
    if not isinstance(before, (int, float)) or not isinstance(after, (int, float)):
        # configs and other identity metrics: drift is context for the
        # triage, never a regression by itself
        return 0.0, 0.0, False, False
    if before == 0:
        # no relative judgement exists against a zero baseline (a rounded
        # 0.000s wall time would read epsilon-nonzero as an astronomical
        # regression); report the movement, never gate on it — EXCEPT for
        # exact counters (rel_tol 0), where zero is a real value, not a
        # rounding artifact: the first rejection/preemption/restart under
        # an unchanged spec is a behavior change and must gate
        rel = 0.0 if after == 0 else math.copysign(float("inf"), after)
        if spec is not None and spec.rel_tol == 0 and after != 0:
            worse = rel > 0 if spec.worse == "higher" else rel < 0
            return rel, 0.0, worse, not worse
        return rel, 0.0, False, False
    rel = (after - before) / abs(before)
    if spec is None:
        return rel, 0.0, False, False  # untracked metric: informational
    tol = spec.rel_tol * (wall_tol_scale if spec.noisy else 1.0)
    worse = rel > tol if spec.worse == "higher" else rel < -tol
    better = rel < -tol if spec.worse == "higher" else rel > tol
    return rel, tol, worse, better


def compare_runs(
    baseline: BenchRun,
    run: BenchRun,
    *,
    wall_tol_scale: float = 1.0,
    specs: Optional[Mapping[str, MetricSpec]] = None,
) -> RunComparison:
    """Judge ``run`` against ``baseline`` metric by metric.

    Workload keys present only in one run are reported (``new_keys`` /
    ``missing_keys``) but never gate: recording a different benchmark
    subset is an operator choice, not a regression.  ``wall_tol_scale``
    multiplies the tolerance of every noisy (timing) metric — CI runners
    pass > 1 to absorb shared-machine scheduling noise without loosening
    the deterministic counter contract.
    """
    specs = SPECS if specs is None else specs
    deltas: List[MetricDelta] = []
    regressions: List[Regression] = []
    improvements: List[MetricDelta] = []
    missing_metrics: List[str] = []
    new_metrics: List[str] = []
    common = [k for k in baseline.metrics if k in run.metrics]
    for key in common:
        before_m, after_m = baseline.metrics[key], run.metrics[key]
        new_metrics.extend(f"{key}.{n}" for n in after_m if n not in before_m)
        for name in before_m:
            if name not in after_m:
                missing_metrics.append(f"{key}.{name}")
                continue
            rel, tol, worse, better = _judge(
                specs.get(name), before_m[name], after_m[name], wall_tol_scale
            )
            delta = MetricDelta(
                key=key, metric=name, before=before_m[name],
                after=after_m[name], rel_delta=rel, tol=tol,
                regressed=worse, improved=better,
            )
            deltas.append(delta)
            if worse:
                regressions.append(Regression(
                    key=key, metric=name, before=before_m[name],
                    after=after_m[name], rel_delta=rel, tol=tol,
                ))
            elif better:
                improvements.append(delta)
    regressions.sort(key=lambda r: -r.severity)
    return RunComparison(
        baseline_id=baseline.run_id,
        run_id=run.run_id,
        deltas=deltas,
        regressions=regressions,
        improvements=improvements,
        new_keys=sorted(set(run.metrics) - set(baseline.metrics)),
        missing_keys=sorted(set(baseline.metrics) - set(run.metrics)),
        missing_metrics=sorted(missing_metrics),
        new_metrics=sorted(new_metrics),
    )
