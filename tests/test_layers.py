"""Layer-level unit + hypothesis property tests (norms, RoPE, FFN, embed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models import layers

_dims = st.sampled_from([4, 8, 16, 32, 64])
_seeds = st.integers(0, 2**31 - 1)


@settings(max_examples=15, deadline=None)
@given(d=_dims, seed=_seeds)
def test_rms_norm_unit_rms(d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, d), jnp.float32) * 7.0
    y = layers.rms_norm({"scale": jnp.ones((d,))}, x, eps=1e-6)
    rms = np.sqrt(np.mean(np.square(np.asarray(y)), axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=2e-3)


@settings(max_examples=15, deadline=None)
@given(d=_dims, seed=_seeds)
def test_layer_norm_zero_mean_unit_var(d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, d), jnp.float32) * 5 + 2
    y = np.asarray(layers.layer_norm({}, x, eps=1e-6))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(y.var(-1), 1.0, rtol=5e-3)


def test_nonparam_layer_norm_has_no_params():
    import repro.configs as configs

    cfg = configs.get_config("olmo-1b")
    assert cfg.nonparam_ln
    init_fn, apply_fn = layers.make_norm(cfg)
    assert init_fn(jnp.float32) == {}
    x = jax.random.normal(jax.random.PRNGKey(0), (2, cfg.d_model), jnp.float32)
    y = apply_fn({}, x)
    assert y.shape == x.shape


@settings(max_examples=10, deadline=None)
@given(seed=_seeds)
def test_rope_preserves_norm(seed):
    """Rotations preserve the L2 norm of every (x1,x2) pair."""
    B, S, H, D = 2, 8, 2, 16
    x = jax.random.normal(jax.random.PRNGKey(seed), (B, S, H, D), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    cos, sin = layers.rope_cos_sin(pos, D, 10000.0)
    y = layers.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_relative_position_property():
    """<rope(q,m), rope(k,n)> depends only on (m - n)."""
    D = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D), jnp.float32)

    def dot_at(m, n):
        pm = jnp.asarray([[m]], jnp.int32)
        pn = jnp.asarray([[n]], jnp.int32)
        cm, sm = layers.rope_cos_sin(pm, D, 10000.0)
        cn, sn = layers.rope_cos_sin(pn, D, 10000.0)
        qr = layers.apply_rope(q, cm, sm)
        kr = layers.apply_rope(k, cn, sn)
        return float(jnp.sum(qr * kr))

    np.testing.assert_allclose(dot_at(5, 3), dot_at(12, 10), rtol=1e-5)
    np.testing.assert_allclose(dot_at(0, 0), dot_at(9, 9), rtol=1e-5)
    assert abs(dot_at(5, 3) - dot_at(5, 0)) > 1e-6  # actually position-dependent


def test_rope_position_zero_is_identity():
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 2, 8), jnp.float32)
    pos = jnp.zeros((1, 1), jnp.int32)
    cos, sin = layers.rope_cos_sin(pos, 8, 10000.0)
    y = layers.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


def test_swiglu_shapes_and_grad():
    p = layers.swiglu_init(jax.random.PRNGKey(0), 16, 32, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 16), jnp.float32)
    y = layers.swiglu(p, x)
    assert y.shape == (2, 5, 16)
    g = jax.grad(lambda p: jnp.sum(layers.swiglu(p, x) ** 2))(p)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in jax.tree.leaves(g))


def test_embed_unembed_tied_consistency():
    p = layers.embed_init(jax.random.PRNGKey(0), 32, 8, jnp.float32)
    tok = jnp.asarray([[0, 5, 31]])
    x = layers.embed(p, tok)
    assert x.shape == (1, 3, 8)
    logits = layers.unembed(p, x)
    assert logits.shape == (1, 3, 32)
    assert logits.dtype == jnp.float32
    # the gold token should score its own embedding's squared norm
    np.testing.assert_allclose(
        float(logits[0, 1, 5]),
        float(jnp.sum(p["embedding"][5] ** 2)),
        rtol=1e-5,
    )


@settings(max_examples=10, deadline=None)
@given(
    d_in=_dims, d_out=_dims, bias=st.booleans(), seed=_seeds
)
def test_dense_bias_and_shapes(d_in, d_out, bias, seed):
    p = layers.dense_init(jax.random.PRNGKey(seed), d_in, d_out, jnp.float32, bias=bias)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (3, d_in), jnp.float32)
    y = layers.dense(p, x)
    assert y.shape == (3, d_out)
    assert ("b" in p) == bias
