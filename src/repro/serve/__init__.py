"""Serving layer: the batched decode engine and the analysis service.

All exports resolve lazily (PEP 562): the analysis service — which needs
only ``repro.analysis`` — doesn't pay the transformer-stack import on
startup, and ``python -m repro.serve.analysis_service`` doesn't double-load
its own module through the package import.
"""

_LAZY_EXPORTS = {
    "Request": "repro.serve.engine",
    "RequestTooLong": "repro.serve.engine",
    "ServeEngine": "repro.serve.engine",
    "AnalysisRequest": "repro.serve.analysis_service",
    "AnalysisService": "repro.serve.analysis_service",
}


def __getattr__(name):
    mod_name = _LAZY_EXPORTS.get(name)
    if mod_name is not None:
        import importlib

        return getattr(importlib.import_module(mod_name), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY_EXPORTS))
