"""Paper-faithful validation of the core analysis library.

These tests pin the reproduction to the paper's OWN numbers:
  * VB = 2 (FP64) / 4 (FP32) on 128-bit SVE          (Fig. 3a dashed lines)
  * SpMV: predicated R_ins ~= 2x, fixed-width ~= 1x  (Fig. 3a SpMV bars)
  * STREAM reduction ~ VB but NO predicted speedup   (Fig. 3b / roofline)
  * synthetic SpMV: speedup saturates at VB as AI grows (Fig. 6)
  * decision tree reproduces Table 3's 26-case classification
"""

import numpy as np
import pytest

from repro.core import hw, metrics, roofline
from repro.core.counters import Events, events_from_analytic
from repro.core.decision_tree import PerfClass, classify
from repro.core.metrics import VectorizationReport


# ---------------------------------------------------------------------------
# Eq. 1 — VB and R_ins
# ---------------------------------------------------------------------------


def test_vb_grace_fp64_is_2():
    assert metrics.vectorization_bound(hw.GRACE_CORE, "fp64") == 2.0


def test_vb_grace_fp32_is_4():
    assert metrics.vectorization_bound(hw.GRACE_CORE, "fp32") == 4.0


def test_vb_grace_fp16_is_8():
    assert metrics.vectorization_bound(hw.GRACE_CORE, "fp16") == 8.0


def test_instruction_reduction_basic():
    assert metrics.instruction_reduction(100, 50) == 2.0
    assert metrics.instruction_reduction(100, 100) == 1.0
    assert metrics.instruction_reduction(0, 0) == 1.0


def test_amdahl_r_ins_collapses_with_serial_fraction():
    """Paper Sec. 4.1: threading-runtime instructions crush R_ins."""
    assert metrics.amdahl_r_ins(4.0, 1.0) == pytest.approx(4.0)
    assert metrics.amdahl_r_ins(4.0, 0.5) == pytest.approx(1.6)
    assert metrics.amdahl_r_ins(4.0, 0.0) == pytest.approx(1.0)
    # monotone in f
    rs = [metrics.amdahl_r_ins(4.0, f) for f in np.linspace(0, 1, 11)]
    assert all(b >= a for a, b in zip(rs, rs[1:]))


def test_spmv_predication_reproduces_fig3a():
    """Ragged rows: predicated (SVE) ~2x vs fixed-width (ASIMD) ~1x."""
    from repro.kernels.spmv.ops import issue_counts

    rng = np.random.default_rng(0)
    row_nnz = rng.integers(1, 65, size=4096)  # ragged in [1, 64]
    counts = issue_counts(row_nnz, width=128, lane=64)
    # SVE-style: every row fits one predicated tile -> R = mean(nnz) ~ 32x/...
    # in ELEMENT units lane=64: ceil(nnz/64)=1 per row; scalar=sum(nnz)
    assert counts["r_ins_predicated"] > 1.5 * counts["r_ins_fixed"]
    # fixed width charges ceil(128/64)=2 issues/row regardless of nnz
    assert counts["fixed_width"] == 2 * len(row_nnz)


def test_vector_issues_ragged_vs_padded():
    ragged = metrics.vector_issues(
        0, "fp32", hw.GRACE_CORE, ragged_extents=[1, 2, 3, 4], tile=4
    )
    assert ragged == 4  # one predicated tile per row
    padded = 4 * int(np.ceil(4 / 4))  # fixed width = max row, 1 tile each too
    assert padded == 4
    # with tile=2: ragged = 1+1+2+2 = 6; padded charges 2 per row = 8
    ragged2 = metrics.vector_issues(
        0, "fp32", hw.GRACE_CORE, ragged_extents=[1, 2, 3, 4], tile=2
    )
    assert ragged2 == 6


# ---------------------------------------------------------------------------
# Eq. 2 — adapted roofline
# ---------------------------------------------------------------------------


def test_roofline_inflection_shift():
    rl64 = roofline.adapted_roofline(hw.GRACE_CORE, "fp64")
    rl32 = roofline.adapted_roofline(hw.GRACE_CORE, "fp32")
    # AI_IRV = AI_IRR * VB (paper Eq. 2)
    assert rl64.ai_irv == pytest.approx(rl64.ai_irr * 2.0)
    assert rl32.ai_irv == pytest.approx(rl32.ai_irr * 4.0)
    # smaller elements move the knee right: fp32 knee > fp64 knee
    assert rl32.ai_irv > rl64.ai_irv


def test_roofline_predicted_speedup_saturates_at_vb():
    """Fig. 6: speedup grows with AI and saturates at VB."""
    rl = roofline.adapted_roofline(hw.GRACE_CORE, "fp64")
    ais = np.logspace(-2, 3, 40)
    sp = [rl.predicted_speedup(a) for a in ais]
    assert all(b >= a - 1e-9 for a, b in zip(sp, sp[1:])), "monotone"
    assert sp[0] == pytest.approx(1.0, abs=1e-6), "memory-bound: no speedup"
    assert sp[-1] == pytest.approx(2.0, rel=1e-6), "saturates at VB=2"


def test_roofline_vectorization_can_flip_compute_to_memory_bound():
    """Paper Fig. 7 red triangles: a kernel right of the scalar knee but left
    of the vector knee is compute-bound scalar, memory-bound vectorized."""
    rl = roofline.adapted_roofline(hw.GRACE_CORE, "fp32")
    ai = (rl.ai_irr + rl.ai_irv) / 2
    assert rl.region(ai, vectorized=False) == "compute-bound"
    assert rl.region(ai, vectorized=True) == "memory-bound"


def test_stream_no_speedup_spmv20_speedup():
    """STREAM triad (AI ~ 0.08) -> ~1x; synthetic SpMV repeat-20 -> ~VB."""
    rl = roofline.adapted_roofline(hw.GRACE_CORE, "fp64")
    # STREAM triad: 2 flops / 24 bytes
    assert rl.predicted_speedup(2 / 24) == pytest.approx(1.0, abs=0.05)
    # paper: repeat=20 FP64 synthetic achieved 1.8x (model: saturated ~ 2x)
    from repro.kernels.spmv.ops import flops_bytes

    fb = flops_bytes(np.full(1024, 32), repeat=20, dtype_bytes=8)
    assert rl.predicted_speedup(fb["ai"]) > 1.7


def test_three_term_roofline_dominance():
    ev = events_from_analytic(
        flops=1e15, hbm_bytes=1e12, collective_bytes=1e10, n_devices=256
    )
    terms = roofline.three_term(ev, hw.TPU_V5E, 256, dtype="bf16", model_flops=8e14)
    assert terms.compute_s == pytest.approx(1e15 / (256 * 197e12))
    assert terms.memory_s == pytest.approx(1e12 / (256 * 819e9))
    assert terms.collective_s == pytest.approx(1e10 / (256 * 200e9))
    assert terms.dominant == "compute"
    assert terms.useful_flop_fraction == pytest.approx(0.8)
    assert 0 < terms.roofline_fraction <= 1.0


def test_model_flops_lm():
    assert roofline.model_flops_lm(1e9, 1e6, training=True) == 6e15
    assert roofline.model_flops_lm(1e9, 1e6, training=False) == 2e15
    assert roofline.model_flops_lm(1e9, 1e6, training=True, n_active=5e8) == 3e15


# ---------------------------------------------------------------------------
# Decision tree — Table 3 reproduction
# ---------------------------------------------------------------------------


def _report(name, dtype, ai, r_ins, gather_frac=0.0, vec_frac=1.0):
    hbm = 1e9
    return VectorizationReport(
        name=name,
        dtype=dtype,
        flops=ai * hbm,
        hbm_bytes=hbm,
        gather_bytes=gather_frac * hbm,
        ins_scalar=r_ins * 1e6,
        ins_vec=1e6,
        vectorizable_fraction=vec_frac,
    )


# The paper's Table 3, 1-thread column, as (name, dtype, AI, R_ins, gather
# fraction, vectorizable fraction) -> expected class.  AI values follow the
# paper's Fig. 7 annotation (GRACE_CORE fp64 knee = 27.6/30 ~ 0.92 flop/B;
# fp32 knee identical in scalar form).
TABLE3_1T = [
    ("YOLOv3", "fp32", 50.0, 3.8, 0.0, 1.0, PerfClass.SPEEDUP),
    ("LLM-training", "fp32", 30.0, 3.6, 0.0, 1.0, PerfClass.SPEEDUP),
    ("LLM-inference", "fp32", 20.0, 3.6, 0.0, 1.0, PerfClass.SPEEDUP),
    ("QC-simulator", "fp64", 2.0, 1.8, 0.0, 1.0, PerfClass.SPEEDUP),
    ("FFT1D", "fp64", 3.0, 1.02, 0.0, 0.05, PerfClass.NOT_VECTORIZED),
    ("FFT2D", "fp64", 3.0, 1.02, 0.0, 0.05, PerfClass.NOT_VECTORIZED),
    ("STREAM", "fp64", 2 / 24, 2.0, 0.0, 1.0, PerfClass.MEMORY_BANDWIDTH_BOUND),
    ("DGEMM", "fp64", 100.0, 1.8, 0.0, 1.0, PerfClass.SPEEDUP),
    ("SGEMM", "fp32", 200.0, 3.7, 0.0, 1.0, PerfClass.SPEEDUP),
    ("SpMV", "fp64", 0.25, 1.99, 0.5, 1.0, PerfClass.MEMORY_LATENCY_BOUND),
    ("Jacobi2D", "fp64", 0.375, 2.0, 0.0, 1.0, PerfClass.MEMORY_BANDWIDTH_BOUND),
    ("AlexNet", "fp32", 40.0, 3.7, 0.0, 1.0, PerfClass.SPEEDUP),
    ("AutoDock", "fp64", 10.0, 1.7, 0.0, 1.0, PerfClass.SPEEDUP),
]


@pytest.mark.parametrize("name,dtype,ai,r_ins,gf,vf,expected", TABLE3_1T)
def test_decision_tree_table3_single_thread(name, dtype, ai, r_ins, gf, vf, expected):
    decision = classify(_report(name, dtype, ai, r_ins, gf, vf), hw.GRACE_CORE)
    assert decision.perf_class == expected, decision.rationale


def test_decision_tree_qc_flips_memory_bound_at_72t():
    """Table 3: QC simulator is Class 4 at 1 thread, Class 2 at 72 threads
    (socket bandwidth saturates; per-core share of BW collapses)."""
    d1 = classify(_report("QC", "fp64", 2.0, 1.8), hw.GRACE_CORE)
    assert d1.perf_class == PerfClass.SPEEDUP
    # at 72 threads the same kernel sees the socket: peak x72, BW only x8.3
    d72 = classify(_report("QC", "fp64", 2.0, 1.8), hw.GRACE_SOCKET)
    assert d72.perf_class == PerfClass.MEMORY_BANDWIDTH_BOUND


def test_decision_tree_jacobi_flips_class1_at_72t():
    """Table 3: Jacobi2D 72T — R_ins collapses (threading runtime) -> Class 1."""
    r = metrics.amdahl_r_ins(2.0, 0.15)  # mostly non-vector instructions
    d = classify(_report("Jacobi2D-72t", "fp64", 0.375, r), hw.GRACE_SOCKET)
    assert d.perf_class == PerfClass.NOT_VECTORIZED


def test_table3_class_counts():
    """15/26 speedup, 6 memory-bound-no-speedup, 5 not-vectorized (paper)."""
    cases_72t = [
        ("YOLOv3", "fp32", 50.0, 2.4, 0.0, 1.0),
        ("LLM-training", "fp32", 30.0, 2.2, 0.0, 1.0),
        ("LLM-inference", "fp32", 20.0, 2.2, 0.0, 1.0),
        ("QC-simulator", "fp64", 2.0, 1.8, 0.0, 1.0),
        ("FFT1D", "fp64", 3.0, 1.02, 0.0, 0.05),
        ("FFT2D", "fp64", 3.0, 1.02, 0.0, 0.05),
        ("STREAM", "fp64", 2 / 24, 2.0, 0.0, 1.0),
        ("DGEMM", "fp64", 100.0, 1.8, 0.0, 1.0),
        ("SGEMM", "fp32", 200.0, 3.7, 0.0, 1.0),
        ("SpMV", "fp64", 0.25, 1.99, 0.5, 1.0),
        ("Jacobi2D", "fp64", 0.375, 1.05, 0.0, 0.15),
        ("AlexNet", "fp32", 40.0, 2.5, 0.0, 1.0),
        ("AutoDock", "fp64", 10.0, 1.7, 0.0, 1.0),
    ]
    chips_1t = [classify(_report(*c[:4], c[4], c[5]), hw.GRACE_CORE).perf_class
                for c in [t[:6] for t in TABLE3_1T]]
    chips_72 = [classify(_report(*c), hw.GRACE_SOCKET).perf_class for c in cases_72t]
    all_classes = chips_1t + chips_72
    counts = {c: all_classes.count(c) for c in PerfClass}
    assert counts[PerfClass.SPEEDUP] == 15
    assert counts[PerfClass.NOT_VECTORIZED] == 5
    assert (
        counts[PerfClass.MEMORY_BANDWIDTH_BOUND]
        + counts[PerfClass.MEMORY_LATENCY_BOUND]
        == 6
    )
