"""The paper's methodology as a user-facing tool: point it at ANY jitted
JAX function and get the full SVE-style vectorization report — validated
counters, VB / R_ins, adapted roofline placement, and the Fig. 8 decision
tree — for both the Grace-class CPU model and the TPU target.

All wiring goes through the unified API: wrap each function in a
``Workload`` and run ONE ``analyze_sweep`` over the whole set.  The sweep
fans (workload x chip) cells over a thread pool (``jobs=4``) while the
single-flight ArtifactCache keeps compiles at one per workload, and the
persistent store makes a second run of this script compile nothing at all.

    PYTHONPATH=src python examples/vectorization_report.py
"""

import jax
import jax.numpy as jnp

from repro.analysis import ArtifactCache, DEFAULT_STORE, Workload, analyze_sweep, format_table
from repro.core import hw

CHIPS = (hw.GRACE_CORE, hw.TPU_V5E)


def build_workloads():
    n = 512
    a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
    # pointer chasing: the SpMV pattern
    idx = jax.random.randint(jax.random.PRNGKey(2), (n * n,), 0, n * n)
    flat = a.reshape(-1)

    # scanned layers: exercises the while-aware counter path
    def scanned(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    return [
        Workload(name="gemm-512", fn=lambda x, y: x @ y, args=(a, b)),
        Workload(name="stream-triad", fn=lambda x, y: x + 3.0 * y, args=(a, b)),
        Workload(name="gather-reduce", fn=lambda x, i: jnp.take(x, i).sum(),
                 args=(flat, idx)),
        Workload(name="scan-8-layers", fn=scanned, args=(a,)),
        # FFT: not MXU-vectorizable (the paper's FFTW Class-1 case)
        Workload(name="fft2d", fn=lambda x, _: jnp.abs(jnp.fft.fft2(x)),
                 args=(a, b)),
    ]


def main():
    wls = build_workloads()
    cache = ArtifactCache(store=DEFAULT_STORE)
    results = analyze_sweep(wls, chips=CHIPS, cache=cache, jobs=4)

    per_chip = len(CHIPS)
    for i, wl in enumerate(wls):
        ev = results[i * per_chip].events
        print(f"\n### {wl.name}")
        print(f"  flops={ev.flops:.3e}  traffic={ev.bytes_accessed:.3e}B  "
              f"gather={ev.gather_bytes:.3e}B  vec_frac={ev.vectorizable_fraction:.2%} "
              f"mxu_share={ev.mxu_fraction:.2%}")
        print(f"  counter validation: structural flops {ev.flops:.3e} vs "
              f"raw cost_analysis {ev.xla_raw_flops:.3e} "
              f"(scan trip counts: {ev.while_trip_counts or 'none'})")
        print(format_table(results[i * per_chip:(i + 1) * per_chip]))

    cells = len(results)
    print(f"\n[{cells} cells: {cache.compiles} compiles, "
          f"{cache.store_hits} store hits, {cache.hits} cache hits — "
          f"store at {cache.store.cache_dir}]")


if __name__ == "__main__":
    main()
