"""Flash-decode: one-token attention over a long KV cache, KV-blocked.

The serve-side hot loop of every decode_* cell: q (B, H, D) attends to a
(B, S, KV, D) cache of which only ``valid_len`` positions are live.  The
kernel streams KV blocks through VMEM keeping a running (max, sum, acc) —
online softmax — and PREDICATES each block on ``pos < valid_len``: ragged
context lengths occupy only ceil(valid/bs) block-issues per head instead of
S/bs, the SVE predication insight applied at the token level (a fixed-width
schedule must process the whole padded cache).

Grid: (B, KV-heads, S/bs) with the KV axis innermost (sequential).  GQA via
G query heads per KV head processed together — the q tile is (G, D), MXU
contractions are (G, D) x (D, bs).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, vl_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, bs: int, ns: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = vl_ref[0]
    q = q_ref[0, 0]  # (G, D)
    k = k_ref[0, 0]  # (bs, D)
    v = v_ref[0, 0]
    D = q.shape[-1]
    scale = 1.0 / math.sqrt(D)

    pos = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    pred = pos < valid  # predicate register analogue

    # skip fully-masked blocks entirely (ragged-length win; on TPU this is
    # the "don't issue the tile" branch)
    @pl.when(si * bs < valid)
    def _work():
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, bs)
        s = jnp.where(pred[None, :], s, NEG_INF)
        m_new = jnp.maximum(m_ref[...], s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_ref[...] - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(
    q: jax.Array,       # (B, KV, G, D)
    k: jax.Array,       # (B, S, KV, D)
    v: jax.Array,       # (B, S, KV, D)
    valid_len: jax.Array,  # (B,) int32 — live cache length per sequence
    *,
    block_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Returns (B, KV, G, D) attention output over the predicated cache."""
    B, KV, G, D = q.shape
    S = k.shape[1]
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    ns = S // bs
    kernel = functools.partial(_decode_kernel, bs=bs, ns=ns)
    from jax.experimental.pallas import tpu as pltpu

    kt = k.transpose(0, 2, 1, 3)  # (B, KV, S, D): head-major streaming
    vt = v.transpose(0, 2, 1, 3)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, ns),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1,), lambda b, h, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, kt, vt, valid_len)


def _decode_kernel_paged(bt_ref, *refs, bs: int, ns: int,
                         quantized: bool = False):
    """Same online-softmax body as :func:`_decode_kernel`; the KV tile for
    logical block ``si`` of sequence ``b`` is DMA'd from pool block
    ``bt_ref[b, si]`` (scalar-prefetched block table drives the index_map),
    so the kernel streams a non-contiguous paged cache without ever
    materializing a gathered copy.

    ``quantized`` threads two per-row fp32 scale tiles (the ELEN axis of
    the pool: int8 rows stream at 1/4 the HBM bytes and are widened back in
    VMEM right before the MXU contraction)."""
    if quantized:
        (q_ref, k_ref, v_ref, ks_ref, vs_ref, vl_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        q_ref, k_ref, v_ref, vl_ref, o_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = vl_ref[0]
    q = q_ref[0, 0]  # (G, D)
    k = k_ref[0, 0]  # (bs, D) — one pool block
    v = v_ref[0, 0]
    if ks_ref is not None:  # dequantize the tile in VMEM, post-DMA
        k = k.astype(jnp.float32) * ks_ref[0][:, None]
        v = v.astype(jnp.float32) * vs_ref[0][:, None]
    elif k.dtype != q.dtype:  # bf16 pool: widen to the compute dtype
        k = k.astype(q.dtype)
        v = v.astype(q.dtype)
    D = q.shape[-1]
    scale = 1.0 / math.sqrt(D)

    pos = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    pred = pos < valid  # per-slot length predication

    @pl.when(si * bs < valid)
    def _work():
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(pred[None, :], s, NEG_INF)
        m_new = jnp.maximum(m_ref[...], s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_ref[...] - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode_paged(
    q: jax.Array,            # (B, KV, G, D)
    k_pool: jax.Array,       # (n_blocks, block_size, KV, D)
    v_pool: jax.Array,       # (n_blocks, block_size, KV, D)
    block_tables: jax.Array,  # (B, nb) int32 — logical -> pool block map
    valid_len: jax.Array,    # (B,) int32 — live length per slot, >= 1
    *,
    k_scale: jax.Array = None,  # (n_blocks, block_size) f32 — int8 pools
    v_scale: jax.Array = None,
    head_shard: tuple = None,   # (shard_idx, n_shards) — local KV heads only
    interpret: bool = True,
) -> jax.Array:
    """Flash-decode over a PAGED cache: the continuous-batching serve path.

    Each slot's KV lives in ``valid_len[b] / block_size`` pool blocks named
    by its block-table row; the kernel walks logical blocks, prefetching
    the table so the BlockSpec index_map resolves the indirection at DMA
    time.  Fully-masked logical blocks (beyond the slot's live prefix) are
    never issued — the same predication economics as the contiguous
    kernel, now compounded with block reuse across requests.  Slots with
    ``valid_len == 0`` produce unspecified output (they have no live
    tokens to attend over); the serving engine masks such slots itself.

    Quantized paging (the ELEN axis of the pool): with int8 pools, pass
    ``k_scale``/``v_scale`` — one fp32 scale per pool ROW, shared across
    heads and the D axis — and each KV tile is dequantized in VMEM after
    the (4x smaller) DMA.  bf16 pools need no scales; the tile is widened
    to the query dtype before the contraction.

    Head sharding (tensor-parallel serving): ``head_shard=(i, n)`` runs
    only shard ``i``'s contiguous 1/n of the KV heads — q and the pools
    are sliced on their head axes and the output shrinks to ``(B, KV/n,
    G, D)``.  Attention is embarrassingly parallel over heads (softmax
    normalizes within a head), so shard outputs concatenate exactly to
    the unsharded result; per-row scales are head-agnostic and pass
    through whole.  :func:`flash_decode_paged_sharded` drives one such
    slice per device of a mesh's model axis via ``shard_map``.
    """
    if head_shard is not None:
        idx, n = head_shard
        kv_total = q.shape[1]
        if not 0 <= idx < n:
            raise ValueError(f"head_shard index {idx} outside [0, {n})")
        if kv_total % n:
            raise ValueError(
                f"{kv_total} KV heads not divisible into {n} shards")
        per = kv_total // n
        q = q[:, idx * per:(idx + 1) * per]
        k_pool = k_pool[:, :, idx * per:(idx + 1) * per]
        v_pool = v_pool[:, :, idx * per:(idx + 1) * per]
    B, KV, G, D = q.shape
    bs = k_pool.shape[1]
    nb = block_tables.shape[1]
    quantized = k_scale is not None
    if quantized and v_scale is None:
        raise ValueError("k_scale and v_scale must be passed together")
    kernel = functools.partial(_decode_kernel_paged, bs=bs, ns=nb,
                               quantized=quantized)
    from jax.experimental.pallas import tpu as pltpu

    kt = k_pool.transpose(0, 2, 1, 3)  # (n_blocks, KV, bs, D): head-major
    vt = v_pool.transpose(0, 2, 1, 3)
    in_specs = [
        pl.BlockSpec((1, 1, G, D), lambda b, h, s, bt: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, bs, D), lambda b, h, s, bt: (bt[b, s], h, 0, 0)),
        pl.BlockSpec((1, 1, bs, D), lambda b, h, s, bt: (bt[b, s], h, 0, 0)),
    ]
    operands = [block_tables, q, kt, vt]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, bs), lambda b, h, s, bt: (bt[b, s], 0)),
            pl.BlockSpec((1, bs), lambda b, h, s, bt: (bt[b, s], 0)),
        ]
        operands += [k_scale, v_scale]
    in_specs.append(pl.BlockSpec((1,), lambda b, h, s, bt: (b,)))
    operands.append(valid_len)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s, bt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(*operands)


def flash_decode_paged_sharded(
    q: jax.Array,             # (B, KV, G, D)
    k_pool: jax.Array,        # (n_blocks, block_size, KV, D)
    v_pool: jax.Array,        # (n_blocks, block_size, KV, D)
    block_tables: jax.Array,  # (B, nb) int32
    valid_len: jax.Array,     # (B,) int32
    *,
    mesh,                     # jax Mesh with a "model" axis
    axis: str = "model",
    k_scale: jax.Array = None,
    v_scale: jax.Array = None,
    interpret: bool = True,
) -> jax.Array:
    """Tensor-parallel paged flash-decode: one kernel launch per device of
    the mesh's ``axis``, each over its local 1/n of the KV heads.

    The pools shard on their head axis (``P(None, None, axis, None)`` —
    the block axis stays replicated so block tables resolve without
    cross-device gathers, matching the serving engine's head-sharded
    block-pool layout), the block table / lengths / per-row scales
    replicate, and the per-shard outputs concatenate on the head axis.
    No collective is needed: softmax normalizes within a head.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n = int(mesh.shape[axis])
    KV = q.shape[1]
    if KV % n:
        raise ValueError(f"{KV} KV heads not divisible over {n} "
                         f"{axis!r}-axis devices")
    head_q = P(None, axis, None, None)
    head_pool = P(None, None, axis, None)
    rep = P(*(None,) * 2)
    rep1 = P(None)
    quantized = k_scale is not None
    if quantized and v_scale is None:
        raise ValueError("k_scale and v_scale must be passed together")

    if quantized:
        def local(qi, kp, vp, bt, vl, ks, vs):
            return flash_decode_paged(qi, kp, vp, bt, vl, k_scale=ks,
                                      v_scale=vs, interpret=interpret)

        fn = shard_map(
            local, mesh=mesh,
            in_specs=(head_q, head_pool, head_pool, rep, rep1, rep, rep),
            out_specs=head_q, check_rep=False,
        )
        return fn(q, k_pool, v_pool, block_tables, valid_len,
                  k_scale, v_scale)

    def local(qi, kp, vp, bt, vl):
        return flash_decode_paged(qi, kp, vp, bt, vl, interpret=interpret)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(head_q, head_pool, head_pool, rep, rep1),
        out_specs=head_q, check_rep=False,
    )
    return fn(q, k_pool, v_pool, block_tables, valid_len)


# ---------------------------------------------------------------------------
# Chunked flash prefill over the paged cache
# ---------------------------------------------------------------------------


def _prefill_commit_kernel(bt_ref, qs_ref, ql_ref, kn_ref, vn_ref,
                           kp_ref, vp_ref, ko_ref, vo_ref, *, bs: int, C: int):
    """Scatter the chunk's K/V rows into one pool block of one slot.

    Grid (B, nb): every logical block of slot ``b`` streams through VMEM;
    rows whose global position lands in ``[q_start, q_start + q_len)`` are
    overlaid with the chunk's new K/V, the rest are copied through
    unchanged, and the block is written back to the (input-aliased) pool.
    Blocks no table row names are never visited and keep their bytes via
    the aliasing; the NULL block (0) may be written by several slots at
    once, so its content stays unspecified — exactly the idle-write
    contract the serving engine already relies on.
    """
    si = pl.program_id(1)
    q_start = qs_ref[0]
    q_len = ql_ref[0]
    pos = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    c_idx = pos - q_start
    in_chunk = (c_idx >= 0) & (c_idx < q_len)  # valid_len predication
    c_clip = jnp.clip(c_idx, 0, C - 1)
    k_blk = kp_ref[0]  # (KV, bs, D)
    v_blk = vp_ref[0]
    k_over = jnp.take(kn_ref[0], c_clip, axis=1)  # (KV, bs, D) chunk rows
    v_over = jnp.take(vn_ref[0], c_clip, axis=1)
    sel = in_chunk[None, :, None]
    ko_ref[0] = jnp.where(sel, k_over, k_blk)
    vo_ref[0] = jnp.where(sel, v_over, v_blk)


def _quantize_rows_kernel(x):
    """Per-row symmetric int8: one fp32 scale per pool row, amax over the
    (heads, D) extent of that row.  ``x`` is (KV, bs, D); returns the int8
    rows and the (bs,) scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(0, 2))
    s = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / s[None, :, None]), -127, 127
    ).astype(jnp.int8)
    return q, s


def _prefill_commit_kernel_q(bt_ref, qs_ref, ql_ref, kn_ref, vn_ref,
                             kp_ref, vp_ref, ksp_ref, vsp_ref,
                             ko_ref, vo_ref, kso_ref, vso_ref,
                             *, bs: int, C: int):
    """Quantizing variant of :func:`_prefill_commit_kernel`: chunk rows are
    quantized to int8 with one fresh fp32 scale per pool row before the
    overlay, and the scale pools ride through the same block-table-indexed
    write-back (rows outside the chunk keep block AND scale bytes)."""
    si = pl.program_id(1)
    q_start = qs_ref[0]
    q_len = ql_ref[0]
    pos = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    c_idx = pos - q_start
    in_chunk = (c_idx >= 0) & (c_idx < q_len)
    c_clip = jnp.clip(c_idx, 0, C - 1)
    k_over = jnp.take(kn_ref[0], c_clip, axis=1)  # (KV, bs, D) chunk rows
    v_over = jnp.take(vn_ref[0], c_clip, axis=1)
    kq, ks = _quantize_rows_kernel(k_over)
    vq, vs = _quantize_rows_kernel(v_over)
    sel = in_chunk[None, :, None]
    ko_ref[0] = jnp.where(sel, kq, kp_ref[0])
    vo_ref[0] = jnp.where(sel, vq, vp_ref[0])
    kso_ref[0] = jnp.where(in_chunk, ks, ksp_ref[0])
    vso_ref[0] = jnp.where(in_chunk, vs, vsp_ref[0])


def _prefill_attn_kernel(bt_ref, *refs, block_c: int, block_s: int,
                         ns: int, G: int, quantized: bool = False):
    """Causal online-softmax over one (query-tile, KV-block) grid cell.

    Same running (max, sum, acc) recurrence as :func:`_decode_kernel_paged`
    lifted to a ``block_c``-row query tile: the G grouped query heads of
    every chunk row are flattened into the tile so one MXU contraction
    covers the whole (block_c*G, block_s) score panel.  KV blocks beyond
    the tile's causal frontier are never issued — prompt-length
    predication, one level up from the decode kernel's ``valid_len``.
    ``quantized`` dequantizes each int8 KV sub-tile with its per-row fp32
    scales, exactly as the decode kernel does.
    """
    if quantized:
        (q_ref, k_ref, v_ref, ks_ref, vs_ref, qs_ref, ql_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (q_ref, k_ref, v_ref, qs_ref, ql_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
        ks_ref = vs_ref = None
    qi = pl.program_id(2)
    si = pl.program_id(3)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qs_ref[0]
    q = q_ref[0, 0]  # (block_c, G, D)
    D = q.shape[-1]
    q = q.reshape(block_c * G, D)
    k = k_ref[0, 0]  # (block_s, D)
    v = v_ref[0, 0]
    if ks_ref is not None:
        k = k.astype(jnp.float32) * ks_ref[0][:, None]
        v = v.astype(jnp.float32) * vs_ref[0][:, None]
    elif k.dtype != q.dtype:
        k = k.astype(q.dtype)
        v = v.astype(q.dtype)
    scale = 1.0 / math.sqrt(D)

    pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)[0]
    q_idx = jax.lax.broadcasted_iota(jnp.int32, (block_c, G), 0).reshape(
        block_c * G
    ) + qi * block_c
    limit = q_start + q_idx  # last key position each query row may see

    # skip KV blocks entirely beyond this query tile's causal frontier
    @pl.when(si * block_s <= q_start + (qi + 1) * block_c - 1)
    def _work():
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(pos[None, :] <= limit[:, None], s, NEG_INF)
        m_new = jnp.maximum(m_ref[...], s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_ref[...] - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (
            (acc_ref[...] / l[:, None]).reshape(block_c, G, D).astype(o_ref.dtype)
        )


def flash_prefill_paged(
    q: jax.Array,             # (B, C, KV, G, D) — chunk queries
    k_new: jax.Array,         # (B, C, KV, D) — chunk keys
    v_new: jax.Array,         # (B, C, KV, D) — chunk values
    k_pool: jax.Array,        # (n_blocks, block_size, KV, D)
    v_pool: jax.Array,        # (n_blocks, block_size, KV, D)
    block_tables: jax.Array,  # (B, nb) int32 — logical -> pool block map
    q_start: jax.Array,       # (B,) int32 — live context length before chunk
    q_len: jax.Array = None,  # (B,) int32 — valid chunk rows (default C)
    *,
    k_scale: jax.Array = None,  # (n_blocks, block_size) f32 — int8 pools
    v_scale: jax.Array = None,
    block_c: int = 8,
    block_s: int = 0,
    interpret: bool = True,
):
    """Chunked flash prefill over a PAGED cache: commit + attend, fused
    per chunk instead of per token.

    A chunk of ``C`` prompt tokens per slot is (1) scattered straight into
    the slot's pool blocks — the commit kernel walks the scalar-prefetched
    block table exactly like :func:`flash_decode_paged`, overlaying rows in
    ``[q_start, q_start + q_len)`` — and (2) attended causally against the
    updated pool with a ``block_c``-row online softmax, so a P-token prompt
    costs ``ceil(P / C)`` kernel launches instead of ``P``.  ``block_s``
    sub-tiles pool blocks (0 means one tile per pool block).

    Requirements and contract:
    * every chunk position must already be backed by a real (non-NULL)
      block-table entry — the engine allocates before it commits;
    * rows at or past ``q_len[b]`` are neither committed nor defined in the
      output (ragged final chunks);
    * the NULL block and pool blocks no table row references have
      unspecified content on return — compare through block tables.

    Quantized paging: with int8 pools pass ``k_scale``/``v_scale`` (one
    fp32 scale per pool row).  The commit kernel quantizes the chunk's
    rows and writes fresh scales alongside the blocks; the attend kernel
    dequantizes each sub-tile in VMEM.  The return grows to ``(out,
    k_pool', v_pool', k_scale', v_scale')``.  bf16 pools need no scales.

    Returns ``(out, k_pool', v_pool')`` with ``out`` shaped like ``q`` and
    the pools in their caller layout.
    """
    from jax.experimental.pallas import tpu as pltpu

    B, C, KV, G, D = q.shape
    bs = k_pool.shape[1]
    nb = block_tables.shape[1]
    quantized = k_scale is not None
    if quantized and v_scale is None:
        raise ValueError("k_scale and v_scale must be passed together")
    if q_len is None:
        q_len = jnp.full((B,), C, jnp.int32)
    bc = min(block_c, C)
    assert C % bc == 0, (C, bc)
    bks = bs if not block_s else min(block_s, bs)
    assert bs % bks == 0, (bs, bks)
    spp = bs // bks  # KV sub-tiles per pool block
    ns = nb * spp

    kp = k_pool.transpose(0, 2, 1, 3)  # (n_blocks, KV, bs, D): head-major
    vp = v_pool.transpose(0, 2, 1, 3)
    kn = k_new.transpose(0, 2, 1, 3)   # (B, KV, C, D)
    vn = v_new.transpose(0, 2, 1, 3)

    pool_spec = pl.BlockSpec((1, KV, bs, D), lambda b, s, bt: (bt[b, s], 0, 0, 0))
    scale_spec = pl.BlockSpec((1, bs), lambda b, s, bt: (bt[b, s], 0))
    commit_in = [
        pl.BlockSpec((1,), lambda b, s, bt: (b,)),
        pl.BlockSpec((1,), lambda b, s, bt: (b,)),
        pl.BlockSpec((1, KV, C, D), lambda b, s, bt: (b, 0, 0, 0)),
        pl.BlockSpec((1, KV, C, D), lambda b, s, bt: (b, 0, 0, 0)),
        pool_spec, pool_spec,
    ]
    commit_out = [pool_spec, pool_spec]
    commit_operands = [block_tables, q_start, q_len, kn, vn, kp, vp]
    commit_shapes = [
        jax.ShapeDtypeStruct(kp.shape, kp.dtype),
        jax.ShapeDtypeStruct(vp.shape, vp.dtype),
    ]
    # pool (and scale) operands alias their outputs so unvisited blocks
    # keep their bytes (indices count the scalar-prefetch operand)
    aliases = {5: 0, 6: 1}
    if quantized:
        commit_in += [scale_spec, scale_spec]
        commit_out += [scale_spec, scale_spec]
        commit_operands += [k_scale, v_scale]
        commit_shapes += [
            jax.ShapeDtypeStruct(k_scale.shape, k_scale.dtype),
            jax.ShapeDtypeStruct(v_scale.shape, v_scale.dtype),
        ]
        aliases = {5: 0, 6: 1, 7: 2, 8: 3}
    commit_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nb),
        in_specs=commit_in,
        out_specs=commit_out,
    )
    commit_body = (
        functools.partial(_prefill_commit_kernel_q, bs=bs, C=C) if quantized
        else functools.partial(_prefill_commit_kernel, bs=bs, C=C)
    )
    committed = pl.pallas_call(
        commit_body,
        grid_spec=commit_spec,
        out_shape=commit_shapes,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*commit_operands)
    if quantized:
        kp, vp, k_scale, v_scale = committed
    else:
        kp, vp = committed

    qh = q.transpose(0, 2, 1, 3, 4)  # (B, KV, C, G, D)
    attn_in = [
        pl.BlockSpec((1, 1, bc, G, D),
                     lambda b, h, qi, s, bt: (b, h, qi, 0, 0)),
        pl.BlockSpec((1, 1, bks, D),
                     lambda b, h, qi, s, bt: (bt[b, s // spp], h, s % spp, 0)),
        pl.BlockSpec((1, 1, bks, D),
                     lambda b, h, qi, s, bt: (bt[b, s // spp], h, s % spp, 0)),
    ]
    attn_operands = [block_tables, qh, kp, vp]
    if quantized:
        attn_in += [
            pl.BlockSpec((1, bks),
                         lambda b, h, qi, s, bt: (bt[b, s // spp], s % spp)),
            pl.BlockSpec((1, bks),
                         lambda b, h, qi, s, bt: (bt[b, s // spp], s % spp)),
        ]
        attn_operands += [k_scale, v_scale]
    attn_in += [
        pl.BlockSpec((1,), lambda b, h, qi, s, bt: (b,)),
        pl.BlockSpec((1,), lambda b, h, qi, s, bt: (b,)),
    ]
    attn_operands += [q_start, q_len]
    attn_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, C // bc, ns),
        in_specs=attn_in,
        out_specs=pl.BlockSpec((1, 1, bc, G, D),
                               lambda b, h, qi, s, bt: (b, h, qi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bc * G,), jnp.float32),
            pltpu.VMEM((bc * G,), jnp.float32),
            pltpu.VMEM((bc * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_prefill_attn_kernel, block_c=bc, block_s=bks,
                          ns=ns, G=G, quantized=quantized),
        grid_spec=attn_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, C, G, D), q.dtype),
        interpret=interpret,
    )(*attn_operands)

    out = out.transpose(0, 2, 1, 3, 4)
    kp = kp.transpose(0, 2, 1, 3)
    vp = vp.transpose(0, 2, 1, 3)
    if quantized:
        return out, kp, vp, k_scale, v_scale
    return out, kp, vp
