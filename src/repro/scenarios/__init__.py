"""Scenario-matrix traffic harness: declarative traffic x fault matrices
over the serve engine, with golden-twin equivalence and per-cell SLO
gating through the perf ledger.

See :mod:`repro.scenarios.matrix` (axes -> seeded cells),
:mod:`repro.scenarios.traffic` (cell -> reproducible request trace),
:mod:`repro.scenarios.faults` (fault plans), and
:mod:`repro.scenarios.runner` (execution, twin diffing, recording).
CLI: ``python -m repro.scenarios {list,run,gate}``.
"""

from repro.scenarios.faults import (
    PLANS,
    FaultPlan,
    SimulatedDeviceLoss,
    get_plan,
)
from repro.scenarios.matrix import (
    MATRICES,
    SERVE_ARCHS,
    ArrivalSpec,
    EosSpec,
    MatrixSpec,
    PromptSpec,
    Scenario,
    SLOSpec,
    cell_seed,
    full_matrix,
    load_matrix,
    smoke_matrix,
)
from repro.scenarios.runner import (
    CellResult,
    TrafficFeeder,
    format_matrix_markdown,
    record_cell,
    run_cell,
    run_matrix,
)
from repro.scenarios.traffic import RequestSpec, sample_trace

__all__ = [
    "ArrivalSpec", "PromptSpec", "EosSpec", "SLOSpec", "Scenario",
    "MatrixSpec", "MATRICES", "SERVE_ARCHS", "cell_seed", "smoke_matrix",
    "full_matrix", "load_matrix",
    "RequestSpec", "sample_trace",
    "FaultPlan", "PLANS", "get_plan", "SimulatedDeviceLoss",
    "CellResult", "TrafficFeeder", "run_cell", "run_matrix", "record_cell",
    "format_matrix_markdown",
]
