"""End-to-end training driver: config -> mesh -> sharded state -> resilient
loop (checkpoint/restart, straggler detection) -> metrics.

Single-host usage (CPU tests / examples):
    PYTHONPATH=src python -m repro.launch.train --arch gpt2-124m --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a fleet, the same entrypoint runs once per host (jax.distributed
initializes from the cluster env); the data pipeline is stateless-by-step so
restarts and elastic resizes replay exactly (see distributed/fault_tolerance).
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.checkpoint import CheckpointStore
from repro.configs.base import ShapeConfig
from repro.data import pipeline
from repro.distributed import sharding as shard_rules
from repro.distributed.fault_tolerance import FaultToleranceConfig, ResilientLoop
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.train import steps as steps_mod

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainJob:
    arch: str = "gpt2-124m"
    smoke: bool = True
    steps: int = 50
    batch: int = 8
    seq: int = 128
    lr: float = 3e-4
    microbatches: int = 1
    remat: str = "none"
    zero: bool = True
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 20
    model_axis: int = 1
    log_every: int = 10


def build_state(job: TrainJob, mesh):
    cfg = (configs.get_smoke_config(job.arch) if job.smoke
           else configs.get_config(job.arch))
    shape = ShapeConfig("train_job", job.seq, job.batch, "train")
    opt_cfg = adamw.AdamWConfig(lr=job.lr, total_steps=max(job.steps, 1))
    run = steps_mod.RunConfig(remat=job.remat, microbatches=job.microbatches,
                              zero=job.zero, opt=opt_cfg)
    params = steps_mod.init_model(jax.random.PRNGKey(job.seed), cfg)
    p_sh = shard_rules.param_shardings(params, mesh)
    params = jax.device_put(params, p_sh)
    opt = adamw.init_opt_state(params, run.opt)
    o_sh = shard_rules.opt_state_shardings(params, p_sh, mesh, zero=run.zero)
    return cfg, shape, run, {"params": params, "opt": opt}, p_sh


def train(job: TrainJob) -> Dict[str, Any]:
    mesh = make_host_mesh(job.model_axis)
    cfg, shape, run, state, p_sh = build_state(job, mesh)
    data_cfg = pipeline.DataConfig(seed=job.seed)
    train_step = jax.jit(steps_mod.make_train_step(cfg, run),
                         donate_argnums=(0, 1))
    metrics_hist = []

    def step_fn(step: int, state):
        batch = pipeline.global_batch(cfg, shape, data_cfg, step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        with mesh:
            params, opt, metrics = train_step(state["params"], state["opt"], batch)
        if step % job.log_every == 0 or step == job.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            metrics_hist.append(m)
            log.info("step %d loss %.4f gnorm %.3f", step, m["loss"], m["grad_norm"])
        return {"params": params, "opt": opt}

    if job.ckpt_dir:
        store = CheckpointStore(job.ckpt_dir)
        ft = FaultToleranceConfig(checkpoint_every=job.ckpt_every, async_save=True)
        loop = ResilientLoop(store, ft, step_fn,
                             lambda: build_state(job, mesh)[3])
        out = loop.run(job.steps)
        state = out["state"]
        result = {"restarts": out["restarts"],
                  "straggler_events": out["straggler_events"]}
    else:
        for step in range(job.steps):
            state = step_fn(step, state)
        result = {"restarts": 0, "straggler_events": 0}

    result.update({
        "final_metrics": metrics_hist[-1] if metrics_hist else {},
        "history": metrics_hist,
        "state": state,
        "cfg": cfg,
    })
    return result


def main(argv=None) -> int:
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    ap = argparse.ArgumentParser()
    for f in dataclasses.fields(TrainJob):
        name = "--" + f.name.replace("_", "-")
        if f.type == "bool" or isinstance(f.default, bool):
            ap.add_argument(name, action="store_true", default=f.default)
        else:
            ap.add_argument(name, type=type(f.default) if f.default is not None else str,
                            default=f.default)
    args = ap.parse_args(argv)
    job = TrainJob(**{f.name: getattr(args, f.name) for f in dataclasses.fields(TrainJob)})
    t0 = time.time()
    out = train(job)
    print(f"done in {time.time()-t0:.1f}s: {out['final_metrics']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
