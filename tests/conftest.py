"""Shared fixtures. Tests run on the single host CPU device (never set
xla_force_host_platform_device_count here — the dry-run owns that knob).

The default artifact store is pointed at a fresh temp dir so test runs
never read a developer's ~/.cache entries (which would turn compile-count
assertions stale) and never pollute it.  Store tests that exercise
cross-process persistence manage their own dirs via ``REPRO_ARTIFACT_DIR``.
"""

import dataclasses
import os
import tempfile

os.environ["REPRO_ARTIFACT_DIR"] = tempfile.mkdtemp(prefix="repro-artifacts-")

import jax
import pytest

import repro.configs as configs
from repro.configs.base import ShapeConfig


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


SMOKE_SHAPE = ShapeConfig("smoke", 32, 2, "train")


@pytest.fixture(scope="session")
def smoke_shape():
    return SMOKE_SHAPE


def dropless(cfg):
    """Copy of a smoke config with MoE capacity high enough to never drop
    tokens — needed when comparing full-sequence vs per-token routing."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0)
    )


ALL_ARCHS = configs.ALL_ARCHS
ASSIGNED_ARCHS = configs.ASSIGNED_ARCHS
