"""Pure-jnp oracle for block-ELL SpMV (+ the paper's repeat-K synthetic)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def dense_from_blockell(values, col_idx, row_nnz, n_cols: int):
    """Reconstruct the dense matrix (host-side, tests only)."""
    nb, rb, width = values.shape
    n_rows = nb * rb
    a = np.zeros((n_rows, n_cols), dtype=np.float64)
    v = np.asarray(values, np.float64)
    c = np.asarray(col_idx)
    nz = np.asarray(row_nnz)
    for b in range(nb):
        for r in range(rb):
            for j in range(int(nz[b, r])):
                a[b * rb + r, c[b, r, j]] += v[b, r, j]
    return a


def spmv_ref(values, col_idx, row_nnz, x, *, repeat: int = 1):
    """y[i] = sum_j val[i,j] * x[col[i,j]] over the first row_nnz[i] entries.

    ``repeat`` mimics the paper's synthetic benchmark: the FMA work is done
    ``repeat`` times (each contributing 1/repeat) — same result, repeat x
    the arithmetic intensity.
    """
    nb, rb, width = values.shape
    lane = jnp.arange(width)[None, None, :]
    mask = lane < row_nnz[:, :, None]
    gathered = x[col_idx] * values  # (nb, rb, width)
    contrib = jnp.where(mask, gathered, 0.0)
    y = jnp.zeros((nb, rb), values.dtype)
    for _ in range(repeat):
        y = y + contrib.sum(axis=-1) / repeat
    return y.reshape(nb * rb)


def make_problem(key, n_rows: int, n_cols: int, *, row_block: int = 8,
                 max_nnz: int = 64, width_pad: int = 128, dtype=jnp.float32,
                 zipf_a: float = 1.3):
    """Random ragged sparse matrix in block-ELL layout (Zipf row lengths —
    the irregularity that defeats fixed-width SIMD in the paper)."""
    import jax

    k1, k2, k3 = jax.random.split(key, 3)
    nb = -(-n_rows // row_block)
    width = -(-max_nnz // width_pad) * width_pad
    # Zipf-ish ragged row lengths in [1, max_nnz]
    u = jax.random.uniform(k1, (nb, row_block))
    row_nnz = (1 + (max_nnz - 1) * u ** zipf_a).astype(jnp.int32)
    col_idx = jax.random.randint(k2, (nb, row_block, width), 0, n_cols)
    values = jax.random.normal(k3, (nb, row_block, width), dtype)
    lane = jnp.arange(width)[None, None, :]
    values = jnp.where(lane < row_nnz[:, :, None], values, 0.0)
    return values, col_idx, row_nnz
