"""Per-architecture smoke tests (assignment requirement): a REDUCED config of
the same family runs one forward + one train step on CPU; output shapes and
finiteness are asserted.  The FULL configs are exercised only by the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.data import pipeline
from repro.optim import adamw
from repro.train import steps as steps_mod
from tests.conftest import SMOKE_SHAPE


@pytest.mark.parametrize("arch", configs.ALL_ARCHS)
def test_smoke_family_matches_full(arch):
    full = configs.get_config(arch)
    smoke = configs.get_smoke_config(arch)
    assert smoke.family == full.family
    assert smoke.is_encoder_decoder == full.is_encoder_decoder
    assert (smoke.moe is None) == (full.moe is None)
    assert (smoke.mla is None) == (full.mla is None)
    assert (smoke.ssm is None) == (full.ssm is None)
    assert smoke.layer_pattern == full.layer_pattern
    # smoke must actually be reduced
    assert smoke.d_model <= 128 and smoke.vocab <= 1024


@pytest.mark.parametrize("arch", configs.ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    run = steps_mod.RunConfig(remat="none", zero=False)
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    batch = pipeline.global_batch(cfg, SMOKE_SHAPE, pipeline.DataConfig(), 0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    logits, aux, labels = steps_mod.model_forward(params, cfg, batch, remat="none")
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))

    opt = adamw.init_opt_state(params, run.opt)
    ts = jax.jit(steps_mod.make_train_step(cfg, run))
    p2, o2, metrics = ts(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    changed = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        params, p2,
    )
    assert max(jax.tree.leaves(changed)) > 0.0


@pytest.mark.parametrize("arch", configs.ALL_ARCHS)
def test_smoke_remat_matches_no_remat(arch):
    """Activation checkpointing must not change the loss value."""
    cfg = configs.get_smoke_config(arch)
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    batch = pipeline.global_batch(cfg, SMOKE_SHAPE, pipeline.DataConfig(), 0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    run_a = steps_mod.RunConfig(remat="none")
    run_b = steps_mod.RunConfig(remat="full")
    la, _ = steps_mod.loss_fn(params, cfg, batch, run_a)
    lb, _ = steps_mod.loss_fn(params, cfg, batch, run_b)
    np.testing.assert_allclose(float(la), float(lb), rtol=1e-5)


def test_vocab_padding_invisible_to_loss():
    """Padded logit columns must not leak probability mass."""
    from repro.models import transformer

    cfg = configs.get_smoke_config("qwen3-1.7b")
    assert cfg.vocab_padded >= cfg.vocab
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (2, 8, cfg.vocab_padded), jnp.float32)
    labels = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    # poison the padded tail: loss must be unchanged because it is masked
    poisoned = logits.at[..., cfg.vocab:].add(100.0)
    l1 = transformer.lm_loss(logits, labels, real_vocab=cfg.vocab)
    l2 = transformer.lm_loss(poisoned, labels, real_vocab=cfg.vocab)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_param_counts_match_claimed_sizes():
    """Analytic parameter counts should land near the advertised sizes."""
    expected = {
        "qwen3-1.7b": (1.2e9, 2.6e9),
        "qwen3-32b": (28e9, 36e9),
        "qwen2.5-14b": (12e9, 16e9),
        "olmo-1b": (0.9e9, 1.6e9),
        "mamba2-370m": (0.30e9, 0.48e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "deepseek-v2-lite-16b": (13e9, 19e9),
        "jamba-1.5-large-398b": (330e9, 450e9),
        "internvl2-76b": (65e9, 85e9),
        "whisper-large-v3": (1.2e9, 2.3e9),
        "gpt2-124m": (0.10e9, 0.15e9),
    }
    for arch, (lo, hi) in expected.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_less_than_total():
    for arch in ("deepseek-moe-16b", "deepseek-v2-lite-16b", "jamba-1.5-large-398b"):
        cfg = configs.get_config(arch)
        assert cfg.active_param_count() < 0.6 * cfg.param_count()


def test_smoke_init_is_deterministic():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    p1 = steps_mod.init_model(jax.random.PRNGKey(7), cfg)
    p2 = steps_mod.init_model(jax.random.PRNGKey(7), cfg)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
