"""The perf-trajectory ledger: append-only, content-addressed BenchRuns.

Every benchmark / analysis / tuning run in this repo already emits a
machine-readable artifact (``summary.json``, ``tuning.json``, the analysis
service report) — and until now each one died with its process.  The ledger
turns them into :class:`BenchRun` records persisted through the same JSON
layer as the analysis pipeline's :class:`~repro.analysis.store.
ArtifactStore` (atomic temp-file + rename writes, corrupt entries skipped,
``$REPRO_ARTIFACT_DIR``-relative directory), under a ``perf/``
subdirectory.

Records are **append-only**: every ``record()`` writes a *new* entry whose
run id is a content address over (environment, metrics, sequence number,
timestamp) — recording the same payload twice appends twice, and no write
ever rewrites an earlier run.  That is what makes the ledger a trajectory:
``runs()`` returns the full history in sequence order, and the regression
gate (:mod:`repro.perf.gate`) compares any point against any baseline
policy (:mod:`repro.perf.baseline`).

Each run is stamped with a :class:`RunEnv` fingerprint — chip, dtype, git
SHA, jax version, active tuned-config hash, host — because per-architecture
speedups only mean anything against a baseline *for that architecture*
(Sharma et al. 2025), and VL-agnostic code makes performance a moving
target across vector lengths (Stephens et al. 2018): the ledger keys its
trajectory by (chip, dtype) series so those axes never get conflated.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import os
import platform
import subprocess
import time
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Union

from repro.analysis.store import ArtifactStore, _default_dir, _store_for

PERF_VERSION = 1

#: Environment variable overriding the derived git SHA (containers/CI
#: sometimes run from an exported tree with no .git).
GIT_SHA_ENV = "REPRO_GIT_SHA"


# ---------------------------------------------------------------------------
# Environment fingerprinting
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def git_sha() -> str:
    """Short git SHA of the working tree, ``$REPRO_GIT_SHA``, or "unknown"."""
    env = os.environ.get(GIT_SHA_ENV)
    if env:
        return env
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True,
            text=True,
            timeout=5,
        )
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def jax_version() -> str:
    try:
        import jax

        return jax.__version__
    except Exception:  # noqa: BLE001 — env stamp must never fail a run
        return "unknown"


def tuned_state_hash() -> str:
    """Hash of every active tuned config across the kernel registry.

    This is the staleness signal the gate's triage keys on: a run recorded
    under one set of tuned configs and a run recorded under another are not
    the same experiment, even at the same git SHA.  Empty string when no
    kernel holds a tuned config.
    """
    try:
        from repro.kernels.registry import KERNELS

        parts = []
        for name in sorted(KERNELS):
            ops = KERNELS[name]
            tuned = getattr(ops, "_tuned", None)
            if tuned:
                for key in sorted(tuned):
                    parts.append(f"{name}@{key}:{sorted(tuned[key].items())!r}")
        if not parts:
            return ""
        return hashlib.sha256("|".join(parts).encode()).hexdigest()[:12]
    except Exception:  # noqa: BLE001 — env stamp must never fail a run
        return ""


@dataclasses.dataclass(frozen=True)
class RunEnv:
    """Environment fingerprint one BenchRun was measured under."""

    chip: str = "grace-core"
    dtype: str = "fp32"
    git_sha: str = "unknown"
    jax_version: str = "unknown"
    tuned_hash: str = ""
    host: str = ""

    def series_key(self) -> str:
        """The trajectory axis: runs compare within one (chip, dtype)."""
        return f"{self.chip}/{self.dtype}"

    def to_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RunEnv":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: str(v) for k, v in d.items() if k in fields})


def capture_env(chip: str = "grace-core", dtype: str = "fp32") -> RunEnv:
    """Stamp the current process: git SHA, jax version, tuned configs, host."""
    return RunEnv(
        chip=chip,
        dtype=dtype,
        git_sha=git_sha(),
        jax_version=jax_version(),
        tuned_hash=tuned_state_hash(),
        host=platform.node(),
    )


# ---------------------------------------------------------------------------
# The record
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BenchRun:
    """One appended trajectory point: env + per-workload metric dicts.

    ``metrics`` maps a workload key (``kernel/gemm@grace-core/fp32``,
    ``bench/fig3_vectorization``, ``tuning/gemm@grace-core/fp32``) to a flat
    dict of named quantities (``wall_s``, ``ai``, ``r_ins``, ``perf_class``,
    ...).  Everything the triage needs to re-run the paper's decision tree
    on a historical point is stored here — a BenchRun is self-contained.
    """

    run_id: str
    seq: int
    timestamp: float
    env: RunEnv
    metrics: Dict[str, Dict[str, Any]]
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "run_id": self.run_id,
            "seq": self.seq,
            "timestamp": self.timestamp,
            "env": self.env.to_dict(),
            "metrics": self.metrics,
            "meta": self.meta,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "BenchRun":
        return cls(
            run_id=str(d["run_id"]),
            seq=int(d["seq"]),
            timestamp=float(d.get("timestamp", 0.0)),
            env=RunEnv.from_dict(d.get("env") or {}),
            metrics={
                str(k): dict(v) for k, v in (d.get("metrics") or {}).items()
            },
            meta=dict(d.get("meta") or {}),
        )

    def metric(self, key: str, name: str, default: Any = None) -> Any:
        return (self.metrics.get(key) or {}).get(name, default)


def run_id_for(
    env: RunEnv, metrics: Mapping[str, Mapping[str, Any]], seq: int, ts: float
) -> str:
    """Content address of one trajectory point.

    Sequence number and timestamp are part of the address on purpose: the
    ledger is a *trajectory*, so two identical measurements made at
    different times are two distinct points, and appending can never
    silently rewrite history.
    """
    payload = json.dumps(
        {"env": env.to_dict(), "metrics": metrics, "seq": seq, "ts": ts},
        sort_keys=True,
        default=str,
    )
    return hashlib.sha256(payload.encode()).hexdigest()[:32]


# ---------------------------------------------------------------------------
# Metric extraction: summary.json / tuning.json / SVEAnalysis reports
# ---------------------------------------------------------------------------


def metrics_from_summary(summary: Mapping[str, Any]) -> Dict[str, Dict[str, Any]]:
    """Per-benchmark rows / wall time / pass-fail from ``summary.json``."""
    out: Dict[str, Dict[str, Any]] = {}
    for bench in summary.get("benchmarks") or []:
        name = bench.get("name", "?")
        out[f"bench/{name}"] = {
            "ok": bool(bench.get("ok")),
            "rows": int(bench.get("rows", 0)),
            "wall_s": float(bench.get("wall_s", 0.0)),
        }
    return out


def _config_token(config: Any) -> str:
    """Order-stable string form of a tuned config dict."""
    if isinstance(config, Mapping):
        return " ".join(f"{k}={v}" for k, v in sorted(config.items()))
    return str(config)


def metrics_from_tuning(report: Mapping[str, Any]) -> Dict[str, Dict[str, Any]]:
    """Per-(kernel, chip, dtype) timings and configs from ``tuning.json``."""
    out: Dict[str, Dict[str, Any]] = {}
    for rec in report.get("records") or []:
        key = f"tuning/{rec['kernel']}@{rec['chip']}/{rec['dtype']}"
        out[key] = {
            "best_time_s": float(rec.get("best_time_s", 0.0)),
            "default_time_s": float(rec.get("default_time_s", 0.0)),
            "speedup_vs_default": float(rec.get("speedup_vs_default", 1.0)),
            "predicted_speedup": float(rec.get("predicted_speedup", 1.0)),
            "config": _config_token(rec.get("config") or {}),
        }
    return out


def _metrics_from_analysis_dict(d: Mapping[str, Any]) -> Dict[str, Any]:
    """Flatten one SVEAnalysis dict into the ledger's metric schema.

    Keeps every quantity the Fig. 8 decision tree and Eq. 2 roofline need,
    so :mod:`repro.perf.triage` can re-classify a historical point without
    the original events.
    """
    hbm = float(d.get("hbm_bytes") or 0.0)
    m: Dict[str, Any] = {
        "ai": float(d.get("ai") or 0.0),
        "r_ins": float(d.get("r_ins") or 0.0),
        "flops": float(d.get("flops") or 0.0),
        "hbm_bytes": hbm,
        "gather_bytes": float(d.get("gather_fraction") or 0.0) * hbm,
        "vectorizable_fraction": float(d.get("vectorizable_fraction") or 0.0),
        "perf_class": int(d.get("perf_class") or 0),
        "predicted_speedup": float(d.get("predicted_speedup") or 1.0),
    }
    if d.get("wall_s") is not None:
        m["wall_s"] = float(d["wall_s"])
    tuning = d.get("tuning") or {}
    if tuning.get("record"):
        m["config"] = _config_token(tuning["record"])
    return m


#: Serving-report quantities the ledger tracks (see
#: :meth:`repro.serve.engine.ServeEngine.stats`).  Slot utilization is the
#: Eq. 1 lane-utilization analogue at the serving layer; fused_steps and
#: the slot-step counters are deterministic given the request trace, so
#: the gate holds them as tightly as the analytic counters.  ``wall_s``
#: is deliberately NOT ingested: the shared spec table would gate it at
#: the benchmark tolerance (10%), tighter than the serving timing specs
#: (tok_s 15%, p95 20%) chosen to absorb short-smoke noise — it would
#: always trip first and make them dead letters.
_SERVING_METRICS = (
    "requests", "new_tokens", "fused_steps", "busy_slot_steps",
    "slot_steps", "slot_utilization", "tok_s",
    "p50_latency_s", "p95_latency_s", "ttft_p50_s", "ttft_p95_s",
    "ttft_p50_steps", "ttft_p95_steps",
    "preemptions", "rejected", "restarts", "prefill_chunk",
    # block-pool dedup (prefix sharing + quantized paging): deterministic
    # given the trace, so the gate holds the counters exactly and the
    # dedup ratio — the memory-side Eq. 1 analogue — like slot_utilization
    "logical_blocks", "physical_blocks", "shared_block_hits",
    "cow_copies", "kv_bytes_served", "kv_bytes_stored",
    "block_dedup_ratio",
    # speculative decoding: exact counters (deterministic given the
    # trace, held at tol 0) plus acceptance_rate — the Eq. 1 active-lane
    # fraction of each k-wide verification issue
    "spec_k", "drafted_tokens", "accepted_tokens", "rejected_tokens",
    "draft_steps", "target_steps", "acceptance_rate",
    # tensor-parallel serving: device count is placement config, and
    # device_lane_utilization (worst device shard's busy-lane fraction,
    # Eq. 1 one level up) is pure slot accounting — both exact
    "mesh_devices", "device_lane_utilization",
)

#: _SERVING_METRICS names that are exact counters (held tight by the gate);
#: the rest are wall-derived floats with noisy tolerances.
_SERVING_INT_METRICS = frozenset((
    "requests", "new_tokens", "fused_steps", "busy_slot_steps",
    "slot_steps", "preemptions", "rejected", "restarts", "prefill_chunk",
    "logical_blocks", "physical_blocks", "shared_block_hits",
    "cow_copies", "kv_bytes_served", "kv_bytes_stored",
    "spec_k", "drafted_tokens", "accepted_tokens", "rejected_tokens",
    "draft_steps", "target_steps", "mesh_devices",
))


def _serving_row(stats: Mapping[str, Any]) -> Dict[str, Any]:
    row: Dict[str, Any] = {}
    for name in _SERVING_METRICS:
        if stats.get(name) is not None:
            row[name] = (int(stats[name]) if name in _SERVING_INT_METRICS
                         else float(stats[name]))
    return row


def metrics_from_serving(report: Mapping[str, Any]) -> Dict[str, Dict[str, Any]]:
    """One metric row per serve run from a ``serve_report`` payload
    (:func:`repro.launch.serve.build_report`), keyed
    ``serve/<arch>@<scheduler>`` so wave and continuous trajectories never
    get conflated.  Chunked-prefill runs (``prefill_chunk > 1``) append a
    ``+prefill<C>`` segment — the chunked and token-by-token trajectories
    are different experiments (fewer fused steps, different TTFT), so the
    gate must never compare one against the other's baseline.  The same
    reasoning forks ``+kv<dtype>`` for quantized KV pools (different
    bytes/block, different accuracy budget), ``+shared`` for
    prefix-sharing runs (different physical-block trajectory), and
    ``+spec<k>`` for speculative-decoding runs (fewer fused target steps
    by design — comparing them against the non-speculative baseline
    would read the win as a regression of the step counters)."""
    stats = report.get("stats") or {}
    chunk = int(report.get("prefill_chunk",
                           stats.get("prefill_chunk", 1)) or 1)
    key = (f"serve/{report.get('arch', '?')}"
           f"@{report.get('scheduler', stats.get('scheduler', '?'))}")
    if chunk > 1:
        key += f"+prefill{chunk}"
    kv_dtype = str(report.get("kv_dtype",
                              stats.get("kv_dtype", "f32")) or "f32")
    if kv_dtype != "f32":
        key += f"+kv{kv_dtype}"
    if report.get("share_prefixes", stats.get("share_prefixes")):
        key += "+shared"
    spec_k = int(report.get("spec_k", stats.get("spec_k", 0)) or 0)
    if spec_k > 0:
        key += f"+spec{spec_k}"
        # adaptive width is a different drafted/accepted trajectory by
        # design (that's the point) — never gate it against fixed-width
        if report.get("spec_adaptive", stats.get("spec_adaptive")):
            key += "+adapt"
    # mesh placement forks the trajectory too: fused-step counters are
    # identical across shapes (the golden contract), but wall metrics and
    # device_lane_utilization are per-shape quantities
    mesh = report.get("mesh", stats.get("mesh"))
    if mesh:
        key += f"+mesh{mesh}"
    row = _serving_row(stats)
    # submit-time rejections live on the report, not in engine stats: the
    # engine never saw those requests (launch.serve counts them)
    if "rejected" not in row and report.get("rejected") is not None:
        row["rejected"] = int(report["rejected"])
    return {key: row} if row else {}


def metrics_from_scenario(report: Mapping[str, Any]) -> Dict[str, Dict[str, Any]]:
    """One metric row per scenario cell from a ``scenario_cell`` payload
    (:meth:`repro.scenarios.runner.CellResult.report`), keyed by the cell's
    ``scenario/<cell_id>`` ledger key so ``repro.perf gate`` compares each
    cell only against its own trajectory (the gate's latest-comparable
    fallback matches on shared metric keys).  ``golden_ok`` / ``slo_ok``
    ride along as booleans: the gate regresses any True -> False flip."""
    stats = report.get("stats") or {}
    key = str(report.get("ledger_key")
              or f"scenario/{report.get('cell_id', '?')}")
    row = _serving_row(stats)
    if not row:
        return {}
    row["rejected"] = int(len(report.get("rejected") or ())
                          if "rejected" not in row else row["rejected"])
    row["restarts"] = int(report.get("restarts", row.get("restarts", 0)))
    if report.get("golden_checked"):
        row["golden_ok"] = bool(report.get("golden_ok"))
    row["slo_ok"] = not report.get("slo_failures")
    return {key: row}


def metrics_from_analysis(
    analyses: Union[Mapping[str, Any], Iterable[Any]],
) -> Dict[str, Dict[str, Any]]:
    """Metric dicts from SVEAnalysis objects, their dicts, or a whole
    analysis-service report (``requests[].results[]`` are walked)."""
    if isinstance(analyses, Mapping):
        cells: List[Mapping[str, Any]] = []
        for req in analyses.get("requests") or []:
            cells.extend(req.get("results") or [])
    else:
        cells = [a.to_dict() if hasattr(a, "to_dict") else a for a in analyses]
    out: Dict[str, Dict[str, Any]] = {}
    for d in cells:
        key = f"{d['workload']}@{d['chip']}/{d['dtype']}"
        out[key] = _metrics_from_analysis_dict(d)
    return out


# ---------------------------------------------------------------------------
# The ledger
# ---------------------------------------------------------------------------


def default_perf_dir() -> str:
    """``<artifact dir>/perf`` — rides ``$REPRO_ARTIFACT_DIR`` so test
    isolation and operator overrides cover the ledger for free."""
    return os.path.join(_default_dir(), "perf")


class Ledger:
    """Append-only trajectory of BenchRuns over one store directory.

    Reads (``runs`` / ``get`` / ``latest`` / ``next_seq``) re-enumerate the
    directory each call — correctness under concurrent recorders is worth
    more than caching at trajectory scale (hundreds of small JSON files);
    callers looping over history should take one ``runs()`` snapshot.
    """

    def __init__(self, root: Optional[str] = None) -> None:
        self.root = root or default_perf_dir()
        self.store: ArtifactStore = _store_for(self.root)

    # -- write ---------------------------------------------------------------

    def record(
        self,
        metrics: Mapping[str, Mapping[str, Any]],
        *,
        env: Optional[RunEnv] = None,
        meta: Optional[Mapping[str, Any]] = None,
    ) -> BenchRun:
        """Append one trajectory point; returns the persisted BenchRun.

        Never rewrites: the run id covers the sequence number and
        timestamp, so even a byte-identical metric payload lands in a new
        entry.  Concurrent recorders may race to the same ``seq``; both
        entries survive (distinct ids) and sorting breaks ties by
        timestamp then id.
        """
        if not metrics:
            raise ValueError("refusing to record an empty metric set")
        env = env or capture_env()
        seq = self.next_seq()
        ts = time.time()
        metrics = {str(k): dict(v) for k, v in metrics.items()}
        run = BenchRun(
            run_id=run_id_for(env, metrics, seq, ts),
            seq=seq,
            timestamp=ts,
            env=env,
            metrics=metrics,
            meta=dict(meta or {}),
        )
        self.store.put_json(
            run.run_id,
            {
                "kind": "perf_run",
                "perf_version": PERF_VERSION,
                "workload": f"perf/{env.series_key()}#{seq}",
                "run": run.to_dict(),
            },
        )
        return run

    def record_sources(
        self,
        *,
        summary: Optional[Mapping[str, Any]] = None,
        tuning: Optional[Mapping[str, Any]] = None,
        analyses: Union[Mapping[str, Any], Iterable[Any], None] = None,
        serving: Optional[Mapping[str, Any]] = None,
        env: Optional[RunEnv] = None,
        meta: Optional[Mapping[str, Any]] = None,
    ) -> BenchRun:
        """Ingest any mix of the stack's artifacts into one BenchRun."""
        metrics: Dict[str, Dict[str, Any]] = {}
        sources: List[str] = []
        if summary is not None:
            metrics.update(metrics_from_summary(summary))
            sources.append("summary")
        if tuning is not None:
            metrics.update(metrics_from_tuning(tuning))
            sources.append("tuning")
        if analyses is not None:
            metrics.update(metrics_from_analysis(analyses))
            sources.append("analysis")
        if serving is not None:
            metrics.update(metrics_from_serving(serving))
            sources.append("serving")
        if env is None and summary is not None and summary.get("env"):
            env = RunEnv.from_dict(summary["env"])
        meta = {**(meta or {}), "sources": sources}
        # an aborted benchmark run must carry its failure count no matter
        # which ingestion path recorded it: baseline resolution filters on
        # meta["failed"] so truncated wall times never anchor a gate
        if summary is not None and summary.get("failed"):
            meta.setdefault("failed", int(summary["failed"]))
        return self.record(metrics, env=env, meta=meta)

    # -- read ----------------------------------------------------------------

    def runs(self, series: Optional[str] = None) -> List[BenchRun]:
        """Every readable run, sequence-ordered; optionally one series."""
        out: List[BenchRun] = []
        for _, payload in self.store.iter_json():
            if payload.get("perf_version") != PERF_VERSION:
                continue
            try:
                run = BenchRun.from_dict(payload["run"])
            except (KeyError, TypeError, ValueError):
                continue  # corrupt-skip: never raise out of enumeration
            if series is None or run.env.series_key() == series:
                out.append(run)
        out.sort(key=lambda r: (r.seq, r.timestamp, r.run_id))
        return out

    def get(self, run_id: str) -> Optional[BenchRun]:
        """Exact or unique-prefix lookup by run id."""
        matches = [r for r in self.runs() if r.run_id.startswith(run_id)]
        return matches[0] if len(matches) == 1 else None

    def latest(self, series: Optional[str] = None) -> Optional[BenchRun]:
        runs = self.runs(series)
        return runs[-1] if runs else None

    def next_seq(self) -> int:
        runs = self.runs()
        return (runs[-1].seq + 1) if runs else 1

    def series(self) -> List[str]:
        return sorted({r.env.series_key() for r in self.runs()})

    def __repr__(self) -> str:
        # no runs() here: repr must not do directory I/O (debugger/logging)
        return f"Ledger({self.root!r})"


def default_ledger() -> Ledger:
    """Ledger over the default directory, resolved at call time (so the
    ``$REPRO_ARTIFACT_DIR`` override is honored, mirroring default_store)."""
    return Ledger(default_perf_dir())
