"""Region-of-interest profiler mirroring the paper's perf wrapper API.

The paper extends a lightweight perf library with four calls:
``configure_measure() / start_measure() / stop_measure() / print_results()``.
We keep that exact API.  Counters come from two sources:

* **wall-clock** — real (CPU) execution time of the ROI, for the small
  paper-suite apps that execute in this container;
* **artifact events** — the PMU-analogue counters of ``counters.Events``,
  attached by the caller (usually from a jitted function's lowered/compiled
  artifact, or an app's analytic model).

In Neoverse V2 at most six events can be collected per group (paper Sec. 3.1);
we keep a ``max_events`` knob for API fidelity, though artifact counters have
no such limit.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

from repro.core.counters import Events

DEFAULT_EVENTS = (
    "INST_RETIRED",
    "LL_CACHE_MISS_RD",
    "MEM_ACCESS_RD",
    "STALL_BACKEND",
    "CPU_CYCLES",
    "VFP_SPEC",
)


@dataclasses.dataclass
class Measurement:
    name: str
    wall_s: float
    counters: Dict[str, float]
    repeats: int = 1


class Profiler:
    """configure/start/stop/print, as in the paper's profiler library."""

    def __init__(self, events: tuple = DEFAULT_EVENTS, max_events: int = 6):
        if len(events) > max_events:
            raise ValueError(
                f"at most {max_events} events per group (Neoverse V2 PMU limit)"
            )
        self.events = events
        self._configured = False
        self._t0: Optional[float] = None
        self._acc = 0.0
        self._repeats = 0
        self.results: List[Measurement] = []

    def configure_measure(self) -> None:
        self._configured = True
        self._acc = 0.0
        self._repeats = 0

    def start_measure(self) -> None:
        if not self._configured:
            raise RuntimeError("configure_measure() first")
        self._t0 = time.perf_counter()

    def stop_measure(self) -> None:
        if self._t0 is None:
            raise RuntimeError("start_measure() first")
        self._acc += time.perf_counter() - self._t0
        self._repeats += 1
        self._t0 = None

    def mean_roi_s(self) -> float:
        """Mean wall-clock seconds per measured ROI repeat so far.

        Public accessor for the accumulated start/stop timings (0.0 before
        any completed repeat) — callers should use this instead of reaching
        into the accumulator fields.
        """
        return self._acc / max(self._repeats, 1)

    def record(self, name: str, events: Events, chip_clock_hz: float = 3.447e9) -> Measurement:
        """Attach artifact counters to the timed ROI and store the result.

        Maps Events -> the paper's Table-1 counter names (see counters.py).
        """
        mem_read_tx = events.hbm_read_bytes / 64.0  # Grace line-sized units
        counters = {
            "INST_RETIRED": events.flops,  # refined by apps via issue model
            "LL_CACHE_MISS_RD": mem_read_tx,
            "MEM_ACCESS_RD": events.bytes_accessed / 64.0,
            "STALL_BACKEND": 0.0,
            "CPU_CYCLES": self._acc * chip_clock_hz,
            "VFP_SPEC": events.flops,
        }
        m = Measurement(
            name=name,
            wall_s=self.mean_roi_s(),
            counters=counters,
            repeats=self._repeats,
        )
        self.results.append(m)
        return m

    def print_results(self) -> str:
        lines = []
        for m in self.results:
            lines.append(f"[ROI {m.name}] wall={m.wall_s*1e3:.3f} ms x{m.repeats}")
            for k in self.events:
                if k in m.counters:
                    lines.append(f"  {k:<18} {m.counters[k]:.4g}")
        out = "\n".join(lines)
        print(out)
        return out


def time_fn(fn, *args, repeats: int = 5, min_time_s: float = 0.1, **kw) -> float:
    """Paper methodology: >=5 repeats, total time >= 0.1 s; returns best-of."""
    import jax

    # warmup/compile
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    times = []
    total = 0.0
    i = 0
    while i < repeats or total < min_time_s:
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        times.append(dt)
        total += dt
        i += 1
        if i > 1000:
            break
    return min(times)
