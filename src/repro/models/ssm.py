"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060), TPU-adapted.

Training / prefill uses the chunked SSD algorithm: the sequence is split into
chunks of Q tokens; within a chunk the recurrence is computed as a masked
(decay-weighted) attention-like contraction (MXU-friendly — this is the
"duality"), and chunk-final states are propagated with a sequential
``lax.scan`` across chunks.  Decode is the O(1) recurrence
``S <- exp(dt*A) S + dt * B (x) x``, read out as ``y = C . S + D x``.

State math is fp32 (long products of decays underflow bf16).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


def _dims(cfg):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_ch = di + 2 * s.n_groups * s.d_state
    return s, d, di, nh, conv_ch


def init_mamba(key, cfg, dtype) -> dict:
    """Projections are kept as separate matrices (wz/wx/wBC/wdt) rather than
    one fused in_proj so each shards cleanly under tensor parallelism:
    head-structured outputs (z, x, dt) column-shard over the ``model`` axis,
    the small group-shared B/C projection replicates."""
    s, d, di, nh, conv_ch = _dims(cfg)
    gn2 = 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 7)
    return {
        "wz": layers.dense_init(ks[0], d, di, dtype),
        "wx": layers.dense_init(ks[1], d, di, dtype),
        "wBC": layers.dense_init(ks[2], d, gn2, dtype),
        "wdt": layers.dense_init(ks[3], d, nh, dtype),
        "conv_x_w": layers.truncated_normal(ks[4], (s.d_conv, di), 1.0, dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_BC_w": layers.truncated_normal(ks[5], (s.d_conv, gn2), 1.0, dtype),
        "conv_BC_b": jnp.zeros((gn2,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": layers.rms_norm_init(di, dtype),
        "out_proj": layers.dense_init(ks[6], di, d, dtype),
    }


def _project_in(params, cfg, x):
    """x (B,S,d) -> z (B,S,di), xBC (B,S,di+2GN) pre-conv, dt (B,S,nh)."""
    z = layers.dense(params["wz"], x)
    xs = layers.dense(params["wx"], x)
    bc = layers.dense(params["wBC"], x)
    dt = layers.dense(params["wdt"], x)
    return z, jnp.concatenate([xs, bc], axis=-1), dt


def _conv_w_b(params):
    w = jnp.concatenate([params["conv_x_w"], params["conv_BC_w"]], axis=-1)
    b = jnp.concatenate([params["conv_x_b"], params["conv_BC_b"]], axis=-1)
    return w, b


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq; d_conv taps as shifted adds (d_conv<=4)."""
    d_conv = w.shape[0]
    S = xBC.shape[1]
    out = jnp.zeros_like(xBC)
    for i in range(d_conv):
        shift = d_conv - 1 - i  # tap i sees x[t - shift]
        xs = xBC if shift == 0 else jnp.pad(xBC, ((0, 0), (shift, 0), (0, 0)))[:, :S]
        out = out + xs * w[i].astype(xBC.dtype)
    return jax.nn.silu(out + b.astype(xBC.dtype))


def _gated_out(params, cfg, y_flat, z):
    y = layers.rms_norm(params["norm"], y_flat * jax.nn.silu(z), cfg.norm_eps)
    return layers.dense(params["out_proj"], y)


def mamba_full(
    params,
    cfg,
    x: jax.Array,
    *,
    initial_state: Optional[jax.Array] = None,
    return_state: bool = False,
):
    """Chunked SSD forward.  x: (B, S, d) -> (B, S, d) [, final state]."""
    s, d, di, nh, conv_ch = _dims(cfg)
    B, S, _ = x.shape
    G, N, P, Q = s.n_groups, s.d_state, s.head_dim, s.chunk
    Q = min(Q, S)
    nc = -(-S // Q)
    pad = nc * Q - S

    z, xBC, dt = _project_in(params, cfg, x)
    cw, cb = _conv_w_b(params)
    xBC = _causal_conv(xBC, cw, cb)
    xs = xBC[..., :di].reshape(B, S, nh, P)
    Bm = xBC[..., di : di + G * N].reshape(B, S, G, N)
    Cm = xBC[..., di + G * N :].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(params["A_log"])  # (nh,)

    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    hpg = nh // G  # heads per group
    # per-chunk tiles, chunk axis LEADING for the scan: (nc, B, Q, ...)
    xs = jnp.moveaxis(xs.reshape(B, nc, Q, nh, P), 1, 0)
    Bc = jnp.moveaxis(Bm.reshape(B, nc, Q, G, N), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(B, nc, Q, G, N), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(B, nc, Q, nh), 1, 0)

    S0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((B, nh, N, P), jnp.float32)
    )
    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(S_prev, inp):
        """One SSD chunk: intra-chunk dual term + state passing.

        All O(Q^2) tensors are transient within this step; jax.checkpoint
        keeps scan autodiff from storing them per chunk.
        """
        x_c, B_c, C_c, dt_c = inp  # (B,Q,nh,P), (B,Q,G,N), (B,Q,G,N), (B,Q,nh)
        dA = dt_c * A  # (B,Q,nh)
        cum = jnp.cumsum(dA, axis=1)
        total = cum[:, -1]  # (B,nh)
        xf = x_c.astype(jnp.float32)
        Bh = jnp.repeat(B_c.astype(jnp.float32), hpg, axis=2)  # (B,Q,nh,N)
        Ch = jnp.repeat(C_c.astype(jnp.float32), hpg, axis=2)

        # intra-chunk: M[q,j] = (C_q.B_j) exp(cum_q - cum_j) dt_j,  j <= q
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,Q,nh)
        decay = jnp.where(tri[None, :, :, None], decay, -jnp.inf)
        M = (
            jnp.einsum("bqhn,bjhn->bqjh", Ch, Bh, preferred_element_type=jnp.float32)
            * jnp.exp(decay)
            * dt_c[:, None, :, :]
        )
        y_c = jnp.einsum("bqjh,bjhp->bqhp", M, xf, preferred_element_type=jnp.float32)

        # inter-chunk: y_q += C_q . (exp(cum_q) * S_prev)
        y_c = y_c + jnp.einsum(
            "bqhn,bhnp->bqhp", Ch * jnp.exp(cum)[..., None], S_prev,
            preferred_element_type=jnp.float32,
        )
        y_c = y_c + params["D"][None, None, :, None] * xf

        # chunk-final local state + state passing
        w = jnp.exp(total[:, None, :] - cum) * dt_c  # (B,Q,nh)
        S_local = jnp.einsum(
            "bqh,bqhn,bqhp->bhnp", w, Bh, xf, preferred_element_type=jnp.float32
        )
        S_new = jnp.exp(total)[:, :, None, None] * S_prev + S_local
        return S_new, y_c

    S_final, y = jax.lax.scan(
        jax.checkpoint(chunk_step), S0, (xs, Bc, Cc, dtc)
    )
    # y: (nc, B, Q, nh, P) -> (B, S, nh*P)
    y = jnp.moveaxis(y, 0, 1).reshape(B, nc * Q, nh * P)[:, :S].astype(x.dtype)
    out = _gated_out(params, cfg, y, z)
    if return_state:
        return out, S_final
    return out


def init_mamba_cache(cfg, batch: int, dtype, layers_stacked: int = 1):
    s, d, di, nh, conv_ch = _dims(cfg)
    return {
        "ssm_state": jnp.zeros((layers_stacked, batch, nh, s.d_state, s.head_dim), jnp.float32),
        "conv_state": jnp.zeros((layers_stacked, batch, s.d_conv - 1, conv_ch), dtype),
    }


def mamba_decode(params, cfg, x, ssm_state, conv_state):
    """One-token step.  x: (B,1,d); ssm_state: (B,nh,N,P); conv_state:
    (B, d_conv-1, conv_ch).  Returns (y, ssm_state, conv_state)."""
    s, d, di, nh, conv_ch = _dims(cfg)
    G, N, P = s.n_groups, s.d_state, s.head_dim
    B = x.shape[0]
    z, xBC, dt = _project_in(params, cfg, x)
    cw, cb = _conv_w_b(params)
    window = jnp.concatenate([conv_state, xBC[:, 0:1, :]], axis=1)  # (B,d_conv,ch)
    conv_out = jnp.einsum("btc,tc->bc", window, cw.astype(x.dtype))
    conv_out = jax.nn.silu(conv_out + cb.astype(x.dtype))
    new_conv_state = window[:, 1:, :]

    xs = conv_out[:, :di].reshape(B, nh, P)
    Bm = conv_out[:, di : di + G * N].reshape(B, G, N)
    Cm = conv_out[:, di + G * N :].reshape(B, G, N)
    hpg = nh // G
    Bh = jnp.repeat(Bm, hpg, axis=1).astype(jnp.float32)  # (B,nh,N)
    Chd = jnp.repeat(Cm, hpg, axis=1).astype(jnp.float32)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,nh)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A)  # (B,nh)
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dt, Bh, xs.astype(jnp.float32))
    S_new = decay[:, :, None, None] * ssm_state + upd
    y = jnp.einsum("bhn,bhnp->bhp", Chd, S_new)
    y = y + params["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, di).astype(x.dtype)
    out = _gated_out(params, cfg, y, z)
    return out, S_new, new_conv_state
