"""Model substrate: every assigned architecture, pure-functional JAX."""

from repro.models import attention, layers, mla, moe, ssm, transformer, whisper  # noqa: F401
