"""Fault plans: declarative fault dimensions for scenario cells.

Each plan is one value of the matrix's fault axis.  The contract every
plan must uphold — and the runner asserts against the fault-free golden
twin — is **no served token may ever differ**: faults may cost steps,
latency, or restarts, never correctness.

* ``none``         — the golden baseline every faulted cell is diffed against.
* ``preempt``      — mid-flight slot eviction via a step hook calling
  :meth:`repro.serve.engine.ServeEngine.preempt`; the engine replays the
  evicted request's prompt + already-served tokens through a rebuilt cache
  (continuous scheduler only: waves have no slots to steal).
* ``device-loss``  — a raised :class:`SimulatedDeviceLoss` mid-drain; the
  runner executes these cells under
  :class:`~repro.distributed.fault_tolerance.ResilientLoop` over a
  :class:`~repro.checkpoint.CheckpointStore`, so the crash restores the
  newest committed chunk and replays (see ``runner._execute_resilient``).
* ``malformed``    — oversized and empty submissions injected into the
  trace; both must be rejected typed at submit() and counted, never
  crash the drain or perturb well-formed requests.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.scenarios.matrix import Scenario
from repro.scenarios.traffic import RequestSpec


class SimulatedDeviceLoss(RuntimeError):
    """The injected 'device fell over' signal: on real fleets this is the
    preemption notice / process death; here it is a typed exception the
    ResilientLoop's restart policy catches."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """The no-op plan ('none') and the base interface."""

    name: str = "none"

    def applies_to(self, cell: Scenario) -> bool:
        return True

    def mutate_trace(self, trace: List[RequestSpec],
                     cell: Scenario) -> List[RequestSpec]:
        return trace

    def make_hook(self, cell: Scenario):
        """Step hook injected into the engine, or None."""
        return None

    @property
    def resilient(self) -> bool:
        """True when the runner must execute the cell under the
        checkpoint-restart loop (chunked serving)."""
        return False


class _PreemptHook:
    """Evict the deepest busy slot every ``every`` fused steps, ``times``
    times total.  Deterministic: driven by the engine's step counter and
    the engine's own deterministic victim choice."""

    def __init__(self, every: int, times: int):
        self.every = every
        self.left = times
        self.next_at = every

    def __call__(self, engine, busy: bool) -> bool:
        if self.left > 0 and busy and engine.steps >= self.next_at:
            if engine.preempt() is not None:
                self.left -= 1
            self.next_at = engine.steps + self.every
        return False  # never holds the drain open


@dataclasses.dataclass(frozen=True)
class PreemptPlan(FaultPlan):
    name: str = "preempt"
    every: int = 5  # fused steps between evictions
    times: int = 2

    def applies_to(self, cell: Scenario) -> bool:
        return cell.scheduler == "continuous"

    def make_hook(self, cell: Scenario):
        return _PreemptHook(self.every, self.times)


class _CrashOnce:
    """Raise SimulatedDeviceLoss at one fused step, once — the analogue of
    :class:`tests.test_checkpoint_ft._Flaky` for the serve path."""

    def __init__(self, at_step: int):
        self.at_step = at_step
        self.armed = True

    def __call__(self, engine, busy: bool) -> bool:
        if self.armed and engine.steps >= self.at_step:
            self.armed = False
            raise SimulatedDeviceLoss(
                f"injected device loss at fused step {engine.steps}"
            )
        return False


@dataclasses.dataclass(frozen=True)
class DeviceLossPlan(FaultPlan):
    name: str = "device-loss"
    fail_chunk: int = 1  # which serve chunk the device dies in
    fail_step: int = 3   # fused steps into that chunk

    @property
    def resilient(self) -> bool:
        return True

    def make_crash_hook(self) -> _CrashOnce:
        return _CrashOnce(self.fail_step)


@dataclasses.dataclass(frozen=True)
class MalformedPlan(FaultPlan):
    """Inject an oversized request (prompt + budget exceeds the per-slot
    cache) and an empty-prompt request.  Injected uids live in their own
    range so twin diffs can never confuse them with sampled traffic."""

    name: str = "malformed"
    uid_base: int = 100_000

    def mutate_trace(self, trace: List[RequestSpec],
                     cell: Scenario) -> List[RequestSpec]:
        rng = np.random.default_rng(cell.seed ^ 0x5EED)
        oversized = RequestSpec(
            uid=self.uid_base,
            arrive_step=0,
            prompt=rng.integers(0, 2, size=cell.max_len + 8).astype(np.int32),
            max_new_tokens=cell.max_new,
            malformed="oversized",
        )
        empty = RequestSpec(
            uid=self.uid_base + 1,
            arrive_step=0,
            prompt=np.zeros((0,), np.int32),
            max_new_tokens=cell.max_new,
            malformed="empty",
        )
        out = list(trace) + [oversized, empty]
        out.sort(key=lambda r: (r.arrive_step, r.uid))
        return out


PLANS = {p.name: p for p in (
    FaultPlan(), PreemptPlan(), DeviceLossPlan(), MalformedPlan(),
)}


def get_plan(name: str) -> FaultPlan:
    if name not in PLANS:
        raise KeyError(f"unknown fault plan {name!r}; have {sorted(PLANS)}")
    return PLANS[name]
