"""The paper's decision tree (Fig. 8): classify SVE/vector-boosted performance.

Four classes, determined from NON-vectorized profile metrics only:

* ``Class 1 — NOT_VECTORIZED``: the kernel cannot be vectorized effectively
  (R_ins ~ 1, or the vectorizable instruction share is tiny: complex control
  flow, library pre-optimization (FFTW), recursion, threading-runtime
  dominance).
* ``Class 2 — MEMORY_BANDWIDTH_BOUND``: vectorizes (R_ins >> 1) but AI is
  left of the inflection point and traffic is streaming — more bandwidth, not
  vectors, is the fix (STREAM; QC sim at 72 threads).
* ``Class 3 — MEMORY_LATENCY_BOUND``: vectorizes, AI left of inflection, and
  the traffic is pointer-chasing (LLC miss ratio above the ideal-streaming
  threshold in the paper; gather-byte share here) — SpMV.
* ``Class 4 — SPEEDUP``: AI right of the inflection point — compute bound,
  vectorization pays (GEMM, CNNs, LLM kernels, AutoDock).

Paper thresholds, kept as defaults and overridable:
  - effective vectorization:   R_ins >= 1.2 (paper: "R_ins_reduction > 1")
  - memory- vs compute-bound:  AI vs AI_inflection = scalar peak / BW
  - latency- vs bandwidth-:    miss-ratio ELEN/cache_line (Grace: 8B/64B = 13%)
    -> TPU: gather-byte share of HBM traffic vs ELEN/transaction granule.
"""

from __future__ import annotations

import dataclasses
import enum

from repro.core import hw
from repro.core.metrics import VectorizationReport
from repro.core.roofline import AdaptedRoofline, adapted_roofline


class PerfClass(enum.IntEnum):
    NOT_VECTORIZED = 1
    MEMORY_BANDWIDTH_BOUND = 2
    MEMORY_LATENCY_BOUND = 3
    SPEEDUP = 4

    def describe(self) -> str:
        return {
            PerfClass.NOT_VECTORIZED: "cannot be vectorized effectively",
            PerfClass.MEMORY_BANDWIDTH_BOUND: "vectorizes; bandwidth-bound, no speedup",
            PerfClass.MEMORY_LATENCY_BOUND: "vectorizes; latency-bound (pointer chasing)",
            PerfClass.SPEEDUP: "compute-bound; vectorization yields speedup",
        }[self]


@dataclasses.dataclass(frozen=True)
class Decision:
    perf_class: PerfClass
    r_ins: float
    ai: float
    ai_inflection: float
    gather_fraction: float
    latency_threshold: float
    rationale: str


def classify(
    report: VectorizationReport,
    chip: hw.ChipSpec = hw.DEFAULT_CHIP,
    *,
    r_ins_threshold: float = 1.2,
    roofline: AdaptedRoofline | None = None,
) -> Decision:
    """Run the paper's decision tree on one profiled kernel/application."""
    rl = roofline or adapted_roofline(chip, report.dtype)
    # Stage 1 — can it vectorize?  (paper: R_ins_reduction filter)
    r_ins = report.r_ins
    effective = r_ins >= r_ins_threshold and report.vectorizable_fraction >= 0.10
    # Stage 2 — memory- or compute-bound?  AI vs inflection (scalar knee:
    # the tree takes the NON-vectorized profile, paper Fig. 8).
    ai = report.ai
    knee = rl.ai_irr
    # Stage 3 — latency or bandwidth?  Grace: LLC miss ratio vs ELEN/line.
    latency_threshold = hw.elen_bits(report.dtype) / 8 / chip.transaction_bytes
    # TPU transactions are 512B so the structural gather share is the signal;
    # keep the paper's Grace threshold shape: ideal streaming ratio ~ 13%.
    latency_threshold = max(latency_threshold, 0.13)

    if not effective:
        cls = PerfClass.NOT_VECTORIZED
        why = (
            f"R_ins={r_ins:.2f} < {r_ins_threshold} or vectorizable FLOP share "
            f"{report.vectorizable_fraction:.2%} < 10%"
        )
    elif ai >= knee:
        cls = PerfClass.SPEEDUP
        why = f"AI={ai:.3g} >= inflection {knee:.3g} flop/B: compute-bound"
    elif report.gather_fraction > latency_threshold:
        cls = PerfClass.MEMORY_LATENCY_BOUND
        why = (
            f"AI={ai:.3g} < {knee:.3g} and gather share "
            f"{report.gather_fraction:.2%} > {latency_threshold:.2%}"
        )
    else:
        cls = PerfClass.MEMORY_BANDWIDTH_BOUND
        why = f"AI={ai:.3g} < inflection {knee:.3g} flop/B, streaming traffic"
    return Decision(
        perf_class=cls,
        r_ins=r_ins,
        ai=ai,
        ai_inflection=knee,
        gather_fraction=report.gather_fraction,
        latency_threshold=latency_threshold,
        rationale=why,
    )
