"""The paper's adapted roofline model (Eq. 2) + the three-term TPU roofline.

Two layers:

1. ``adapted_roofline`` — the paper's model verbatim: a scalar ceiling, a
   vectorized ceiling boosted by VLEN/ELEN, and inflection points
   AI_IRR = peak/BW and AI_IRV = AI_IRR * VLEN/ELEN.  Reducing ELEN (or
   lengthening VLEN) raises the compute ceiling AND moves the inflection
   right — which is how vectorization flips compute-bound kernels into
   memory-bound ones (paper Fig. 7, red triangles).

2. ``three_term`` — the deployment roofline for a (arch x shape x mesh) cell:

       compute    = FLOPs            / (chips * peak_flops(dtype))
       memory     = HBM bytes        / (chips * hbm_bw)
       collective = collective bytes / (chips * ici_bw)

   The dominant term is the bottleneck; roofline fraction = dominant-term
   bound / achievable-time model.  All inputs are GLOBAL quantities (see
   counters.events_from_compiled).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import hw
from repro.core.counters import Events
from repro.core.metrics import arithmetic_intensity, vectorization_bound


# ---------------------------------------------------------------------------
# Paper Eq. 2 — scalar vs vectorized inflection points
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdaptedRoofline:
    """Paper's roofline for one (chip, dtype): ceilings + inflection points."""

    chip: str
    dtype: str
    scalar_peak: float  # FLOP/s, vectorization disabled
    vector_peak: float  # FLOP/s, ideal vectorization (= scalar * VB)
    bw: float  # bytes/s
    ai_irr: float  # scalar inflection (paper: AI_IRR)
    ai_irv: float  # vectorized inflection (paper: AI_IRV = AI_IRR * VLEN/ELEN)
    vb: float

    def attainable(self, ai: float, vectorized: bool = True) -> float:
        """Attainable FLOP/s at arithmetic intensity ``ai``."""
        peak = self.vector_peak if vectorized else self.scalar_peak
        return min(peak, ai * self.bw)

    def predicted_speedup(self, ai: float) -> float:
        """Vectorization speedup the model predicts at intensity ``ai``.

        Saturates at VB in the compute-bound region and decays toward 1 in
        the memory-bound region — the paper's Fig. 6 curve.
        """
        s = self.attainable(ai, True) / max(self.attainable(ai, False), 1e-30)
        return max(1.0, s)

    def region(self, ai: float, vectorized: bool = True) -> str:
        knee = self.ai_irv if vectorized else self.ai_irr
        return "memory-bound" if ai < knee else "compute-bound"


def adapted_roofline(
    chip: hw.ChipSpec, dtype: str, *, scalar_dtype: str | None = None
) -> AdaptedRoofline:
    vb = vectorization_bound(chip, dtype)
    if scalar_dtype is None:
        scalar_dtype = "scalar_" + dtype if ("scalar_" + dtype) in chip.peak_flops else "scalar"
    scalar_peak = (
        chip.peak_flops[scalar_dtype]
        if scalar_dtype in chip.peak_flops
        else chip.peak(dtype) / vb
    )
    vector_peak = (
        chip.peak(dtype) if dtype in chip.peak_flops else scalar_peak * vb
    )
    ai_irr = scalar_peak / chip.hbm_bw
    # paper Eq. 2: AI_IRV = AI_IRR * VLEN/ELEN — equivalently vector_peak/BW
    ai_irv = vector_peak / chip.hbm_bw
    return AdaptedRoofline(
        chip=chip.name,
        dtype=dtype,
        scalar_peak=scalar_peak,
        vector_peak=vector_peak,
        bw=chip.hbm_bw,
        ai_irr=ai_irr,
        ai_irv=ai_irv,
        vb=vb,
    )


# ---------------------------------------------------------------------------
# Three-term roofline for distributed cells
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """Per-step lower-bound times, in seconds, for one (arch, shape, mesh)."""

    compute_s: float
    memory_s: float
    collective_s: float
    chips: int
    dtype: str
    flops: float
    hbm_bytes: float
    collective_bytes: float
    model_flops: float = 0.0  # 6*N*D (dense) / 6*N_active*D (MoE); 0 if n/a

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def bound_s(self) -> float:
        """Roofline lower bound on step time (no overlap assumed between
        the dominant term and the rest; perfectly overlapped otherwise)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        if self.flops <= 0 or self.model_flops <= 0:
            return 0.0
        return min(self.model_flops / self.flops, 10.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step bound that is UNAVOIDABLE work: useful-FLOP
        time or the minimal-HBM-traffic time, whichever floor is higher.
        1.0 = the step runs exactly at its physics floor (e.g. decode at the
        cache-read bandwidth bound); low values = the bound is inflated by
        redundant compute or avoidable collectives."""
        if self.bound_s <= 0:
            return 0.0
        useful = self.model_flops if self.model_flops > 0 else self.flops
        useful_time = (useful / max(self.flops, 1e-30)) * self.compute_s
        floor = max(useful_time, self.memory_s)
        return min(1.0, floor / self.bound_s)

    def to_dict(self) -> Dict[str, float | str | int]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bound_s": self.bound_s,
            "dominant": self.dominant,
            "chips": self.chips,
            "dtype": self.dtype,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_flop_fraction": self.useful_flop_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def three_term(
    events: Events,
    chip: hw.ChipSpec,
    chips: int,
    *,
    dtype: str = "bf16",
    model_flops: float = 0.0,
) -> RooflineTerms:
    peak = chip.peak(dtype)
    compute_s = events.flops / (chips * peak) if peak else 0.0
    memory_s = events.bytes_accessed / (chips * chip.hbm_bw)
    ici = chip.ici_bw()
    collective_s = events.collective_bytes / (chips * ici) if ici else 0.0
    return RooflineTerms(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        chips=chips,
        dtype=dtype,
        flops=events.flops,
        hbm_bytes=events.bytes_accessed,
        collective_bytes=events.collective_bytes,
        model_flops=model_flops,
    )


def model_flops_lm(
    n_params: float, tokens: float, *, training: bool = True, n_active: float | None = None
) -> float:
    """MODEL_FLOPS = 6*N*D for training, 2*N*D for inference forward.

    For MoE pass ``n_active`` (activated params per token).
    """
    n = n_active if n_active is not None else n_params
    factor = 6.0 if training else 2.0
    return factor * n * tokens


def model_flops_cell(cfg, shape) -> float:
    """Architecture-aware MODEL_FLOPS for one (cfg, shape) cell.

    Extends the 6·N·D / 2·N·D parameter term with the sequence-dependent
    compute the parameter count cannot see — without it every long-context
    attention cell reports a bogus "waste" factor:

      * attention (per layer, per token, fwd): 4·S_eff·H·hd
        (QKᵀ + PV; S_eff = S/2 causal train/prefill, S for cached decode)
      * SSD/Mamba-2 (per layer, per token, fwd): 2·Q·nh·(N+P) intra-chunk
        dual term + 4·nh·N·P state update/readout
      * whisper encoder: non-causal attention on S_enc per encoder layer

    Training multiplies the sequence terms by 3 (fwd + bwd), matching the
    6N/2N convention.
    """
    from repro.configs.base import LayerKind

    training = shape.kind == "train"
    pass_factor = 3.0 if training else 1.0
    n_active = cfg.active_param_count()
    if shape.kind == "decode":
        tokens = float(shape.global_batch)
        s_eff = float(shape.seq_len)  # full cache read per new token
    else:
        tokens = float(shape.tokens)
        s_eff = shape.seq_len / 2.0  # causal average context

    total = model_flops_lm(
        cfg.param_count(), tokens, training=training, n_active=n_active
    )

    # per-layer sequence terms
    attn_unit = 4.0 * s_eff * cfg.n_heads * cfg.head_dim
    if cfg.mla is not None:
        ml = cfg.mla
        attn_unit = 2.0 * s_eff * cfg.n_heads * (
            ml.qk_nope_dim + ml.qk_rope_dim + ml.v_head_dim
        )
    ssd_unit = 0.0
    if cfg.ssm is not None:
        s = cfg.ssm
        nh = s.n_heads(cfg.d_model)
        Q = min(s.chunk, shape.seq_len)
        if shape.kind == "decode":
            ssd_unit = 4.0 * nh * s.d_state * s.head_dim  # O(1) recurrence
        else:
            ssd_unit = (2.0 * (Q / 2.0) * nh * (s.d_state + s.head_dim)
                        + 4.0 * nh * s.d_state * s.head_dim)

    pattern = cfg._full_pattern()
    n_attn = sum(1 for k in pattern if k == LayerKind.ATTN)
    n_mamba = sum(1 for k in pattern if k == LayerKind.MAMBA)
    total += pass_factor * tokens * (n_attn * attn_unit + n_mamba * ssd_unit)

    if cfg.is_encoder_decoder:
        s_enc = max(shape.seq_len // 4, 8)
        cross = 4.0 * s_enc * cfg.n_heads * cfg.head_dim
        n_dec = cfg.n_layers - cfg.enc_layers
        total += pass_factor * tokens * n_dec * cross
        if shape.kind != "decode":  # encoder runs only in train/prefill
            enc_tokens = float(shape.global_batch) * s_enc
            enc_attn = 4.0 * s_enc * cfg.n_heads * cfg.head_dim  # non-causal
            total += pass_factor * enc_tokens * cfg.enc_layers * enc_attn
    return float(total)
