"""MLA (absorbed decode == decompressed attention) and MoE dispatch semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import mla, moe


def _mla_cfg():
    return configs.get_smoke_config("deepseek-v2-lite-16b")


def test_mla_decode_matches_full():
    """Absorbed decode over the compressed cache must equal decompressed
    full attention, token by token."""
    cfg = _mla_cfg()
    params = mla.init_mla(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32)
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    y_full = mla.mla_full(params, cfg, x, positions)

    cache = jax.tree.map(
        lambda a: a[0], mla.init_mla_cache(cfg, B, S, jnp.float32, 1)
    )
    ys = []
    for t in range(S):
        y, c_new, kr_new = mla.mla_decode(
            params, cfg, x[:, t:t + 1, :], cache["c"], cache["k_rope"], jnp.asarray(t)
        )
        cache["c"] = jax.lax.dynamic_update_slice(cache["c"], c_new, (0, t, 0))
        cache["k_rope"] = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new, (0, t, 0))
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_dec), rtol=2e-4, atol=2e-4)


def test_mla_cache_is_compressed():
    """The decode cache must hold kv_lora+rope per token, not 2*H*D (the
    paper's ELEN lesson at the cache level)."""
    cfg = _mla_cfg()
    c = mla.init_mla_cache(cfg, batch=1, max_len=16, dtype=jnp.bfloat16, layers_stacked=1)
    per_tok = c["c"].shape[-1] + c["k_rope"].shape[-1]
    gqa_equiv = 2 * cfg.n_kv_heads * cfg.head_dim
    assert per_tok < gqa_equiv / 2, (per_tok, gqa_equiv)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def _moe_cfg(capacity=64.0):
    base = configs.get_smoke_config("deepseek-moe-16b")
    return dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, capacity_factor=capacity)
    )


def moe_dense_reference(params, cfg, x):
    """Route every token through its top-k experts with NO capacity limit."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    logits = xf.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    y = jnp.zeros((T, d), jnp.float32)
    for e in range(m.n_routed):
        ge = jnp.where(idx == e, gates, 0.0).sum(-1)  # (T,)
        g = xf @ params["wi_gate"][e]
        u = xf @ params["wi_up"][e]
        h = jax.nn.silu(g) * u
        ye = h @ params["wo"][e]
        y = y + ge[:, None] * ye.astype(jnp.float32)
    if m.n_shared > 0:
        from repro.models import layers
        y = y + layers.swiglu(params["shared"], xf).astype(jnp.float32)
    return y.reshape(B, S, d).astype(x.dtype)


def test_moe_matches_dense_reference_when_dropless():
    cfg = _moe_cfg(capacity=64.0)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model), jnp.float32)
    y, aux = moe.moe_ffn(params, cfg, x)
    y_ref = moe_dense_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux))


def test_moe_aux_loss_is_one_when_balanced():
    """Perfectly uniform router -> Switch aux ~= 1.0."""
    cfg = _moe_cfg()
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    # zero router weights -> uniform probs -> aux = E * E*(1/E)*(1/E) = 1
    params = dict(params, router=jnp.zeros_like(params["router"]))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    _, aux = moe.moe_ffn(params, cfg, x)
    np.testing.assert_allclose(float(aux), 1.0, rtol=0.15)


def test_moe_capacity_drops_tokens_not_nans():
    """Pathological capacity -> outputs shrink toward shared-expert-only,
    never NaN."""
    cfg = _moe_cfg(capacity=0.01)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    y, aux = moe.moe_ffn(params, cfg, x)
    assert np.all(np.isfinite(np.asarray(y)))


def test_moe_gradients_reach_all_experts_with_ample_capacity():
    cfg = _moe_cfg(capacity=64.0)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    # enough tokens that every expert gets some assignment w.h.p.
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32, cfg.d_model), jnp.float32)

    def loss(p):
        y, aux = moe.moe_ffn(p, cfg, x)
        return jnp.sum(jnp.square(y)) + aux

    g = jax.grad(loss)(params)
    per_expert = jnp.abs(g["wi_gate"]).sum(axis=(1, 2))
    assert float(jnp.min(per_expert)) > 0.0, "some expert got no gradient"


def test_moe_permutation_equivariance():
    """Permuting tokens permutes outputs (dispatch bookkeeping is sound)."""
    cfg = _moe_cfg(capacity=64.0)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model), jnp.float32)
    perm = jnp.asarray([3, 1, 7, 0, 5, 2, 6, 4])
    y1, _ = moe.moe_ffn(params, cfg, x)
    y2, _ = moe.moe_ffn(params, cfg, x[:, perm, :])
    np.testing.assert_allclose(
        np.asarray(y1[:, perm, :]), np.asarray(y2), rtol=2e-4, atol=2e-4
    )
