"""Trace-time mesh plan: how model code should lay activations onto the mesh.

Model modules (transformer, moe) are mesh-agnostic; distribution-aware
launchers (cells.py, train.py) activate a ``MeshPlan`` around tracing, and
the modules read it to place sharding constraints (sequence parallelism,
hierarchical MoE dispatch).  The default plan is a no-op, so tests and
single-device runs never touch jax sharding machinery.

Optimization flags ride on the plan so the PAPER-FAITHFUL baseline
(`dryrun --baseline`) traces the plain path and the optimized variant the
constrained one — both recorded separately in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    n_data: int = 1                      # product of data-parallel axis sizes
    n_model: int = 1
    data_axes: Tuple[str, ...] = ()      # ("pod", "data") on the multi-pod mesh
    model_axis: Optional[str] = None
    seq_parallel: bool = False           # Megatron-SP residual constraints
    moe_impl: str = "global"             # global | hierarchical | shard_map
    mesh: Optional[object] = dataclasses.field(default=None, compare=False)

    @property
    def moe_hierarchical(self) -> bool:
        return self.moe_impl == "hierarchical"

    @property
    def dp(self):
        """The data axes as a PartitionSpec entry."""
        if not self.data_axes:
            return None
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    @property
    def active(self) -> bool:
        return bool(self.data_axes) or self.model_axis is not None


_PLAN: contextvars.ContextVar[MeshPlan] = contextvars.ContextVar(
    "repro_mesh_plan", default=MeshPlan()
)


def current() -> MeshPlan:
    return _PLAN.get()


@contextlib.contextmanager
def use_plan(plan: MeshPlan):
    token = _PLAN.set(plan)
    try:
        yield plan
    finally:
        _PLAN.reset(token)


def plan_for_mesh(mesh, *, seq_parallel: bool = False,
                  moe_impl: str = "global") -> MeshPlan:
    names = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    n_model = mesh.shape["model"] if "model" in names else 1
    return MeshPlan(
        n_data=n_data,
        n_model=n_model,
        data_axes=data_axes,
        model_axis="model" if "model" in names else None,
        seq_parallel=seq_parallel,
        moe_impl=moe_impl,
        mesh=mesh,
    )


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that degrades to a no-op when no mesh is
    active (unit tests, single-device runs)."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def shard_seq(x: jax.Array, plan: MeshPlan) -> jax.Array:
    """Residual-stream constraint for sequence parallelism: (B, S, d) with
    batch over the data axes and SEQUENCE over the model axis.  GSPMD then
    lowers each block's output projection to reduce-scatter(+all-gather on
    entry) instead of a full all-reduce — half the TP collective volume."""
    if not (plan.seq_parallel and plan.model_axis):
        return x
    if x.ndim != 3 or x.shape[1] % plan.n_model != 0:
        return x
    return constrain(x, P(plan.dp, plan.model_axis, None))
