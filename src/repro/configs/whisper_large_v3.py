"""Whisper-large-v3 — encoder-decoder; conv frontend STUBBED.

[arXiv:2212.04356; unverified]  32 encoder + 32 decoder layers,
d_model=1280, 20H (MHA kv=20, head_dim=64), d_ff=5120, vocab=51866.
``input_specs`` supplies post-conv frames (B, seq//4, d_model).  The
assigned shapes exceed Whisper's native 30-s window — stress configuration,
recorded in DESIGN.md §4.  20 heads do not divide the 16-way model axis;
the sharding rules fall back (see distributed/sharding.py).
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=64,  # 32 enc + 32 dec
    enc_layers=32,
    is_encoder_decoder=True,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    qkv_bias=True,
    rms_norm=False,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=4,
    enc_layers=2,
    is_encoder_decoder=True,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    qkv_bias=True,
    rms_norm=False,
    param_dtype="float32",
    compute_dtype="float32",
)
