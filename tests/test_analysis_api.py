"""The unified Workload API + one-call pipeline (repro.analysis).

Golden paths from the paper: registry kernels land in their Table-3
decision-tree classes in ONE ``analyze()`` call; ``analyze_sweep`` compiles
each workload exactly once across a multi-chip sweep; the registry exposes
all six kernels and all 13 benchmark apps.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    ArtifactCache,
    SVEAnalysis,
    Workload,
    analyze,
    analyze_events,
    analyze_sweep,
    format_table,
    get_workload,
    list_workloads,
    register,
    workload,
)
from repro.analysis.workload import clear_registry
from repro.core import hw
from repro.core.decision_tree import PerfClass


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_exposes_kernels_and_apps():
    names = list_workloads()
    assert len(names) >= 19
    for k in ("gemm", "stream-triad", "spmv", "jacobi2d", "qc-gate",
              "flash-decode"):
        assert f"kernel/{k}" in names
    import benchmarks.apps as apps_mod

    assert len(apps_mod.APP_NAMES) == 13
    for a in apps_mod.APP_NAMES:
        assert f"app/{a}" in names


def test_get_workload_unknown_name_raises():
    with pytest.raises(KeyError, match="unknown workload"):
        get_workload("kernel/nope")


def test_workload_decorator_registers_and_returns_fn():
    @workload(name="test/saxpy", dtype="fp32",
              args=lambda: (jnp.ones(128), jnp.ones(128)),
              flops=256.0, hbm_bytes=128 * 3 * 4.0, replace=True)
    def saxpy(x, y):
        return x + 2.0 * y

    wl = get_workload("test/saxpy")
    assert wl.fn is saxpy
    assert saxpy.__workload__ is wl
    assert wl.has_analytic_model
    assert len(wl.example_args()) == 2  # lazy thunk resolved on demand


def test_duplicate_registration_rejected():
    register(Workload(name="test/dup"), replace=True)
    with pytest.raises(ValueError, match="already registered"):
        register(Workload(name="test/dup"))


# ---------------------------------------------------------------------------
# golden-path decision-tree classes (paper Table 3)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,expected", [
    ("kernel/stream-triad", PerfClass.MEMORY_BANDWIDTH_BOUND),  # Class 2
    ("kernel/spmv", PerfClass.MEMORY_LATENCY_BOUND),            # Class 3
    ("kernel/gemm", PerfClass.SPEEDUP),                         # Class 4
])
def test_analyze_golden_classes(name, expected):
    """One call, no caller-side wiring of counters/metrics/roofline/tree."""
    result = analyze(name)  # default chip: the paper's grace-core model
    assert isinstance(result, SVEAnalysis)
    assert result.perf_class == expected
    # the report carries every headline quantity of the paper's method
    assert result.vb == 4.0  # fp32 on 128-bit SVE
    assert result.r_ins > 1.0
    assert result.ai > 0.0
    assert result.bound in ("memory-bound", "compute-bound")
    assert result.ai_inflection > 0.0


def test_analyze_report_is_serializable():
    result = analyze("kernel/gemm")
    d = result.to_dict()
    for key in ("vb", "r_ins", "ai", "bound", "perf_class", "events"):
        assert key in d
    assert isinstance(result.to_json(), str)
    assert "kernel/gemm" in result.table()
    assert "class" in format_table([result])


def test_analyze_accepts_ad_hoc_workload_compiled_source():
    a = jax.random.normal(jax.random.PRNGKey(0), (128, 128), jnp.float32)
    wl = Workload(name="adhoc-matmul", fn=lambda x: x @ x, args=(a,),
                  dtype="fp32")
    assert not wl.has_analytic_model
    result = analyze(wl, hw.GRACE_CORE)
    assert result.source == "compiled"
    # a 128^3 matmul is unmistakably a dot in the artifact
    assert result.events.flops >= 2 * 128**3
    assert result.perf_class in tuple(PerfClass)


def test_analyze_multi_chip_classes_differ_by_knee():
    """QC on grace-core: AI sits between the scalar knee (1T) and the knee
    once bandwidth is shared — the decision is chip-model-dependent."""
    r_core = analyze("kernel/gemm", hw.GRACE_CORE)
    r_tpu = analyze("kernel/gemm", hw.TPU_V5E, dtype="fp32")
    assert r_core.chip == "grace-core"
    assert r_tpu.chip == "tpu-v5e"
    assert r_core.vb != r_tpu.vb  # 128-bit SVE vs 8x128x32 VPU issue


def test_time_roi_measures_wall_time():
    result = analyze("kernel/stream-triad", time_roi=True)
    assert result.wall_s is not None and result.wall_s > 0.0


# ---------------------------------------------------------------------------
# analyze_sweep: compile-once caching
# ---------------------------------------------------------------------------


def test_sweep_compiles_each_workload_once():
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 64), jnp.float32)
    wls = [
        Workload(name="sweep-mm", fn=lambda x: x @ x, args=(a,), dtype="fp32"),
        Workload(name="sweep-add", fn=lambda x: x + x, args=(a,), dtype="fp32"),
    ]
    cache = ArtifactCache()
    results = analyze_sweep(
        wls, chips=(hw.GRACE_CORE, hw.GRACE_SOCKET, hw.TPU_V5E), cache=cache
    )
    assert len(results) == 2 * 3
    assert cache.compiles == 2  # one compile per workload, not per cell
    assert cache.hits == 2 * 2  # remaining (workload, chip) cells hit cache


def test_sweep_analytic_source_never_compiles():
    cache = ArtifactCache()
    results = analyze_sweep(
        ["kernel/gemm", "kernel/stream-triad"],
        chips=(hw.GRACE_CORE, hw.GRACE_SOCKET),
        cache=cache,
    )
    assert len(results) == 4
    assert cache.compiles == 0  # analytic models short-circuit compilation
    assert all(r.source == "analytic" for r in results)


def test_sweep_elen_sensitivity_moves_vb_and_r_ins():
    """The paper's ELEN sweep at fixed VLEN: fp64 -> fp32 doubles VB, and
    the analytic issue model follows the overridden ELEN (not the
    workload's base dtype)."""
    results = analyze_sweep(
        ["kernel/stream-triad"], chips=(hw.GRACE_CORE,),
        dtypes=("fp64", "fp32", "fp16"),
    )
    assert [r.vb for r in results] == [2.0, 4.0, 8.0]
    assert [r.r_ins for r in results] == [2.0, 4.0, 8.0]
    assert [r.report.dtype for r in results] == ["fp64", "fp32", "fp16"]


def test_cache_distinguishes_same_named_workloads():
    """Two distinct workloads sharing a name must not share events."""
    a = jnp.ones((32, 32), jnp.float32)
    small = Workload(name="same-name", fn=lambda x: x + x, args=(a,))
    big = Workload(name="same-name", fn=lambda x: x @ x, args=(a,))
    cache = ArtifactCache()
    ev_small = analyze(small, cache=cache).events
    ev_big = analyze(big, cache=cache).events
    assert cache.compiles == 2
    assert ev_big.flops > ev_small.flops  # matmul >> elementwise add


def test_clear_registry_recovers_builtins():
    """clear_registry + next lookup re-registers kernels and apps."""
    names_before = set(list_workloads())
    clear_registry()
    try:
        assert set(list_workloads()) >= {
            n for n in names_before if n.startswith(("kernel/", "app/"))
        }
        assert analyze("kernel/gemm").perf_class == PerfClass.SPEEDUP
    finally:
        clear_registry()
        list_workloads()  # restore for later tests


def test_tag_filter_does_not_materialize_lazy_entries():
    kernels = list_workloads(tags=("kernel",))
    assert len(kernels) == 7
    assert "kernel/flash-prefill" in kernels
    assert all(k.startswith("kernel/") for k in kernels)
    apps = list_workloads(tags=("app",))
    assert len(apps) == 13
    # the filter must come from registry-side tags, not from building the
    # suite: the LLM apps take ~10s to build, a pure name filter must not
    assert all(a.startswith("app/") for a in apps)


# ---------------------------------------------------------------------------
# apps ride the same API
# ---------------------------------------------------------------------------


def test_app_suite_members_are_workloads():
    import benchmarks.apps as apps_mod

    wl = get_workload("app/STREAM")
    assert isinstance(wl, Workload)
    assert isinstance(wl, apps_mod.App)
    result = analyze(wl)
    assert result.perf_class == PerfClass.MEMORY_BANDWIDTH_BOUND
    # the issue model (Eq. 1) is inherited from Workload
    ins = wl.issue_model(hw.GRACE_CORE)
    assert ins["vb"] == 4.0


def test_analyze_events_tail_matches_full_pipeline():
    from repro.core.counters import events_from_analytic

    ev = events_from_analytic(flops=1e9, hbm_bytes=1e6)  # AI = 1000
    result = analyze_events("synthetic", ev, hw.GRACE_CORE, dtype="fp32")
    assert result.perf_class == PerfClass.SPEEDUP
    assert result.ai == pytest.approx(1000.0)
