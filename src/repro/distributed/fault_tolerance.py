"""Fault tolerance for long multi-pod runs: checkpoint-restart, straggler
mitigation, elastic scaling.

Single-container semantics note: this module implements the *control logic*
(restart policy, straggler detection, elastic resharding) as testable pure
components; the transport (process death, TPU preemption signal) is the
platform's.  On Cloud TPU the same logic hangs off the preemption notice +
``jax.distributed`` restart; nothing here assumes a single process except
the simulated-failure tests.

* **Checkpoint-restart**: ``ResilientLoop`` wraps a step function; on any
  exception it restores the newest committed checkpoint and replays from
  there (data pipeline is stateless-by-step, so replay is exact).
* **Straggler mitigation**: per-step wall time is tracked against a rolling
  median; steps slower than ``straggler_factor`` x median raise a log event
  — on a real fleet this triggers hot-spare swap-in; here it is recorded and
  surfaced in metrics so the policy is testable.
* **Elastic scaling**: ``CheckpointStore.restore(shardings=...)`` re-places
  host arrays onto whatever mesh the restarted job has (fewer/more pods);
  nothing in the training state pins a mesh shape.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from typing import Any, Callable, Dict, Optional

from repro.checkpoint import CheckpointStore

log = logging.getLogger("repro.fault_tolerance")


@dataclasses.dataclass
class FaultToleranceConfig:
    checkpoint_every: int = 100
    keep: int = 3
    max_restarts: int = 10
    straggler_factor: float = 2.0
    straggler_window: int = 32
    async_save: bool = True


class StragglerDetector:
    def __init__(self, factor: float, window: int):
        self.factor = factor
        self.times: deque = deque(maxlen=window)
        self.events = 0

    def observe(self, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.factor * med:
                self.events += 1
                is_straggler = True
                log.warning("straggler step: %.3fs vs median %.3fs", dt, med)
        self.times.append(dt)
        return is_straggler


class ResilientLoop:
    """Run (step_fn, state) to `total_steps` surviving injected failures."""

    def __init__(
        self,
        store: CheckpointStore,
        cfg: FaultToleranceConfig,
        step_fn: Callable[[int, Any], Any],
        make_initial_state: Callable[[], Any],
        *,
        shardings: Any = None,
    ):
        self.store = store
        self.cfg = cfg
        self.step_fn = step_fn
        self.make_initial_state = make_initial_state
        self.shardings = shardings
        self.straggler = StragglerDetector(cfg.straggler_factor, cfg.straggler_window)
        self.restarts = 0

    def _restore_or_init(self):
        latest = self.store.latest_step()
        if latest is None:
            return 0, self.make_initial_state()
        step, state, _ = self.store.restore(
            self.make_initial_state(), step=latest, shardings=self.shardings
        )
        log.info("restored checkpoint at step %d", step)
        return step, state

    def run(self, total_steps: int) -> Dict[str, Any]:
        step, state = self._restore_or_init()
        while step < total_steps:
            try:
                t0 = time.perf_counter()
                state = self.step_fn(step, state)
                self.straggler.observe(time.perf_counter() - t0)
                step += 1
                if step % self.cfg.checkpoint_every == 0 or step == total_steps:
                    self.store.save(
                        step, state, blocking=not self.cfg.async_save,
                        extra={"data_step": step},
                    )
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — restart on any step fault
                self.restarts += 1
                log.error("step %d failed (%s); restart %d", step, e, self.restarts)
                if self.restarts > self.cfg.max_restarts:
                    raise
                step, state = self._restore_or_init()
        self.store.wait()
        return {
            "final_step": step,
            "restarts": self.restarts,
            "straggler_events": self.straggler.events,
            "state": state,
        }
