"""One benchmark per paper table/figure.  Each function returns a list of
CSV rows (dicts) and is invoked by benchmarks.run.

fig3  — R_ins_reduction + speedup across the suite (paper Fig. 3a/3b)
fig4  — 1-thread vs 72-thread (socket) scaling of both metrics (Fig. 4)
fig5  — QC-simulator speedup vs thread count (Fig. 5)
fig6  — synthetic SpMV: speedup vs arithmetic intensity x ELEN (Fig. 6)
fig7  — adapted-roofline placement of every app (Fig. 7)
table3 — decision-tree classification of 26 cases vs the paper (Table 3)
dryrun — the TPU deployment roofline per (arch x shape x mesh) (§Roofline)
"""

from __future__ import annotations

import glob
import json
import math
import os
from typing import Dict, List

import numpy as np

from benchmarks import apps as apps_mod
from repro.core import hw, metrics
from repro.core.decision_tree import PerfClass, classify
from repro.core.roofline import adapted_roofline


def _bw_at_threads(t: int) -> float:
    """Grace STREAM bandwidth saturation: 30 GB/s @1T -> 250 GB/s plateau
    (paper Sec. 3; Fig. 5 shows saturation around 8 threads)."""
    return min(30e9 * t, 250e9)


def _chip_at_threads(t: int) -> hw.ChipSpec:
    import dataclasses

    return dataclasses.replace(
        hw.GRACE_CORE,
        name=f"grace-{t}t",
        peak_flops={k: v * t for k, v in hw.GRACE_CORE.peak_flops.items()},
        hbm_bw=_bw_at_threads(t),
    )


# ---------------------------------------------------------------------------


def fig3_vectorization() -> List[Dict]:
    """R_ins (issue model) + predicted & measured speedup per app."""
    rows = []
    for app in apps_mod.suite().values():
        rep = app.report(hw.GRACE_CORE)
        rl = adapted_roofline(hw.GRACE_CORE, app.dtype)
        wall = apps_mod.measure(app)
        rows.append({
            "app": app.name,
            "dtype": app.dtype,
            "problem": app.problem,
            "vb": rl.vb,
            "r_ins": round(rep.r_ins, 3),
            "ai": f"{rep.ai:.4g}",
            "speedup_predicted": round(rl.predicted_speedup(rep.ai), 3),
            "wall_s_cpu": f"{wall:.5f}",
            "vectorizable_fraction": app.vectorizable_fraction,
        })
    return rows


def fig4_thread_scaling() -> List[Dict]:
    """1-thread vs 72-thread: R_ins collapse for runtime-heavy apps, and the
    memory-bound flip for QC/STREAM (paper Fig. 4)."""
    # apps whose 72T instruction stream is dominated by threading runtime
    runtime_heavy = {"YOLOv3": 0.45, "AlexNet": 0.45,
                     "LLM-training": 0.5, "LLM-inference": 0.5}
    rows = []
    for app in apps_mod.suite().values():
        for threads in (1, 72):
            chip = _chip_at_threads(threads)
            vf = app.vectorizable_fraction
            if threads == 72 and app.name in runtime_heavy:
                vf = runtime_heavy[app.name]  # OpenMP runtime instructions
            vb = metrics.vectorization_bound(chip, app.dtype)
            r_ins = metrics.amdahl_r_ins(vb, vf)
            rl = adapted_roofline(chip, app.dtype)
            rows.append({
                "app": app.name, "threads": threads,
                "r_ins": round(r_ins, 3),
                "speedup_predicted": round(rl.predicted_speedup(app.ai), 3),
                "region": rl.region(app.ai),
            })
    return rows


def fig5_qc_sensitivity() -> List[Dict]:
    """QC speedup vs thread count: collapses as bandwidth saturates ~8T."""
    app = apps_mod.suite()["QC-simulator"]
    rows = []
    for threads in (1, 2, 4, 8, 16, 32, 72):
        rl = adapted_roofline(_chip_at_threads(threads), app.dtype)
        rows.append({
            "threads": threads,
            "ai": f"{app.ai:.4g}",
            "speedup_predicted": round(rl.predicted_speedup(app.ai), 3),
            "bw_gbs": _bw_at_threads(threads) / 1e9,
            "region": rl.region(app.ai),
        })
    return rows


def fig6_synthetic_spmv() -> List[Dict]:
    """The synthetic benchmark: speedup vs repeat-K intensity, per ELEN.
    Reproduces: saturation at VB (2x fp64 / 4x fp32), and ~no speedup at
    K=1 (memory-bound).  Wall time measured on CPU for the fp32 variant."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.spmv import ops as spmv_ops, ref as spmv_ref

    vals, cols, nnz = spmv_ref.make_problem(
        jax.random.PRNGKey(0), 1024, 1024, row_block=8, max_nnz=64, width_pad=128
    )
    x = jax.random.normal(jax.random.PRNGKey(1), (1024,), jnp.float32)
    nnz_np = np.asarray(nnz)
    rows = []
    for dtype, dbytes in (("fp64", 8), ("fp32", 4), ("fp16", 2)):
        rl = adapted_roofline(hw.GRACE_CORE, dtype)
        for repeat in (1, 2, 5, 10, 20, 40):
            fb = spmv_ops.flops_bytes(nnz_np, repeat=repeat, dtype_bytes=dbytes)
            row = {
                "dtype": dtype, "repeat": repeat, "ai": f"{fb['ai']:.4g}",
                "vb": rl.vb,
                "speedup_predicted": round(rl.predicted_speedup(fb["ai"]), 3),
            }
            if dtype == "fp32":
                import time

                fn = jax.jit(lambda r=repeat: spmv_ref.spmv_ref(
                    vals, cols, nnz, x, repeat=r))
                out = fn(); jax.block_until_ready(out)
                t0 = time.perf_counter()
                for _ in range(3):
                    jax.block_until_ready(fn())
                row["wall_s_cpu"] = f"{(time.perf_counter() - t0) / 3:.5f}"
            rows.append(row)
    return rows


def fig7_roofline() -> List[Dict]:
    """Adapted-roofline placement (paper Fig. 7): each app's AI vs the
    scalar/vector knees; flags the compute->memory flip (red triangles)."""
    rows = []
    for app in apps_mod.suite().values():
        rl = adapted_roofline(hw.GRACE_CORE, app.dtype)
        scalar_region = rl.region(app.ai, vectorized=False)
        vector_region = rl.region(app.ai, vectorized=True)
        rows.append({
            "app": app.name, "dtype": app.dtype, "ai": f"{app.ai:.4g}",
            "ai_irr": f"{rl.ai_irr:.4g}", "ai_irv": f"{rl.ai_irv:.4g}",
            "scalar_region": scalar_region,
            "vector_region": vector_region,
            "flips_to_memory_bound": scalar_region == "compute-bound"
            and vector_region == "memory-bound",
        })
    return rows


# paper Table 3 ground truth (SN, app) -> (class@1T, class@72T)
_TABLE3_PAPER = {
    "YOLOv3": (4, 4), "LLM-training": (4, 4), "LLM-inference": (4, 4),
    "QC-simulator": (4, 2), "FFT1D": (1, 1), "FFT2D": (1, 1),
    "STREAM": (2, 2), "DGEMM": (4, 4), "SGEMM": (4, 4), "SpMV": (3, 3),
    "Jacobi2D": (2, 1), "AlexNet": (4, 4), "AutoDock": (4, 4),
}

_RUNTIME_HEAVY_72T = {"Jacobi2D": 0.15, "YOLOv3": 0.45, "AlexNet": 0.45,
                      "LLM-training": 0.5, "LLM-inference": 0.5}


def table3_decision_tree() -> List[Dict]:
    rows = []
    agree = 0
    for app in apps_mod.suite().values():
        expected = _TABLE3_PAPER.get(app.name)
        got = []
        for threads in (1, 72):
            chip = _chip_at_threads(threads)
            rep = app.report(chip)
            if threads == 72 and app.name in _RUNTIME_HEAVY_72T:
                vb = metrics.vectorization_bound(chip, app.dtype)
                r = metrics.amdahl_r_ins(vb, _RUNTIME_HEAVY_72T[app.name])
                import dataclasses

                rep = dataclasses.replace(
                    rep, ins_vec=rep.ins_scalar / r,
                    vectorizable_fraction=_RUNTIME_HEAVY_72T[app.name],
                )
            got.append(int(classify(rep, chip).perf_class))
        match = expected is not None and tuple(got) == expected
        agree += int(match)
        rows.append({
            "app": app.name,
            "class_1t": got[0], "class_72t": got[1],
            "paper_1t": expected[0] if expected else "",
            "paper_72t": expected[1] if expected else "",
            "match": match,
        })
    rows.append({"app": f"AGREEMENT {agree}/{len(_TABLE3_PAPER)}",
                 "class_1t": "", "class_72t": "", "paper_1t": "",
                 "paper_72t": "", "match": ""})
    return rows


def dryrun_roofline(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    """The §Roofline deliverable table, read from the dry-run artifacts."""
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*__single__*.json"))):
        d = json.load(open(f))
        rl = d["roofline"]
        rows.append({
            "cell": d["cell"],
            "mesh": d["mesh"],
            "variant": "baseline" if d.get("baseline") else "optimized",
            "compute_s": f"{rl['compute_s']:.4g}",
            "memory_s": f"{rl['memory_s']:.4g}",
            "collective_s": f"{rl['collective_s']:.4g}",
            "dominant": rl["dominant"],
            "bound_s": f"{rl['bound_s']:.4g}",
            "model_flops": f"{rl['model_flops']:.4g}",
            "useful_flop_fraction": round(rl["useful_flop_fraction"], 3),
            "roofline_fraction": round(rl["roofline_fraction"], 3),
            "gb_per_device": round(d["memory_per_device"]["total_gb"], 2),
        })
    return rows


def sve_analysis_sweep() -> List[Dict]:
    """Every registered workload (6 kernels + 13 apps) through the one-call
    pipeline on both chip models — the unified-API view of Table 3/Fig. 7."""
    from repro.analysis import analyze_sweep

    return [r.row() for r in analyze_sweep(chips=(hw.GRACE_CORE, hw.TPU_V5E))]


ALL = {
    "fig3_vectorization": fig3_vectorization,
    "fig4_thread_scaling": fig4_thread_scaling,
    "fig5_qc_sensitivity": fig5_qc_sensitivity,
    "fig6_synthetic_spmv": fig6_synthetic_spmv,
    "fig7_roofline": fig7_roofline,
    "table3_decision_tree": table3_decision_tree,
    "sve_analysis_sweep": sve_analysis_sweep,
    "dryrun_roofline": dryrun_roofline,
}
