"""While-aware structural HLO cost model: validated against ground truth.

The central finding (mirrors the paper's PMU-event validation): XLA's
``cost_analysis()`` counts while/scan bodies ONCE — a counter that must be
rejected for scanned programs — while the structural walk with
known_trip_count multipliers reproduces the unrolled ground truth exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hlo_cost
from repro.core.counters import _cost_get, events_from_compiled

N, K = 128, 8


def _scan_matmul():
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=K)
        return y

    x = jax.ShapeDtypeStruct((N, N), jnp.float32)
    return jax.jit(f).lower(x).compile()


def _unrolled_matmul():
    def g(x):
        for _ in range(K):
            x = x @ x
        return x

    x = jax.ShapeDtypeStruct((N, N), jnp.float32)
    return jax.jit(g).lower(x).compile()


def test_cost_analysis_undercounts_scan_bodies():
    """The rejected counter: scan flops == 1 iteration, unrolled == K."""
    # cost_analysis() returns a dict or a 1-list of dicts depending on the
    # jax version; _cost_get is the version-proof accessor counters.py uses
    scan_flops = _cost_get(_scan_matmul().cost_analysis(), "flops")
    unrolled_flops = _cost_get(_unrolled_matmul().cost_analysis(), "flops")
    assert unrolled_flops == pytest.approx(K * 2 * N**3, rel=0.01)
    assert scan_flops == pytest.approx(2 * N**3, rel=0.01)  # body counted once


def test_structural_model_scales_scan_exactly():
    hc = hlo_cost.cost_of_module(_scan_matmul().as_text())
    assert hc.mxu_flops == pytest.approx(K * 2 * N**3, rel=1e-6)
    assert hc.while_trip_counts == [K]
    assert hc.unknown_trip_counts == 0


def test_structural_model_matches_unrolled():
    hc = hlo_cost.cost_of_module(_unrolled_matmul().as_text())
    assert hc.mxu_flops == pytest.approx(K * 2 * N**3, rel=1e-6)
    assert hc.while_trip_counts == []


def test_nested_scans_multiply():
    def g(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jax.ShapeDtypeStruct((N, N), jnp.float32)
    compiled = jax.jit(g).lower(x).compile()
    hc = hlo_cost.cost_of_module(compiled.as_text())
    assert hc.mxu_flops == pytest.approx(12 * 2 * N**3, rel=1e-6)
    assert sorted(hc.while_trip_counts) == [3, 4]


def test_traffic_scales_with_scan():
    hc_scan = hlo_cost.cost_of_module(_scan_matmul().as_text())
    # each iteration must move at least in+out of the dot: 3*N*N*4 bytes
    assert hc_scan.traffic_bytes >= K * 3 * N * N * 4
    # and not be absurdly larger (copies at most ~3x)
    assert hc_scan.traffic_bytes <= 10 * K * 3 * N * N * 4


def test_dynamic_update_slice_charges_slice_not_buffer():
    def f(cache, new):
        return jax.lax.dynamic_update_slice(cache, new, (0, 5))

    cache = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    new = jax.ShapeDtypeStruct((1024, 1), jnp.float32)
    compiled = jax.jit(f, donate_argnums=(0,)).lower(cache, new).compile()
    hc = hlo_cost.cost_of_module(compiled.as_text())
    buffer_bytes = 1024 * 1024 * 4
    assert hc.traffic_bytes < 0.1 * buffer_bytes, (
        f"DUS charged {hc.traffic_bytes} — billing the whole cache"
    )


def test_events_from_compiled_uses_structural_flops():
    compiled = _scan_matmul()
    ev = events_from_compiled(compiled, n_devices=1)
    assert ev.flops >= K * 2 * N**3
    assert ev.xla_raw_flops == pytest.approx(2 * N**3, rel=0.01)
    assert ev.while_trip_counts == [K]


def test_vpu_estimate_for_elementwise_program():
    def f(a, b):
        return jnp.tanh(a) * b + 1.0

    a = jax.ShapeDtypeStruct((4096,), jnp.float32)
    compiled = jax.jit(f).lower(a, a).compile()
    hc = hlo_cost.cost_of_module(compiled.as_text())
    assert hc.mxu_flops == 0.0
    assert hc.vpu_flop_estimate >= 4096
    assert hc.traffic_bytes >= 3 * 4096 * 4  # two reads + one write


def test_trip_count_parsers():
    assert hlo_cost._TRIP_RE.search(
        'backend_config={"known_trip_count":{"n":"64"}}'
    ).group(1) == "64"
    comp = hlo_cost._Computation(name="cond")
    comp.ops.append(hlo_cost._Op("c", "constant", "s32[]", "%c = s32[] constant(28)"))
    assert hlo_cost.trip_count_of(comp) == 28
    empty = hlo_cost._Computation(name="cond2")
    assert hlo_cost.trip_count_of(empty) is None
