"""Declarative scenario matrices: axes -> cartesian, seeded `Scenario` cells.

A :class:`MatrixSpec` names one value-list per traffic axis — arrival
process x prompt-length distribution x EOS-probability x scheduler x
architecture x fault plan x device mesh — and :meth:`MatrixSpec.cells` expands the
cartesian product into :class:`Scenario` cells (skipping combinations a
fault plan declares invalid, e.g. slot preemption under the lockstep wave
scheduler, which has no slots to preempt).

Every cell carries a **derived seed**: SHA-256 over the spec seed and the
cell's *traffic* key.  Two properties follow by construction:

* same spec => same sampled traffic, process- and machine-independent
  (the acceptance bar: a scenario is a reproducible experiment, not a
  lucky workload);
* the traffic key excludes the scheduler and the fault axis, so a faulted
  cell, its fault-free golden twin, and the same traffic under the other
  scheduler all sample IDENTICAL requests — the paper's methodology of
  varying one axis while pinning the rest (Sec. 5's per-app sweeps).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.serve.engine import SCHEDULERS

#: Architectures the serve engine is golden-verified on (PR 5).
SERVE_ARCHS = (
    "gpt2-124m", "qwen3-1.7b", "mamba2-370m", "deepseek-v2-lite-16b",
    "deepseek-moe-16b", "jamba-1.5-large-398b",
)


# ---------------------------------------------------------------------------
# Axis value specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """When requests arrive, measured in fused decode steps (the engine's
    clock): ``poisson`` (exponential interarrivals at ``rate`` requests per
    step), ``bursty`` (``burst`` requests every ``gap`` steps), or
    ``replay`` (explicit step offsets, cycled over the request count)."""

    kind: str = "poisson"
    rate: float = 0.5
    burst: int = 4
    gap: int = 24
    steps: Sequence[int] = ()

    def __post_init__(self):
        if self.kind not in ("poisson", "bursty", "replay"):
            raise ValueError(f"unknown arrival kind {self.kind!r}")
        if self.kind == "poisson" and self.rate <= 0:
            raise ValueError("poisson arrival needs rate > 0")
        if self.kind == "replay" and not self.steps:
            raise ValueError("replay arrival needs explicit steps")
        object.__setattr__(self, "steps", tuple(int(s) for s in self.steps))

    @property
    def slug(self) -> str:
        if self.kind == "poisson":
            return f"poisson{self.rate:g}"
        if self.kind == "bursty":
            return f"burst{self.burst}x{self.gap}"
        return f"replay{len(self.steps)}"


@dataclasses.dataclass(frozen=True)
class PromptSpec:
    """Prompt-length distribution: ``uniform`` on [lo, hi], ``fixed`` at
    ``n``, or ``bimodal`` (``long`` tokens with probability ``p_long``,
    else ``short`` — the ragged mix lockstep scheduling pads worst)."""

    kind: str = "uniform"
    lo: int = 4
    hi: int = 16
    n: int = 8
    short: int = 4
    long: int = 24
    p_long: float = 0.25

    def __post_init__(self):
        if self.kind not in ("uniform", "fixed", "bimodal"):
            raise ValueError(f"unknown prompt kind {self.kind!r}")
        if self.kind == "uniform" and not 1 <= self.lo <= self.hi:
            raise ValueError(f"bad uniform bounds [{self.lo}, {self.hi}]")

    @property
    def slug(self) -> str:
        if self.kind == "uniform":
            return f"u{self.lo}-{self.hi}"
        if self.kind == "fixed":
            return f"fix{self.n}"
        return f"bi{self.short}-{self.long}p{self.p_long:g}"


@dataclasses.dataclass(frozen=True)
class EosSpec:
    """Per-token stop probability.  Real EOS is a model-emitted token; for
    a seeded traffic model we sample the *consequence* instead: each
    request's token budget is capped at Geometric(``p_early``) (so
    completions go ragged exactly as stochastic EOS makes them), which
    keeps the trace deterministic under any parameter init."""

    p_early: float = 0.0

    def __post_init__(self):
        if not 0.0 <= self.p_early < 1.0:
            raise ValueError(f"p_early must be in [0, 1), got {self.p_early}")

    @property
    def slug(self) -> str:
        return f"eos{self.p_early:g}"


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Per-cell service-level floors/ceilings checked by the runner.

    Defaults are deliberately loose enough for shared CI runners — the
    machinery (violation -> failed cell -> non-zero gate exit) is the
    contract; operators tighten the numbers per deployment."""

    min_tok_s: float = 0.05
    max_p95_latency_s: float = 120.0
    max_ttft_p95_s: float = 120.0
    min_slot_utilization: float = 0.05
    #: optional ceiling on the DETERMINISTIC step-clock TTFT (fused steps
    #: from submit to first token): same trace => same value on any
    #: machine, so it can be pinned tight where wall ceilings stay loose.
    #: None disables the check (the default: it is a per-matrix contract).
    max_ttft_p95_steps: Optional[float] = None

    def check(self, stats: Mapping[str, Any]) -> List[str]:
        """Violation strings (empty = SLOs met)."""
        out = []
        checks = (
            ("tok_s", self.min_tok_s, "floor", "tok/s"),
            ("p95_latency_s", self.max_p95_latency_s, "ceiling", "p95 latency"),
            ("ttft_p95_s", self.max_ttft_p95_s, "ceiling", "p95 TTFT"),
            ("slot_utilization", self.min_slot_utilization, "floor",
             "slot utilization"),
        )
        if self.max_ttft_p95_steps is not None:
            checks += (("ttft_p95_steps", self.max_ttft_p95_steps,
                        "ceiling", "p95 TTFT steps"),)
        for name, bound, kind, label in checks:
            val = stats.get(name)
            if val is None:
                out.append(f"{label}: metric {name!r} missing from stats")
            elif kind == "floor" and float(val) < bound:
                out.append(f"{label} {float(val):.4g} < floor {bound:g}")
            elif kind == "ceiling" and float(val) > bound:
                out.append(f"{label} {float(val):.4g} > ceiling {bound:g}")
        return out


# ---------------------------------------------------------------------------
# Cells
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One fully-pinned cell of the matrix: every axis chosen, seed derived."""

    arrival: ArrivalSpec
    prompt: PromptSpec
    eos: EosSpec
    scheduler: str
    arch: str
    fault: str
    requests: int
    max_new: int
    max_batch: int
    max_len: int
    block_size: int
    seed: int  # derived: see cell_seed()
    slo: SLOSpec = SLOSpec()
    prefill_chunk: int = 1
    prefill_budget: Optional[int] = None
    #: prefix-sharing axis: "none" = plain traffic, engine sharing off;
    #: "shared" = shared-prefix traffic, COW engine sharing ON;
    #: "shared-off" = the SAME shared-prefix traffic, sharing disabled
    #: (the golden baseline a "shared" cell is diffed against)
    prompt_sharing: str = "none"
    #: speculative-decoding axis: 0 = off, k > 0 = the engine drafts and
    #: verifies k tokens per fused target step (continuous only).  The
    #: axis never changes the sampled traffic — a speculating cell's
    #: golden baseline is the SAME cell with speculation off
    #: (:meth:`spec_twin`), which must serve byte-identical streams.
    spec_k: int = 0
    #: device-mesh axis: None = single-device serving, "DxM" = the engine
    #: shards params and the paged KV pool over a data-x-model host mesh
    #: (continuous only).  Sharding never changes the sampled traffic — a
    #: meshed cell's golden baseline is the SAME cell unsharded
    #: (:meth:`mesh_twin`), which must serve byte-identical streams.
    mesh: Optional[str] = None

    def __post_init__(self):
        if self.prompt_sharing not in ("none", "shared", "shared-off"):
            raise ValueError(
                f"unknown prompt_sharing {self.prompt_sharing!r}")
        if self.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {self.spec_k}")
        if self.mesh is not None:
            from repro.launch.mesh import parse_mesh

            parse_mesh(self.mesh)  # raises MeshShapeError on junk

    @property
    def share_prefixes(self) -> bool:
        """Whether the ENGINE deduplicates (the traffic *shape* is shared
        for both "shared" and "shared-off")."""
        return self.prompt_sharing == "shared"

    @property
    def traffic_key(self) -> str:
        """Axes the sampled traffic depends on.  Scheduler, fault, the
        prefill-chunking axis, the speculation axis, and the sharing MODE
        are EXCLUDED so twins, cross-scheduler cells, and
        chunked-vs-token-by-token cells all share a trace.  The traffic *shape* (shared prefixes vs plain) is
        included — it changes the sampled prompts — but "shared" and
        "shared-off" collapse onto the same key, so the COW engine and its
        sharing-disabled baseline serve byte-identical requests."""
        parts = [
            self.arrival.slug, self.prompt.slug, self.eos.slug, self.arch,
            f"n{self.requests}", f"new{self.max_new}",
        ]
        if self.prompt_sharing != "none":
            parts.append("sharedpfx")
        return "/".join(parts)

    @property
    def cell_id(self) -> str:
        parts = [
            self.arrival.slug, self.prompt.slug, self.eos.slug,
            self.scheduler, self.arch, self.fault,
        ]
        if self.prefill_chunk > 1:
            parts.append(f"pc{self.prefill_chunk}")
        if self.prompt_sharing != "none":
            parts.append(self.prompt_sharing)
        if self.spec_k > 0:
            parts.append(f"spec{self.spec_k}")
        if self.mesh is not None:
            parts.append(f"m{self.mesh}")
        return "/".join(parts)

    @property
    def ledger_key(self) -> str:
        """Workload key of this cell's BenchRun row in the perf ledger."""
        return f"scenario/{self.cell_id}"

    def twin(self) -> "Scenario":
        """The fault-free golden twin: same everything, fault='none'.
        Shares the seed (fault is outside the traffic key), so both cells
        sample byte-identical traffic."""
        return dataclasses.replace(self, fault="none")

    def chunk_twin(self) -> "Scenario":
        """The token-by-token golden twin of a chunked-prefill cell: same
        traffic (the chunk axis is outside the traffic key), fault-free,
        ``prefill_chunk=1``.  Chunked serving must match it uid-for-uid."""
        return dataclasses.replace(self, fault="none", prefill_chunk=1,
                                   prefill_budget=None)

    def sharing_twin(self) -> "Scenario":
        """The sharing-disabled golden twin of a COW-sharing cell: same
        shared-prefix traffic (the sharing mode is outside the traffic
        key), fault-free, ``prompt_sharing="shared-off"``.  The COW engine
        must serve byte-identical streams while storing strictly fewer
        physical blocks."""
        return dataclasses.replace(self, fault="none",
                                   prompt_sharing="shared-off")

    def spec_twin(self) -> "Scenario":
        """The speculation-off golden twin of a speculative cell: same
        traffic (the speculation axis is outside the traffic key),
        fault-free, ``spec_k=0``.  The speculative engine must serve
        byte-identical streams — speculation may only change how many
        fused target steps they cost."""
        return dataclasses.replace(self, fault="none", spec_k=0)

    def mesh_twin(self) -> "Scenario":
        """The unsharded golden twin of a meshed cell: same traffic (the
        mesh axis is outside the traffic key), fault-free, ``mesh=None``.
        The sharded engine must serve byte-identical streams — the mesh
        may only change where the math runs."""
        return dataclasses.replace(self, fault="none", mesh=None)


def cell_seed(spec_seed: int, traffic_key: str) -> int:
    """Deterministic 32-bit seed for one cell's traffic sampler."""
    digest = hashlib.sha256(f"{spec_seed}|{traffic_key}".encode()).digest()
    return int.from_bytes(digest[:4], "big")


# ---------------------------------------------------------------------------
# The matrix
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MatrixSpec:
    """The declarative matrix: one value-list per axis + shared sizing."""

    arrivals: List[ArrivalSpec] = dataclasses.field(
        default_factory=lambda: [ArrivalSpec()])
    prompts: List[PromptSpec] = dataclasses.field(
        default_factory=lambda: [PromptSpec()])
    eos: List[EosSpec] = dataclasses.field(
        default_factory=lambda: [EosSpec()])
    schedulers: List[str] = dataclasses.field(
        default_factory=lambda: list(SCHEDULERS))
    archs: List[str] = dataclasses.field(
        default_factory=lambda: ["gpt2-124m"])
    faults: List[str] = dataclasses.field(
        default_factory=lambda: ["none"])
    #: prefill-chunking axis: 1 = token-by-token, >1 = chunked prefill
    #: (continuous scheduler only; wave combos are skipped)
    prefill_chunks: List[int] = dataclasses.field(
        default_factory=lambda: [1])
    prefill_budget: Optional[int] = None
    #: prefix-sharing axis ("none" / "shared" / "shared-off"): sharing
    #: cells run continuous-only (the wave path has no block pool to
    #: deduplicate); "shared" cells are golden-diffed against their
    #: sharing-disabled twin by the runner
    prompt_sharing: List[str] = dataclasses.field(
        default_factory=lambda: ["none"])
    #: speculative-decoding axis (0 = off, k > 0 = draft/verify width):
    #: speculating cells run continuous-only and are golden-diffed
    #: against their speculation-off twin by the runner
    speculate: List[int] = dataclasses.field(
        default_factory=lambda: [0])
    #: device-mesh axis (None = single device, "DxM" = tensor-parallel
    #: serving over a data-x-model host mesh): meshed cells run
    #: continuous-only and are golden-diffed against their unsharded
    #: twin by the runner.  Shapes needing more devices than the process
    #: has are an execution-time failure, not an expansion-time skip —
    #: force host devices via XLA_FLAGS to run them.
    meshes: List[Optional[str]] = dataclasses.field(
        default_factory=lambda: [None])
    requests: int = 6
    max_new: int = 8
    max_batch: int = 2
    max_len: int = 64
    block_size: int = 8
    seed: int = 0
    slo: SLOSpec = dataclasses.field(default_factory=SLOSpec)

    def cells(self) -> List[Scenario]:
        """Cartesian expansion, invalid (fault x scheduler) combos skipped."""
        import itertools

        from repro.scenarios.faults import get_plan  # cycle-free at call time

        out: List[Scenario] = []
        for sched in self.schedulers:
            if sched not in SCHEDULERS:
                raise ValueError(f"unknown scheduler {sched!r}")
        combos = itertools.product(
            self.archs, self.schedulers, self.arrivals, self.prompts,
            self.eos, self.faults, self.prefill_chunks, self.prompt_sharing,
            self.speculate, self.meshes,
        )
        for arch, sched, arr, pr, eo, fault, pc, ps, sk, mesh in combos:
            if pc > 1 and sched != "continuous":
                continue  # wave has no chunked path
            if ps != "none" and sched != "continuous":
                continue  # wave has no block pool to deduplicate
            if sk > 0 and sched != "continuous":
                continue  # speculation verifies over the paged cache
            if sk > 0 and pc > 1:
                continue  # speculation owns the multi-token window
            if mesh is not None and sched != "continuous":
                continue  # only the paged continuous path is sharded
            cell = Scenario(
                arrival=arr, prompt=pr, eos=eo,
                scheduler=sched, arch=arch, fault=fault,
                requests=self.requests,
                max_new=self.max_new,
                max_batch=self.max_batch,
                max_len=self.max_len,
                block_size=self.block_size,
                seed=0, slo=self.slo,
                prefill_chunk=pc,
                prefill_budget=self.prefill_budget if pc > 1 else None,
                prompt_sharing=ps,
                spec_k=sk,
                mesh=mesh,
            )
            if not get_plan(fault).applies_to(cell):
                continue
            out.append(dataclasses.replace(
                cell, seed=cell_seed(self.seed, cell.traffic_key),
            ))
        return out

    # -- JSON round-trip (spec files for the CLI) ---------------------------

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        for arr in d["arrivals"]:
            arr["steps"] = list(arr["steps"])
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "MatrixSpec":
        kw: Dict[str, Any] = {}
        for f in dataclasses.fields(cls):
            if f.name not in d:
                continue
            v = d[f.name]
            if f.name == "arrivals":
                v = [ArrivalSpec(**a) for a in v]
            elif f.name == "prompts":
                v = [PromptSpec(**a) for a in v]
            elif f.name == "eos":
                v = [EosSpec(**a) for a in v]
            elif f.name == "slo":
                v = SLOSpec(**v)
            kw[f.name] = v
        return cls(**kw)

    @classmethod
    def from_json(cls, path: str) -> "MatrixSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def smoke_matrix() -> MatrixSpec:
    """The CI matrix: 2 archs x both schedulers x every fault plan, over
    Poisson arrivals on ragged uniform prompts with stochastic early stop."""
    return MatrixSpec(
        arrivals=[ArrivalSpec(kind="poisson", rate=0.5)],
        prompts=[PromptSpec(kind="uniform", lo=4, hi=12)],
        eos=[EosSpec(p_early=0.1)],
        schedulers=list(SCHEDULERS),
        archs=["gpt2-124m", "qwen3-1.7b"],
        faults=["none", "preempt", "device-loss", "malformed"],
        requests=6,
        max_new=8,
        max_batch=2,
        max_len=64,
        block_size=8,
    )


def full_matrix() -> MatrixSpec:
    """The wide matrix: every arrival/length/EOS shape, all six serve
    architectures, every fault plan.  Expansion is cheap; running it is an
    operator decision (``--only`` filters, ``--jobs`` fans out)."""
    return MatrixSpec(
        arrivals=[
            ArrivalSpec(kind="poisson", rate=0.5),
            ArrivalSpec(kind="bursty", burst=4, gap=24),
            ArrivalSpec(kind="replay", steps=(0, 0, 1, 5, 9, 30)),
        ],
        prompts=[
            PromptSpec(kind="uniform", lo=4, hi=16),
            PromptSpec(kind="bimodal", short=4, long=24, p_long=0.25),
        ],
        eos=[EosSpec(p_early=0.0), EosSpec(p_early=0.15)],
        schedulers=list(SCHEDULERS),
        archs=list(SERVE_ARCHS),
        faults=["none", "preempt", "device-loss", "malformed"],
        prompt_sharing=["none", "shared"],
        speculate=[0, 4],
        meshes=[None, "1x1"],
        requests=8,
        max_new=8,
        max_batch=2,
        max_len=64,
        block_size=8,
    )


MATRICES = {"smoke": smoke_matrix, "full": full_matrix}


def load_matrix(name_or_path: Optional[str]) -> MatrixSpec:
    """Resolve a named matrix ('smoke', 'full') or a JSON spec file."""
    if not name_or_path:
        return smoke_matrix()
    if name_or_path in MATRICES:
        return MATRICES[name_or_path]()
    return MatrixSpec.from_json(name_or_path)
