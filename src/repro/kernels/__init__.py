"""Pallas kernels for the paper's compute hot-spots, behind one registry.

Six kernel packages (gemm, stream, spmv, jacobi2d, qc_gate, flash_decode),
each validated against a pure-jnp/numpy oracle in ``<pkg>/ref.py``.  The
jit call surfaces live in :mod:`repro.kernels.registry`: every kernel is a
``KernelOps`` exposing ``ref`` / ``kernel`` / ``interpret`` variants and is
auto-registered as a ``Workload`` (``kernel/<name>``) for
``repro.analysis.analyze``.

    from repro.kernels import registry

    y = registry.GEMM(x, w)                  # interpret-mode Pallas
    y = registry.GEMM.kernel(x, w)           # compiled Pallas path
    y_ref = registry.GEMM.ref(x, w)          # oracle
    registry.list_kernels()                  # all nine entry points

The per-package ``ops.py`` modules remain as thin shims re-exporting the
registry objects plus their package-specific cost/issue models.
"""

from repro.kernels import registry  # noqa: F401
from repro.kernels.registry import (  # noqa: F401
    KERNELS,
    KernelOps,
    get_kernel,
    list_kernels,
    register_kernel,
)
