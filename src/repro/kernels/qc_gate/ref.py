"""Oracle for the RX-gate kernel: dense complex matrix semantics."""

from __future__ import annotations

import numpy as np


def rx_ref(re, im, qubit: int, theta: float):
    """Complex-arithmetic reference on the host (numpy complex128)."""
    psi = np.asarray(re, np.float64) + 1j * np.asarray(im, np.float64)
    n_amp = psi.shape[0]
    inner = 1 << qubit
    psi = psi.reshape(n_amp // (2 * inner), 2, inner)
    c, s = np.cos(theta / 2), np.sin(theta / 2)
    a, b = psi[:, 0], psi[:, 1]
    out = np.stack([c * a - 1j * s * b, c * b - 1j * s * a], axis=1)
    out = out.reshape(n_amp)
    return out.real.astype(np.float32), out.imag.astype(np.float32)


def flops_bytes(n_qubits: int, dtype_bytes: int = 4) -> dict:
    """Per gate: 6 real flops per amplitude; read+write both planes."""
    n_amp = float(1 << n_qubits)
    flops = 6.0 * n_amp
    bytes_ = 4.0 * n_amp * dtype_bytes  # re/im read + write
    return {"flops": flops, "bytes": bytes_, "ai": flops / bytes_}
