"""Jit wrappers for the STREAM kernels + the paper's ELEN instruction model."""

from __future__ import annotations

import functools
import math

import jax

from repro.kernels.stream import kernel as _k

copy = jax.jit(_k.stream_copy, static_argnames=("block_rows", "interpret"))
scale = jax.jit(_k.stream_scale, static_argnums=(1,),
                static_argnames=("block_rows", "interpret"))
add = jax.jit(_k.stream_add, static_argnames=("block_rows", "interpret"))
triad = jax.jit(_k.stream_triad, static_argnums=(2,),
                static_argnames=("block_rows", "interpret"))


def issue_counts(n_elements: int, elen_bits: int, vlen_bits: int = 128) -> dict:
    """Paper Sec. 4.2: R_ins for STREAM tracks VB = VLEN/ELEN even though
    wall time is bandwidth-bound and flat."""
    lanes = vlen_bits // elen_bits
    return {
        "scalar": n_elements,
        "vector": math.ceil(n_elements / lanes),
        "r_ins": n_elements / math.ceil(n_elements / lanes),
        "vb": lanes,
    }
