"""Mamba-2 370M — pure SSM (SSD / state-space duality), attention-free.

[arXiv:2405.21060; unverified]  48L, d_model=1024, ssm_state=128,
vocab=50280, d_ff=0 (no separate MLP — the Mamba block IS the layer).
"""

from repro.configs.base import LayerKind, ModelConfig, SSMConfig

FULL = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,  # attention-free; unused
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    layer_pattern=(LayerKind.MAMBA,),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    head_dim=16,
    d_ff=0,
    vocab=256,
    layer_pattern=(LayerKind.MAMBA,),
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16),
    param_dtype="float32",
    compute_dtype="float32",
)
