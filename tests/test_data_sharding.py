"""Data-pipeline determinism (hypothesis) + sharding-rule properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

import repro.configs as configs
from repro.configs.base import SHAPES, ShapeConfig
from repro.data import pipeline
from repro.distributed import sharding
from repro.train import steps as steps_mod

SMOKE = ShapeConfig("smoke", 16, 4, "train")


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 10_000), seed=st.integers(0, 100))
def test_batch_is_pure_function_of_seed_and_step(step, seed):
    cfg = configs.get_smoke_config("qwen3-1.7b")
    dc = pipeline.DataConfig(seed=seed)
    b1 = pipeline.global_batch(cfg, SMOKE, dc, step)
    b2 = pipeline.global_batch(cfg, SMOKE, dc, step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_different_steps_differ():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    dc = pipeline.DataConfig(seed=0)
    b1 = pipeline.global_batch(cfg, SMOKE, dc, 0)
    b2 = pipeline.global_batch(cfg, SMOKE, dc, 1)
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_tokens_in_vocab_and_labels_shifted():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    b = pipeline.global_batch(cfg, SMOKE, pipeline.DataConfig(), 3)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < cfg.vocab
    # labels are next-token-shifted views of one stream
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@settings(max_examples=10, deadline=None)
@given(nproc=st.sampled_from([1, 2, 4]))
def test_host_slices_tile_the_global_batch(nproc):
    cfg = configs.get_smoke_config("qwen3-1.7b")
    dc = pipeline.DataConfig(seed=1)
    full = pipeline.global_batch(cfg, SMOKE, dc, 5)
    parts = []
    for p in range(nproc):
        sl = pipeline.host_slice_for(p, nproc, SMOKE.global_batch)
        parts.append(pipeline.global_batch(cfg, SMOKE, dc, 5, host_slice=sl)["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts, 0), full["tokens"])


def test_modality_stubs_match_input_specs():
    for arch in ("whisper-large-v3", "internvl2-76b"):
        cfg = configs.get_smoke_config(arch)
        b = pipeline.global_batch(cfg, SMOKE, pipeline.DataConfig(), 0)
        specs = configs.input_specs(cfg, SMOKE)
        assert set(b) == set(specs), arch
        for k in b:
            assert tuple(b[k].shape) == tuple(specs[k].shape), (arch, k)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_rules_roles(mesh11):
    mesh = mesh11
    assert sharding.spec_for_path("blocks/slot0/attn/wq/w", (64, 128), mesh) == P(None, "model")
    assert sharding.spec_for_path("blocks/slot0/attn/wo/w", (128, 64), mesh) == P("model", None)
    assert sharding.spec_for_path("embed/embedding", (512, 64), mesh) == P("model", None)
    assert sharding.spec_for_path("blocks/slot0/moe/wi_gate", (8, 64, 32), mesh) == P("model", None, None)
    assert sharding.spec_for_path("blocks/slot0/ffn/wi_gate", (64, 128), mesh) == P(None, "model")
    assert sharding.spec_for_path("blocks/slot0/ffn/wo", (128, 64), mesh) == P("model", None)
    assert sharding.spec_for_path("blocks/slot0/norm1/scale", (64,), mesh) == P(None)
    # stacked leading (scan) axis is never sharded
    assert sharding.spec_for_path("blocks/slot0/attn/wq/w", (9, 64, 128), mesh) == P(None, None, "model")


def test_divisibility_fallback():
    """A dim not divisible by the axis size falls back, never errors.
    Uses an AbstractMesh so a 16-way axis exists without 16 devices."""
    from jax.sharding import AbstractMesh

    amesh = AbstractMesh((16, 16), ("data", "model"))
    spec = sharding.spec_for_path("blocks/slot0/attn/wq/w", (64, 100), amesh)
    assert spec == P(None, None)  # 100 % 16 != 0 -> replicate
    spec2 = sharding.spec_for_path("blocks/slot0/attn/wq/w", (64, 128), amesh)
    assert spec2 == P(None, "model")


@settings(max_examples=25, deadline=None)
@given(
    d0=st.integers(1, 300),
    d1=st.integers(1, 300),
    path=st.sampled_from([
        "attn/wq/w", "attn/wo/w", "embed/embedding", "ffn/wi_gate",
        "moe/router", "norm1/scale", "mystery/leaf",
    ]),
)
def test_specs_always_divisible(d0, d1, path):
    """Property: whatever the shape, the chosen spec's axes divide the dims."""
    from jax.sharding import AbstractMesh

    amesh = AbstractMesh((4, 8), ("data", "model"))
    spec = sharding.spec_for_path(path, (d0, d1), amesh)
    for dim, axes in zip((d0, d1), spec):
        if axes is None:
            continue
        names = axes if isinstance(axes, tuple) else (axes,)
        size = int(np.prod([dict(zip(amesh.axis_names, amesh.axis_sizes))[n] for n in names]))
        assert dim % size == 0


def test_zero_extends_first_free_dim():
    from jax.sharding import AbstractMesh

    amesh = AbstractMesh((4, 8), ("data", "model"))
    # param spec shards dim1 over model; ZeRO should add data on dim0
    z = sharding.zero_shard_spec(P(None, "model"), (16, 64), amesh)
    assert z == P("data", "model")
    # dim0 not divisible -> tries dim1 (taken) -> stays
    z2 = sharding.zero_shard_spec(P(None, "model"), (15, 64), amesh)
    assert z2 == P(None, "model")


def test_batch_spec_falls_back_to_seq(mesh11):
    from jax.sharding import AbstractMesh

    amesh = AbstractMesh((2, 16, 16), ("pod", "data", "model"))
    # batch 1 (long_500k): dim0 can't shard over 32 data ways
    spec = sharding.batch_spec(amesh, 1, 3, seq_axis=1, seq_len=524288)
    assert spec[0] is None and spec[1] == ("pod", "data")


def test_input_shardings_cover_all_cells():
    """Every (arch x shape) cell gets a full sharding pytree with no error."""
    from jax.sharding import AbstractMesh

    amesh = AbstractMesh((16, 16), ("data", "model"))
    for arch in configs.ASSIGNED_ARCHS:
        cfg = configs.get_config(arch)
        for s in SHAPES.values():
            if not configs.shape_applicable(cfg, s):
                continue
            specs = configs.input_specs(cfg, s)
            sh = sharding.input_shardings(specs, amesh, batch=s.global_batch)
            assert jax.tree.structure(sh) == jax.tree.structure(specs)
