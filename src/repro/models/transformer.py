"""Decoder-only LM core covering dense / MoE / SSM / hybrid architectures.

Depth is expressed as ``n_superblocks`` repetitions of a *superblock* (the
smallest repeating layer pattern, e.g. Jamba's [m m m m a m m m]); parameters
of all superblocks are stacked on a leading axis and the forward pass is a
``lax.scan`` over that axis, so the lowered HLO is O(1) in depth — essential
for 72–80-layer models compiled against 512-device meshes.

Paths:
* ``forward``      — teacher-forced logits for training (optionally remat'd)
* ``prefill``      — forward + KV/SSM cache construction, last-token logits
* ``decode_step``  — one-token serve step over fixed-size caches
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerKind, ModelConfig
from repro.models import attention, layers, mla, moe, ssm


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _slot_is_moe(cfg: ModelConfig, slot: int) -> bool:
    offset = 1 if (cfg.moe is not None and cfg.moe.first_dense) else 0
    return cfg._is_moe_layer(offset + slot)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _init_slot(key, cfg: ModelConfig, kind: LayerKind, is_moe: bool, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    norm_init, _ = layers.make_norm(cfg)
    p: Dict[str, Any] = {"norm1": norm_init(dtype), "norm2": norm_init(dtype)}
    if kind == LayerKind.ATTN:
        if cfg.mla is not None:
            p["mla"] = mla.init_mla(k1, cfg, dtype)
        else:
            p["attn"] = attention.init_attention(k1, cfg, dtype)
    else:
        p["mamba"] = ssm.init_mamba(k1, cfg, dtype)
    if is_moe:
        p["moe"] = moe.init_moe(k2, cfg, dtype)
    elif cfg.d_ff > 0:
        p["ffn"] = layers.swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype)
    else:
        del p["norm2"]  # pure-Mamba block: norm -> mixer -> residual only
    return p


def _init_superblock(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, len(cfg.superblock))
    return {
        f"slot{i}": _init_slot(ks[i], cfg, kind, _slot_is_moe(cfg, i), dtype)
        for i, kind in enumerate(cfg.superblock)
    }


def init_lm(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    k_emb, k_blocks, k_first, k_head = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": layers.embed_init(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
    }
    norm_init, _ = layers.make_norm(cfg)
    params["final_norm"] = norm_init(dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(k_head, cfg.d_model, cfg.vocab_padded, dtype)
    if cfg.moe is not None and cfg.moe.first_dense:
        params["first_block"] = _init_slot(
            k_first, cfg, LayerKind.ATTN, is_moe=False, dtype=dtype
        )
    nsb = cfg.n_superblocks
    keys = jax.random.split(k_blocks, nsb)
    params["blocks"] = jax.vmap(lambda k: _init_superblock(k, cfg, dtype))(keys)
    return params


# --------------------------------------------------------------------------
# forward blocks
# --------------------------------------------------------------------------


def _apply_slot_full(p, cfg, kind, is_moe, x, positions, collect_cache: bool):
    from repro.distributed import context as mesh_ctx

    plan = mesh_ctx.current()
    _, norm_fn = layers.make_norm(cfg)
    h = norm_fn(p["norm1"], x)
    cache = None
    if kind == LayerKind.ATTN:
        if cfg.mla is not None:
            if collect_cache:
                att, cache = mla.mla_full_with_cache(p["mla"], cfg, h, positions)
            else:
                att = mla.mla_full(p["mla"], cfg, h, positions)
        else:
            if collect_cache:
                att, cache = attention.attention_full_with_cache(
                    p["attn"], cfg, h, positions
                )
            else:
                att = attention.attention_full(p["attn"], cfg, h, positions)
    else:
        if collect_cache:
            att, state = ssm.mamba_full(p["mamba"], cfg, h, return_state=True)
            # conv state = last d_conv-1 pre-conv xBC rows; recompute cheaply
            cache = {"ssm_state": state, "conv_state": _conv_tail(p["mamba"], cfg, h)}
        else:
            att = ssm.mamba_full(p["mamba"], cfg, h)
    # sequence-parallel residual: GSPMD turns the output-projection
    # all-reduce into reduce-scatter (+ all-gather on the next block entry)
    x = mesh_ctx.shard_seq(x + att, plan)
    if is_moe:
        f, aux = moe.moe_ffn(p["moe"], cfg, norm_fn(p["norm2"], x))
    elif "ffn" in p:
        f, aux = layers.swiglu(p["ffn"], norm_fn(p["norm2"], x)), jnp.zeros((), jnp.float32)
    else:
        return x, jnp.zeros((), jnp.float32), cache
    return mesh_ctx.shard_seq(x + f, plan), aux, cache


def _conv_tail(p_mamba, cfg, h):
    """Pre-activation conv window tail for decode handoff: (B, d_conv-1, ch)."""
    _, xBC, _ = ssm._project_in(p_mamba, cfg, h[:, -(cfg.ssm.d_conv - 1) :, :])
    return xBC


def _block_full(cfg, collect_cache):
    def fn(p_blk, x, positions):
        aux_total = jnp.zeros((), jnp.float32)
        caches = {}
        for i, kind in enumerate(cfg.superblock):
            x, aux, cache = _apply_slot_full(
                p_blk[f"slot{i}"], cfg, kind, _slot_is_moe(cfg, i), x, positions,
                collect_cache,
            )
            aux_total = aux_total + aux
            if collect_cache:
                caches[f"slot{i}"] = cache
        return x, aux_total, caches

    return fn


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(f"unknown remat policy {policy!r}")


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------


def forward(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    img_embeds: Optional[jax.Array] = None,
    remat: str = "none",
) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced forward.  tokens: (B, S_text).  Returns (logits fp32
    (B,S,V), moe_aux).  With ``img_embeds`` (B, S_img, d) the sequence is
    [img, text] (InternVL-style stub frontend)."""
    x = layers.embed(params["embed"], tokens).astype(jnp.dtype(cfg.compute_dtype))
    if img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)

    aux_total = jnp.zeros((), jnp.float32)
    if "first_block" in params:
        x, aux, _ = _apply_slot_full(
            params["first_block"], cfg, LayerKind.ATTN, False, x, positions, False
        )
        aux_total = aux_total + aux

    block = _block_full(cfg, collect_cache=False)

    def scan_body(x, p_blk):
        y, aux, _ = block(p_blk, x, positions)
        return y, aux

    scan_fn = _remat(scan_body, remat)
    x, auxs = jax.lax.scan(scan_fn, x, params["blocks"])
    aux_total = aux_total + auxs.sum()

    _, norm_fn = layers.make_norm(cfg)
    x = norm_fn(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = layers.dense(params["lm_head"], x).astype(jnp.float32)
    return logits, aux_total


def lm_loss(
    logits: jax.Array,
    labels: jax.Array,
    *,
    mask: Optional[jax.Array] = None,
    real_vocab: Optional[int] = None,
) -> jax.Array:
    """Token-mean cross entropy.  labels: (B, S) int32; -1 = ignore.
    ``real_vocab`` masks the sharding-padded tail of the vocab dim."""
    V = logits.shape[-1]
    if real_vocab is not None and real_vocab < V:
        pad_mask = jnp.arange(V) < real_vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    if mask is None:
        mask = labels >= 0
    labels_safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction, not take_along_axis: a vocab-dim gather would
    # force GSPMD to all-gather the vocab-sharded fp32 logits.  bf16 one-hot
    # (exact for 0/1) halves the temp; accumulate fp32.
    onehot = jax.nn.one_hot(labels_safe, V, dtype=jnp.bfloat16)
    gold = jnp.einsum(
        "bsv,bsv->bs", logits.astype(jnp.bfloat16), onehot,
        preferred_element_type=jnp.float32,
    )
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Fixed-size cache pytree matching the superblock structure."""
    dtype = jnp.dtype(cfg.compute_dtype)
    nsb = cfg.n_superblocks
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32), "blocks": {}}
    for i, kind in enumerate(cfg.superblock):
        if kind == LayerKind.ATTN:
            if cfg.mla is not None:
                c = mla.init_mla_cache(cfg, batch, max_len, dtype, nsb)
            else:
                kv, hd = cfg.n_kv_heads, cfg.head_dim
                c = {
                    "k": jnp.zeros((nsb, batch, max_len, kv, hd), dtype),
                    "v": jnp.zeros((nsb, batch, max_len, kv, hd), dtype),
                }
        else:
            c = {
                "ssm_state": jnp.zeros(
                    (nsb, batch, cfg.ssm.n_heads(cfg.d_model), cfg.ssm.d_state,
                     cfg.ssm.head_dim), jnp.float32,
                ),
                "conv_state": jnp.zeros(
                    (nsb, batch, cfg.ssm.d_conv - 1,
                     cfg.ssm.d_inner(cfg.d_model)
                     + 2 * cfg.ssm.n_groups * cfg.ssm.d_state), dtype,
                ),
            }
        cache["blocks"][f"slot{i}"] = c
    if cfg.moe is not None and cfg.moe.first_dense:
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        if cfg.mla is not None:
            cache["first_block"] = jax.tree.map(
                lambda a: a[0], mla.init_mla_cache(cfg, batch, max_len, dtype, 1)
            )
        else:
            cache["first_block"] = {
                "k": jnp.zeros((batch, max_len, kv, hd), dtype),
                "v": jnp.zeros((batch, max_len, kv, hd), dtype),
            }
    return cache


def _apply_slot_decode(p, cfg, kind, is_moe, x, cache, pos):
    """Returns (x, delta): delta holds NEW-TOKEN slices for attention caches
    (committed by the caller in one top-level update) and full replacement
    states for SSM slots."""
    _, norm_fn = layers.make_norm(cfg)
    h = norm_fn(p["norm1"], x)
    if kind == LayerKind.ATTN:
        if cfg.mla is not None:
            att, c_new, kr_new = mla.mla_decode(
                p["mla"], cfg, h, cache["c"], cache["k_rope"], pos
            )
            delta = {"c": c_new, "k_rope": kr_new}
        else:
            att, k_new, v_new = attention.attention_decode(
                p["attn"], cfg, h, cache["k"], cache["v"], pos
            )
            delta = {"k": k_new, "v": v_new}
    else:
        att, s_new, conv_new = ssm.mamba_decode(
            p["mamba"], cfg, h, cache["ssm_state"], cache["conv_state"]
        )
        delta = {"ssm_state": s_new, "conv_state": conv_new}
    x = x + att
    if is_moe:
        f, _ = moe.moe_ffn(p["moe"], cfg, norm_fn(p["norm2"], x))
    elif "ffn" in p:
        f = layers.swiglu(p["ffn"], norm_fn(p["norm2"], x))
    else:
        return x, delta
    return x + f, delta


_SEQ_CACHE_KEYS = ("k", "v", "c", "k_rope")  # (.., S, ...) caches, seq axis


def _commit(cache_leaf, delta_leaf, pos, key: str, stacked: bool):
    """Write a new-token slice (or replacement state) into the cache."""
    if key in _SEQ_CACHE_KEYS:
        start = (0, 0, pos) + (0,) * (cache_leaf.ndim - 3) if stacked else (
            (0, pos) + (0,) * (cache_leaf.ndim - 2)
        )
        return jax.lax.dynamic_update_slice(
            cache_leaf, delta_leaf.astype(cache_leaf.dtype), start
        )
    return delta_leaf.astype(cache_leaf.dtype)  # SSM states: full replace


def decode_step(
    params, cfg: ModelConfig, tokens: jax.Array, cache: Dict[str, Any]
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One serve step: tokens (B,1) + cache -> (logits (B,1,V) fp32, cache)."""
    pos = cache["pos"]
    x = layers.embed(params["embed"], tokens).astype(jnp.dtype(cfg.compute_dtype))

    new_cache: Dict[str, Any] = {"pos": pos + 1, "blocks": None}
    if "first_block" in params:
        x, fb_delta = _apply_slot_decode(
            params["first_block"], cfg, LayerKind.ATTN, False, x,
            cache["first_block"], pos,
        )
        new_cache["first_block"] = {
            k: _commit(cache["first_block"][k], d, pos, k, stacked=False)
            for k, d in fb_delta.items()
        }

    def scan_body(x, inp):
        p_blk, c_blk = inp
        deltas = {}
        for i, kind in enumerate(cfg.superblock):
            x, delta = _apply_slot_decode(
                p_blk[f"slot{i}"], cfg, kind, _slot_is_moe(cfg, i), x,
                c_blk[f"slot{i}"], pos,
            )
            deltas[f"slot{i}"] = delta
        return x, deltas

    x, deltas = jax.lax.scan(scan_body, x, (params["blocks"], cache["blocks"]))
    # single top-level commit: deltas are stacked (nsb, B, 1, ...) slices
    new_cache["blocks"] = {
        slot: {
            k: _commit(cache["blocks"][slot][k], d, pos, k, stacked=True)
            for k, d in slot_deltas.items()
        }
        for slot, slot_deltas in deltas.items()
    }

    _, norm_fn = layers.make_norm(cfg)
    x = norm_fn(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = layers.dense(params["lm_head"], x).astype(jnp.float32)
    return logits, new_cache


# --------------------------------------------------------------------------
# serving: paged cache (slot-level continuous batching)
# --------------------------------------------------------------------------

#: Physical block 0 is reserved as the NULL block: block tables of idle
#: serving slots point at it, so their (masked-out) scatter writes land in
#: garbage space and can never corrupt a live request's cache.
NULL_BLOCK = 0

#: KV pool storage dtypes along the paper's ELEN axis: "f32" keeps the
#: pool in the model's compute dtype (the unquantized baseline), "bf16"
#: halves it, "int8" quarters it with one fp32 scale per (token row,
#: cache key) — more elements per vector lane at lower precision, the
#: same trade the paper's ELEN sweep measures.
KV_DTYPES = ("f32", "bf16", "int8")


def _pool_dtype(cfg: ModelConfig, kv_dtype: str):
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                         f"got {kv_dtype!r}")
    if kv_dtype == "bf16":
        return jnp.bfloat16
    if kv_dtype == "int8":
        return jnp.int8
    return jnp.dtype(cfg.compute_dtype)


def init_paged_cache(
    cfg: ModelConfig, slots: int, max_len: int, block_size: int,
    kv_dtype: str = "f32", *, mesh=None,
) -> Dict[str, Any]:
    """Paged cache pytree: attention caches become pooled blocks.

    Sequence caches are laid out as a physical pool ``(nsb, n_blocks,
    block_size, ...)`` addressed through an engine-owned block table
    ``(slots, max_len // block_size)`` mapping each slot's logical block to
    a pool block.  The pool holds ``1 + slots * max_len/block_size``
    blocks — enough for every slot at full length plus the reserved
    :data:`NULL_BLOCK` — so admission never fails and freed blocks are
    recycled across requests.  SSM / conv states are O(1) per slot and stay
    densely indexed by slot (there is nothing to page).

    ``kv_dtype`` selects the pool's storage precision (:data:`KV_DTYPES`);
    ``"int8"`` adds an fp32 ``<key>_scale`` pool of shape ``(nsb,
    n_blocks, block_size)`` — one symmetric scale per committed token row,
    so dequantization is exact per row and stale rows can never poison a
    live one through a shared scale.
    """
    if max_len % block_size:
        raise ValueError(f"max_len {max_len} not a multiple of block_size "
                         f"{block_size}")
    dtype = jnp.dtype(cfg.compute_dtype)  # SSM/conv states: never quantized
    pool_dtype = _pool_dtype(cfg, kv_dtype)
    nsb = cfg.n_superblocks
    n_blocks = 1 + slots * (max_len // block_size)
    cache: Dict[str, Any] = {"blocks": {}}

    def _attn_pool(stacked: int):
        if cfg.mla is not None:
            ml = cfg.mla
            c = {
                "c": jnp.zeros(
                    (stacked, n_blocks, block_size, ml.kv_lora_rank),
                    pool_dtype),
                "k_rope": jnp.zeros(
                    (stacked, n_blocks, block_size, ml.qk_rope_dim),
                    pool_dtype),
            }
        else:
            kv, hd = cfg.n_kv_heads, cfg.head_dim
            c = {
                "k": jnp.zeros((stacked, n_blocks, block_size, kv, hd),
                               pool_dtype),
                "v": jnp.zeros((stacked, n_blocks, block_size, kv, hd),
                               pool_dtype),
            }
        if kv_dtype == "int8":
            for k in list(c):
                c[k + "_scale"] = jnp.zeros(
                    (stacked, n_blocks, block_size), jnp.float32
                )
        return c

    # mesh != None: place every pool by the serve sharding rules (k/v head
    # axis split over `model`, SSM heads/conv channels likewise, scale and
    # MLA latent pools replicated) — a pure-placement device_put, so the
    # sharded cache is byte-identical to the replicated one
    for i, kind in enumerate(cfg.superblock):
        if kind == LayerKind.ATTN:
            c = _attn_pool(nsb)
        else:
            c = {
                "ssm_state": jnp.zeros(
                    (nsb, slots, cfg.ssm.n_heads(cfg.d_model), cfg.ssm.d_state,
                     cfg.ssm.head_dim), jnp.float32,
                ),
                "conv_state": jnp.zeros(
                    (nsb, slots, cfg.ssm.d_conv - 1,
                     cfg.ssm.d_inner(cfg.d_model)
                     + 2 * cfg.ssm.n_groups * cfg.ssm.d_state), dtype,
                ),
            }
        cache["blocks"][f"slot{i}"] = c
    if cfg.moe is not None and cfg.moe.first_dense:
        cache["first_block"] = jax.tree.map(lambda a: a[0], _attn_pool(1))
    if mesh is not None:
        from repro.distributed import sharding as shard_rules
        cache = jax.device_put(
            cache, shard_rules.paged_cache_shardings(cache, mesh)
        )
    return cache


def _gather_paged(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Logical per-slot view of a block pool.

    pool: (n_blocks, block_size, ...); block_tables: (B, nb) ->
    (B, nb * block_size, ...).  Garbage rows (NULL_BLOCK, recycled blocks)
    are fine: the attention mask hides everything >= the slot's position.
    """
    B, nb = block_tables.shape
    g = pool[block_tables]  # (B, nb, block_size, ...)
    return g.reshape((B, nb * pool.shape[1]) + pool.shape[2:])


def _commit_paged(pool, delta, flat_idx, key: str, stacked: bool):
    """Per-slot scatter write of one new-token slice into the block pool.

    ``flat_idx`` (B,) indexes the flattened (n_blocks * block_size) token
    axis; idle slots all alias NULL_BLOCK offsets, where duplicate writes
    are harmless by construction.
    """
    if key not in _SEQ_CACHE_KEYS:
        return delta.astype(pool.dtype)  # SSM states: full replace
    if stacked:
        nsb, n_blocks, bs = pool.shape[:3]
        flat = pool.reshape((nsb, n_blocks * bs) + pool.shape[3:])
        vals = delta.astype(pool.dtype)[:, :, 0]  # (nsb, B, ...)
        return flat.at[:, flat_idx].set(vals).reshape(pool.shape)
    n_blocks, bs = pool.shape[:2]
    flat = pool.reshape((n_blocks * bs,) + pool.shape[2:])
    vals = delta.astype(pool.dtype)[:, 0]  # (B, ...)
    return flat.at[flat_idx].set(vals).reshape(pool.shape)


def reset_paged_slots(cache: Dict[str, Any], mask: jax.Array) -> Dict[str, Any]:
    """Zero the SSM/conv state of every slot where ``mask`` (B,) is True.

    Called when a finished slot is refilled with a new request: attention
    blocks need no scrub (the per-slot mask hides stale tokens) but
    recurrent state is accumulated, so a fresh request must start from
    zeros.
    """
    def _scrub(slot_cache):
        out = {}
        for k, leaf in slot_cache.items():
            if k in ("ssm_state", "conv_state"):
                m = mask.reshape((1, mask.shape[0]) + (1,) * (leaf.ndim - 2))
                out[k] = jnp.where(m, jnp.zeros((), leaf.dtype), leaf)
            else:
                out[k] = leaf
        return out

    new = dict(cache)
    new["blocks"] = {s: _scrub(c) for s, c in cache["blocks"].items()}
    return new


#: per-slot recurrent-state leaves of a paged cache (everything that is
#: NOT paged: attention rows rewind by masking, these rewind by restore)
_STATE_KEYS = ("ssm_state", "conv_state")


def slot_state(cache: Dict[str, Any]) -> Dict[str, Any]:
    """Reference snapshot of every per-slot recurrent-state leaf.

    jax arrays are immutable, so holding the leaves IS the snapshot —
    no copy, no device work.  Speculative verification snapshots before
    committing k+1 tokens: attention rows past a rejection point are
    hidden by the position mask, but SSM/conv state is *accumulated* by
    every scanned token, so a rejected suffix must be undone with
    :func:`restore_slot_state` + a replay of the accepted prefix.
    Empty per-slot dicts for attention-only architectures.
    """
    return {
        s: {k: leaf for k, leaf in c.items() if k in _STATE_KEYS}
        for s, c in cache["blocks"].items()
    }


def restore_slot_state(
    cache: Dict[str, Any], state: Dict[str, Any], mask: jax.Array
) -> Dict[str, Any]:
    """Restore recurrent state from a :func:`slot_state` snapshot for every
    slot where ``mask`` (B,) is True; other slots keep their current state
    bitwise (``where`` with a False lane is identity)."""
    def _blend(slot_cache, snap):
        out = dict(slot_cache)
        for k, leaf in snap.items():
            m = mask.reshape((1, mask.shape[0]) + (1,) * (leaf.ndim - 2))
            out[k] = jnp.where(m, leaf, slot_cache[k])
        return out

    new = dict(cache)
    new["blocks"] = {
        s: _blend(c, state.get(s, {})) for s, c in cache["blocks"].items()
    }
    return new


def copy_paged_block(
    cache: Dict[str, Any], src: jax.Array, dst: jax.Array
) -> Dict[str, Any]:
    """Copy physical pool block ``src`` into ``dst`` on every paged leaf.

    The device half of copy-on-write: when a slot is about to write a
    generated token into a block other slots still reference, the engine
    allocates ``dst``, copies ``src``'s bytes (scale pools included — a
    quantized row travels with its scale), and repoints its block table.
    ``src``/``dst`` may be traced scalars, so one jit trace serves every
    copy.  SSM/conv states are per-slot, not paged; they pass through.
    """
    def _copy(slot_cache, stacked: bool):
        out = {}
        for k, leaf in slot_cache.items():
            if k in _SEQ_CACHE_KEYS or k.endswith("_scale"):
                if stacked:
                    out[k] = leaf.at[:, dst].set(leaf[:, src])
                else:
                    out[k] = leaf.at[dst].set(leaf[src])
            else:
                out[k] = leaf
        return out

    new = dict(cache)
    new["blocks"] = {s: _copy(c, True) for s, c in cache["blocks"].items()}
    if "first_block" in cache:
        new["first_block"] = _copy(cache["first_block"], False)
    return new


def paged_block_bytes(
    cfg: ModelConfig, block_size: int, kv_dtype: str = "f32"
) -> int:
    """Bytes one physical block stores across every attention layer.

    Host-side arithmetic (no device pool needed) for the block-dedup
    ratio: logical blocks served x this = bytes served, physical blocks
    allocated x this = bytes stored.  int8 counts its fp32 per-row scales
    — the quantized pool's true footprint.
    """
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                         f"got {kv_dtype!r}")
    itemsize = {
        "f32": jnp.dtype(cfg.compute_dtype).itemsize, "bf16": 2, "int8": 1,
    }[kv_dtype]
    if cfg.mla is not None:
        row_elems = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
    else:
        row_elems = 2 * cfg.n_kv_heads * cfg.head_dim
    n_attn = cfg.n_superblocks * sum(
        1 for k in cfg.superblock if k == LayerKind.ATTN
    )
    if cfg.moe is not None and cfg.moe.first_dense:
        n_attn += 1  # the unstacked first dense block pages its cache too
    per_layer = block_size * row_elems * itemsize
    if kv_dtype == "int8":
        per_layer += 2 * block_size * 4  # one fp32 scale per row per key
    return n_attn * per_layer


def _commit_paged_masked(pool, delta, flat_idx, key: str, stacked: bool,
                         active: jax.Array):
    """Commit one token's delta, predicated per slot on ``active`` (B,).

    Sequence pools need no extra masking — the caller already routes
    inactive slots' ``flat_idx`` into NULL_BLOCK — but SSM/conv states are
    full replacements, so inactive slots keep their previous state
    bitwise.  ``where`` with an all-true mask is a bitwise identity, which
    is what keeps the decode path's numerics untouched by this refactor.
    """
    if key in _SEQ_CACHE_KEYS:
        return _commit_paged(pool, delta, flat_idx, key, stacked)
    new = delta.astype(pool.dtype)
    lead = (1,) if stacked else ()
    m = active.reshape(lead + (active.shape[0],) + (1,) * (pool.ndim - len(lead) - 1))
    return jnp.where(m, new, pool)


def _quantize_token(delta, stacked: bool):
    """Symmetric per-row int8 quantization of one token's cache slice.

    delta: ``(nsb, B, 1, ...)`` (stacked) or ``(B, 1, ...)`` float ->
    ``(q int8 same shape, scale fp32 (nsb, B, 1) / (B, 1))``.  One scale
    per committed row keeps dequantization exact per token: a recycled or
    null-block row's garbage scale can never touch a live row.
    """
    lead = 3 if stacked else 2
    axes = tuple(range(lead, delta.ndim))
    amax = jnp.max(jnp.abs(delta.astype(jnp.float32)), axis=axes)
    s = jnp.maximum(amax / 127.0, 1e-8)
    sb = s.reshape(s.shape + (1,) * (delta.ndim - s.ndim))
    q = jnp.clip(jnp.round(delta.astype(jnp.float32) / sb), -127, 127)
    return q.astype(jnp.int8), s


def _commit_slot(c_slot, slot_deltas, flat_idx, stacked: bool,
                 active: jax.Array, kv_dtype: str):
    """Commit one layer-slot's deltas, carrying non-delta leaves through.

    Scale pools have no delta of their own — they are derived from their
    data leaf's delta at commit time — so this iterates the CACHE's keys,
    not the delta's: a quantized pool's ``<key>_scale`` leaf is written
    alongside ``<key>`` and every other leaf passes through untouched.
    """
    out = {}
    for k, leaf in c_slot.items():
        if k.endswith("_scale"):
            continue  # written alongside its data leaf below
        d = slot_deltas.get(k)
        if d is None:
            out[k] = leaf
            if k + "_scale" in c_slot:
                out[k + "_scale"] = c_slot[k + "_scale"]
        elif k in _SEQ_CACHE_KEYS and kv_dtype == "int8":
            q, s = _quantize_token(d, stacked)
            out[k] = _commit_paged(leaf, q, flat_idx, k, stacked)
            out[k + "_scale"] = _commit_paged(
                c_slot[k + "_scale"], s, flat_idx, k, stacked
            )
        else:
            out[k] = _commit_paged_masked(leaf, d, flat_idx, k, stacked,
                                          active)
    return out


def _paged_token_step(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,
    cache: Dict[str, Any],
    positions: jax.Array,
    block_tables: jax.Array,
    active: jax.Array,
    *,
    block_size: int,
    kv_dtype: str = "f32",
) -> Tuple[jax.Array, Dict[str, Any]]:
    """The shared one-token cell of the paged serve path.

    Every per-slot op here (embed row, norms, per-slot attention over the
    gathered view, per-token MoE routing, SSM recurrence) is independent
    across batch rows, so a token's numerics depend only on its own slot's
    inputs — the invariant that makes chunked prefill bit-exact against
    token-by-token decode.  ``active`` (B,) predicates commits: inactive
    slots scatter their sequence writes into NULL_BLOCK and keep their
    recurrent state, exactly like idle slots always have.

    With ``kv_dtype != "f32"`` the sequence pools are stored quantized:
    gathers dequantize back to the compute dtype (int8 multiplies by the
    per-row fp32 scale) and commits quantize the new token's row — the
    attention math itself always runs at compute precision.
    """
    pos_b = positions.astype(jnp.int32)
    nb = block_tables.shape[1]
    blk = jnp.take_along_axis(
        block_tables, jnp.minimum(pos_b // block_size, nb - 1)[:, None], axis=1
    )[:, 0]
    flat_idx = jnp.where(
        active, blk * block_size + pos_b % block_size,
        NULL_BLOCK * block_size,
    )  # (B,) pool token index
    compute = jnp.dtype(cfg.compute_dtype)
    x = layers.embed(params["embed"], tokens).astype(compute)

    def _view(c_slot):
        """Gather logical per-slot views of this layer's sequence pools,
        dequantizing quantized storage back to compute precision."""
        out = {}
        for k, leaf in c_slot.items():
            if k.endswith("_scale"):
                continue  # consumed by its data leaf's dequant below
            if k not in _SEQ_CACHE_KEYS:
                out[k] = leaf
                continue
            g = _gather_paged(leaf, block_tables)
            if kv_dtype == "int8":
                s = _gather_paged(c_slot[k + "_scale"], block_tables)
                g = g.astype(compute) * s.reshape(
                    s.shape + (1,) * (g.ndim - s.ndim)
                ).astype(compute)
            elif kv_dtype == "bf16":
                g = g.astype(compute)
            out[k] = g
        return out

    new_cache: Dict[str, Any] = {"blocks": None}
    if "first_block" in params:
        x, fb_delta = _apply_slot_decode(
            params["first_block"], cfg, LayerKind.ATTN, False, x,
            _view(cache["first_block"]), pos_b,
        )
        new_cache["first_block"] = _commit_slot(
            cache["first_block"], fb_delta, flat_idx, False, active, kv_dtype
        )

    def scan_body(x, inp):
        p_blk, c_blk = inp
        deltas = {}
        for i, kind in enumerate(cfg.superblock):
            x, delta = _apply_slot_decode(
                p_blk[f"slot{i}"], cfg, kind, _slot_is_moe(cfg, i), x,
                _view(c_blk[f"slot{i}"]), pos_b,
            )
            deltas[f"slot{i}"] = delta
        return x, deltas

    x, deltas = jax.lax.scan(scan_body, x, (params["blocks"], cache["blocks"]))
    new_cache["blocks"] = {
        slot: _commit_slot(cache["blocks"][slot], slot_deltas, flat_idx,
                           True, active, kv_dtype)
        for slot, slot_deltas in deltas.items()
    }

    _, norm_fn = layers.make_norm(cfg)
    x = norm_fn(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = layers.dense(params["lm_head"], x).astype(jnp.float32)
    return logits, new_cache


def decode_step_paged(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,
    cache: Dict[str, Any],
    positions: jax.Array,
    block_tables: jax.Array,
    *,
    block_size: int,
    kv_dtype: str = "f32",
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One continuous-batching serve step over the paged cache.

    tokens: (B, 1); positions: (B,) per-slot cache lengths; block_tables:
    (B, nb) logical->physical block map.  Each slot attends over its own
    live prefix (mask ``< positions[slot]``) and the new token commits as a
    per-slot scatter at ``positions[slot]`` — predication-style slot
    accounting: finished/idle slots write into NULL_BLOCK and are masked
    out rather than synchronized on.  Scheduling state (positions, tables,
    allocator) lives with the caller; the cache holds only device pools.
    """
    active = jnp.ones((tokens.shape[0],), jnp.bool_)
    return _paged_token_step(
        params, cfg, tokens, cache, positions, block_tables, active,
        block_size=block_size, kv_dtype=kv_dtype,
    )


def prefill_step_paged(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,
    cache: Dict[str, Any],
    positions: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    block_size: int,
    kv_dtype: str = "f32",
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Commit a chunk of C prompt tokens per slot in ONE fused call.

    tokens: (B, C) — slot ``b``'s next ``lengths[b]`` known tokens (prompt
    or replayed), zero-padded past its length; positions: (B,) per-slot
    cache lengths before the chunk; lengths: (B,) int32 in [0, C].  The
    chunk is a ``lax.scan`` of the SAME per-token cell the decode path
    runs, with slot ``b`` active for the first ``lengths[b]`` iterations —
    so a P-token prompt costs ceil(P/C) fused calls instead of P while
    producing bit-identical logits, sequence pools, and SSM states (dense
    SSM states advance by in-chunk recurrence, never the parallel chunk
    scan, precisely because SSD's chunked accumulation order differs
    bitwise).  Returns (logits (B, C, vocab_padded) fp32 — iteration ``c``'s
    row for every slot; callers read row ``lengths[b]-1`` — and the updated
    cache).  Slots with ``lengths[b] == 0`` commit nothing and keep their
    state; their logit rows are garbage by contract.
    """
    B, C = tokens.shape
    pos0 = positions.astype(jnp.int32)
    lens = lengths.astype(jnp.int32)

    def body(cache, xs):
        tok_c, c = xs
        logits, cache = _paged_token_step(
            params, cfg, tok_c[:, None], cache, pos0 + c, block_tables,
            c < lens, block_size=block_size, kv_dtype=kv_dtype,
        )
        return cache, logits[:, 0]

    cache, logits = jax.lax.scan(
        body, cache, (tokens.T, jnp.arange(C, dtype=jnp.int32))
    )
    return jnp.transpose(logits, (1, 0, 2)), cache


def prefill(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    img_embeds: Optional[jax.Array] = None,
    remat: str = "none",
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Process the prompt, build caches, return last-token logits + cache."""
    x = layers.embed(params["embed"], tokens).astype(jnp.dtype(cfg.compute_dtype))
    if img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)

    cache: Dict[str, Any] = {"pos": jnp.full((), S, jnp.int32)}
    if "first_block" in params:
        x, _, fb_cache = _apply_slot_full(
            params["first_block"], cfg, LayerKind.ATTN, False, x, positions, True
        )
        cache["first_block"] = fb_cache

    block = _block_full(cfg, collect_cache=True)

    def scan_body(x, p_blk):
        y, _, caches = block(p_blk, x, positions)
        return y, caches

    scan_fn = _remat(scan_body, remat)
    x, block_caches = jax.lax.scan(scan_fn, x, params["blocks"])
    cache["blocks"] = block_caches

    _, norm_fn = layers.make_norm(cfg)
    x_last = norm_fn(params["final_norm"], x[:, -1:, :])
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x_last)
    else:
        logits = layers.dense(params["lm_head"], x_last).astype(jnp.float32)
    return logits, cache
