"""Benchmark driver: one benchmark per paper table/figure.

Usage:
    PYTHONPATH=src python -m benchmarks.run [--only fig3_vectorization]
    PYTHONPATH=src python -m benchmarks.run --out experiments/bench
    PYTHONPATH=src python -m benchmarks.run --list

Writes one CSV per benchmark and prints each table.  ``--list`` enumerates
both the figure/table benchmarks and every workload registered in the
unified ``repro.analysis`` registry.
"""

from __future__ import annotations

import argparse
import csv
import os
import sys
import time


def _write_csv(path: str, rows) -> None:
    if not rows:
        return
    keys = list(rows[0].keys())
    for r in rows[1:]:
        for k in r:
            if k not in keys:
                keys.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(rows)


def _print_table(name: str, rows) -> None:
    print(f"\n== {name} " + "=" * max(0, 66 - len(name)))
    if not rows:
        print("(no rows)")
        return
    keys = list(rows[0].keys())
    widths = {k: max(len(str(k)), *(len(str(r.get(k, ""))) for r in rows)) for k in keys}
    print("  ".join(str(k).ljust(widths[k]) for k in keys))
    for r in rows:
        print("  ".join(str(r.get(k, "")).ljust(widths[k]) for k in keys))


def _list() -> int:
    from benchmarks.figures import ALL
    from repro.analysis import list_workloads

    print("benchmarks (python -m benchmarks.run --only <name>):")
    for name in ALL:
        print(f"  {name}")
    print("\nworkloads (repro.analysis.analyze(<name>)):")
    for name in list_workloads():
        print(f"  {name}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single benchmark")
    ap.add_argument("--list", action="store_true",
                    help="list benchmarks + registered workloads and exit")
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args(argv)

    if args.list:
        return _list()

    from benchmarks.figures import ALL

    if args.only is not None and args.only not in ALL:
        print(f"error: unknown benchmark {args.only!r}; available: "
              f"{', '.join(ALL)}", file=sys.stderr)
        return 2

    os.makedirs(args.out, exist_ok=True)
    todo = {args.only: ALL[args.only]} if args.only else ALL
    failed = []
    for name, fn in todo.items():
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001 — report all benchmark failures
            import traceback

            traceback.print_exc()
            failed.append((name, repr(e)))
            continue
        _write_csv(os.path.join(args.out, f"{name}.csv"), rows)
        _print_table(name, rows)
        print(f"[{name}: {len(rows)} rows in {time.time() - t0:.1f}s]")
    if failed:
        print(f"\nFAILED: {failed}")
        return 1
    print(f"\nall {len(todo)} benchmarks written to {args.out}/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
