"""Jit wrapper for the flash-decode kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_decode.kernel import flash_decode as _fd


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(q, k, v, valid_len, *, block_s: int = 512, interpret: bool = True):
    return _fd(q, k, v, valid_len, block_s=block_s, interpret=interpret)
