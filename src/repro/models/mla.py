"""Multi-head Latent Attention (DeepSeek-V2), kv_lora_rank-compressed cache.

Train/prefill: decompress K/V per position and reuse the generic chunked
flash path (KV=H, G=1).  Decode: *absorbed* form — queries are projected into
the latent space so the cache holds only (kv_lora_rank + qk_rope_dim) per
token (6.4x smaller than GQA here), and attention reads the compressed cache
directly.  This is the paper's ELEN lesson at the KV-cache level: smaller
elements-per-token moves the memory-roofline term down.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers

NEG_INF = -1e30


def init_mla(key, cfg, dtype) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    ml = cfg.mla
    ks = jax.random.split(key, 5)
    qd = ml.qk_nope_dim + ml.qk_rope_dim
    return {
        "wq": layers.dense_init(ks[0], d, h * qd, dtype),
        "w_dkv": layers.dense_init(ks[1], d, ml.kv_lora_rank + ml.qk_rope_dim, dtype),
        "kv_norm": layers.rms_norm_init(ml.kv_lora_rank, dtype),
        "w_uk": layers.dense_init(ks[2], ml.kv_lora_rank, h * ml.qk_nope_dim, dtype),
        "w_uv": layers.dense_init(ks[3], ml.kv_lora_rank, h * ml.v_head_dim, dtype),
        "wo": layers.dense_init(ks[4], h * ml.v_head_dim, d, dtype),
    }


def _q_and_latent(params, cfg, x, positions):
    B, S, _ = x.shape
    h, ml = cfg.n_heads, cfg.mla
    qd = ml.qk_nope_dim + ml.qk_rope_dim
    q = layers.dense(params["wq"], x).reshape(B, S, h, qd)
    q_nope, q_rope = q[..., : ml.qk_nope_dim], q[..., ml.qk_nope_dim :]
    ckv = layers.dense(params["w_dkv"], x)
    c, k_rope = ckv[..., : ml.kv_lora_rank], ckv[..., ml.kv_lora_rank :]
    c = layers.rms_norm(params["kv_norm"], c, cfg.norm_eps)
    cos, sin = layers.rope_cos_sin(positions, ml.qk_rope_dim, cfg.rope_theta)
    q_rope = layers.apply_rope(q_rope, cos, sin)
    k_rope = layers.apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return q_nope, q_rope, c, k_rope


def mla_full(params, cfg, x, positions, *, causal: bool = True) -> jax.Array:
    """Training / prefill: decompress and run chunked flash attention."""
    from repro.models.attention import flash_attention

    B, S, _ = x.shape
    h, ml = cfg.n_heads, cfg.mla
    q_nope, q_rope, c, k_rope = _q_and_latent(params, cfg, x, positions)
    k_nope = layers.dense(params["w_uk"], c).reshape(B, S, h, ml.qk_nope_dim)
    v = layers.dense(params["w_uv"], c).reshape(B, S, h, ml.v_head_dim)
    # pack nope+rope into one contraction dim; rope part shared across heads
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)  # (B,S,h,qd)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, h, ml.qk_rope_dim))],
        axis=-1,
    )
    q5 = q_cat.reshape(B, S, h, 1, q_cat.shape[-1])  # KV=h, G=1
    out = flash_attention(q5, k_cat, v, causal=causal)
    out = out.reshape(B, S, h * ml.v_head_dim)
    return layers.dense(params["wo"], out)


def mla_full_with_cache(params, cfg, x, positions):
    """Prefill variant that also returns the compressed-latent cache."""
    from repro.models.attention import flash_attention

    B, S, _ = x.shape
    h, ml = cfg.n_heads, cfg.mla
    q_nope, q_rope, c, k_rope = _q_and_latent(params, cfg, x, positions)
    k_nope = layers.dense(params["w_uk"], c).reshape(B, S, h, ml.qk_nope_dim)
    v = layers.dense(params["w_uv"], c).reshape(B, S, h, ml.v_head_dim)
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, h, ml.qk_rope_dim))],
        axis=-1,
    )
    out = flash_attention(q_cat.reshape(B, S, h, 1, q_cat.shape[-1]), k_cat, v, causal=True)
    out = out.reshape(B, S, h * ml.v_head_dim)
    return layers.dense(params["wo"], out), {"c": c, "k_rope": k_rope}


def init_mla_cache(cfg, batch: int, max_len: int, dtype, layers_stacked: int = 1):
    ml = cfg.mla
    return {
        "c": jnp.zeros((layers_stacked, batch, max_len, ml.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((layers_stacked, batch, max_len, ml.qk_rope_dim), dtype),
    }


def mla_decode(params, cfg, x, cache_c, cache_kr, pos):
    """Absorbed one-token decode over the compressed cache — READ-ONLY.

    x: (B,1,d); cache_c: (B,S,lora); cache_kr: (B,S,rope); pos: scalar
    int32 length, or a (B,) vector of per-slot lengths (continuous
    batching over the paged latent cache).
    Returns (y, c_new (B,1,lora), kr_new (B,1,rope)); the caller commits the
    new-token slices into the stacked cache once per step.
    """
    B = x.shape[0]
    h, ml = cfg.n_heads, cfg.mla
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos_b[:, None]
    q_nope, q_rope, c_new, kr_new = _q_and_latent(params, cfg, x, positions)
    S = cache_c.shape[1]
    # absorb W_uk into the query: q_lat (B,1,h,lora)
    w_uk = params["w_uk"]["w"].reshape(ml.kv_lora_rank, h, ml.qk_nope_dim)
    q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope, w_uk.astype(q_nope.dtype))
    scale = 1.0 / math.sqrt(ml.qk_nope_dim + ml.qk_rope_dim)
    s_old = (
        jnp.einsum("bqhl,bsl->bhqs", q_lat, cache_c, preferred_element_type=jnp.float32)
        + jnp.einsum("bqhr,bsr->bhqs", q_rope, cache_kr, preferred_element_type=jnp.float32)
    ) * scale
    mask = jnp.arange(S)[None, :] < pos_b[:, None]  # (B, S) per-slot prefix
    s_old = jnp.where(mask[:, None, None, :], s_old, NEG_INF)
    s_new = (
        jnp.einsum("bqhl,bsl->bhqs", q_lat, c_new, preferred_element_type=jnp.float32)
        + jnp.einsum("bqhr,bsr->bhqs", q_rope, kr_new, preferred_element_type=jnp.float32)
    ) * scale
    # two-way online-softmax merge — a concat along the model-sharded seq
    # axis would all-gather the latent cache per layer (see attention.py)
    m_old = s_old.max(axis=-1)                      # (B,h,1)
    p_old = jnp.exp(s_old - m_old[..., None])
    l_old = p_old.sum(axis=-1)
    ctx_old = jnp.einsum(
        "bhqs,bsl->bqhl", p_old.astype(cache_c.dtype), cache_c,
        preferred_element_type=jnp.float32,
    )
    s_new1 = s_new[..., 0]                          # (B,h,1)
    m = jnp.maximum(m_old, s_new1)
    w_old = jnp.exp(m_old - m)
    w_new = jnp.exp(s_new1 - m)
    denom = (l_old * w_old + w_new).transpose(0, 2, 1)[..., None]  # (B,1,h,1)
    wo_ = w_old.transpose(0, 2, 1)[..., None]
    wn_ = w_new.transpose(0, 2, 1)[..., None]
    ctx = ((ctx_old * wo_ + c_new.astype(jnp.float32)[:, :, None, :] * wn_)
           / denom).astype(x.dtype)
    w_uv = params["w_uv"]["w"].reshape(ml.kv_lora_rank, h, ml.v_head_dim)
    out = jnp.einsum("bqhl,lhv->bqhv", ctx, w_uv.astype(x.dtype))
    out = out.reshape(B, 1, h * ml.v_head_dim)
    return layers.dense(params["wo"], out), c_new, kr_new
