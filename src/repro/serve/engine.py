"""Slot-level continuously-batched serving engine over a paged KV cache.

The production serve path: a fixed set of ``max_batch`` slots advances
through one fused :func:`~repro.models.transformer.decode_step_paged` per
token, and every slot carries its OWN cache position.  When a request
finishes (EOS or token budget) its slot is refilled from the queue on the
very next step and its cache blocks return to a shared pool — finished
slots are masked out and reassigned, never waited on.  This is the paper's
predication insight (Eq. 1: keep the lanes busy) executed at the serving
layer, where a fused decode step is the vector issue and the batch slots
are its lanes; :func:`repro.core.metrics.slot_utilization` reports the
resulting busy-lane fraction.

The KV cache is PAGED: attention caches live in a physical block pool
addressed through per-slot block tables (``block_size`` tokens per block,
block 0 reserved as the null block idle slots write into), so a slot's
logical cache never moves when requests of different lengths come and go,
and blocks freed by one request are immediately reused by the next.
Scheduling state — positions, block tables, the free list — is host-side
numpy ("slot accounting"); only the pools live on device, and the fused
step is compiled exactly once per engine.

``prefill_chunk > 1`` turns on prefill/decode disaggregation: prompts are
committed up to ``prefill_chunk`` tokens per fused
:func:`~repro.models.transformer.prefill_step_paged` call (a scan over
the same per-token cell as decode, so served streams stay bit-identical)
while in-flight decode slots keep advancing one token per step in the
SAME fused call.  ``prefill_budget`` caps the total prefill tokens
admitted per step — decode tokens are never counted against it — so a
long prompt cannot starve decode latency; time-to-first-token
(``ttft_p50_s``/``ttft_p95_s``) is the metric this trades against raw
step count.

``scheduler="wave"`` keeps the legacy lockstep behavior (admit a wave,
run every slot to the wave's horizon) as the golden-equivalence baseline:
both schedulers feed identical per-request token sequences, so greedy
outputs must match token-for-token while the continuous scheduler spends
strictly fewer fused steps on ragged workloads.

**Step hooks** let a traffic harness drive the engine from outside the
drain loop: every scheduling iteration calls each hook with
``hook(engine, busy) -> bool`` (the return value means "I may still
deliver work").  Hooks submit mid-flight arrivals, inject faults
(:meth:`ServeEngine.preempt`, a raised exception simulating device loss),
or just observe.  Preempted requests are requeued with their progress and
*replayed*: already-served tokens are fed back verbatim on resume, so a
preemption can never change the served token stream — the scenario
harness (:mod:`repro.scenarios`) asserts exactly that against a
fault-free golden twin.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import LayerKind, ModelConfig
from repro.core import metrics as core_metrics
from repro.models import transformer
from repro.serve.block_pool import BlockPool
from repro.serve.sampling import SlotSampler

SCHEDULERS = ("continuous", "wave")

#: A step hook: called once per scheduling iteration with (engine, busy);
#: returns True while it may still deliver work (keeps the drain alive).
StepHook = Callable[["ServeEngine", bool], bool]

#: Spin cap for a fully idle engine whose hooks keep claiming pending
#: work without ever submitting any — a misbehaving hook, not traffic.
_MAX_IDLE_SPINS = 100_000


def _bucket_width(m: int, cap: int) -> int:
    """Smallest power-of-two >= m, clamped to cap (chunk-scan widths are
    bucketed so each width traces once and partial chunks don't pay for
    the full chunk's masked cells)."""
    w = 1
    while w < m:
        w *= 2
    return min(w, cap)


def _dev(x: np.ndarray) -> jax.Array:
    """Hand a scheduler array to the device WITHOUT aliasing it.

    On the CPU backend ``jnp.asarray`` zero-copies a 64-byte-aligned
    contiguous numpy buffer, so the device computation reads the host
    memory directly — but the drain loops mutate these arrays in place
    immediately after dispatch, and the fused step's cache-commit thunks
    can still be reading them after the logits sync (XLA CPU completes
    outputs independently).  A private copy makes the handoff immune:
    the device may alias the copy, which nothing ever mutates.
    """
    return jnp.asarray(np.array(x, copy=True))


def _dev_placed(sharding: NamedSharding):
    """Mesh-aware `_dev`: hand a scheduler array to every device of the
    mesh under an explicit sharding (replicated for slot accounting,
    slot-over-data for token lanes).  The committed placement matches the
    fused steps' ``in_shardings`` exactly, so dispatch never re-infers or
    re-shards; the private copy keeps the same anti-aliasing contract as
    the single-device path."""

    def put(x: np.ndarray) -> jax.Array:
        return jax.device_put(np.array(x, copy=True), sharding)

    return put


@functools.lru_cache(maxsize=None)
def _jit_decode(cfg: ModelConfig):
    """One compiled dense decode step per ModelConfig (configs are frozen
    dataclasses, so engines serving the same config share the trace)."""
    return jax.jit(lambda p, t, c: transformer.decode_step(p, cfg, t, c))


@functools.lru_cache(maxsize=None)
def _jit_decode_paged(cfg: ModelConfig, block_size: int, kv_dtype: str):
    return jax.jit(
        lambda p, t, c, pos, bt: transformer.decode_step_paged(
            p, cfg, t, c, pos, bt, block_size=block_size, kv_dtype=kv_dtype
        )
    )


@functools.lru_cache(maxsize=None)
def _jit_prefill_paged(cfg: ModelConfig, block_size: int, kv_dtype: str):
    """Fused chunked-prefill step (chunk width is baked into the token
    array's shape, so each (config, block_size, chunk) traces once)."""
    return jax.jit(
        lambda p, t, c, pos, bt, lens: transformer.prefill_step_paged(
            p, cfg, t, c, pos, bt, lens, block_size=block_size,
            kv_dtype=kv_dtype
        )
    )


@functools.lru_cache(maxsize=1)
def _jit_reset_slots():
    return jax.jit(transformer.reset_paged_slots)


@functools.lru_cache(maxsize=1)
def _jit_copy_block():
    """COW device copy (src/dst are traced scalars: one trace per cache
    structure serves every copy).  The cache operand is DONATED: the
    copy updates the pool buffers in place instead of rebuilding every
    leaf, so a single-block COW costs O(block), not O(pool), and never
    transiently doubles pool memory.  Safe because both drain loops
    rebind ``cache`` to the result and never touch the old reference."""
    return jax.jit(transformer.copy_paged_block, donate_argnums=0)


@functools.lru_cache(maxsize=None)
def _sharded_jits(cfg: ModelConfig, batch: int, max_len: int,
                  block_size: int, kv_dtype: str, mesh):
    """Mesh-partitioned twins of the paged jit factories.

    One compiled step per (config, batch, max_len, block, kv_dtype, mesh)
    — Mesh is hashable, so engines serving the same shape share traces
    exactly like the single-device factories.  Every step is invoked with
    EXPLICIT ``in_shardings``/``out_shardings``: params follow
    :func:`repro.distributed.sharding.param_shardings` (column/row-parallel
    projections, expert-parallel MoE stacks), the paged cache follows
    :func:`~repro.distributed.sharding.paged_cache_shardings` (head-split
    block pools), tokens follow :func:`~repro.distributed.sharding.batch_spec`
    (slots over the data axes), and all host-side slot accounting
    (positions, block tables, lens, masks) plus the logits output stay
    replicated.  Shapes are derived via ``jax.eval_shape`` — nothing is
    allocated here.
    """
    from repro.distributed import sharding as shard_rules

    p_struct = jax.eval_shape(
        lambda key: transformer.init_lm(key, cfg), jax.random.PRNGKey(0)
    )
    cache_struct = jax.eval_shape(
        lambda: transformer.init_paged_cache(
            cfg, batch, max_len, block_size, kv_dtype
        )
    )
    p_sh = shard_rules.serve_param_shardings(p_struct, mesh)
    cache_sh = shard_rules.paged_cache_shardings(cache_struct, mesh)
    rep = shard_rules.replicated(mesh)
    tok = NamedSharding(mesh, shard_rules.batch_spec(mesh, batch, 2))
    snap_sh = shard_rules.paged_cache_shardings(
        transformer.slot_state(cache_struct), mesh
    )
    decode = jax.jit(
        lambda p, t, c, pos, bt: transformer.decode_step_paged(
            p, cfg, t, c, pos, bt, block_size=block_size, kv_dtype=kv_dtype
        ),
        in_shardings=(p_sh, tok, cache_sh, rep, rep),
        out_shardings=(rep, cache_sh),
    )
    prefill = jax.jit(
        lambda p, t, c, pos, bt, lens: transformer.prefill_step_paged(
            p, cfg, t, c, pos, bt, lens, block_size=block_size,
            kv_dtype=kv_dtype
        ),
        in_shardings=(p_sh, tok, cache_sh, rep, rep, rep),
        out_shardings=(rep, cache_sh),
    )
    reset = jax.jit(
        transformer.reset_paged_slots,
        in_shardings=(cache_sh, rep), out_shardings=cache_sh,
    )
    copy = jax.jit(
        transformer.copy_paged_block, donate_argnums=0,
        in_shardings=(cache_sh, rep, rep), out_shardings=cache_sh,
    )
    restore = jax.jit(
        transformer.restore_slot_state,
        in_shardings=(cache_sh, snap_sh, rep), out_shardings=cache_sh,
    )
    return {
        "decode": decode, "prefill": prefill, "reset": reset,
        "copy": copy, "restore": restore, "tok_sharding": tok,
        "rep_sharding": rep,
    }


class RequestTooLong(ValueError):
    """Raised at submit() time when prompt + budget exceed one slot's cache.

    Typed and early on purpose: under the old in-wave ``assert`` a single
    oversized request crashed the whole wave it was batched into.
    """


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never stops early

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        self.generated: List[int] = []
        self.done = False
        self.submitted_s: Optional[float] = None
        self.started_s: Optional[float] = None
        self.first_token_s: Optional[float] = None
        self.finished_s: Optional[float] = None
        # step-clock twins of the wall-clock stamps: fused-step counter at
        # submit and at first token — deterministic given the trace, so
        # the perf gate can hold TTFT tight where wall time is noisy
        self.submitted_step: Optional[int] = None
        self.first_token_step: Optional[int] = None

    @property
    def latency_s(self) -> Optional[float]:
        """Submit -> finish wall time (includes queue wait — the quantity
        continuous batching exists to shrink)."""
        if self.submitted_s is None or self.finished_s is None:
            return None
        return self.finished_s - self.submitted_s

    @property
    def ttft_s(self) -> Optional[float]:
        """Submit -> first generated token (time-to-first-token).  Survives
        preemption: replayed tokens never restamp it."""
        if self.submitted_s is None or self.first_token_s is None:
            return None
        return self.first_token_s - self.submitted_s

    @property
    def ttft_steps(self) -> Optional[int]:
        """Fused steps between submit and first generated token — the
        deterministic TTFT (same trace => same value on any machine)."""
        if self.submitted_step is None or self.first_token_step is None:
            return None
        return self.first_token_step - self.submitted_step


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 256, scheduler: str = "continuous",
                 block_size: int = 16, prefill_chunk: int = 1,
                 prefill_budget: Optional[int] = None,
                 kv_dtype: str = "f32", share_prefixes: bool = False,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0, spec_k: int = 0,
                 draft_cfg: Optional[ModelConfig] = None,
                 draft_params=None, spec_adaptive: bool = False,
                 mesh=None):
        if scheduler not in SCHEDULERS:
            raise ValueError(f"scheduler must be one of {SCHEDULERS}, "
                             f"got {scheduler!r}")
        if scheduler == "continuous" and max_len % block_size:
            # wave mode uses the dense cache and never touches the pool
            raise ValueError(f"max_len {max_len} must be a multiple of "
                             f"block_size {block_size}")
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        if prefill_chunk > 1 and scheduler != "continuous":
            raise ValueError(
                "chunked prefill (prefill_chunk > 1) requires the "
                "continuous scheduler; wave mode replays prompts densely"
            )
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError(
                f"prefill_budget must be >= 1 (or None), got {prefill_budget}"
            )
        if kv_dtype not in transformer.KV_DTYPES:
            raise ValueError(
                f"kv_dtype must be one of {transformer.KV_DTYPES}, "
                f"got {kv_dtype!r}"
            )
        if kv_dtype != "f32" and scheduler != "continuous":
            raise ValueError(
                "quantized KV blocks require the continuous scheduler; "
                "wave mode serves from the dense unquantized cache"
            )
        if share_prefixes and scheduler != "continuous":
            raise ValueError(
                "prefix sharing requires the continuous scheduler's "
                "paged block pool"
            )
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0 (0 = off), got {spec_k}")
        if spec_k > 0:
            if draft_cfg is None or draft_params is None:
                raise ValueError(
                    "speculative decoding (spec_k > 0) requires a draft "
                    "model: pass draft_cfg and draft_params"
                )
            if scheduler != "continuous":
                raise ValueError(
                    "speculative decoding requires the continuous "
                    "scheduler's paged cache"
                )
            if prefill_chunk > 1:
                raise ValueError(
                    "speculative decoding runs its own multi-token "
                    "verification window; combine it with prefill_chunk=1"
                )
        elif draft_cfg is not None or draft_params is not None:
            raise ValueError(
                "a draft model was provided but spec_k is 0; pass "
                "spec_k >= 1 to enable speculative decoding"
            )
        if spec_adaptive and spec_k == 0:
            raise ValueError(
                "spec_adaptive requires speculative decoding (spec_k >= 1)"
            )
        if mesh is not None:
            if scheduler != "continuous":
                raise ValueError(
                    "mesh serving requires the continuous scheduler; wave "
                    "mode is the single-device golden baseline"
                )
            for ax in ("data", "model"):
                if ax not in mesh.axis_names:
                    raise ValueError(
                        f"serve mesh must carry ('data', 'model') axes, "
                        f"got {mesh.axis_names}"
                    )
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.scheduler = scheduler
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        self.prefill_budget = prefill_budget
        self.kv_dtype = kv_dtype
        self.share_prefixes = share_prefixes
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.sample_seed = int(sample_seed)
        self.spec_k = int(spec_k)
        self.queue: Deque[Request] = deque()
        self.completed: Dict[int, Request] = {}
        # slot accounting (Eq. 1 analogue): fused steps are vector issues,
        # slots are lanes, busy_slot_steps counts the useful lane-steps
        self.steps = 0
        self.busy_slot_steps = 0
        self.wall_s = 0.0
        self.preemptions = 0
        # block-pool dedup accounting, accumulated across drains (see
        # repro.serve.block_pool): served vs stored block-spans, prefix
        # hits, and copy-on-write divergences
        self.logical_blocks = 0
        self.physical_blocks = 0
        self.shared_block_hits = 0
        self.cow_copies = 0
        # speculative-decoding accounting (all zero when spec_k == 0, so
        # the ledger schema is identical across +spec forks): exact token
        # counters plus the two step clocks — draft fused calls vs target
        # fused calls (the latter mirrors self.steps)
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.rejected_tokens = 0
        self.draft_steps = 0
        #: step hooks (see module docstring): traffic feeders, fault plans
        self.step_hooks: List[StepHook] = []
        #: uid -> physical block ids the request occupied, in allocation
        #: order (pool-reuse introspection; continuous scheduler only)
        self.block_history: Dict[int, List[int]] = {}
        self.mesh = mesh
        self.spec_adaptive = bool(spec_adaptive)
        self._decode = _jit_decode(cfg)
        if mesh is None:
            self._decode_paged = _jit_decode_paged(cfg, block_size, kv_dtype)
            self._prefill_paged = _jit_prefill_paged(cfg, block_size, kv_dtype)
            self._reset_slots = _jit_reset_slots()
            self._copy_block = _jit_copy_block()
            self._restore_state = None
            self._dev = _dev
            self._dev_tok = _dev
        else:
            # tensor-parallel serve path: params are placed once by the
            # Megatron-style rules, every fused step carries explicit
            # in/out shardings, and host arrays are committed replicated
            # (tokens: slot-over-data) so no dispatch ever re-infers
            # placement — sharding is pure placement, never semantics
            from repro.distributed import sharding as shard_rules
            self.params = jax.device_put(
                params, shard_rules.serve_param_shardings(params, mesh)
            )
            sj = _sharded_jits(cfg, max_batch, max_len, block_size,
                               kv_dtype, mesh)
            self._decode_paged = sj["decode"]
            self._prefill_paged = sj["prefill"]
            self._reset_slots = sj["reset"]
            self._copy_block = sj["copy"]
            self._restore_state = sj["restore"]
            rep, tok = sj["rep_sharding"], sj["tok_sharding"]
            self._dev = _dev_placed(rep)
            self._dev_tok = _dev_placed(tok)
        self._has_state = any(k != LayerKind.ATTN for k in cfg.superblock)
        # per-device busy-lane accounting (Eq. 1 one level up): the data
        # axis shards the slot lanes across device groups when divisible;
        # otherwise (and with no mesh) there is a single shard and
        # device_lane_utilization degenerates to slot_utilization
        n_data = 1
        if mesh is not None:
            from repro.launch.mesh import axis_size
            n_data = axis_size(mesh, "data")
        self._lane_shards = n_data if max_batch % n_data == 0 else 1
        self._lanes_per_shard = max_batch // self._lane_shards
        self.device_busy_lane_steps = np.zeros(self._lane_shards, np.int64)
        self._sampler = SlotSampler(
            cfg.vocab, temperature=self.temperature, top_k=self.top_k,
            seed=self.sample_seed,
        )
        if self.spec_k > 0:
            # imported here, not at module top: speculative.py reuses this
            # module's jit factories, so the import is one-directional only
            # at definition time
            from repro.serve.speculative import SpeculativeDecoder
            self._spec: Optional[SpeculativeDecoder] = SpeculativeDecoder(
                draft_cfg, draft_params, self.spec_k, target_cfg=cfg,
                block_size=block_size, temperature=self.temperature,
                top_k=self.top_k, seed=self.sample_seed,
                adaptive=self.spec_adaptive, mesh=mesh,
                max_batch=max_batch, max_len=max_len,
            )
        else:
            self._spec = None
        # token-work budget for the drain-loop runaway guard: grows with
        # every submit (and preemption replay), so hook-fed traffic gets
        # the same exact occupancy bound pre-submitted traffic always had
        self._submitted_work = 0
        # live continuous-drain state (positions/tables/free/slots); only
        # non-None while _drain_continuous runs — preempt() needs it
        self._live: Optional[Dict[str, Any]] = None

    # -- bookkeeping -----------------------------------------------------------

    @property
    def total_slot_steps(self) -> int:
        return self.steps * self.max_batch

    @property
    def slot_utilization(self) -> float:
        return core_metrics.slot_utilization(
            self.busy_slot_steps, self.steps, self.max_batch
        )

    @property
    def mesh_shape(self) -> Optional[str]:
        """The mesh as a ``DxM`` string (ledger fork segment), or None
        when serving single-device."""
        if self.mesh is None:
            return None
        from repro.launch.mesh import axis_size
        return (f"{axis_size(self.mesh, 'data')}x"
                f"{axis_size(self.mesh, 'model')}")

    @property
    def device_lane_utilization(self) -> float:
        return core_metrics.device_lane_utilization(
            self.device_busy_lane_steps.tolist(), self.steps,
            self._lanes_per_shard,
        )

    def _note_busy(self, busy_flags) -> None:
        """Fold one fused step's per-slot busy flags into both the global
        busy-lane counter and the per-device-shard counters (slot ``b``
        belongs to data shard ``b // lanes_per_shard``, matching
        `batch_spec`'s contiguous slot-over-data layout)."""
        flags = [bool(f) for f in busy_flags]
        self.busy_slot_steps += sum(flags)
        lps = self._lanes_per_shard
        for s in range(self._lane_shards):
            self.device_busy_lane_steps[s] += sum(
                flags[s * lps:(s + 1) * lps]
            )

    def _new_cache(self):
        """A fresh paged cache, placed by the mesh's pool rules when one
        is active (head-split k/v pools, replicated scale pools)."""
        return transformer.init_paged_cache(
            self.cfg, self.max_batch, self.max_len, self.block_size,
            self.kv_dtype, mesh=self.mesh,
        )

    def submit(self, req: Request) -> None:
        horizon = len(req.prompt) + req.max_new_tokens
        if horizon > self.max_len:
            raise RequestTooLong(
                f"request {req.uid}: prompt[{len(req.prompt)}] + "
                f"max_new_tokens[{req.max_new_tokens}] = {horizon} exceeds "
                f"the per-slot cache ({self.max_len} tokens)"
            )
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.uid}: empty prompt")
        req.submitted_s = time.time()
        req.submitted_step = self.steps
        self._submitted_work += horizon
        self.queue.append(req)

    def add_step_hook(self, hook: StepHook) -> None:
        self.step_hooks.append(hook)

    def warmup(self) -> None:
        """Compile the engine's fused step before any traffic arrives.

        One throwaway call on a dummy cache (all-null block tables: every
        paged write lands in the reserved null block, and the cache is
        discarded), so the jit trace is cached by shape when the drain
        loop makes its first real call.  Without this, the first request's
        TTFT measures XLA compilation, not scheduling — production servers
        warm up for exactly this reason.  No-op on engine counters.
        """
        B = self.max_batch
        if self.scheduler == "wave":
            cache = transformer.init_cache(self.cfg, B, self.max_len)
            out = self._decode(
                self.params, jnp.zeros((B, 1), jnp.int32), cache
            )
            jax.block_until_ready(out[0])
            return
        cache = self._new_cache()
        pos = jnp.zeros((B,), jnp.int32)
        bt = jnp.zeros((B, self.max_len // self.block_size), jnp.int32)
        if self.prefill_chunk > 1:
            # chunked engines dispatch the native decode step plus one
            # scan trace per power-of-two bucket width — warm every width
            # the drain can hit so no compile lands inside a request
            w = 2
            while True:
                w = min(w, self.prefill_chunk)
                out = self._prefill_paged(
                    self.params, jnp.zeros((B, w), jnp.int32),
                    cache, pos, bt, jnp.zeros((B,), jnp.int32),
                )
                jax.block_until_ready(out[0])
                if w == self.prefill_chunk:
                    break
                w *= 2
        if self._spec is not None:
            # speculative engines dispatch the (k+1)-wide verification
            # scan (replay reuses the same trace) and the draft model's
            # 1-wide step — warm both alongside the native decode step
            out = self._prefill_paged(
                self.params, jnp.zeros((B, self.spec_k + 1), jnp.int32),
                cache, pos, bt, jnp.zeros((B,), jnp.int32),
            )
            jax.block_until_ready(out[0])
            self._spec.warmup(self)
        out = self._decode_paged(
            self.params, jnp.zeros((B, 1), jnp.int32), cache, pos, bt
        )
        jax.block_until_ready(out[0])

    def _call_hooks(self, busy: bool) -> bool:
        """Run every step hook; True while any may still deliver work."""
        pending = False
        for hook in self.step_hooks:
            pending = bool(hook(self, busy)) or pending
        return pending

    def _absorb_pool(self, pool: BlockPool) -> None:
        """Fold one drain's block-pool dedup counters into the engine's
        (each ``run_until_drained`` builds a fresh cache and pool)."""
        self.logical_blocks += pool.logical_blocks
        self.physical_blocks += pool.physical_blocks
        self.shared_block_hits += pool.shared_hits
        self.cow_copies += pool.cow_copies

    def _finish(self, req: Request) -> None:
        req.done = True
        if req.finished_s is None:
            req.finished_s = time.time()
        self.completed[req.uid] = req

    def _note_first_token(self, req: Request) -> None:
        if req.first_token_s is None:
            req.first_token_s = time.time()
            req.first_token_step = self.steps  # the call that produced it

    def preempt(self, uid: Optional[int] = None) -> Optional[int]:
        """Evict one in-flight request from its slot (continuous only).

        The request is requeued at the FRONT of the queue with its
        ``generated`` tokens intact; on re-admission the engine replays
        prompt + generated through the rebuilt cache and only then starts
        appending, so the served stream is bit-identical to an unfaulted
        run.  Picks ``uid``'s slot, or the deepest busy slot (max cache
        position, lowest slot index on ties).  Returns the preempted uid,
        or None when nothing was preemptible.  Only callable from a step
        hook while the continuous scheduler is draining.
        """
        live = self._live
        if live is None:
            raise RuntimeError(
                "preempt() is only available from a step hook while the "
                "continuous scheduler is draining"
            )
        slot_req, positions = live["slot_req"], live["positions"]
        block_tables, pool = live["block_tables"], live["pool"]
        if uid is not None:
            picks = [b for b, r in enumerate(slot_req)
                     if r is not None and r.uid == uid]
        else:
            picks = sorted(
                (b for b, r in enumerate(slot_req) if r is not None),
                key=lambda b: (-int(positions[b]), b),
            )
        if not picks:
            return None
        b = picks[0]
        req = slot_req[b]
        # replay budget: the resumed run re-spends prompt + generated steps
        self._submitted_work += len(req.prompt) + req.max_new_tokens
        # decref, never free: a prefix-shared block may still back another
        # slot's cache — it returns to the free list only at refcount 0
        for j in range(block_tables.shape[1]):
            if block_tables[b, j] != 0:
                pool.decref(int(block_tables[b, j]))
        block_tables[b] = 0
        positions[b] = 0
        live["tokens"][b, :] = 0
        slot_req[b] = None
        self.queue.appendleft(req)
        self.preemptions += 1
        return req.uid

    # -- wave scheduler (legacy lockstep, golden baseline) ---------------------

    def _run_wave(self, wave: List[Request]) -> None:
        B = self.max_batch
        cache = transformer.init_cache(self.cfg, B, self.max_len)
        prompt_len = np.array(
            [len(r.prompt) for r in wave] + [1] * (B - len(wave)), np.int32
        )
        horizon = int(max(
            len(r.prompt) + r.max_new_tokens for r in wave
        ))
        if horizon > self.max_len:  # unreachable: submit() already rejects
            raise RequestTooLong(f"wave horizon {horizon} > {self.max_len}")
        tokens = np.zeros((B, 1), np.int32)
        for s, r in enumerate(wave):
            tokens[s, 0] = r.prompt[0]
            r.started_s = time.time()

        for t in range(horizon - 1):
            self._call_hooks(busy=True)  # arrivals land in the NEXT wave
            self._note_busy(
                [not r.done for r in wave] + [False] * (B - len(wave))
            )
            logits, cache = self._decode(self.params, _dev(tokens), cache)
            self.steps += 1
            slots = list(wave) + [None] * (B - len(wave))
            nxt = self._sampler.select(logits, slots)[:, 0]
            for s, r in enumerate(wave):
                if r.done:
                    continue
                if t + 1 < prompt_len[s]:
                    tokens[s, 0] = r.prompt[t + 1]  # still consuming prompt
                else:
                    tok = int(nxt[s])
                    self._note_first_token(r)
                    r.generated.append(tok)
                    tokens[s, 0] = tok
                    if (len(r.generated) >= r.max_new_tokens or tok == r.eos_id):
                        r.done = True
                        r.finished_s = time.time()
            if all(r.done for r in wave):
                break
        for r in wave:
            self._finish(r)

    def _drain_waves(self, max_waves: int) -> None:
        waves = 0
        idle_spins = 0
        while True:
            pending = self._call_hooks(busy=False)
            if not self.queue:
                if not pending:
                    break
                idle_spins += 1  # hooks promise work; let them deliver
                if idle_spins > _MAX_IDLE_SPINS:
                    raise RuntimeError(
                        "step hooks report pending work but never submit"
                    )
                continue
            idle_spins = 0
            if waves >= max_waves:
                raise RuntimeError("serve loop did not drain")
            wave = [self.queue.popleft()
                    for _ in range(min(self.max_batch, len(self.queue)))]
            self._run_wave(wave)
            waves += 1

    # -- continuous scheduler (per-slot positions, paged blocks) ---------------

    def _drain_continuous(self, max_steps: Optional[int]) -> None:
        B, bs = self.max_batch, self.block_size
        nb_slot = self.max_len // bs
        cache = self._new_cache()
        positions = np.zeros(B, np.int32)
        block_tables = np.zeros((B, nb_slot), np.int32)  # 0 = null block
        pool = BlockPool(1 + B * nb_slot, bs,
                         share_prefixes=self.share_prefixes)
        slot_req: List[Optional[Request]] = [None] * B
        tokens = np.zeros((B, 1), np.int32)
        reset_mask = np.zeros(B, bool)
        self._live = {
            "positions": positions, "block_tables": block_tables,
            "free": pool.free, "pool": pool, "slot_req": slot_req,
            "tokens": tokens,
        }
        idle_spins = 0

        try:
            while True:
                pending = self._call_hooks(
                    busy=any(r is not None for r in slot_req)
                )
                # refill: finished slots take the next queued request NOW —
                # the lane is re-predicated, not idled until a wave drains
                for b in range(B):
                    if slot_req[b] is None and self.queue:
                        r = self.queue.popleft()
                        slot_req[b] = r
                        if r.started_s is None:
                            r.started_s = time.time()
                        positions[b] = 0
                        block_tables[b] = 0
                        tokens[b, 0] = r.prompt[0]
                        reset_mask[b] = True
                if all(r is None for r in slot_req):
                    if not pending:
                        break
                    idle_spins += 1  # hooks promise work; let them deliver
                    if idle_spins > _MAX_IDLE_SPINS:
                        raise RuntimeError(
                            "step hooks report pending work but never submit"
                        )
                    continue
                idle_spins = 0
                # exact occupancy bound: a request holds its slot for at
                # most prompt + max_new - 1 steps (replays re-budgeted at
                # preemption), so submitted work is a hard cap
                budget = (max_steps if max_steps is not None
                          else self._submitted_work + B)
                if self.steps >= budget:
                    raise RuntimeError("serve loop did not drain")
                # allocate the write block for any slot whose position entered
                # an unmapped logical block (covers fresh admissions at 0 too);
                # with sharing on, acquire() may return another slot's block
                # holding the same exact prompt chain instead of a fresh one
                for b, r in enumerate(slot_req):
                    if r is not None:
                        j = positions[b] // bs
                        if block_tables[b, j] == 0:
                            blk = pool.acquire(r.prompt, j)
                            block_tables[b, j] = blk
                            self.block_history.setdefault(r.uid, []).append(blk)
                        # copy-on-write: a generated-token row diverges the
                        # block's content, so a block other slots still
                        # reference gets a private copy first (prompt rows
                        # write through — sharers write identical bytes)
                        if positions[b] >= len(r.prompt):
                            if pool.refcount_of(
                                    int(block_tables[b, j])) > 1:
                                old = int(block_tables[b, j])
                                new = pool.cow(old)
                                cache = self._copy_block(
                                    cache, jnp.int32(old), jnp.int32(new)
                                )
                                block_tables[b, j] = new
                                self.block_history.setdefault(
                                    r.uid, []
                                ).append(new)
                            # in-place generated write: any registry key
                            # claiming this row or beyond is now stale —
                            # trim it before a later prompt can match it
                            pool.note_generated_write(
                                int(block_tables[b, j]),
                                int(positions[b]) % bs,
                            )
                if self._has_state and reset_mask.any():
                    cache = self._reset_slots(cache, self._dev(reset_mask))
                reset_mask[:] = False

                self._note_busy(r is not None for r in slot_req)
                logits, cache = self._decode_paged(
                    self.params, self._dev_tok(tokens), cache,
                    self._dev(positions), self._dev(block_tables),
                )
                self.steps += 1
                nxt = self._sampler.select(logits, slot_req)[:, 0]
                for b, r in enumerate(slot_req):
                    if r is None:
                        continue
                    t = int(positions[b])
                    positions[b] = t + 1
                    if t + 1 < len(r.prompt):
                        tokens[b, 0] = r.prompt[t + 1]  # still consuming prompt
                        continue
                    gi = t + 1 - len(r.prompt)
                    if gi < len(r.generated):
                        # replay after preemption: this token was already
                        # served — feed it back, never re-append
                        tokens[b, 0] = r.generated[gi]
                        continue
                    tok = int(nxt[b])
                    self._note_first_token(r)
                    r.generated.append(tok)
                    tokens[b, 0] = tok
                    if (len(r.generated) >= r.max_new_tokens
                            or tok == r.eos_id):
                        self._finish(r)
                        # release the slot's blocks (LIFO: the next admission
                        # reuses this request's blocks first); shared blocks
                        # survive under their other referents' refcounts
                        for j in range(nb_slot):
                            if block_tables[b, j] != 0:
                                pool.decref(int(block_tables[b, j]))
                        block_tables[b] = 0
                        positions[b] = 0
                        tokens[b, 0] = 0
                        slot_req[b] = None
        finally:
            self._absorb_pool(pool)
            self._live = None

    # -- continuous scheduler, chunked prefill (prefill/decode disaggregation) -

    def _drain_continuous_chunked(self, max_steps: Optional[int]) -> None:
        """Continuous drain where prompts are committed ``prefill_chunk``
        tokens per fused call instead of one.

        Every busy slot feeds its *known* tokens (prompt, then any tokens
        already generated — i.e. a preemption replay) in order: a slot at
        position ``t0`` with ``n_rem`` known tokens left receives
        ``n_b = min(chunk, n_rem)`` of them this step.  Decode slots
        (``n_rem == 1``: the fed token is the newest generated one) always
        advance and are never counted against ``prefill_budget``; prefill
        slots share the budget in slot order and stall at ``n_b = 0`` when
        it runs out — that is the disaggregation: decode latency no longer
        queues behind a long prompt, because the prompt's chunks are
        admitted under a per-step token budget alongside every decode
        step.  A slot appends a new token only on the step that consumes
        its last known token, from the logits row of that token; all other
        rows are discarded.  The fused step is
        :func:`~repro.models.transformer.prefill_step_paged`, a scan over
        the same per-token cell as decode, so served streams are
        bit-identical to the token-by-token scheduler.
        """
        B, bs, C = self.max_batch, self.block_size, self.prefill_chunk
        nb_slot = self.max_len // bs
        cache = self._new_cache()
        positions = np.zeros(B, np.int32)
        block_tables = np.zeros((B, nb_slot), np.int32)  # 0 = null block
        pool = BlockPool(1 + B * nb_slot, bs,
                         share_prefixes=self.share_prefixes)
        slot_req: List[Optional[Request]] = [None] * B
        tokens = np.zeros((B, C), np.int32)
        lengths = np.zeros(B, np.int32)
        reset_mask = np.zeros(B, bool)
        self._live = {
            "positions": positions, "block_tables": block_tables,
            "free": pool.free, "pool": pool, "slot_req": slot_req,
            "tokens": tokens,
        }
        idle_spins = 0

        try:
            while True:
                pending = self._call_hooks(
                    busy=any(r is not None for r in slot_req)
                )
                for b in range(B):
                    if slot_req[b] is None and self.queue:
                        r = self.queue.popleft()
                        slot_req[b] = r
                        if r.started_s is None:
                            r.started_s = time.time()
                        positions[b] = 0
                        block_tables[b] = 0
                        reset_mask[b] = True
                if all(r is None for r in slot_req):
                    if not pending:
                        break
                    idle_spins += 1  # hooks promise work; let them deliver
                    if idle_spins > _MAX_IDLE_SPINS:
                        raise RuntimeError(
                            "step hooks report pending work but never submit"
                        )
                    continue
                idle_spins = 0
                # same exact occupancy bound as the token-by-token drain: a
                # chunked step never advances a slot by less than one token
                # unless budget-stalled, and at least one slot advances
                budget = (max_steps if max_steps is not None
                          else self._submitted_work + B)
                if self.steps >= budget:
                    raise RuntimeError("serve loop did not drain")
                # admission: hand each slot its next known tokens under the
                # per-step prefill budget, and map the blocks they land in
                tokens[:] = 0
                lengths[:] = 0
                budget_left = (self.prefill_budget
                               if self.prefill_budget is not None else B * C)
                for b, r in enumerate(slot_req):
                    if r is None:
                        continue
                    t0 = int(positions[b])
                    known = len(r.prompt) + len(r.generated)
                    n_rem = known - t0
                    if n_rem <= 1:
                        n_b = 1  # decode: always advances, never budgeted
                    else:
                        n_b = min(C, n_rem, budget_left)
                        budget_left -= n_b
                    if n_b <= 0:
                        continue  # prefill stalled by budget this step
                    for c in range(n_b):
                        p = t0 + c
                        tokens[b, c] = (
                            r.prompt[p] if p < len(r.prompt)
                            else r.generated[p - len(r.prompt)]
                        )
                    lengths[b] = n_b
                    for j in range(t0 // bs, (t0 + n_b - 1) // bs + 1):
                        if block_tables[b, j] == 0:
                            blk = pool.acquire(r.prompt, j)
                            block_tables[b, j] = blk
                            self.block_history.setdefault(
                                r.uid, []
                            ).append(blk)
                    # copy-on-write for any block receiving a generated-token
                    # row this step while other slots still reference it
                    gen_from = max(t0, len(r.prompt))
                    if gen_from < t0 + n_b:
                        for j in range(gen_from // bs,
                                       (t0 + n_b - 1) // bs + 1):
                            old = int(block_tables[b, j])
                            if pool.refcount_of(old) > 1:
                                new = pool.cow(old)
                                cache = self._copy_block(
                                    cache, jnp.int32(old), jnp.int32(new)
                                )
                                block_tables[b, j] = new
                                self.block_history.setdefault(
                                    r.uid, []
                                ).append(new)
                            # in-place generated rows land from
                            # max(gen_from, j*bs) onward in this block:
                            # trim any registry key claiming them
                            pool.note_generated_write(
                                int(block_tables[b, j]),
                                max(gen_from, j * bs) % bs,
                            )
                if self._has_state and reset_mask.any():
                    cache = self._reset_slots(cache, self._dev(reset_mask))
                reset_mask[:] = False

                self._note_busy(lengths > 0)
                # disaggregated dispatch: a step with no prefill chunk in
                # flight (every busy slot advances exactly 1 token) runs
                # the native 1-wide decode step — decode never pays a
                # chunk-wide scan; steps that DO carry prefill run the
                # scan sliced to the smallest power-of-two bucket >= the
                # widest chunk, so partial chunks don't burn masked cells.
                # Both are bitwise safe: decode_step_paged is the C=1 cell
                # of prefill_step_paged, a masked cell is identity on the
                # cache, and a budget-stalled slot (lengths == 0 with
                # mapped blocks) always takes the masked scan path so it
                # is never fed a garbage token.
                pure_decode = all(
                    lengths[b] == 1 for b, r in enumerate(slot_req)
                    if r is not None
                )
                if pure_decode:
                    logits, cache = self._decode_paged(
                        self.params, self._dev_tok(tokens[:, :1]), cache,
                        self._dev(positions), self._dev(block_tables),
                    )
                else:
                    w = _bucket_width(int(lengths.max()), C)
                    logits, cache = self._prefill_paged(
                        self.params, self._dev_tok(tokens[:, :w]), cache,
                        self._dev(positions), self._dev(block_tables),
                        self._dev(lengths),
                    )
                self.steps += 1
                # one transfer: select from each slot's LAST fed row (only
                # slots that just consumed their final known token use it)
                last = jnp.maximum(jnp.asarray(lengths) - 1, 0)
                rows = logits[jnp.arange(B), last][:, None]
                nxt = self._sampler.select(rows, slot_req)[:, 0]
                for b, r in enumerate(slot_req):
                    if r is None or lengths[b] == 0:
                        continue
                    n_b = int(lengths[b])
                    t0 = int(positions[b])
                    positions[b] = t0 + n_b
                    if t0 + n_b < len(r.prompt) + len(r.generated):
                        continue  # still prefilling (or replaying)
                    tok = int(nxt[b])
                    self._note_first_token(r)
                    r.generated.append(tok)
                    if (len(r.generated) >= r.max_new_tokens
                            or tok == r.eos_id):
                        self._finish(r)
                        for j in range(nb_slot):
                            if block_tables[b, j] != 0:
                                pool.decref(int(block_tables[b, j]))
                        block_tables[b] = 0
                        positions[b] = 0
                        tokens[b, :] = 0
                        slot_req[b] = None
        finally:
            self._absorb_pool(pool)
            self._live = None

    # -- public ----------------------------------------------------------------

    def run_until_drained(
        self, max_waves: int = 1000, *, max_steps: Optional[int] = None
    ) -> Dict[int, Request]:
        t0 = time.time()
        if self.scheduler == "wave":
            self._drain_waves(max_waves)
        elif self._spec is not None:
            self._spec.drain(self, max_steps)
        elif self.prefill_chunk > 1:
            self._drain_continuous_chunked(max_steps)
        else:
            self._drain_continuous(max_steps)
        self.wall_s += time.time() - t0
        return self.completed

    def stats(self) -> Dict[str, Any]:
        """Serving metrics in the perf-ledger schema (see
        :func:`repro.perf.ledger.metrics_from_serving`)."""
        lat = sorted(
            r.latency_s for r in self.completed.values()
            if r.latency_s is not None
        )
        ttft = sorted(
            r.ttft_s for r in self.completed.values()
            if r.ttft_s is not None
        )
        ttft_steps = sorted(
            r.ttft_steps for r in self.completed.values()
            if r.ttft_steps is not None
        )
        new_tokens = sum(len(r.generated) for r in self.completed.values())
        block_bytes = transformer.paged_block_bytes(
            self.cfg, self.block_size, self.kv_dtype
        )
        kv_bytes_served = self.logical_blocks * block_bytes
        kv_bytes_stored = self.physical_blocks * block_bytes
        return {
            "scheduler": self.scheduler,
            "prefill_chunk": self.prefill_chunk,
            "prefill_budget": self.prefill_budget,
            "kv_dtype": self.kv_dtype,
            "share_prefixes": self.share_prefixes,
            # mesh placement: the DxM shape string keys the +mesh<DxM>
            # ledger fork; device_lane_utilization is Eq. 1 one level up
            # (worst device shard's busy-lane fraction — deterministic
            # slot accounting, gated at tol 0)
            "mesh": self.mesh_shape,
            "mesh_devices": (self.mesh.devices.size
                             if self.mesh is not None else 1),
            "device_lane_utilization": self.device_lane_utilization,
            "spec_adaptive": self.spec_adaptive,
            "requests": len(self.completed),
            "new_tokens": new_tokens,
            "fused_steps": self.steps,
            "busy_slot_steps": self.busy_slot_steps,
            "slot_steps": self.total_slot_steps,
            "slot_utilization": self.slot_utilization,
            "preemptions": self.preemptions,
            # block-pool dedup: bytes served / bytes stored is the
            # memory-side Eq. 1 analogue (see core.metrics.block_dedup_ratio)
            "logical_blocks": self.logical_blocks,
            "physical_blocks": self.physical_blocks,
            "shared_block_hits": self.shared_block_hits,
            "cow_copies": self.cow_copies,
            "kv_bytes_served": kv_bytes_served,
            "kv_bytes_stored": kv_bytes_stored,
            # speculative decoding: exact counters (zeros when off, so
            # the schema is stable across +spec ledger forks) plus the
            # Eq. 1 lane-utilization analogue — accepted drafts are the
            # active lanes of each k-wide verification issue
            "spec_k": self.spec_k,
            "drafted_tokens": self.drafted_tokens,
            "accepted_tokens": self.accepted_tokens,
            "rejected_tokens": self.rejected_tokens,
            "draft_steps": self.draft_steps,
            "target_steps": self.steps,
            "acceptance_rate": core_metrics.acceptance_rate(
                self.accepted_tokens, self.drafted_tokens
            ),
            # pure-SSM models page zero KV bytes; fall back to block-
            # granular units there so sharing still registers (the ratio
            # is unit-agnostic: served / stored)
            "block_dedup_ratio": core_metrics.block_dedup_ratio(
                kv_bytes_served, kv_bytes_stored
            ) if block_bytes > 0 else core_metrics.block_dedup_ratio(
                self.logical_blocks, self.physical_blocks
            ),
            "wall_s": self.wall_s,
            "tok_s": new_tokens / self.wall_s if self.wall_s > 0 else 0.0,
            "p50_latency_s": float(np.percentile(lat, 50)) if lat else 0.0,
            "p95_latency_s": float(np.percentile(lat, 95)) if lat else 0.0,
            "ttft_p50_s": float(np.percentile(ttft, 50)) if ttft else 0.0,
            "ttft_p95_s": float(np.percentile(ttft, 95)) if ttft else 0.0,
            "ttft_p50_steps": (float(np.percentile(ttft_steps, 50))
                               if ttft_steps else 0.0),
            "ttft_p95_steps": (float(np.percentile(ttft_steps, 95))
                               if ttft_steps else 0.0),
        }
