from repro.distributed import fault_tolerance, sharding  # noqa: F401
