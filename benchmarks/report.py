"""Regenerate the EXPERIMENTS.md §Roofline tables from experiments/dryrun/.

    PYTHONPATH=src python -m benchmarks.report
"""

from __future__ import annotations

import glob
import json
import os
import re
import sys

HEADER = ("| cell | dom | bound_s | compute_s | memory_s | collective_s "
          "| useful | roofline | GB/dev | fits16GB |")
SEP = "|---|---|---|---|---|---|---|---|---|---|"


def _row(d) -> str:
    rl = d["roofline"]
    mem = d["memory_per_device"]
    return (f"| {d['cell']} | {rl['dominant']} | {rl['bound_s']:.4g} "
            f"| {rl['compute_s']:.3g} | {rl['memory_s']:.3g} "
            f"| {rl['collective_s']:.3g} "
            f"| {rl['useful_flop_fraction']:.2f} | {rl['roofline_fraction']:.2f} "
            f"| {mem['total_gb']:.1f} | {'y' if mem['fits_16gb_hbm'] else 'n'} |")


def table(pattern: str, dryrun_dir: str, sort_key=None) -> str:
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, pattern))):
        rows.append(json.load(open(f)))
    if sort_key:
        rows.sort(key=sort_key)
    return "\n".join([HEADER, SEP] + [_row(d) for d in rows])


def multipod_table(dryrun_dir: str) -> str:
    """single vs multi side-by-side for a representative subset."""
    picks = ["qwen3-32b@train_4k", "jamba-1.5-large-398b@train_4k",
             "deepseek-moe-16b@train_4k", "qwen3-1.7b@decode_32k",
             "internvl2-76b@prefill_32k", "mamba2-370m@long_500k"]
    out = ["| cell | mesh | bound_s | dominant | collective_s | GB/dev |",
           "|---|---|---|---|---|---|"]
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*__opt.json"))):
        d = json.load(open(f))
        if d["cell"] not in picks:
            continue
        rl = d["roofline"]
        out.append(f"| {d['cell']} | {d['mesh']} | {rl['bound_s']:.4g} "
                   f"| {rl['dominant']} | {rl['collective_s']:.3g} "
                   f"| {d['memory_per_device']['total_gb']:.1f} |")
    return "\n".join(out)


def main() -> int:
    dryrun_dir = "experiments/dryrun"
    with open("EXPERIMENTS.md") as f:
        text = f.read()

    roofline = table("*__single__opt.json", dryrun_dir,
                     sort_key=lambda d: d["cell"])
    text = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\n### |\n---|\Z)",
        "<!-- ROOFLINE_TABLE -->\n" + roofline + "\n",
        text, flags=re.S,
    )
    text = re.sub(
        r"<!-- MULTIPOD_TABLE -->.*?(?=\n---|\Z)",
        "<!-- MULTIPOD_TABLE -->\n" + multipod_table(dryrun_dir) + "\n",
        text, flags=re.S,
    )
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    n = len(glob.glob(os.path.join(dryrun_dir, "*__single__opt.json")))
    print(f"EXPERIMENTS.md tables regenerated ({n} single-pod cells)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
