"""Shared neural-net layers: norms, rotary embeddings, SwiGLU FFN, initializers.

Pure-functional: ``init_*`` builds a param pytree; ``apply``-style functions
take (params, inputs).  Norm math runs in fp32 regardless of compute dtype
(standard mixed-precision practice; matches MaxText/T5X).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, scale: float, dtype) -> jax.Array:
    # fan-in scaled init; fp32 draw then cast
    stddev = scale / max(1.0, (shape[-2] if len(shape) >= 2 else shape[-1]) ** 0.5)
    x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * stddev
    return x.astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, *, bias: bool = False):
    kw, kb = jax.random.split(key)
    p = {"w": truncated_normal(kw, (d_in, d_out), 1.0, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params, x: jax.Array) -> jax.Array:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


# --- norms -------------------------------------------------------------------


def rms_norm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    if params is not None and "scale" in params:
        y = y * params["scale"].astype(jnp.float32)
    return y.astype(dtype)


def layer_norm_init(d: int, dtype, *, parametric: bool = True):
    if not parametric:
        return {}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """LayerNorm; with empty params this is OLMo's non-parametric LN."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    if params and "scale" in params:
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def make_norm(cfg):
    """Returns (init_fn(dtype)->params, apply_fn(params,x)->x) per config."""
    if cfg.nonparam_ln:
        return (lambda dtype: {}), (lambda p, x: layer_norm({}, x, cfg.norm_eps))
    if cfg.rms_norm:
        return (
            lambda dtype: rms_norm_init(cfg.d_model, dtype),
            lambda p, x: rms_norm(p, x, cfg.norm_eps),
        )
    return (
        lambda dtype: layer_norm_init(cfg.d_model, dtype),
        lambda p, x: layer_norm(p, x, cfg.norm_eps),
    )


# --- rotary ------------------------------------------------------------------


def rope_cos_sin(positions: jax.Array, dim: int, theta: float) -> tuple:
    """cos/sin tables for given positions: (..., dim//2), fp32."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:  # (S, D/2) -> broadcast over batch and heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, S, D/2)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


# --- FFN ---------------------------------------------------------------------


def swiglu_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": truncated_normal(k1, (d_model, d_ff), 1.0, dtype),
        "wi_up": truncated_normal(k2, (d_model, d_ff), 1.0, dtype),
        "wo": truncated_normal(k3, (d_ff, d_model), 1.0, dtype),
    }


def swiglu(params, x: jax.Array) -> jax.Array:
    g = x @ params["wi_gate"].astype(x.dtype)
    u = x @ params["wi_up"].astype(x.dtype)
    return (jax.nn.silu(g) * u) @ params["wo"].astype(x.dtype)


def embed_init(key, vocab: int, d_model: int, dtype):
    return {"embedding": truncated_normal(key, (vocab, d_model), 1.0, dtype)}


def embed(params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params, x: jax.Array) -> jax.Array:
    """Project to vocab logits; fp32 output for a stable softmax/loss."""
    return (x @ params["embedding"].astype(x.dtype).T).astype(jnp.float32)
