"""Batched LM serving: request queue -> waves of fused decode steps.

Shows the serving shape the decode_* dry-run cells model: one jitted
decode_step advances the whole batch one token per call over a fixed-size
KV cache; ragged prompts switch over per-slot (predication at the serving
layer).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

import repro.configs as configs
from repro.serve.engine import Request, ServeEngine
from repro.train import steps as steps_mod


def main():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_batch=4, max_len=96)

    rng = np.random.default_rng(0)
    n_requests = 10
    for uid in range(n_requests):
        plen = int(rng.integers(3, 24))
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, size=plen).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 12)),
        ))

    t0 = time.time()
    done = engine.run_until_drained()
    dt = time.time() - t0
    new_tokens = sum(len(r.generated) for r in done.values())
    print(f"served {len(done)} requests / {new_tokens} new tokens in "
          f"{engine.steps} fused steps, {dt:.2f}s ({new_tokens/dt:.1f} tok/s)")
    for uid in sorted(done):
        r = done[uid]
        print(f"  req {uid:2d}: prompt len {len(r.prompt):2d} -> "
              f"{len(r.generated):2d} tokens: {r.generated[:8]}"
              f"{'...' if len(r.generated) > 8 else ''}")
    assert len(done) == n_requests


if __name__ == "__main__":
    main()
