"""AnalysisService: queue/wave serving of the SVE pipeline (repro.serve).

Mirrors the ServeEngine contracts: submissions drain in waves of
``max_batch``, all waves share one ArtifactCache (same-workload requests
dedupe to one compile), errors are captured per request, and the drain
report is machine-readable JSON.
"""

import json

import jax.numpy as jnp
import pytest

from repro.analysis import ArtifactCache, Workload, analyze
from repro.core import hw
from repro.core.decision_tree import PerfClass
from repro.serve.analysis_service import AnalysisRequest, AnalysisService, main


def test_service_drains_in_waves_and_matches_direct_analyze():
    svc = AnalysisService(max_batch=2, cache=ArtifactCache())
    names = ["kernel/gemm", "kernel/spmv", "kernel/stream-triad"]
    reqs = [svc.submit(n, chips=("grace-core",)) for n in names]
    assert [r.uid for r in reqs] == [0, 1, 2]
    completed = svc.run_until_drained()
    assert svc.waves == 2  # 3 requests / max_batch 2
    assert set(completed) == {0, 1, 2}
    for req, name in zip(reqs, names):
        assert req.done and req.error is None
        assert len(req.results) == 1
        direct = analyze(name, hw.GRACE_CORE)
        assert req.results[0].to_dict() == direct.to_dict()


def test_service_request_sweeps_chips_and_dtypes():
    svc = AnalysisService(cache=ArtifactCache())
    req = svc.submit("kernel/stream-triad", chips=("grace-core",),
                     dtypes=("fp64", "fp32", "fp16"))
    svc.run_until_drained()
    assert [r.vb for r in req.results] == [2.0, 4.0, 8.0]  # the ELEN sweep


def test_same_workload_across_requests_compiles_once():
    a = jnp.ones((48, 48), jnp.float32)
    wl = Workload(name="svc-shared", fn=lambda x: x @ x, args=(a,))
    cache = ArtifactCache()
    svc = AnalysisService(max_batch=8, jobs=4, cache=cache)
    for chips in (("grace-core",), ("tpu-v5e",), ("grace-socket",)):
        svc.submit(wl, chips=chips, source="compiled")
    svc.run_until_drained()
    assert cache.compiles == 1  # single-flight across the whole wave
    assert all(len(r.results) == 1 for r in svc.completed.values())


def test_compile_failure_is_captured_not_fatal():
    """A workload that blows up at trace time fails ITS request only."""
    a = jnp.ones((8, 8), jnp.float32)

    def boom(x):
        raise RuntimeError("trace failure")

    svc = AnalysisService(cache=ArtifactCache())
    bad = svc.submit(Workload(name="svc-bad", fn=boom, args=(a,)),
                     source="compiled")
    ok = svc.submit("kernel/gemm")
    svc.run_until_drained()
    assert bad.done and bad.error and "trace failure" in bad.error
    assert bad.results == []
    assert ok.error is None and ok.results[0].perf_class == PerfClass.SPEEDUP
    assert svc.report()["service"]["errors"] == 1


def test_failing_lazy_builder_is_captured_not_fatal():
    """A registered workload whose lazy builder raises fails only its own
    request; the rest of the wave drains."""
    from repro.analysis import register_lazy

    def broken_builder():
        raise RuntimeError("builder exploded")

    register_lazy("test/broken-builder", broken_builder, replace=True)
    svc = AnalysisService(cache=ArtifactCache())
    bad = svc.submit("test/broken-builder")
    ok = svc.submit("kernel/gemm")
    svc.run_until_drained()
    assert bad.done and bad.error and "builder exploded" in bad.error
    assert ok.error is None and ok.results[0].perf_class == PerfClass.SPEEDUP


def test_unknown_workload_and_chip_are_captured_not_raised():
    svc = AnalysisService(cache=ArtifactCache())
    bad_wl = svc.submit("kernel/nope")
    bad_chip = svc.submit("kernel/gemm", chips=("warp-core",))
    ok = svc.submit("kernel/gemm")
    svc.run_until_drained()
    assert bad_wl.error and "unknown workload" in bad_wl.error
    assert bad_chip.error and "unknown chip" in bad_chip.error
    assert ok.error is None and ok.results[0].perf_class == PerfClass.SPEEDUP
    report = svc.report()
    assert report["service"]["errors"] == 2
    assert report["service"]["requests"] == 3


def test_report_is_json_serializable_trajectory_point():
    svc = AnalysisService(max_batch=4, jobs=2, cache=ArtifactCache())
    svc.submit("kernel/gemm", chips=("grace-core", "tpu-v5e"))
    svc.run_until_drained()
    report = json.loads(json.dumps(svc.report()))
    assert report["kind"] == "analysis_service_report"
    assert report["schema"] == 1  # versioned so consumers can evolve
    # perf-ledger context: an isolated test store holds no trajectory yet
    assert set(report["trajectory"]) == {"runs", "latest_run_id", "series"}
    svc_stats = report["service"]
    for key in ("requests", "cells", "waves", "wall_s", "compiles",
                "store_hits", "jobs", "errors"):
        assert key in svc_stats
    assert svc_stats["cells"] == 2
    rows = report["requests"][0]["results"]
    assert rows[0]["workload"] == "kernel/gemm"
    assert {r["chip"] for r in rows} == {"grace-core", "tpu-v5e"}


def test_parallel_wave_matches_serial_wave():
    names = ["kernel/gemm", "kernel/spmv", "kernel/jacobi2d"]

    def drain(jobs):
        svc = AnalysisService(max_batch=8, jobs=jobs, cache=ArtifactCache())
        for n in names:
            svc.submit(n, chips=("grace-core", "grace-socket"))
        svc.run_until_drained()
        return [r.to_dict() for req in svc.completed.values()
                for r in req.results]

    serial, parallel = drain(1), drain(4)
    assert parallel == serial


def test_resubmitting_request_object_gets_fresh_uid():
    svc = AnalysisService(cache=ArtifactCache())
    req = AnalysisRequest(uid=-1, workload="kernel/gemm")
    out = svc.submit(req)
    assert out is req and req.uid == 0


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_emits_json_report(tmp_path, capsys):
    out = tmp_path / "report.json"
    rc = main(["--workloads", "kernel/gemm", "kernel/stream-triad",
               "--chips", "grace-core", "--no-store", "--jobs", "2",
               "--out", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["service"]["requests"] == 2
    table = capsys.readouterr().err
    assert "kernel/gemm" in table  # the human-readable table went to stderr


def test_cli_record_lands_in_the_served_series(tmp_path, monkeypatch):
    """--record stamps the RunEnv with the dtype actually served (here the
    --dtypes override), so series-scoped gate/baseline lookups find it."""
    monkeypatch.setenv("REPRO_ARTIFACT_DIR", str(tmp_path))
    out = tmp_path / "report.json"
    rc = main(["--workloads", "kernel/gemm", "--chips", "grace-core",
               "--dtypes", "bf16", "--no-store", "--record",
               "--out", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["schema"] == 1
    from repro.perf import default_ledger

    (run,) = default_ledger().runs()
    assert report["run_id"] == run.run_id  # stamped into the payload
    assert run.env.series_key() == "grace-core/bf16"
    assert set(run.metrics) == {"kernel/gemm@grace-core/bf16"}
    assert report["trajectory"]["runs"] == 1  # refreshed post-record


def test_cli_record_rides_store_dir_not_global_state(tmp_path, monkeypatch):
    """--store-dir isolates the trajectory too: runs land in (and the
    report's trajectory block reads) <store-dir>/perf, not the shared
    default ledger."""
    from repro.perf import Ledger, default_ledger

    monkeypatch.setenv("REPRO_ARTIFACT_DIR", str(tmp_path / "default"))
    out = tmp_path / "report.json"
    rc = main(["--workloads", "kernel/gemm", "--chips", "grace-core",
               "--store-dir", str(tmp_path / "proj"), "--record",
               "--out", str(out)])
    assert rc == 0
    assert default_ledger().runs() == []  # global ledger untouched
    (run,) = Ledger(str(tmp_path / "proj" / "perf")).runs()
    assert json.loads(out.read_text())["run_id"] == run.run_id


def test_cli_rejects_unknown_workload(capsys):
    rc = main(["--workloads", "kernel/nope", "--no-store"])
    assert rc == 2
    assert "unknown workloads" in capsys.readouterr().err


def test_cli_list(capsys):
    assert main(["--list"]) == 0
    assert "kernel/gemm" in capsys.readouterr().out
