"""Oracle + analytic terms for the flash-decode kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_ref(q, k, v, valid_len):
    """q (B,KV,G,D); k/v (B,S,KV,D); valid_len (B,) -> (B,KV,G,D)."""
    B, KV, G, D = q.shape
    S = k.shape[1]
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(D)
    mask = jnp.arange(S)[None, :] < valid_len[:, None]  # (B, S)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def quantize_rows(x):
    """Per-row symmetric int8 for a pool-layout array ``x`` (..., bs, KV, D):
    one fp32 scale per (block, row), amax over that row's (KV, D) extent —
    the exact formula the commit kernel applies.  Returns (int8, scales)
    with scales shaped like ``x`` minus its last two axes."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-2, -1))
    s = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / s[..., None, None]), -127, 127
    ).astype(jnp.int8)
    return q, s


def dequantize_pool(pool, scale):
    """Widen an int8 pool (n_blocks, bs, KV, D) back to fp32 with its
    per-row scales (n_blocks, bs)."""
    return pool.astype(jnp.float32) * scale[..., None, None]


def decode_paged_ref(q, k_pool, v_pool, block_tables, valid_len,
                     k_scale=None, v_scale=None):
    """Paged oracle: gather each slot's logical view, then run the dense
    reference.  q (B,KV,G,D); k/v_pool (n_blocks, bs, KV, D); block_tables
    (B, nb); valid_len (B,) with every live slot >= 1.  With int8 pools
    pass ``k_scale``/``v_scale`` (n_blocks, bs) and the gather dequantizes
    first — the whole-array analogue of the kernel's per-tile widening."""
    B = q.shape[0]
    nb = block_tables.shape[1]
    bs = k_pool.shape[1]
    if k_scale is not None:
        k_pool = dequantize_pool(k_pool, k_scale)
        v_pool = dequantize_pool(v_pool, v_scale)
    k = k_pool[block_tables].reshape(B, nb * bs, *k_pool.shape[2:])
    v = v_pool[block_tables].reshape(B, nb * bs, *v_pool.shape[2:])
    return decode_ref(q, k, v, valid_len)


def prefill_paged_ref(q, k_new, v_new, k_pool, v_pool, block_tables,
                      q_start, q_len=None, k_scale=None, v_scale=None):
    """Chunked-prefill oracle: scatter the chunk into the pools through the
    block tables, then run dense causal attention over each slot's gathered
    view.  q (B,C,KV,G,D); k/v_new (B,C,KV,D); pools (n_blocks,bs,KV,D);
    block_tables (B,nb); q_start/q_len (B,).  Returns (out, k_pool',
    v_pool') — the same contract as ``flash_prefill_paged`` (rows at or
    past ``q_len`` are neither committed nor defined in the output).  With
    int8 pools pass ``k_scale``/``v_scale``: chunk rows are quantized with
    :func:`quantize_rows` before the scatter, scales scattered alongside,
    and the gathered view dequantized — the return grows to ``(out,
    k_pool', v_pool', k_scale', v_scale')``."""
    B, C, KV, G, D = q.shape
    bs = k_pool.shape[1]
    nb = block_tables.shape[1]
    quantized = k_scale is not None
    if q_len is None:
        q_len = jnp.full((B,), C, jnp.int32)
    pos = q_start[:, None] + jnp.arange(C)[None, :]           # (B, C) global
    blk = jnp.take_along_axis(block_tables, pos // bs, axis=1)
    flat = blk * bs + pos % bs                                # (B, C)
    valid = jnp.arange(C)[None, :] < q_len[:, None]
    kf = k_pool.reshape(-1, KV, D)
    vf = v_pool.reshape(-1, KV, D)
    idx = jnp.where(valid, flat, kf.shape[0]).reshape(-1)     # OOB rows drop
    k_rows, v_rows = k_new, v_new
    if quantized:
        k_rows, ks_rows = quantize_rows(k_new)
        v_rows, vs_rows = quantize_rows(v_new)
        k_scale2 = k_scale.reshape(-1).at[idx].set(
            ks_rows.reshape(-1), mode="drop").reshape(k_scale.shape)
        v_scale2 = v_scale.reshape(-1).at[idx].set(
            vs_rows.reshape(-1), mode="drop").reshape(v_scale.shape)
    kf = kf.at[idx].set(k_rows.reshape(-1, KV, D), mode="drop")
    vf = vf.at[idx].set(v_rows.reshape(-1, KV, D), mode="drop")
    k_pool2 = kf.reshape(k_pool.shape)
    v_pool2 = vf.reshape(v_pool.shape)

    kd, vd = k_pool2, v_pool2
    if quantized:
        kd = dequantize_pool(k_pool2, k_scale2)
        vd = dequantize_pool(v_pool2, v_scale2)
    k = kd[block_tables].reshape(B, nb * bs, KV, D)
    v = vd[block_tables].reshape(B, nb * bs, KV, D)
    s = jnp.einsum("bckgd,bskd->bckgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    j = jnp.arange(nb * bs)
    causal = j[None, None, :] <= (pos[:, :, None])            # key <= q pos
    s = jnp.where(causal[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bckgs,bskd->bckgd", p, v.astype(jnp.float32))
    out = out.astype(q.dtype)
    if quantized:
        return out, k_pool2, v_pool2, k_scale2, v_scale2
    return out, k_pool2, v_pool2


def prefill_flops_bytes(B, C, KV, G, D, q_start, dtype_bytes: int = 2) -> dict:
    """Per chunk: every query row attends its causal prefix; traffic = the
    committed chunk write plus the live K+V reads up to each row."""
    live = float(sum(int(s) * C + C * (C + 1) / 2 for s in q_start))
    flops = 4.0 * KV * G * D * live
    bytes_ = 2.0 * KV * D * dtype_bytes * (live + B * C)
    return {"flops": flops, "bytes": bytes_,
            "ai": flops / bytes_ if bytes_ else 0}


def flops_bytes(B, KV, G, D, valid_len, dtype_bytes: int = 2) -> dict:
    """Per decode step: 2*2*H*D flops per live cache token; traffic = live
    K+V reads (the q/output traffic is negligible)."""
    live = float(sum(int(v) for v in valid_len))
    flops = 4.0 * KV * G * D * live
    bytes_ = 2.0 * KV * D * dtype_bytes * live
    return {"flops": flops, "bytes": bytes_, "ai": flops / bytes_ if bytes_ else 0}


def issue_counts(valid_len, S: int, block_s: int) -> dict:
    """Predicated vs fixed-width block issues (the SVE lesson at token level)."""
    import math as m

    pred = sum(m.ceil(max(int(v), 1) / block_s) for v in valid_len)
    fixed = len(valid_len) * (S // block_s)
    return {"predicated": pred, "fixed": fixed,
            "r_issue": fixed / pred if pred else 0.0}
