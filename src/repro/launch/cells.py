"""Cell = (architecture x input shape x mesh): spec building, AOT lowering,
and artifact analysis shared by the dry-run, the roofline table, and the
perf-iteration harness.

Nothing here allocates device memory: params/optimizer/cache stand-ins are
ShapeDtypeStructs (built with ``jax.eval_shape``) carrying NamedShardings,
and cells are only ``.lower()``-ed and ``.compile()``-d.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.core import counters as counters_mod
from repro.core import hw
from repro.core import roofline as roofline_mod
from repro.distributed import context as mesh_ctx
from repro.distributed import sharding as shard_rules
from repro.optim import adamw
from repro.train import steps as steps_mod


def _with_plan(fn, plan):
    """Activate the mesh plan during TRACING of fn (sharding constraints in
    model code read it via contextvar)."""

    def wrapped(*args, **kwargs):
        with mesh_ctx.use_plan(plan):
            return fn(*args, **kwargs)

    return wrapped


# Large archs that need reduced-precision optimizer state to fit HBM
# (Gopher-style bf16 Adam moments; recorded in EXPERIMENTS.md).
_BF16_STATE_ARCHS = {"jamba-1.5-large-398b"}


def run_config_for(arch: str, shape: ShapeConfig, *, baseline: bool = False) -> steps_mod.RunConfig:
    if shape.kind != "train":
        return steps_mod.RunConfig(remat="none", zero=False)
    opt = adamw.AdamWConfig()
    if arch in _BF16_STATE_ARCHS:
        opt = dataclasses.replace(opt, state_dtype="bfloat16", master_weights=False)
    if baseline:
        # paper-faithful baseline posture: full remat, no ZeRO
        return steps_mod.RunConfig(remat="full", zero=False, opt=opt)
    return steps_mod.RunConfig(remat="full", zero=True, opt=opt)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    run: steps_mod.RunConfig
    fn: Callable
    args: Tuple[Any, ...]  # ShapeDtypeStructs with shardings attached
    donate: Tuple[int, ...]
    model_flops: float
    out_shardings: Any = None
    dtype: str = "bf16"

    @property
    def name(self) -> str:
        return f"{self.arch}@{self.shape.name}"


def _output_shardings(cfg: ModelConfig, out_spec, mesh: Mesh, batch: int):
    """Constrain step outputs: without this, GSPMD is free to replicate the
    prefill cache / logits (observed: 119 GB/device on qwen3-32b prefill)."""
    vp = cfg.vocab_padded

    def f(path, leaf):
        pstr = shard_rules._path_str(path)
        shape = leaf.shape
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        if shape[-1] == vp and len(shape) >= 2:
            spec = shard_rules.batch_spec(mesh, batch, len(shape))
            dims = list(spec) + [None] * (len(shape) - len(spec))
            if shape[-1] % axis_size_model(mesh) == 0:
                dims[-1] = "model"
            return NamedSharding(mesh, P(*dims))
        if len(shape) >= 3 or "cache" in pstr or "state" in pstr:
            return NamedSharding(
                mesh, shard_rules._cache_spec(pstr, shape, mesh, batch)
            )
        return NamedSharding(mesh, shard_rules.batch_spec(mesh, batch, len(shape)))

    return jax.tree_util.tree_map_with_path(f, out_spec)


def axis_size_model(mesh: Mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


def _attach(spec_tree, shard_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        spec_tree,
        shard_tree,
    )


def _opt_shardings(opt_spec, p_shardings, mesh: Mesh, *, zero: bool):
    """Mirror param shardings onto m/v/master; ZeRO-extend over data axes."""

    def build(sub):
        def f(p_sh, leaf):
            if not zero:
                return p_sh
            spec = shard_rules.zero_shard_spec(p_sh.spec, leaf.shape, mesh)
            return NamedSharding(mesh, spec)

        return jax.tree.map(f, p_shardings, sub)

    out = {"step": NamedSharding(mesh, P())}
    for k in ("m", "v", "master"):
        if k in opt_spec:
            out[k] = build(opt_spec[k])
    return out


def build_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    *,
    baseline: bool = False,
    run_override: Optional[steps_mod.RunConfig] = None,
) -> Cell:
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    if not configs.shape_applicable(cfg, shape):
        raise ValueError(f"{arch} x {shape_name}: skipped (full-attention @ 500k)")
    run = run_override or run_config_for(arch, shape, baseline=baseline)
    # Beyond-paper distribution optimizations ride the optimized variant
    # only.  Sequence parallelism is TRAIN-ONLY and non-MoE:
    #  * MoE: the EP entry is batch-split; a seq-sharded residual costs an
    #    all-gather per MoE layer (measured +0.27s, §Perf iter A3c);
    #  * prefill: the chunked-attention scans interact badly with a
    #    seq-sharded residual (measured 9-12x compute blowup on the 32k
    #    prefill cells, §Perf iter C2 — refuted hypothesis, reverted);
    #  * train: keeps the win — half TP volume AND model-sharded remat
    #    residuals (qwen3-32b train 151->39 GB/device).
    # (A residual-level SP-on-prefill exception for non-dividing head counts
    # was tried and REFUTED: the constraint does not reach the flash-tile
    # interior, so whisper-prefill's replicated attention is unchanged —
    # EXPERIMENTS.md §Perf C3.  The real fix is a context-parallel attention
    # schedule inside the kernel; recorded as the top un-taken lever.)
    plan = mesh_ctx.plan_for_mesh(
        mesh,
        seq_parallel=(not baseline and shape.kind == "train"
                      and cfg.moe is None),
        moe_impl="global" if baseline else "shard_map",
    )

    key = jax.random.PRNGKey(0)
    params_spec = jax.eval_shape(lambda: steps_mod.init_model(key, cfg))
    p_shardings = shard_rules.param_shardings(params_spec, mesh)
    params_in = _attach(params_spec, p_shardings)

    in_specs = configs.input_specs(cfg, shape)
    in_shardings = shard_rules.input_shardings(
        in_specs, mesh, batch=shape.global_batch
    )
    inputs_in = _attach(in_specs, in_shardings)

    n_active = cfg.active_param_count()

    if shape.kind == "train":
        opt_spec = jax.eval_shape(lambda p: adamw.init_opt_state(p, run.opt), params_spec)
        o_shardings = _opt_shardings(opt_spec, p_shardings, mesh, zero=run.zero)
        opt_in = _attach(opt_spec, o_shardings)
        train_fn = _with_plan(steps_mod.make_train_step(cfg, run), plan)
        fn = lambda p, o, b: train_fn(p, o, b)  # noqa: E731
        model_flops = roofline_mod.model_flops_cell(cfg, shape)
        metrics_spec = jax.eval_shape(fn, params_in, opt_in, inputs_in)[2]
        out_sh = (p_shardings, o_shardings,
                  jax.tree.map(lambda _: NamedSharding(mesh, P()), metrics_spec))
        return Cell(arch, shape, cfg, run, fn, (params_in, opt_in, inputs_in),
                    donate=(0, 1), model_flops=model_flops, out_shardings=out_sh)

    if shape.kind == "prefill":
        pf = _with_plan(steps_mod.make_prefill_step(cfg, run), plan)
        fn = lambda p, b: pf(p, **b)  # noqa: E731
        model_flops = roofline_mod.model_flops_cell(cfg, shape)
        out_spec = jax.eval_shape(fn, params_in, inputs_in)
        out_sh = _output_shardings(cfg, out_spec, mesh, shape.global_batch)
        return Cell(arch, shape, cfg, run, fn, (params_in, inputs_in),
                    donate=(), model_flops=model_flops, out_shardings=out_sh)

    # decode
    dec = _with_plan(steps_mod.make_decode_step(cfg, run), plan)
    fn = lambda p, b: dec(p, **b)  # noqa: E731
    model_flops = roofline_mod.model_flops_cell(cfg, shape)
    out_spec = jax.eval_shape(fn, params_in, inputs_in)
    out_sh = _output_shardings(cfg, out_spec, mesh, shape.global_batch)
    return Cell(arch, shape, cfg, run, fn, (params_in, inputs_in),
                donate=(1,), model_flops=model_flops, out_shardings=out_sh)


def lower_cell(cell: Cell, mesh: Mesh):
    jitted = jax.jit(
        cell.fn, donate_argnums=cell.donate, out_shardings=cell.out_shardings
    )
    with mesh:
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    return lowered, compiled


def _tree_bytes(tree) -> float:
    total = 0.0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            n = 1
            for s in leaf.shape:
                n *= int(s)
            total += n * jnp.dtype(leaf.dtype).itemsize
    return total


def analytic_hbm_bytes(cell: Cell) -> Dict[str, float]:
    """GLOBAL HBM-traffic model for one step (TPU-target semantics).

    The structural HLO traffic count is kept as a *diagnostic* (see
    ``events.hlo_traffic_bytes``): the pure-jnp chunked attention/SSD paths
    materialize per-tile intermediates that the production Pallas kernels
    keep in VMEM, so raw HLO bytes overstate the target machine's HBM
    traffic.  This model charges what MUST move on the TPU:

      * weights     — read per pass (fwd + remat recompute + grad-weight
                      pass for training), grads written+read, params written
      * optimizer   — moments/master read+write (exact spec byte-sums)
      * activations — residual-stream reads/writes per layer boundary
      * caches      — decode reads the full KV/SSM cache every step;
                      prefill writes it once
      * logits      — fp32 logit write (+read in bwd) and embedding gathers
    """
    cfg, shape = cell.cfg, cell.shape
    L, d = cfg.n_layers, cfg.d_model
    pbytes = _tree_bytes(cell.args[0])
    # MoE decode with tiny batches touches only the activated experts
    if (cfg.moe is not None and shape.kind == "decode"
            and shape.global_batch * cfg.moe.top_k < cfg.moe.n_routed):
        pbytes *= cfg.active_param_count() / cfg.param_count()
    T = shape.tokens if shape.kind != "decode" else shape.global_batch
    act_unit = T * d * 2.0  # bf16 residual stream
    logit_bytes = T * cfg.vocab_padded * 4.0
    out: Dict[str, float] = {}
    if shape.kind == "train":
        opt_bytes = _tree_bytes(cell.args[1])
        out["weights"] = 6.0 * pbytes  # 3 weight reads + grad w/r + param write
        out["optimizer"] = 2.0 * opt_bytes
        out["activations"] = 8.0 * L * act_unit
        out["logits"] = 2.0 * logit_bytes + 4.0 * T * d * 2.0
    elif shape.kind == "prefill":
        out["weights"] = pbytes
        out["activations"] = 4.0 * L * act_unit  # includes the cache write
        out["logits"] = shape.global_batch * cfg.vocab_padded * 4.0
    else:  # decode
        cache_bytes = 0.0
        if len(cell.args) > 1 and isinstance(cell.args[1], dict):
            cache_bytes = _tree_bytes(cell.args[1].get("cache", {}))
        out["weights"] = pbytes
        out["cache_read"] = cache_bytes
        out["activations"] = 8.0 * L * act_unit
        out["logits"] = logit_bytes
    out["total"] = float(sum(out.values()))
    return out


def analyze_cell(cell: Cell, mesh: Mesh, compiled, chip: hw.ChipSpec = hw.TPU_V5E):
    """Events + three-term roofline + SVE classification for a compiled cell.

    compute & collective terms: while-aware structural HLO model
    (core.hlo_cost); memory term: analytic TPU-traffic model
    (``analytic_hbm_bytes``), with the raw structural HLO traffic kept as a
    diagnostic in events.  The per-cell Eq.-1/Fig.-8 report rides the
    unified pipeline (``repro.analysis.analyze_events``) on the adjusted
    events.
    """
    from repro.analysis import analyze_events

    hlo_text = compiled.as_text()
    chips = mesh.size
    events = counters_mod.events_from_compiled(
        compiled, hlo_text=hlo_text, n_devices=chips
    )
    analytic_mem = analytic_hbm_bytes(cell)
    hlo_traffic = events.bytes_accessed
    events.hlo_traffic_bytes = hlo_traffic
    events.bytes_accessed = analytic_mem["total"]
    events.hbm_read_bytes = analytic_mem["total"] * 0.6
    terms = roofline_mod.three_term(
        events, chip, chips, dtype=cell.dtype, model_flops=cell.model_flops
    )
    sve = analyze_events(cell.name, events, chip, dtype=cell.dtype)
    mem = compiled.memory_analysis()
    return {
        "sve": {
            "perf_class": int(sve.perf_class),
            "perf_class_name": sve.perf_class.name,
            "vb": sve.vb,
            "r_ins": sve.r_ins,
            "ai": sve.ai,
            "ai_inflection": sve.ai_inflection,
            "bound": sve.bound,
            "rationale": sve.decision.rationale,
        },
        "cell": cell.name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips,
        "events": events.to_dict(),
        "roofline": terms.to_dict(),
        "analytic_memory": analytic_mem,
        "hlo_traffic_bytes": hlo_traffic,
        "memory_per_device": {
            "argument_bytes": float(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": float(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": float(getattr(mem, "temp_size_in_bytes", 0)),
            "code_bytes": float(getattr(mem, "generated_code_size_in_bytes", 0)),
            "total_gb": (
                float(getattr(mem, "argument_size_in_bytes", 0))
                + float(getattr(mem, "output_size_in_bytes", 0))
                + float(getattr(mem, "temp_size_in_bytes", 0))
            ) / 1e9,
            "fits_16gb_hbm": (
                float(getattr(mem, "argument_size_in_bytes", 0))
                + float(getattr(mem, "output_size_in_bytes", 0))
                + float(getattr(mem, "temp_size_in_bytes", 0))
            ) < 16e9,
        },
    }
