from repro.train.steps import (  # noqa: F401
    BASELINE_RUN,
    OPTIMIZED_RUN,
    RunConfig,
    init_model,
    loss_fn,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    model_forward,
)
