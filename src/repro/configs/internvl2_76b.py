"""InternVL2-76B — ViT frontend (stub) + InternLM2-76B dense LM backbone.

[arXiv:2404.16821; unverified]  80L, d_model=8192, 64H (GQA kv=8),
d_ff=28672, vocab=128256.  The InternViT-6B vision tower is a STUB per the
assignment: ``input_specs`` provides precomputed patch embeddings
(B, 256, d_model); text tokens fill the remaining sequence.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    n_img_tokens=256,
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    n_img_tokens=8,
    param_dtype="float32",
    compute_dtype="float32",
)
