"""The roofline-guided autotuner (repro.tuning).

ISSUE-3 contracts: spaces enumerate/clamp/VMEM-filter candidates
deterministically; roofline pruning is monotone (more predicted traffic is
never predicted faster); ``tune()`` persists a TuningRecord and a second
*process* tuning the same (kernel, chip, dtype) performs zero timing runs;
corrupt records are dropped and re-tuned, never raised; KernelOps resolves
tuned configs at call time with explicit kwargs winning; and the legacy
``gemm/ops.py`` tile heuristic is behavior-pinned onto the shared path.
"""

import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.store import ArtifactStore
from repro.core import hw
from repro.core.roofline import adapted_roofline
from repro.kernels.registry import get_kernel
from repro.tuning import (
    TuningRecord,
    load_record,
    load_tuned,
    outlook,
    predicted_time_s,
    prune,
    save_record,
    timing_runs,
    tunable_kernels,
    tune,
    tune_kernels,
    tuning_fingerprint,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _gemm_args(n=128, dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, n), dtype)
    y = jax.random.normal(jax.random.PRNGKey(1), (n, n), dtype)
    return (x, y)


@pytest.fixture
def gemm_ops():
    ops = get_kernel("gemm")
    ops.clear_tuned()
    yield ops
    ops.clear_tuned()


# ---------------------------------------------------------------------------
# TuningSpace enumeration
# ---------------------------------------------------------------------------


def test_gemm_space_candidates_clamp_dedup_and_divide(gemm_ops):
    space = gemm_ops.tuning_space
    args = _gemm_args(256)
    cands = space.candidates(args)
    # 512-valued axes clamp onto 256 and dedupe: {256,128}^3
    assert len(cands) == 8
    for cfg in cands:
        assert 256 % cfg["bm"] == 0 and 256 % cfg["bn"] == 0 and 256 % cfg["bk"] == 0
    # deterministic enumeration order: first candidate is the largest tiles
    assert cands[0] == {"bm": 256, "bn": 256, "bk": 256}


def test_space_vmem_budget_filters_candidates(gemm_ops):
    space = gemm_ops.tuning_space
    args = _gemm_args(256)
    # 256^3 tiles need vmem_bytes(256,256,256,4) = 1.25 MiB at fp32; a
    # budget below that must eliminate every 256-wide bm/bn pair
    tight = dataclasses.replace(space, vmem_budget=800_000)
    cands = tight.candidates(args)
    assert cands and all(
        space.vmem_model({**c}, args, 4) <= 800_000 for c in cands
    )
    assert {"bm": 256, "bn": 256, "bk": 256} not in cands


def test_space_subset_caps_axes(gemm_ops):
    tiny = gemm_ops.tuning_space.subset(1)
    assert all(len(v) == 1 for v in tiny.axes.values())
    assert tiny.size() == 1  # 1 per axis, dtypes capped to 1 too
    assert tiny.token() != gemm_ops.tuning_space.token()  # re-tunes


def test_validate_rejects_non_dividing_config(gemm_ops):
    space = gemm_ops.tuning_space
    args = _gemm_args(256)
    assert space.validate({"bm": 192, "bn": 192, "bk": 192}, args) is None
    ok = space.validate({"bm": 512, "bn": 128, "bk": 512}, args)
    assert ok == {"bm": 256, "bn": 128, "bk": 256}  # clamped to the problem


# ---------------------------------------------------------------------------
# Roofline pruning monotonicity
# ---------------------------------------------------------------------------


def test_pruning_monotone():
    """The Eq.-2 score never ranks a config with more predicted traffic (or
    more FLOPs) ahead of one with less — the safety property that makes
    analytic pruning sound."""
    rl = adapted_roofline(hw.GRACE_CORE, "fp32")
    times = [predicted_time_s(1e9, b, rl) for b in (1e3, 1e6, 1e9, 1e12)]
    assert times == sorted(times)
    times_f = [predicted_time_s(f, 1e6, rl) for f in (1e6, 1e9, 1e12)]
    assert times_f == sorted(times_f)


def test_prune_orders_by_predicted_time_and_counts(gemm_ops):
    space = gemm_ops.tuning_space
    args = _gemm_args(256)
    survivors, pruned = prune(space, args, hw.GRACE_CORE, "fp32", keep=3)
    assert len(survivors) == 3 and pruned == 5  # 8 candidates total
    scores = [s for _, s in survivors]
    assert scores == sorted(scores)
    # keep >= candidates: nothing pruned
    all_s, none_pruned = prune(space, args, hw.GRACE_CORE, "fp32", keep=100)
    assert none_pruned == 0 and len(all_s) == 8


def test_gemm_larger_tiles_predict_less_traffic(gemm_ops):
    """The GEMM traffic model (x re-streamed per bn tile of y and vice
    versa) must make the roofline prefer larger tiles in the memory term."""
    space = gemm_ops.tuning_space
    args = _gemm_args(512)
    big = space.traffic_model({"bm": 256, "bn": 256, "bk": 128}, args)
    small = space.traffic_model({"bm": 128, "bn": 128, "bk": 128}, args)
    assert big < small


# ---------------------------------------------------------------------------
# tune(): records, persistence, defaults
# ---------------------------------------------------------------------------


def test_tune_returns_valid_persisted_record(tmp_path, gemm_ops):
    args = _gemm_args(128)
    rec = tune(gemm_ops, args, store=str(tmp_path), keep=2, repeats=1)
    assert isinstance(rec, TuningRecord) and not rec.cached
    assert rec.kernel == "gemm" and rec.chip == "grace-core" and rec.dtype == "fp32"
    assert rec.config in gemm_ops.tuning_space.candidates(args)
    assert rec.best_time_s > 0 and rec.speedup_vs_default >= 1.0
    assert rec.timed >= 1 and rec.mode == "interpret"
    store = ArtifactStore(str(tmp_path))
    assert store.entries() == {rec.fingerprint: "gemm"}


def test_tune_counts_are_consistent(tmp_path, gemm_ops):
    args = _gemm_args(256)
    rec = tune(gemm_ops, args, store=str(tmp_path), keep=3, repeats=1)
    assert rec.candidates == 8
    assert rec.pruned == 5
    # 3 survivors timed, +1 if the default config was not among them
    assert rec.timed in (3, 4)


def test_tune_same_process_store_hit_is_timing_free(tmp_path, gemm_ops):
    args = _gemm_args(128)
    first = tune(gemm_ops, args, store=str(tmp_path), keep=2, repeats=1)
    n = timing_runs()
    second = tune(gemm_ops, args, store=str(tmp_path), keep=2, repeats=1)
    assert second.cached and not first.cached
    assert second.config == first.config
    assert timing_runs() == n  # zero timing runs on the hit
    third = tune(gemm_ops, args, store=str(tmp_path), keep=2, repeats=1,
                 force=True)
    assert not third.cached and timing_runs() > n  # force re-times


def test_tune_never_ships_worse_than_default(tmp_path, gemm_ops):
    rec = tune(gemm_ops, _gemm_args(128), store=str(tmp_path), keep=2,
               repeats=1)
    assert rec.best_time_s <= rec.default_time_s


def test_tune_with_invalid_default_uses_best_survivor_as_baseline(tmp_path):
    """A problem the kernel's hard-coded default does not divide must not
    crash the default-baseline timing (the default is simply inapplicable)."""
    ops = get_kernel("stream-triad")
    ops.clear_tuned()
    try:
        a = jnp.ones((320, 128), jnp.float32)
        b = jnp.ones((320, 128), jnp.float32)
        # default block_rows=256 does not divide 320; survivors (320/64/32/8) do
        rec = tune(ops, (a, b, 3.0), store=str(tmp_path), keep=2, repeats=1)
        assert 320 % rec.config["block_rows"] == 0
        assert rec.default_config == rec.config  # best doubles as baseline
        assert rec.speedup_vs_default == 1.0
    finally:
        ops.clear_tuned()


def test_store_stamps_win_over_payload_keys(tmp_path):
    """put_json must not let a colliding payload key poison the version
    stamp (which would turn the entry into a permanent corrupt-drop miss)."""
    store = ArtifactStore(str(tmp_path))
    store.put_json("aa" * 16, {"version": 99, "fingerprint": "spoof", "x": 1})
    back = store.get_json("aa" * 16)
    assert back is not None and back["x"] == 1
    assert back["fingerprint"] == "aa" * 16 and store.dropped_corrupt == 0


def test_tune_dtype_axis_changes_fingerprint_and_casts(tmp_path, gemm_ops):
    args = _gemm_args(128)
    r32 = tune(gemm_ops, args, store=str(tmp_path), keep=1, repeats=1)
    r16 = tune(gemm_ops, args, dtype="bf16", store=str(tmp_path), keep=1,
               repeats=1)
    assert r16.dtype == "bf16" and r16.fingerprint != r32.fingerprint
    assert len(ArtifactStore(str(tmp_path)).entries()) == 2


def test_tune_kernels_sweep_and_jobs(tmp_path):
    recs = tune_kernels(["jacobi2d", "stream-triad"], store=str(tmp_path),
                        keep=2, repeats=1, cap=2, jobs=2)
    assert [r.kernel for r in recs] == ["jacobi2d", "stream-triad"]
    assert all(not r.cached for r in recs)
    again = tune_kernels(["jacobi2d", "stream-triad"], store=str(tmp_path),
                         keep=2, repeats=1, cap=2, jobs=2)
    assert all(r.cached for r in again)
    for name in ("jacobi2d", "stream-triad"):
        get_kernel(name).clear_tuned()


# ---------------------------------------------------------------------------
# Corrupt-record recovery
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("garbage", ["{not json", '{"version": 99}',
                                     '{"version": 1, "tuning_version": 99}'])
def test_corrupt_tuning_record_recovered(tmp_path, gemm_ops, garbage):
    args = _gemm_args(128)
    space = gemm_ops.tuning_space
    fp = tuning_fingerprint("gemm", gemm_ops.raw, args, "grace-core", "fp32",
                            space)
    store = ArtifactStore(str(tmp_path))
    os.makedirs(str(tmp_path), exist_ok=True)
    with open(store.path_for(fp), "w") as f:
        f.write(garbage)
    rec = tune(gemm_ops, args, store=store, keep=2, repeats=1)
    assert not rec.cached and rec.fingerprint == fp  # re-tuned, not raised
    assert store.dropped_corrupt == 1
    # ... and the re-tune healed the entry for the next reader
    healed = load_record(ArtifactStore(str(tmp_path)), fp)
    assert healed is not None and healed.cached and healed.config == rec.config


def test_record_round_trip(tmp_path):
    rec = TuningRecord(
        kernel="k", chip="c", dtype="fp32", fingerprint="f" * 32,
        config={"bm": 256}, default_config={"bm": 128},
        best_time_s=1.0, default_time_s=2.0,
        predicted_best_s=0.5, predicted_default_s=1.0,
        space_size=9, candidates=4, pruned=2, timed=2,
    )
    store = ArtifactStore(str(tmp_path))
    save_record(store, rec)
    back = load_record(store, rec.fingerprint)
    assert back is not None and back.cached
    assert back.config == {"bm": 256} and back.speedup_vs_default == 2.0
    assert json.loads(json.dumps(rec.to_dict())) == rec.to_dict()


# ---------------------------------------------------------------------------
# KernelOps resolution: call-time pickup, explicit kwargs win, repr
# ---------------------------------------------------------------------------


def test_kernelops_resolves_tuned_config_and_explicit_kwargs_win(
    tmp_path, gemm_ops
):
    args = _gemm_args(256)
    rec = tune(gemm_ops, args, store=str(tmp_path), keep=2, repeats=1)
    assert gemm_ops.tuned_config() == rec.config
    assert "tuned[" in repr(gemm_ops) and "grace-core/fp32" in repr(gemm_ops)
    out_tuned = gemm_ops(*args)                       # resolves rec.config
    out_explicit = gemm_ops(*args, bm=128, bn=128, bk=128)
    np.testing.assert_allclose(
        np.asarray(out_tuned), np.asarray(out_explicit), rtol=2e-5, atol=2e-5
    )
    gemm_ops.clear_tuned()
    assert repr(gemm_ops) == "KernelOps('gemm')"


def test_kernelops_drops_config_that_does_not_fit_the_problem(gemm_ops):
    # a nonsense installed config (e.g. tuned on another problem family)
    gemm_ops.set_tuned({"bm": 192, "bn": 192, "bk": 192},
                       chip="grace-core", dtype="fp32")
    args = _gemm_args(256)  # 256 % 192 != 0: config must be dropped
    out = gemm_ops(*args)   # falls back to the kernel's own defaults
    ref = gemm_ops.ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_call_resolves_config_matching_call_dtype(gemm_ops):
    """After a multi-dtype sweep, an fp32 call must resolve the fp32-tuned
    config even when bf16 was tuned (activated) last."""
    gemm_ops.set_tuned({"bm": 128, "bn": 128, "bk": 64},
                       chip="grace-core", dtype="fp32")
    gemm_ops.set_tuned({"bm": 64, "bn": 64, "bk": 64},
                       chip="grace-core", dtype="bf16")  # most recent
    args32 = _gemm_args(128, jnp.float32)
    kw = gemm_ops._tuned_kwargs(args32, {"interpret": True})
    assert (kw["bm"], kw["bk"]) == (128, 64)  # the fp32 entry, not bf16
    args16 = _gemm_args(128, jnp.bfloat16)
    kw16 = gemm_ops._tuned_kwargs(args16, {"interpret": True})
    assert kw16["bm"] == 64


def test_partial_explicit_kwargs_keep_remaining_tuned_axes(gemm_ops):
    """Caller overriding ONE axis must not discard the other tuned axes:
    validation sees the call as it executes (caller values win)."""
    gemm_ops.set_tuned({"bm": 256, "bn": 256, "bk": 256},
                       chip="grace-core", dtype="fp32")
    args = _gemm_args(256)
    kw = gemm_ops._tuned_kwargs(args, {"interpret": True, "bm": 128})
    assert kw["bm"] == 128                    # explicit kwarg untouched
    assert kw["bn"] == 256 and kw["bk"] == 256  # tuned axes still merged


def test_outlook_finds_record_for_non_base_dtype(tmp_path, gemm_ops):
    """The ELEN axis must round-trip through outlook(): tune at bf16 then
    analyze/outlook at bf16 sees the persisted record (args are cast before
    fingerprinting, exactly as tune() casts them)."""
    args = _gemm_args(128)
    rec = tune(gemm_ops, args, dtype="bf16", store=str(tmp_path), keep=1,
               repeats=1)
    o = outlook(gemm_ops, args, hw.GRACE_CORE, dtype="bf16",
                store=str(tmp_path))
    assert o["record"] == rec.config


def test_load_tuned_picks_up_record_without_timing(tmp_path, gemm_ops):
    args = _gemm_args(128)
    rec = tune(gemm_ops, args, store=str(tmp_path), keep=2, repeats=1)
    gemm_ops.clear_tuned()
    n = timing_runs()
    got = load_tuned(gemm_ops, args=args, store=str(tmp_path))
    assert got is not None and got.cached and timing_runs() == n
    assert gemm_ops.tuned_config() == rec.config
    assert load_tuned(gemm_ops, args=_gemm_args(64),
                      store=str(tmp_path)) is None  # other problem: miss


def test_active_config_changes_workload_fingerprint(tmp_path, gemm_ops):
    """fingerprint_extra: a tuned KernelOps must not share compiled-artifact
    store entries with its untuned self."""
    from repro.analysis import Workload, workload_fingerprint

    args = _gemm_args(128)
    wl = Workload(name="fp-gemm", fn=gemm_ops, args=args)
    base = workload_fingerprint(wl)
    gemm_ops.set_tuned({"bm": 64, "bn": 64, "bk": 64},
                       chip="grace-core", dtype="fp32")
    assert workload_fingerprint(wl) != base
    gemm_ops.clear_tuned()
    assert workload_fingerprint(wl) == base


# ---------------------------------------------------------------------------
# analyze() / outlook integration
# ---------------------------------------------------------------------------


def test_analyze_reports_tuning_outlook_for_kernels():
    from repro.analysis import analyze

    result = analyze("kernel/gemm")
    t = result.tuning
    assert t is not None and t["kernel"] == "gemm"
    assert set(t["best_config"]) == {"bm", "bn", "bk"}
    assert t["predicted_speedup"] >= 1.0
    assert "tuning" in result.to_dict() and "tuned" in result.row()
    # non-kernel workloads carry no outlook
    assert analyze("app/STREAM").tuning is None


def test_outlook_surfaces_persisted_record(tmp_path, gemm_ops):
    args = _gemm_args(128)
    assert outlook(gemm_ops, args, hw.GRACE_CORE, dtype="fp32",
                   store=str(tmp_path))["record"] is None
    rec = tune(gemm_ops, args, store=str(tmp_path), keep=2, repeats=1)
    o = outlook(gemm_ops, args, hw.GRACE_CORE, dtype="fp32",
                store=str(tmp_path))
    assert o["record"] == rec.config and o["record_time_s"] == rec.best_time_s


def test_service_report_carries_tuning_block():
    from repro.analysis import ArtifactCache
    from repro.serve.analysis_service import AnalysisService

    svc = AnalysisService(cache=ArtifactCache())
    svc.submit("kernel/gemm", chips=("grace-core",))
    svc.submit("kernel/spmv", chips=("grace-core",))  # no space: absent
    svc.run_until_drained()
    report = svc.report()
    assert "gemm@grace-core/fp32" in report["tuning"]
    assert set(report["tuning"]["gemm@grace-core/fp32"]) == {
        "best_config", "predicted_speedup", "record"
    }
    assert not any(k.startswith("spmv") for k in report["tuning"])


# ---------------------------------------------------------------------------
# the legacy gemm heuristic: behavior-pinned on the shared path
# ---------------------------------------------------------------------------


def test_gemm_pick_tiles_golden():
    """Golden values captured from the pre-refactor ops.py search loop: the
    delegation to repro.tuning.spaces must be behavior-identical."""
    from repro.kernels.gemm import ops as gops

    assert gops.pick_tiles(4096, 4096, 4096) == (512, 512, 1024)
    assert gops.pick_tiles(4096, 4096, 4096, vmem_budget=4 * 2**20) == (512, 512, 1024)
    assert gops.pick_tiles(4096, 4096, 4096, vmem_budget=2 * 2**20) == (512, 512, 256)
    assert gops.pick_tiles(256, 256, 256) == (256, 256, 256)
    assert gops.pick_tiles(1024, 512, 2048, in_bytes=4) == (512, 512, 1024)
    assert gops.vmem_bytes(512, 512, 1024) == 3670016
    assert gops.vmem_bytes(128, 128, 128) == 163840
    assert gops.vmem_bytes(256, 256, 512, 4) == 1572864


def test_gemm_default_shapes_unchanged_by_refactor():
    """The default-shape contract of the old test, kept verbatim."""
    from repro.kernels.gemm import ops as gops

    bm, bn, bk = gops.pick_tiles(4096, 4096, 4096, vmem_budget=4 * 2**20)
    assert gops.vmem_bytes(bm, bn, bk) <= 4 * 2**20
    assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0


# ---------------------------------------------------------------------------
# cross-process: second tune() performs zero timing runs (the acceptance)
# ---------------------------------------------------------------------------


_TUNE_SCRIPT = """
import json
import jax, jax.numpy as jnp
from repro.tuning import timing_runs, tune
x = jax.random.normal(jax.random.PRNGKey(0), (128, 128), jnp.float32)
y = jax.random.normal(jax.random.PRNGKey(1), (128, 128), jnp.float32)
rec = tune("gemm", (x, y), keep=2, repeats=1)
print(json.dumps({"cached": rec.cached, "timing_runs": timing_runs(),
                  "config": rec.config, "fingerprint": rec.fingerprint}))
"""


def test_second_tune_process_performs_zero_timing_runs(tmp_path):
    """The headline acceptance: a fresh process tuning an already-tuned
    (kernel, chip, dtype) gets the record from the store and never times."""
    env = {**os.environ, "PYTHONPATH": "src",
           "REPRO_ARTIFACT_DIR": str(tmp_path)}
    runs = []
    for _ in range(2):
        proc = subprocess.run(
            [sys.executable, "-c", _TUNE_SCRIPT], capture_output=True,
            text=True, env=env, cwd=REPO_ROOT, check=True, timeout=300,
        )
        runs.append(json.loads(proc.stdout.strip().splitlines()[-1]))
    assert runs[0]["cached"] is False and runs[0]["timing_runs"] > 0
    assert runs[1]["cached"] is True and runs[1]["timing_runs"] == 0
    assert runs[0]["config"] == runs[1]["config"]
    assert runs[0]["fingerprint"] == runs[1]["fingerprint"]


# ---------------------------------------------------------------------------
# flash-prefill tuning space (ISSUE-7: the chunked-prefill kernel)
# ---------------------------------------------------------------------------


def _fp_args(B=2, C=16, KV=2, G=4, D=16, bs=8, nb=8):
    """Args shaped like the registry workload (see _flash_prefill_workload)."""
    n_blocks = 1 + B * nb
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, C, KV, G, D), jnp.float32)
    kn = jax.random.normal(ks[1], (B, C, KV, D), jnp.float32)
    vn = jax.random.normal(ks[2], (B, C, KV, D), jnp.float32)
    kp = jax.random.normal(ks[3], (n_blocks, bs, KV, D), jnp.float32)
    vp = jax.random.normal(ks[4], (n_blocks, bs, KV, D), jnp.float32)
    bt = jnp.asarray(1 + np.arange(B * nb).reshape(B, nb), jnp.int32)
    return (q, kn, vn, kp, vp, bt, jnp.asarray((24, 0), jnp.int32))


@pytest.fixture
def fp_ops():
    ops = get_kernel("flash-prefill")
    ops.clear_tuned()
    yield ops
    ops.clear_tuned()


def test_flash_prefill_is_tunable():
    assert "flash-prefill" in tunable_kernels()


def test_flash_prefill_space_clamps_and_rejects(fp_ops):
    space = fp_ops.tuning_space
    args = _fp_args()
    # oversize tiles clamp to the problem (C=16 chunk, bs=8 pool blocks)
    assert space.validate({"block_c": 64, "block_s": 512}, args) == {
        "block_c": 16, "block_s": 8}
    # block_s=0 means one tile per pool block and must survive validation
    assert space.validate({"block_c": 8, "block_s": 0}, args) == {
        "block_c": 8, "block_s": 8}
    # a chunk width that does not divide C is rejected, not silently run
    assert space.validate({"block_c": 3, "block_s": 8}, args) is None
    # every enumerated candidate divides the problem after clamping
    for cfg in space.candidates(args):
        v = space.validate(cfg, args)
        assert v is not None
        assert 16 % v["block_c"] == 0 and 8 % v["block_s"] == 0


def test_flash_prefill_traffic_monotone_in_block_c(fp_ops):
    """Wider query tiles stream the causal KV prefix fewer times, so the
    traffic model must be non-increasing in block_c — the property that
    lets roofline pruning rank candidates soundly."""
    space = fp_ops.tuning_space
    args = _fp_args()
    traffic = [space.traffic_model({"block_c": bc, "block_s": 8}, args)
               for bc in (1, 2, 4, 8, 16)]
    assert all(a >= b for a, b in zip(traffic, traffic[1:])), traffic
    assert traffic[0] > traffic[-1]
    # ... and pruning on this space orders survivors by predicted time
    survivors, pruned = prune(space, args, hw.GRACE_CORE, "fp32", keep=4)
    assert len(survivors) == 4 and pruned > 0
    scores = [s for _, s in survivors]
    assert scores == sorted(scores)


def test_flash_prefill_tune_persists_and_validates(tmp_path, fp_ops):
    """tune() on the prefill kernel returns a problem-valid config and
    persists a record keyed by the prefill fingerprint."""
    store = ArtifactStore(str(tmp_path))
    args = _fp_args()
    rec = tune("flash-prefill", args, keep=2, repeats=1, store=store)
    assert not rec.cached
    assert fp_ops.tuning_space.validate(rec.config, args) == rec.config
    again = tune("flash-prefill", args, keep=2, repeats=1, store=store)
    assert again.cached and again.config == rec.config


_FP_TUNE_SCRIPT = """
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.tuning import timing_runs, tune
B, C, KV, G, D, bs, nb = 2, 16, 2, 4, 16, 8, 8
ks = jax.random.split(jax.random.PRNGKey(0), 5)
args = (
    jax.random.normal(ks[0], (B, C, KV, G, D), jnp.float32),
    jax.random.normal(ks[1], (B, C, KV, D), jnp.float32),
    jax.random.normal(ks[2], (B, C, KV, D), jnp.float32),
    jax.random.normal(ks[3], (1 + B * nb, bs, KV, D), jnp.float32),
    jax.random.normal(ks[4], (1 + B * nb, bs, KV, D), jnp.float32),
    jnp.asarray(1 + np.arange(B * nb).reshape(B, nb), jnp.int32),
    jnp.asarray((24, 0), jnp.int32),
)
rec = tune("flash-prefill", args, keep=2, repeats=1)
print(json.dumps({"cached": rec.cached, "timing_runs": timing_runs(),
                  "config": rec.config, "fingerprint": rec.fingerprint}))
"""


def test_second_prefill_tune_process_performs_zero_timing_runs(tmp_path):
    """Cross-process acceptance for the new kernel: the second process
    loads the persisted record and never times a candidate."""
    env = {**os.environ, "PYTHONPATH": "src",
           "REPRO_ARTIFACT_DIR": str(tmp_path)}
    runs = []
    for _ in range(2):
        proc = subprocess.run(
            [sys.executable, "-c", _FP_TUNE_SCRIPT], capture_output=True,
            text=True, env=env, cwd=REPO_ROOT, check=True, timeout=300,
        )
        runs.append(json.loads(proc.stdout.strip().splitlines()[-1]))
    assert runs[0]["cached"] is False and runs[0]["timing_runs"] > 0
    assert runs[1]["cached"] is True and runs[1]["timing_runs"] == 0
    assert runs[0]["config"] == runs[1]["config"]
    assert runs[0]["fingerprint"] == runs[1]["fingerprint"]
