"""Persistent, content-addressed artifact store for extracted Events.

This is the persistence layer under the paper's Sec. 3.1 counter
methodology: the PMU-analogue events extracted from compiled XLA artifacts
(Table 1) are cached across processes so the counters are collected once
per distinct workload, ever.  Compiling a workload just to read its
PMU-analogue counters is the expensive step of the pipeline (seconds per workload for the LLM cells), and the
counters themselves are tiny, chip-independent JSON.  This module persists
them across *processes*: each workload is keyed by a **fingerprint** of what
actually determines its compiled artifact —

  * the workload name,
  * the abstract shapes/dtypes of its example arguments (values don't reach
    the lowered HLO, shapes do),
  * a structural hash of the callable's bytecode (code, consts, names,
    closure values), and
  * the device count.

so a re-run of ``analyze`` / ``analyze_sweep`` / ``benchmarks.run`` in a
fresh process gets a store hit and performs zero compiles, while changing
an input shape, a dtype, or the function body changes the fingerprint and
forces a recompile.

Storage is one JSON file per fingerprint under a cache directory
(``$REPRO_ARTIFACT_DIR``, default ``~/.cache/repro/artifacts``).  Writes are
atomic (temp file + rename) so parallel sweeps and concurrent processes can
share one directory; unreadable or truncated files are treated as misses
and deleted, never raised.
"""

from __future__ import annotations

import functools
import hashlib
import json
import os
import tempfile
from typing import Any, Dict, Iterator, Optional, Tuple

from repro.core.counters import Events

STORE_VERSION = 1

#: Environment variable overriding the default store directory.
STORE_DIR_ENV = "REPRO_ARTIFACT_DIR"


def _default_dir() -> str:
    return os.environ.get(
        STORE_DIR_ENV,
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "artifacts"),
    )


# ---------------------------------------------------------------------------
# Workload fingerprinting
# ---------------------------------------------------------------------------


def _code_token(fn: Any, parts: list, seen: set) -> None:
    """Append a structural description of ``fn``'s bytecode to ``parts``.

    Uses co_code + names + nested code objects (NOT memory addresses or
    source locations), so the token is stable across processes for the same
    source — including lambdas, which ``__qualname__`` alone cannot
    distinguish.
    """
    code = getattr(fn, "__code__", None)
    if code is None:
        # callables may advertise extra behavioral state (e.g. a KernelOps
        # with an active tuned config changes what a call compiles to)
        extra = getattr(fn, "fingerprint_extra", None)
        if extra:
            parts.append(str(extra))
        # jit wrappers / KernelOps carry the original via __wrapped__
        wrapped = getattr(fn, "__wrapped__", None)
        if wrapped is not None and id(wrapped) not in seen:
            seen.add(id(wrapped))
            _code_token(wrapped, parts, seen)
            return
        parts.append(getattr(fn, "__qualname__", None) or repr(type(fn)))
        return
    stack = [code]
    while stack:
        c = stack.pop()
        if id(c) in seen:
            continue
        seen.add(id(c))
        parts.append(c.co_code.hex())
        parts.append(repr(c.co_names))
        parts.append(repr(c.co_varnames))
        for const in c.co_consts:
            if hasattr(const, "co_code"):
                stack.append(const)
            else:
                parts.append(repr(const))
    closure = getattr(fn, "__closure__", None)
    if closure:
        for cell in closure:
            try:
                _value_token(cell.cell_contents, parts, seen)
            except ValueError:  # empty cell
                parts.append("<empty-cell>")
    # default-argument values are behavior, but live outside co_consts
    for d in getattr(fn, "__defaults__", None) or ():
        _value_token(d, parts, seen)
    for k, v in sorted((getattr(fn, "__kwdefaults__", None) or {}).items()):
        parts.append(k)
        _value_token(v, parts, seen)


def _value_token(value: Any, parts: list, seen: set) -> None:
    """Token for a closure-cell / default / partial-bound value.

    Shaped values (arrays) contribute their abstract (shape, dtype): array
    ``repr`` elides both for large arrays, so two different-shaped captures
    would otherwise collide — and shapes, not values, are what reach the
    lowered HLO.  Callables recurse into their bytecode: their ``repr``
    embeds a memory address, which would make fingerprints process-local.
    """
    if hasattr(value, "shape") and hasattr(value, "dtype"):
        parts.append(_arg_signature(value))
        return
    if callable(value):
        if id(value) not in seen:
            seen.add(id(value))
            _code_token(value, parts, seen)
        return
    parts.append(repr(value)[:256])


def fn_token(fn: Any) -> str:
    """Cross-process-stable identity token for a workload callable."""
    parts: list = []
    seen: set = set()
    f = fn
    while isinstance(f, functools.partial):
        for a in f.args:
            _value_token(a, parts, seen)
        for k, v in sorted((f.keywords or {}).items()):
            parts.append(k)
            _value_token(v, parts, seen)
        f = f.func
    _code_token(f, parts, seen)
    return "|".join(parts)


def _arg_signature(arg: Any) -> str:
    """Abstract (shape, dtype) signature of one example argument."""
    shape = getattr(arg, "shape", None)
    dtype = getattr(arg, "dtype", None)
    if shape is not None:
        return f"{tuple(shape)}:{dtype}"
    if isinstance(arg, dict):
        items = ",".join(f"{k}={_arg_signature(v)}" for k, v in sorted(arg.items()))
        return "{" + items + "}"
    if isinstance(arg, (list, tuple)):
        return "(" + ",".join(_arg_signature(v) for v in arg) + ")"
    return f"{type(arg).__name__}:{arg!r}"


#: Public alias: other content-addressed layers (the tuning-record store)
#: key on the same abstract argument signatures.
arg_signature = _arg_signature


@functools.lru_cache(maxsize=1)
def _compiler_token() -> str:
    """jax/jaxlib versions: a compiler upgrade changes what a compile would
    produce (fusion, traffic, op census), so it must change the address."""
    try:
        import jax
        import jaxlib

        return f"jax={jax.__version__},jaxlib={jaxlib.version.__version__}"
    except Exception:
        return "jax=unknown"


def workload_fingerprint(wl: Any) -> str:
    """Content address of a Workload's compiled-artifact events.

    name + abstract arg shapes/dtypes + fn hash + n_devices + compiler
    version, hex-digested.  Materializes lazy example args (array
    construction) but never compiles.
    """
    h = hashlib.sha256()
    h.update(f"v{STORE_VERSION}|{_compiler_token()}|".encode())
    h.update(f"{wl.name}|n_devices={wl.n_devices}|".encode())
    for a in wl.example_args():
        h.update(_arg_signature(a).encode())
        h.update(b";")
    h.update(fn_token(wl.fn).encode())
    return h.hexdigest()[:32]


# ---------------------------------------------------------------------------
# The store
# ---------------------------------------------------------------------------


class ArtifactStore:
    """Disk-backed, content-addressed map fingerprint -> JSON payload.

    The generic layer is :meth:`get_json` / :meth:`put_json` (one JSON file
    per fingerprint, version-checked, corrupt entries dropped); on top of it
    sit the typed surfaces — :meth:`get`/:meth:`put` for extracted
    :class:`Events`, and the tuning-record store in
    :mod:`repro.tuning.records`, which reuses the same directory layout,
    atomicity, and recovery guarantees for persisted kernel tunings.

    ``hits`` / ``misses`` / ``puts`` / ``dropped_corrupt`` are exposed for
    tests and cost accounting.  All operations tolerate concurrent writers:
    puts go through a temp file + ``os.replace``, and any file that fails to
    parse is removed and reported as a miss.
    """

    def __init__(self, cache_dir: Optional[str] = None) -> None:
        self.cache_dir = cache_dir or _default_dir()
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.dropped_corrupt = 0

    def path_for(self, fingerprint: str) -> str:
        return os.path.join(self.cache_dir, f"{fingerprint}.json")

    def _drop_corrupt(self, path: str) -> None:
        """Remove an unreadable/stale entry and account it as a miss."""
        self.dropped_corrupt += 1
        self.misses += 1
        try:
            os.remove(path)
        except OSError:
            pass

    def discard(self, fingerprint: str) -> None:
        """Corrupt-entry drop for typed layers that fail to decode a payload
        ``get_json`` already accepted: reverses that hit and accounts the
        entry as dropped+missed (callers must not adjust counters)."""
        self.hits -= 1
        self._drop_corrupt(self.path_for(fingerprint))

    def get_json(self, fingerprint: str) -> Optional[Dict[str, Any]]:
        """Raw payload for ``fingerprint`` or None; never raises.

        Corrupt / truncated / stale-version files are deleted and reported
        as misses — the typed layers above recompute and heal the entry.
        """
        path = self.path_for(fingerprint)
        try:
            with open(path) as f:
                payload = json.load(f)
            if payload.get("version") != STORE_VERSION:
                raise ValueError(f"store version {payload.get('version')}")
        except FileNotFoundError:
            self.misses += 1
            return None
        except (ValueError, KeyError, TypeError, OSError):
            self._drop_corrupt(path)
            return None
        self.hits += 1
        return payload

    def get(self, fingerprint: str) -> Optional[Events]:
        payload = self.get_json(fingerprint)
        if payload is None:
            return None
        try:
            return Events.from_dict(payload["events"])
        except (ValueError, KeyError, TypeError):
            self.discard(fingerprint)  # reverses the get_json hit
            return None

    def put_json(self, fingerprint: str, payload: Dict[str, Any]) -> str:
        """Atomically persist ``payload`` (version/fingerprint filled in)."""
        os.makedirs(self.cache_dir, exist_ok=True)
        path = self.path_for(fingerprint)
        # the store's stamps must win over any same-named payload keys, or a
        # colliding key would make every later get_json() drop the entry
        payload = {**payload, "version": STORE_VERSION, "fingerprint": fingerprint}
        fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, path)  # atomic vs concurrent readers/writers
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise
        self.puts += 1
        return path

    def put(self, fingerprint: str, events: Events, *, workload: str = "") -> str:
        return self.put_json(
            fingerprint, {"workload": workload, "events": events.to_dict()}
        )

    def iter_json(self, namespace: str = "") -> Iterator[Tuple[str, Dict[str, Any]]]:
        """Yield ``(fingerprint, payload)`` for every readable entry.

        The enumeration surface for layers that need to *list* their
        records (the perf ledger's trajectory, ``python -m repro.tuning
        --records``, the gate's TuningRecord staleness check) without
        globbing store internals.  ``namespace`` selects a subdirectory of
        this store's ``cache_dir`` (e.g. ``"tuning"`` from the root store);
        empty means the store's own directory.

        Enumeration is read-only and corrupt-*skipping*: a truncated,
        unparseable, or stale-version file is silently passed over, never
        deleted — a concurrent writer may be mid-rename, and listing must
        not race it the way ``get_json``'s self-healing delete may.
        Entries come back in deterministic (filename-sorted) order.
        """
        root = os.path.join(self.cache_dir, namespace) if namespace else self.cache_dir
        try:
            names = os.listdir(root)
        except OSError:
            return
        for fname in sorted(names):
            if not fname.endswith(".json"):
                continue
            try:
                with open(os.path.join(root, fname)) as f:
                    payload = json.load(f)
                if payload.get("version") != STORE_VERSION:
                    continue
                fingerprint = str(payload["fingerprint"])
            except (ValueError, KeyError, TypeError, OSError):
                continue
            yield fingerprint, payload

    def entries(self) -> Dict[str, str]:
        """fingerprint -> workload name for every readable entry."""
        return {
            fp: payload.get("workload", "")
            for fp, payload in self.iter_json()
        }

    def clear(self) -> int:
        """Delete every entry; returns the number of files removed."""
        n = 0
        try:
            names = os.listdir(self.cache_dir)
        except OSError:
            return 0
        for fname in names:
            if fname.endswith(".json"):
                try:
                    os.remove(os.path.join(self.cache_dir, fname))
                    n += 1
                except OSError:
                    pass
        return n

    def __repr__(self) -> str:
        return (
            f"ArtifactStore({self.cache_dir!r}, hits={self.hits}, "
            f"misses={self.misses}, puts={self.puts})"
        )


@functools.lru_cache(maxsize=None)
def _store_for(cache_dir: str) -> ArtifactStore:
    return ArtifactStore(cache_dir)


def default_store() -> ArtifactStore:
    """Process-wide store for the default cache dir.

    Resolves ``$REPRO_ARTIFACT_DIR`` at *call* time (one memoized store per
    directory), so tests can point the default store at a temp dir.
    """
    return _store_for(_default_dir())
