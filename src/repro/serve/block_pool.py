"""Refcounted prefix-sharing block pool — host-side slot accounting.

Real traffic at scale is dominated by requests sharing a handful of
system prompts, so the paged KV pool should store each shared prefix
once.  :class:`BlockPool` owns the free list the continuous scheduler
used to hold directly and adds three things on top:

* **Prefix lookup.**  A physical block whose span lies inside a prompt
  will hold a pure function of that prompt prefix (the per-token cache
  commit depends only on the tokens at and before it), so the pool keys
  blocks by the *exact token chain* they will contain: full spans by
  ``prompt[: (j + 1) * block_size]``, a prompt's ragged last span by
  ``(chain, tail)``.  :meth:`acquire` returns an existing block when a
  new request's span matches — the two slots then write the same bytes
  through the same physical block (duplicate scatters of identical
  values), and each slot's reads stay below its own position, so
  sharing is invisible to the served streams.
* **Refcounts.**  A block is live while any slot's block table points at
  it; :meth:`decref` returns it to the free list (and evicts its lookup
  keys) only at zero — freeing a shared block under a surviving slot is
  exactly the aliasing bug the property tests hammer.
* **Copy-on-write.**  The first *generated* token a slot writes into a
  block other slots still reference diverges the content, so the engine
  calls :meth:`cow` to take a private copy first.  Prompt rows never
  need this: an exact chain match means every sharer write-through
  produces bit-identical bytes.

A partial (ragged last span) entry with registered tail ``T`` may be
shared by a request whose own tail ``t`` satisfies ``t == T[: len(t)]``:
the joiner only ever *reads* rows below its own prompt length, which the
registrant wrote as prompt rows, and any write past a prompt is a
generated row and therefore COWs.  The reverse (``t`` longer than ``T``)
is rejected — the extra rows would collide with the registrant's
generated tokens.  Partial-tail sharing makes one more hook necessary:
once the registrant decrefs away, the shorter-tailed sharer owns the
block alone (refcount 1), so its generated rows land IN PLACE — rows the
registered key still claims as prompt content.  The engine therefore
calls :meth:`note_generated_write` on every in-place generated write,
which trims each registered tail back to the rows still holding the
claimed prompt bytes (evicting keys left claiming nothing), so no later
request can match a stale key and alias diverged content.

Dedup accounting: ``logical_blocks`` counts block-spans *served* (every
acquire, shared or not), ``physical_blocks`` counts blocks *stored*
(every fresh allocation, COW copies included); their ratio is the
block-dedup ratio :func:`repro.core.metrics.block_dedup_ratio` reports —
the memory-side analogue of the paper's Eq. 1 lane utilization.

The free list keeps the engine's original LIFO discipline (``popleft``
to allocate, ``appendleft`` to free) so a sharing-disabled pool is
bit-compatible with the pre-pool scheduler, block ids included.
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Sequence, Tuple

#: reserved null block idle slots harmlessly write into; never allocated
NULL_BLOCK = 0

TokenChain = Tuple[int, ...]
#: reverse-map key descriptors: ("full", chain) or ("partial", chain, tail)
_KeyDesc = Tuple


class BlockPool:
    """Refcounted physical block allocator with optional prefix sharing."""

    def __init__(self, n_blocks: int, block_size: int, *,
                 share_prefixes: bool = False):
        if n_blocks < 2:
            raise ValueError(f"need >= 2 blocks (null + 1), got {n_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.share_prefixes = share_prefixes
        #: LIFO free list (popleft/appendleft), block 0 excluded forever
        self.free: Deque[int] = deque(range(1, n_blocks))
        self.refcount: List[int] = [0] * n_blocks
        # prefix lookup: exact token chain -> physical block
        self._full: Dict[TokenChain, int] = {}
        self._partial: Dict[TokenChain, List[Tuple[TokenChain, int]]] = {}
        self._keys: Dict[int, List[_KeyDesc]] = {}  # block -> registered keys
        # dedup accounting (see module docstring)
        self.logical_blocks = 0
        self.physical_blocks = 0
        self.shared_hits = 0
        self.cow_copies = 0

    # -- core refcounting ------------------------------------------------------

    def alloc(self) -> int:
        """Take a fresh block off the free list (refcount 1)."""
        if not self.free:
            raise RuntimeError(
                f"block pool exhausted: all {self.n_blocks - 1} usable "
                f"blocks are referenced"
            )
        blk = self.free.popleft()
        self.refcount[blk] = 1
        self.logical_blocks += 1
        self.physical_blocks += 1
        return blk

    def incref(self, blk: int) -> None:
        if self.refcount[blk] < 1:
            raise RuntimeError(f"incref on dead block {blk}")
        self.refcount[blk] += 1

    def decref(self, blk: int) -> None:
        """Drop one reference; at zero the block's lookup keys are evicted
        and it returns to the HEAD of the free list (LIFO reuse)."""
        if blk == NULL_BLOCK:
            raise RuntimeError("decref on the null block")
        if self.refcount[blk] < 1:
            raise RuntimeError(f"double free of block {blk}")
        self.refcount[blk] -= 1
        if self.refcount[blk] == 0:
            self._evict_keys(blk)
            self.free.appendleft(blk)

    def refcount_of(self, blk: int) -> int:
        return self.refcount[blk]

    # -- prefix sharing --------------------------------------------------------

    def acquire(self, prompt: Sequence[int], j: int) -> int:
        """Map logical block ``j`` of a slot serving ``prompt``.

        With sharing enabled and the span inside the prompt, an existing
        block holding the same exact chain is returned (refcount bumped)
        instead of a fresh allocation; a fresh allocation registers its
        future content so later identical prompts can share it — even
        slots admitted in the same step, since the joiner writes through
        the same bytes and never reads past its own position.
        """
        bs = self.block_size
        P = len(prompt)
        span_start = j * bs
        if not self.share_prefixes or span_start >= P:
            return self.alloc()  # generated-only span: never shared
        if (j + 1) * bs <= P:  # full prompt span
            chain = _chain(prompt, (j + 1) * bs)
            hit = self._full.get(chain)
            if hit is not None:
                return self._share(hit)
            blk = self.alloc()
            self._full[chain] = blk
            self._keys.setdefault(blk, []).append(("full", chain))
            return blk
        # ragged last prompt span: (chain of full spans, tail)
        chain = _chain(prompt, span_start)
        tail = _chain(prompt, P)[span_start:]
        for reg_tail, blk in self._partial.get(chain, ()):
            if len(tail) <= len(reg_tail) and reg_tail[: len(tail)] == tail:
                return self._share(blk)
        blk = self.alloc()
        self._partial.setdefault(chain, []).append((tail, blk))
        self._keys.setdefault(blk, []).append(("partial", chain, tail))
        return blk

    def cow(self, blk: int) -> int:
        """Copy-on-write: detach from shared ``blk``, return a private
        replacement (the caller copies the device bytes and repoints its
        block table).  No ``logical_blocks`` bump — the span was already
        counted when acquired."""
        if self.refcount[blk] < 2:
            raise RuntimeError(f"cow on unshared block {blk}")
        if not self.free:
            raise RuntimeError(
                f"block pool exhausted: no free block to copy-on-write "
                f"block {blk}"
            )
        new = self.free.popleft()
        self.refcount[new] = 1
        self.physical_blocks += 1
        self.cow_copies += 1
        self.decref(blk)
        return new

    def note_generated_write(self, blk: int, row: int) -> None:
        """A generated-token row just landed in ``blk`` IN PLACE at
        ``row`` (no COW — the writer owns the block alone).

        Rows at and past ``row`` no longer encode any registered prompt
        chain, so every lookup key claiming them is trimmed back to the
        rows still holding the claimed bytes (``tail[:row]``), or
        evicted when nothing valid remains.  Without this, a
        shorter-tailed sharer that outlives the registrant of a partial
        span diverges the block under the registrant's stale key, and a
        later request matching that key would alias — and write-through
        corrupt — the live owner's generated rows.  Idempotent and cheap
        (generated rows only ever extend forward), so the engine calls
        it on every in-place generated write.
        """
        descs = self._keys.get(blk)
        if not descs:
            return  # unregistered (generated-only span or COW copy)
        kept: List[_KeyDesc] = []
        for desc in descs:
            if desc[0] == "full":
                # a full key claims the whole span; by construction full
                # spans lie inside every sharer's prompt and never take
                # generated rows, but evicting is the safe default
                if self._full.get(desc[1]) == blk:
                    del self._full[desc[1]]
                continue
            _, chain, tail = desc
            if len(tail) <= row:  # key claims only rows below the write
                kept.append(desc)
                continue
            entries = self._partial.setdefault(chain, [])
            entries[:] = [e for e in entries
                          if not (e[1] == blk and e[0] == tail)]
            if row > 0:  # rows [0, row) still encode chain + tail[:row]
                entries.append((tail[:row], blk))
                kept.append(("partial", chain, tail[:row]))
            if not entries:
                del self._partial[chain]
        if kept:
            self._keys[blk] = kept
        else:
            self._keys.pop(blk, None)

    def registered_claims(self) -> List[Tuple[TokenChain, int]]:
        """Every ``(token chain, block)`` the prefix registry currently
        claims — a block appears with chain ``c`` iff a request whose
        prompt starts with ``c`` may be handed that block by
        :meth:`acquire`.  White-box oracle for the content-vs-key
        consistency property tests."""
        out: List[Tuple[TokenChain, int]] = list(self._full.items())
        for chain, entries in self._partial.items():
            for tail, blk in entries:
                out.append((chain + tail, blk))
        return out

    def _share(self, blk: int) -> int:
        self.incref(blk)
        self.logical_blocks += 1
        self.shared_hits += 1
        return blk

    def _evict_keys(self, blk: int) -> None:
        for desc in self._keys.pop(blk, ()):
            if desc[0] == "full":
                if self._full.get(desc[1]) == blk:
                    del self._full[desc[1]]
            else:
                entries = self._partial.get(desc[1], [])
                entries[:] = [e for e in entries if e[1] != blk]
                if not entries and desc[1] in self._partial:
                    del self._partial[desc[1]]

    # -- dedup accounting ------------------------------------------------------

    @property
    def dedup_ratio(self) -> float:
        """Bytes served / bytes stored (block-granular, so the byte scale
        cancels); 1.0 with sharing off, > 1.0 once any span is shared."""
        if self.physical_blocks == 0:
            return 1.0
        return self.logical_blocks / self.physical_blocks

    # -- invariants (the property tests' oracle) -------------------------------

    def check_invariants(self) -> None:
        """Raise AssertionError on any broken pool invariant."""
        live = [b for b in range(self.n_blocks) if self.refcount[b] > 0]
        assert NULL_BLOCK not in live, "null block acquired a refcount"
        assert all(c >= 0 for c in self.refcount), "negative refcount"
        free = list(self.free)
        assert len(free) == len(set(free)), f"duplicate free blocks: {free}"
        assert NULL_BLOCK not in free, "null block on the free list"
        assert not set(free) & set(live), (
            f"blocks both free and referenced: {set(free) & set(live)}"
        )
        # conservation: every non-null block is either free or referenced
        assert len(live) + len(free) == self.n_blocks - 1, (
            f"lost blocks: {len(live)} live + {len(free)} free "
            f"!= {self.n_blocks - 1}"
        )
        # the prefix registry never outlives its blocks
        for blk in self._full.values():
            assert self.refcount[blk] >= 1, f"registry holds dead block {blk}"
        for entries in self._partial.values():
            for _, blk in entries:
                assert self.refcount[blk] >= 1, (
                    f"registry holds dead block {blk}"
                )
        # the registry and its reverse map agree in both directions (a
        # one-sided trim/evict would leave a stale key matchable)
        for blk, descs in self._keys.items():
            for desc in descs:
                if desc[0] == "full":
                    assert self._full.get(desc[1]) == blk, (
                        f"reverse map holds full key for {blk} the "
                        f"registry dropped"
                    )
                else:
                    assert (desc[2], blk) in self._partial.get(desc[1], []), (
                        f"reverse map holds partial key for {blk} the "
                        f"registry dropped"
                    )
        for chain, blk in self._full.items():
            assert ("full", chain) in self._keys.get(blk, []), (
                f"full key for {blk} missing from its reverse map"
            )
        for chain, entries in self._partial.items():
            assert entries, f"empty partial entry list for chain {chain}"
            for tail, blk in entries:
                assert ("partial", chain, tail) in self._keys.get(blk, []), (
                    f"partial key for {blk} missing from its reverse map"
                )
        assert self.physical_blocks <= self.logical_blocks, (
            "stored more block-spans than were served"
        )


def _chain(prompt: Sequence[int], end: int) -> TokenChain:
    """Exact token chain key for ``prompt[:end]`` (hashable ints)."""
    return tuple(int(t) for t in prompt[:end])
