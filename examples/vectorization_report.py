"""The paper's methodology as a user-facing tool: point it at ANY jitted
JAX function and get the full SVE-style vectorization report — validated
counters, VB / R_ins, adapted roofline placement, and the Fig. 8 decision
tree — for both the Grace-class CPU model and the TPU target.

All wiring now goes through the unified API: wrap the function in a
``Workload`` and call ``analyze`` (or sweep chips with ``analyze_sweep``,
which compiles each workload exactly once).

    PYTHONPATH=src python examples/vectorization_report.py
"""

import jax
import jax.numpy as jnp

from repro.analysis import ArtifactCache, Workload, analyze_sweep, format_table
from repro.core import hw

CHIPS = (hw.GRACE_CORE, hw.TPU_V5E)


def report(name, fn, args, dtype="fp32", cache=None):
    """One call: compile once, analyze on every chip model."""
    wl = Workload(name=name, fn=fn, args=args, dtype=dtype)
    results = analyze_sweep([wl], chips=CHIPS, cache=cache)
    ev = results[0].events
    print(f"\n### {name}")
    print(f"  flops={ev.flops:.3e}  traffic={ev.bytes_accessed:.3e}B  "
          f"gather={ev.gather_bytes:.3e}B  vec_frac={ev.vectorizable_fraction:.2%} "
          f"mxu_share={ev.mxu_fraction:.2%}")
    print(f"  counter validation: structural flops {ev.flops:.3e} vs "
          f"raw cost_analysis {ev.xla_raw_flops:.3e} "
          f"(scan trip counts: {ev.while_trip_counts or 'none'})")
    print(format_table(results))
    return results


def main():
    n = 512
    a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
    cache = ArtifactCache()

    report("gemm-512", lambda x, y: x @ y, (a, b), cache=cache)

    report("stream-triad", lambda x, y: x + 3.0 * y, (a, b), cache=cache)

    # pointer chasing: the SpMV pattern
    idx = jax.random.randint(jax.random.PRNGKey(2), (n * n,), 0, n * n)
    flat = a.reshape(-1)
    report("gather-reduce", lambda x, i: jnp.take(x, i).sum(), (flat, idx),
           cache=cache)

    # scanned layers: exercises the while-aware counter path
    def scanned(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y
    report("scan-8-layers", scanned, (a,), cache=cache)

    # FFT: not MXU-vectorizable (the paper's FFTW Class-1 case)
    report("fft2d", lambda x, _: jnp.abs(jnp.fft.fft2(x)), (a, b), cache=cache)

    print(f"\n[{cache.compiles} compiles for "
          f"{cache.compiles + cache.hits} analysis cells]")


if __name__ == "__main__":
    main()
