"""Divisibility-aware sharding rules: param pytrees -> NamedSharding pytrees.

Megatron-style tensor parallelism over the ``model`` axis:
  * embeddings / lm_head: vocab over ``model``
  * attention q/k/v projections: output (head) dim over ``model``
  * attention output proj / FFN down proj: input dim over ``model``  (row)
  * FFN up/gate: output dim over ``model``  (column)
  * MoE expert stacks (E, d, f): expert dim over ``model``  (EP)
  * Mamba z/x/dt projections + conv + out_proj: d_inner over ``model``
  * everything else (norms, scalars, routers, B/C projections): replicated

A dim is sharded on an axis only if divisible; otherwise the rule falls back
to the next candidate dim or replication (e.g. whisper's 20-head projections
keep the fused output dim sharded because 20*64=1280 divides 16 even though
20 heads alone would not).

Batch ("data"-parallel) sharding of activations uses all of (pod, data);
ZeRO-style optimizer-state sharding adds those axes to the first divisible
replicated dim of each state tensor.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, data_axes

# (path-regex, candidate specs tried in order; first fully-divisible wins).
# Specs name logical roles; `model` is the TP axis.  Regexes match the
# "/"-joined param path, e.g. "blocks/slot0/attn/wq/w".
_RULES = [
    # attention / mla / dense projections  — column-parallel
    (r"(wq|wk|wv|w_uk|w_uv|wz|wx|wdt|lm_head)/w$", [P(None, "model"), P(None, None)]),
    (r"(wq|wk|wv|wz|wx|wdt)/b$", [P("model"), P(None)]),
    # row-parallel (contracting dim sharded)
    (r"(wo|out_proj)/w$", [P("model", None), P(None, None)]),
    (r"(wo|out_proj)/b$", [P(None)]),
    # embeddings: vocab over model
    (r"embed(ding)?s?/embedding$", [P("model", None), P(None, None)]),
    # MoE expert stacks (E, d, f) / (E, f, d): expert-parallel
    (r"moe/(wi_gate|wi_up|wo)$", [P("model", None, None), P(None, None, None)]),
    (r"moe/router$", [P(None, None)]),
    # dense / shared-expert SwiGLU FFN (raw arrays, not {w,b} dicts)
    (r"(ffn|shared)/(wi_gate|wi_up)$", [P(None, "model"), P(None, None)]),
    (r"(ffn|shared)/wo$", [P("model", None), P(None, None)]),
    # mamba conv + small projections
    (r"conv_x_[wb]$", [P(None, "model"), P(None)]),
    (r"conv_BC_[wb]$", [P(None, None), P(None)]),
    (r"wBC/w$", [P(None, None)]),
    (r"wBC/b$", [P(None)]),
    (r"(A_log|D|dt_bias)$", [P(None)]),
    # kv-down (MLA) small projection
    (r"w_dkv/w$", [P(None, None)]),
    # norms and leftovers: replicate
    (r".*", [P(None)]),
]


def _fits(spec: P, shape, mesh: Mesh) -> bool:
    if len(spec) > len(shape):
        return False
    for dim, axes in zip(shape[-len(spec):] if spec else (), spec):
        if axes is None:
            continue
        names = axes if isinstance(axes, tuple) else (axes,)
        size = 1
        for n in names:
            if n not in mesh.axis_names:
                return False
            size *= axis_size(mesh, n)
        if dim % size != 0:
            return False
    return True


def _pad_spec(spec: P, rank: int) -> P:
    """Left-pad with None for stacked leading axes (scan-over-layers)."""
    pad = rank - len(spec)
    return P(*([None] * pad + list(spec)))


def spec_for_path(path: str, shape, mesh: Mesh) -> P:
    for pattern, candidates in _RULES:
        if re.search(pattern, path):
            for cand in candidates:
                if _fits(cand, shape, mesh):
                    return _pad_spec(cand, len(shape))
            return P(*([None] * len(shape)))
    return P(*([None] * len(shape)))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(params: Any, mesh: Mesh):
    """NamedSharding pytree for a model param pytree."""

    def f(path, leaf):
        spec = spec_for_path(_path_str(path), leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(f, params)


# --------------------------------------------------------------------------
# activations / inputs
# --------------------------------------------------------------------------


def batch_spec(mesh: Mesh, batch: int, rank: int, *, seq_axis: Optional[int] = None,
               seq_len: int = 0) -> P:
    """Shard dim0 (batch) over the data axes; if batch is too small, fall
    back to sharding the sequence dim (long-context decode, batch=1)."""
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= axis_size(mesh, a)
    dims = [None] * rank
    if batch % dp_size == 0:
        dims[0] = dp if len(dp) > 1 else dp[0]
    elif seq_axis is not None and seq_len % dp_size == 0:
        dims[seq_axis] = dp if len(dp) > 1 else dp[0]
    return P(*dims)


def input_shardings(specs: Any, mesh: Mesh, *, batch: int):
    """Shardings for the input_specs pytree (tokens, labels, stubs, caches).

    Caches: batch dim is index 1 (stacked layers lead); when batch doesn't
    divide the data axes (long_500k, B=1), the sequence dim shards instead,
    and SSM states shard their head dim over ``model``.
    """

    def f(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        if "cache" in pstr or "ssm_state" in pstr or "conv_state" in pstr or (
            len(shape) >= 4
        ):
            return NamedSharding(mesh, _cache_spec(pstr, shape, mesh, batch))
        # flat inputs: tokens/labels (B, S), stubs (B, S, d)
        return NamedSharding(mesh, batch_spec(mesh, batch, len(shape)))

    return jax.tree_util.tree_map_with_path(f, specs)


def _cache_spec(pstr: str, shape, mesh: Mesh, batch: int) -> P:
    dp = data_axes(mesh)
    dp_axes = dp if len(dp) > 1 else (dp[0] if dp else None)
    dp_size = 1
    for a in dp:
        dp_size *= axis_size(mesh, a)
    m = axis_size(mesh, "model")
    dims = [None] * len(shape)
    if len(shape) == 0 or "pos" in pstr:
        return P()
    # identify batch axis: stacked caches are (nsb, B, ...), whisper too;
    # non-stacked (first_block) are (B, ...)
    b_axis = 1 if (len(shape) >= 2 and shape[0] != batch and shape[1] == batch) else 0
    if batch % dp_size == 0 and shape[b_axis] == batch:
        dims[b_axis] = dp_axes
        if "ssm_state" in pstr or "conv_state" in pstr:
            # shard heads (ssm) / channels (conv) over model when divisible
            ax = b_axis + 1 if "ssm_state" in pstr else len(shape) - 1
            if shape[ax] % m == 0:
                dims[ax] = "model"
            return P(*dims)
        # attention caches (.., B, S, ...): ALSO shard the long seq dim over
        # `model` — a 549 GB 32k-prefill cache must spread over all chips.
        seq_axis = b_axis + 1
        if len(shape) > seq_axis + 1 and shape[seq_axis] % m == 0:
            dims[seq_axis] = "model"
        return P(*dims)
    # batch too small (long_500k, B=1): shard heads/channels over model for
    # SSM state; shard the seq dim over (data x model) for attention caches
    if "ssm_state" in pstr:
        if shape[b_axis + 1] % m == 0:
            dims[b_axis + 1] = "model"
        return P(*dims)
    if "conv_state" in pstr:
        if shape[-1] % m == 0:
            dims[-1] = "model"
        return P(*dims)
    seq_axis = b_axis + 1
    if len(shape) > seq_axis:
        full = tuple(dp) + ("model",)
        if shape[seq_axis] % (dp_size * m) == 0:
            dims[seq_axis] = full
        elif shape[seq_axis] % dp_size == 0:
            dims[seq_axis] = dp_axes
    return P(*dims)


# --------------------------------------------------------------------------
# ZeRO optimizer-state sharding
# --------------------------------------------------------------------------


def zero_shard_spec(param_spec: P, shape, mesh: Mesh) -> P:
    """Add the data axes to the first unsharded, divisible dim (ZeRO-1/3)."""
    dp = data_axes(mesh)
    if not dp:
        return param_spec
    dp_size = 1
    for a in dp:
        dp_size *= axis_size(mesh, a)
    dims = list(param_spec) + [None] * (len(shape) - len(param_spec))
    for i, (d, s) in enumerate(zip(shape, dims)):
        if s is None and d % dp_size == 0 and d > 0:
            dims[i] = dp if len(dp) > 1 else dp[0]
            return P(*dims)
    return P(*dims)


def opt_state_shardings(params, p_shardings, mesh: Mesh, *, zero: bool = True):
    """Shardings for AdamW state (m, v, master) mirroring param shapes."""

    def f(p_leaf, s_leaf):
        if not zero:
            return s_leaf
        spec = zero_shard_spec(s_leaf.spec, p_leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(f, params, p_shardings)
