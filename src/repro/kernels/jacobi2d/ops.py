"""Jit wrapper + multi-sweep driver for the Jacobi2D kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.jacobi2d.kernel import jacobi_step as _step


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def jacobi_step(u, *, block_rows: int = 128, interpret: bool = True):
    return _step(u, block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("sweeps", "block_rows", "interpret"))
def jacobi(u, *, sweeps: int = 10, block_rows: int = 128, interpret: bool = True):
    def body(u, _):
        return _step(u, block_rows=block_rows, interpret=interpret), None

    u, _ = jax.lax.scan(body, u, None, length=sweeps)
    return u
