"""One-call SVE analysis pipeline: Workload -> SVEAnalysis.

``analyze(workload)`` chains the paper's whole method — PMU-analogue event
extraction (``core.counters``), Eq. 1 metrics (VB, R_ins, AI), the adapted
roofline (Eq. 2) and the Fig. 8 decision tree — into a single call that
returns a typed, serializable report.  Callers never wire counters /
metrics / roofline / decision_tree by hand again.

Event sources (``source=``):

* ``"analytic"`` — the workload's Sec.-3.3-style flops/bytes model;
* ``"compiled"`` — lower + compile the workload's callable and extract
  events from the XLA artifact (``counters.events_from_compiled``);
* ``"auto"`` (default) — analytic when the model is present, else compiled.

``analyze_sweep`` amortizes compilation: compiled artifacts are
chip-independent (events are GLOBAL quantities), so a multi-chip /
multi-ELEN sweep compiles each workload exactly once via ``ArtifactCache``.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

from repro.core import hw, metrics
from repro.core.counters import Events, events_from_analytic, events_from_compiled
from repro.core.decision_tree import Decision, PerfClass, classify
from repro.core.metrics import VectorizationReport
from repro.core.roofline import AdaptedRoofline, adapted_roofline
from repro.analysis.workload import Workload, get_workload, list_workloads

WorkloadLike = Union[str, Workload]


# ---------------------------------------------------------------------------
# Compiled-artifact cache (the sweep's compile-once guarantee)
# ---------------------------------------------------------------------------


class ArtifactCache:
    """Cache of per-workload compiled-artifact Events.

    Events are chip-independent (global flops/bytes/collective quantities),
    so one compile serves every (chip, dtype) cell of a sweep.  ``compiles``
    and ``hits`` are exposed for tests and cost accounting.
    """

    def __init__(self) -> None:
        # keyed by Workload identity, with the Workload kept alive so ids
        # can't be recycled: two distinct workloads that happen to share a
        # name must never read each other's events
        self._events: Dict[int, tuple] = {}
        self.compiles = 0
        self.hits = 0

    def events_for(self, wl: Workload) -> Events:
        if wl.fn is None:
            raise ValueError(f"{wl.name}: no callable to compile")
        key = id(wl)
        if key in self._events:
            self.hits += 1
            return self._events[key][1]
        import jax

        self.compiles += 1
        compiled = jax.jit(wl.fn).lower(*wl.example_args()).compile()
        ev = events_from_compiled(compiled, n_devices=wl.n_devices)
        self._events[key] = (wl, ev)
        return ev

    def clear(self) -> None:
        self._events.clear()
        self.compiles = 0
        self.hits = 0


#: Module-level default cache shared by bare ``analyze`` calls.
DEFAULT_CACHE = ArtifactCache()


# ---------------------------------------------------------------------------
# The typed report
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SVEAnalysis:
    """Everything the paper derives about one workload on one chip model."""

    workload: str
    chip: str
    dtype: str
    source: str  # "analytic" | "compiled"
    events: Events
    report: VectorizationReport
    roofline: AdaptedRoofline
    decision: Decision
    wall_s: Optional[float] = None

    # -- the paper's headline quantities, flattened -------------------------
    @property
    def vb(self) -> float:
        return self.roofline.vb

    @property
    def r_ins(self) -> float:
        return self.report.r_ins

    @property
    def ai(self) -> float:
        return self.report.ai

    @property
    def ai_inflection(self) -> float:
        return self.decision.ai_inflection

    @property
    def perf_class(self) -> PerfClass:
        return self.decision.perf_class

    @property
    def bound(self) -> str:
        """Adapted-roofline region: "memory-bound" or "compute-bound"."""
        return self.roofline.region(self.ai)

    @property
    def predicted_speedup(self) -> float:
        return self.roofline.predicted_speedup(self.ai)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "workload": self.workload,
            "chip": self.chip,
            "dtype": self.dtype,
            "source": self.source,
            "vb": self.vb,
            "r_ins": self.r_ins,
            "ai": self.ai,
            "ai_inflection": self.ai_inflection,
            "bound": self.bound,
            "predicted_speedup": self.predicted_speedup,
            "perf_class": int(self.perf_class),
            "perf_class_name": self.perf_class.name,
            "rationale": self.decision.rationale,
            "gather_fraction": self.report.gather_fraction,
            "vectorizable_fraction": self.report.vectorizable_fraction,
            "flops": self.report.flops,
            "hbm_bytes": self.report.hbm_bytes,
            "wall_s": self.wall_s,
            "events": self.events.to_dict(),
            "roofline": dataclasses.asdict(self.roofline),
        }

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), **kw)

    def row(self) -> Dict[str, Any]:
        """One flat table row (the CSV/pretty-print projection)."""
        return {
            "workload": self.workload,
            "chip": self.chip,
            "dtype": self.dtype,
            "vb": f"{self.vb:.0f}",
            "r_ins": f"{self.r_ins:.3g}",
            "ai": f"{self.ai:.4g}",
            "knee": f"{self.ai_inflection:.4g}",
            "bound": self.bound,
            "class": f"{int(self.perf_class)} {self.perf_class.name}",
            "speedup_pred": f"{self.predicted_speedup:.3g}",
            "wall_s": "" if self.wall_s is None else f"{self.wall_s:.5f}",
        }

    def table(self) -> str:
        return format_table([self])

    def __str__(self) -> str:
        return (
            f"[{self.workload} @ {self.chip}/{self.dtype}] "
            f"VB={self.vb:.0f} R_ins={self.r_ins:.3g} AI={self.ai:.4g} "
            f"({self.bound}) Class {int(self.perf_class)} "
            f"({self.perf_class.describe()})"
        )


def format_table(results: Sequence[SVEAnalysis]) -> str:
    """Pretty fixed-width table over ``SVEAnalysis.row()`` projections."""
    rows = [r.row() for r in results]
    if not rows:
        return "(no results)"
    keys = list(rows[0].keys())
    widths = {k: max(len(k), *(len(str(r[k])) for r in rows)) for k in keys}
    lines = ["  ".join(k.ljust(widths[k]) for k in keys)]
    for r in rows:
        lines.append("  ".join(str(r[k]).ljust(widths[k]) for k in keys))
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# The pipeline
# ---------------------------------------------------------------------------


def _resolve(wl: WorkloadLike) -> Workload:
    return get_workload(wl) if isinstance(wl, str) else wl


def _report_from_events(
    name: str, dtype: str, ev: Events, chip: hw.ChipSpec
) -> VectorizationReport:
    """Eq.-1 report from artifact events: scalar baseline = one element per
    issue slot; effective R_ins = Amdahl over the vectorizable FLOP share."""
    vb = metrics.vectorization_bound(chip, dtype)
    r_eff = metrics.amdahl_r_ins(vb, ev.vectorizable_fraction)
    ins_scalar = ev.flops / 2.0
    return VectorizationReport(
        name=name,
        dtype=dtype,
        flops=ev.flops,
        hbm_bytes=ev.bytes_accessed,
        gather_bytes=ev.gather_bytes,
        ins_scalar=ins_scalar,
        ins_vec=ins_scalar / max(r_eff, 1e-30),
        vectorizable_fraction=ev.vectorizable_fraction,
        collective_bytes=ev.collective_bytes,
    )


def _time_roi(wl: Workload) -> Optional[float]:
    """ROI wall time through the paper's profiler API (Sec. 3.1)."""
    if wl.fn is None:
        return None
    import jax

    from repro.core.profiler import Profiler

    args = wl.example_args()
    prof = Profiler()
    prof.configure_measure()
    jax.block_until_ready(wl.fn(*args))  # warmup/compile outside the ROI
    prof.start_measure()
    jax.block_until_ready(wl.fn(*args))
    prof.stop_measure()
    return prof._acc / max(prof._repeats, 1)


def analyze(
    wl: WorkloadLike,
    chip: hw.ChipSpec = hw.GRACE_CORE,
    *,
    dtype: Optional[str] = None,
    source: str = "auto",
    time_roi: bool = False,
    cache: Optional[ArtifactCache] = None,
) -> SVEAnalysis:
    """Run the paper's full method on one workload, on one chip model.

    Chains compile/lower (cached) -> event extraction -> Eq. 1 metrics ->
    adapted roofline (Eq. 2) -> Fig. 8 decision tree, plus an optional
    profiler-timed ROI, and returns the typed :class:`SVEAnalysis`.
    """
    wl = _resolve(wl)
    dtype = dtype or wl.dtype
    if source not in ("auto", "analytic", "compiled"):
        raise ValueError(f"source must be auto|analytic|compiled, got {source!r}")
    if source == "auto":
        source = "analytic" if wl.has_analytic_model else "compiled"

    if source == "analytic":
        if not wl.has_analytic_model:
            raise ValueError(f"{wl.name}: no analytic model for source='analytic'")
        ev = events_from_analytic(
            flops=wl.flops,
            hbm_bytes=wl.hbm_bytes,
            gather_bytes=wl.gather_bytes,
            collective_bytes=wl.collective_bytes,
            n_devices=wl.n_devices,
        )
        ev.nonvec_flops = wl.flops * (1.0 - wl.vectorizable_fraction)
        report = wl.report(chip, dtype=dtype)
    else:
        ev = (cache or DEFAULT_CACHE).events_for(wl)
        report = _report_from_events(wl.name, dtype, ev, chip)

    rl = adapted_roofline(chip, dtype)
    decision = classify(report, chip, roofline=rl)
    wall = _time_roi(wl) if time_roi else None
    return SVEAnalysis(
        workload=wl.name,
        chip=chip.name,
        dtype=dtype,
        source=source,
        events=ev,
        report=report,
        roofline=rl,
        decision=decision,
        wall_s=wall,
    )


def analyze_events(
    name: str,
    events: Events,
    chip: hw.ChipSpec = hw.GRACE_CORE,
    *,
    dtype: str = "fp32",
) -> SVEAnalysis:
    """The pipeline's tail for callers that already hold Events (e.g. the
    dry-run, which post-processes events with its analytic traffic model)."""
    report = _report_from_events(name, dtype, events, chip)
    rl = adapted_roofline(chip, dtype)
    return SVEAnalysis(
        workload=name,
        chip=chip.name,
        dtype=dtype,
        source="compiled",
        events=events,
        report=report,
        roofline=rl,
        decision=classify(report, chip, roofline=rl),
    )


def analyze_compiled(
    name: str,
    compiled: Any,
    chip: hw.ChipSpec = hw.GRACE_CORE,
    *,
    dtype: str = "fp32",
    n_devices: Optional[int] = None,
) -> SVEAnalysis:
    """Analyze an already-compiled ``jax.stages.Compiled`` artifact."""
    ev = events_from_compiled(compiled, n_devices=n_devices)
    return analyze_events(name, ev, chip, dtype=dtype)


def analyze_sweep(
    workloads: Optional[Iterable[WorkloadLike]] = None,
    chips: Sequence[hw.ChipSpec] = (hw.GRACE_CORE, hw.TPU_V5E),
    *,
    dtypes: Optional[Sequence[str]] = None,
    source: str = "auto",
    time_roi: bool = False,
    cache: Optional[ArtifactCache] = None,
) -> List[SVEAnalysis]:
    """``analyze`` over a (workload x chip x dtype) grid, compiling each
    workload at most once (events are chip-independent; see ArtifactCache).

    ``workloads`` defaults to every registered workload; ``dtypes`` defaults
    to each workload's own dtype.
    """
    cache = cache or ArtifactCache()
    names = list(workloads) if workloads is not None else list_workloads()
    out: List[SVEAnalysis] = []
    for w in names:
        wl = _resolve(w)
        for chip in chips:
            for dtype in dtypes or (wl.dtype,):
                out.append(
                    analyze(
                        wl,
                        chip,
                        dtype=dtype,
                        source=source,
                        time_roi=time_roi,
                        cache=cache,
                    )
                )
    return out
