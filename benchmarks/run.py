"""Benchmark driver: one benchmark per paper table/figure.

Usage:
    PYTHONPATH=src python -m benchmarks.run [--only fig3_vectorization]
    PYTHONPATH=src python -m benchmarks.run --out experiments/bench --jobs 4
    PYTHONPATH=src python -m benchmarks.run --tune [--tune-cap 2]
    PYTHONPATH=src python -m benchmarks.run --record --gate [--baseline latest]
    PYTHONPATH=src python -m benchmarks.run --list

Writes one CSV per benchmark, a machine-readable ``summary.json`` (per-
benchmark rows / wall time / pass-fail, stamped with the run environment:
git SHA, chip, jax version, dtype, active tuned-config hash — the stable
artifact the perf trajectory ledger ingests), and prints each table.
``--jobs N`` runs benchmarks concurrently on a thread pool (each
benchmark's analyses share the persistent artifact store, so repeat runs
skip compilation).  ``--tune`` runs the roofline-guided kernel autotuner
first (records persist in the tuning store — a repeat run performs zero
timing runs) and writes its machine-readable report to
``<out>/tuning.json``; ``--tune-cap N`` shrinks every tuning axis to its
first N values (the CI tiny-space knob).  ``--record`` appends this run
(summary + tuning report when present) to the perf ledger
(``repro.perf``); ``--gate`` additionally compares it against
``--baseline`` (``latest`` | ``pinned:<sha>`` | ``median:<K>``) and exits
non-zero on confirmed regressions, printing each one's Fig.-8 triage.
``--list`` enumerates both the figure/table benchmarks and every workload
registered in the unified ``repro.analysis`` registry.
"""

from __future__ import annotations

import argparse
import csv
import json
import os
import sys
import time
from concurrent.futures import ThreadPoolExecutor


def _write_csv(path: str, rows) -> None:
    if not rows:
        return
    keys = list(rows[0].keys())
    for r in rows[1:]:
        for k in r:
            if k not in keys:
                keys.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(rows)


def _print_table(name: str, rows) -> None:
    print(f"\n== {name} " + "=" * max(0, 66 - len(name)))
    if not rows:
        print("(no rows)")
        return
    keys = list(rows[0].keys())
    widths = {k: max(len(str(k)), *(len(str(r.get(k, ""))) for r in rows)) for k in keys}
    print("  ".join(str(k).ljust(widths[k]) for k in keys))
    for r in rows:
        print("  ".join(str(r.get(k, "")).ljust(widths[k]) for k in keys))


def _list() -> int:
    from benchmarks.figures import ALL
    from repro.analysis import list_workloads

    print("benchmarks (python -m benchmarks.run --only <name>):")
    for name in ALL:
        print(f"  {name}")
    print("\nworkloads (repro.analysis.analyze(<name>)):")
    for name in list_workloads():
        print(f"  {name}")
    return 0


def _run_tuning(out_dir: str, *, jobs: int, cap=None, repeats: int = 2) -> dict:
    """Roofline-guided sweep over every tunable kernel -> tuning.json.

    Runs before the benchmarks so tuned configs are active for them; store
    hits make repeat invocations timing-free.  Returns the report dict so
    ``--record`` can ingest it into the perf ledger alongside the summary.
    """
    from repro.tuning import format_records, report_dict, tune_kernels

    t0 = time.time()
    records = tune_kernels(jobs=jobs, cap=cap, repeats=repeats)
    print("\n== tuning " + "=" * 60)
    print(format_records(records))
    report = report_dict(records, wall_s=time.time() - t0)
    path = os.path.join(out_dir, "tuning.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
    cached = sum(1 for r in records if r.cached)
    print(f"[{len(records)} tuning records ({cached} cached) -> {path}]")
    return report


def _run_benchmark(name: str, fn) -> dict:
    """Execute one benchmark; never raises (summary rows record failures)."""
    t0 = time.time()
    try:
        rows = fn()
        return {"name": name, "ok": True, "rows": len(rows),
                "wall_s": round(time.time() - t0, 3), "error": None,
                "_rows": rows}
    except Exception as e:  # noqa: BLE001 — report all benchmark failures
        import traceback

        traceback.print_exc()
        return {"name": name, "ok": False, "rows": 0,
                "wall_s": round(time.time() - t0, 3), "error": repr(e),
                "_rows": []}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single benchmark")
    ap.add_argument("--list", action="store_true",
                    help="list benchmarks + registered workloads and exit")
    ap.add_argument("--jobs", type=int, default=1,
                    help="run benchmarks concurrently on a thread pool")
    ap.add_argument("--tune", action="store_true",
                    help="run the kernel autotuner first; writes tuning.json")
    ap.add_argument("--tune-cap", type=int, default=None,
                    help="shrink tuning axes to their first N values")
    ap.add_argument("--tune-repeats", type=int, default=2,
                    help="timing repeats per tuning survivor (best-of)")
    ap.add_argument("--record", action="store_true",
                    help="append this run to the perf trajectory ledger")
    ap.add_argument("--gate", action="store_true",
                    help="gate this run against --baseline (implies --record); "
                         "exit non-zero on confirmed regressions")
    ap.add_argument("--baseline", default="latest",
                    help="gate baseline policy: latest | pinned:<prefix> | "
                         "median:<K>")
    ap.add_argument("--tol-wall", type=float, default=1.0,
                    help="scale the gate's noisy (wall-time) tolerances")
    ap.add_argument("--chip", default="grace-core",
                    help="chip name stamped into the run environment")
    ap.add_argument("--dtype", default="fp32",
                    help="dtype stamped into the run environment")
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args(argv)

    if args.list:
        return _list()

    if args.gate:
        # fail a malformed policy BEFORE minutes of benchmarks run
        from repro.perf.baseline import validate_policy

        try:
            validate_policy(args.baseline)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2

    from benchmarks.figures import ALL

    if args.only is not None and args.only not in ALL:
        print(f"error: unknown benchmark {args.only!r}; available: "
              f"{', '.join(ALL)}", file=sys.stderr)
        return 2

    os.makedirs(args.out, exist_ok=True)
    tuning_report = None
    if args.tune:
        tuning_report = _run_tuning(args.out, jobs=args.jobs, cap=args.tune_cap,
                                    repeats=args.tune_repeats)
    todo = {args.only: ALL[args.only]} if args.only else ALL
    t_total = time.time()
    if args.jobs > 1 and len(todo) > 1:
        with ThreadPoolExecutor(max_workers=args.jobs) as pool:
            results = list(pool.map(
                lambda item: _run_benchmark(*item), todo.items()
            ))
    else:
        results = [_run_benchmark(name, fn) for name, fn in todo.items()]

    failed = []
    for res in results:
        rows = res.pop("_rows")
        if not res["ok"]:
            failed.append((res["name"], res["error"]))
            continue
        _write_csv(os.path.join(args.out, f"{res['name']}.csv"), rows)
        _print_table(res["name"], rows)
        print(f"[{res['name']}: {res['rows']} rows in {res['wall_s']:.1f}s]")

    from repro.perf import capture_env

    env = capture_env(chip=args.chip, dtype=args.dtype)
    summary = {
        "kind": "benchmarks_summary",
        "schema": 1,
        "benchmarks": results,  # per-benchmark rows, wall time, pass/fail
        "total_wall_s": round(time.time() - t_total, 3),
        "jobs": args.jobs,
        "passed": sum(1 for r in results if r["ok"]),
        "failed": len(failed),
        # git SHA / chip / jax version / dtype / tuned-config hash: the
        # perf ledger ingests summaries without re-deriving environment
        "env": env.to_dict(),
    }
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)

    gate_failed = False
    if args.record or args.gate:
        from repro.perf import default_ledger, gate_run

        ledger = default_ledger()
        # a run with failed benchmarks is still a trajectory point (its
        # ok=False rows are the signal), but meta["failed"] marks it so
        # baseline resolution never anchors on an aborted run's wall times
        run = ledger.record_sources(
            summary=summary, tuning=tuning_report, env=env,
            meta={"out": args.out, "only": args.only, "failed": len(failed)},
        )
        print(f"\n[perf ledger: recorded run {run.run_id[:12]} "
              f"(seq {run.seq}) -> {ledger.root}]")
        if args.gate:
            result = gate_run(run, ledger, policy=args.baseline,
                              wall_tol_scale=args.tol_wall)
            print(result.describe())
            gate_failed = not result.ok

    if failed:
        print(f"\nFAILED: {failed}")
        return 1
    print(f"\nall {len(todo)} benchmarks written to {args.out}/ "
          f"(+ summary.json)")
    return 1 if gate_failed else 0


if __name__ == "__main__":
    sys.exit(main())
