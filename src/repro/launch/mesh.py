"""Production mesh builders.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import; smoke
tests and benches see the real single CPU device).
"""

from __future__ import annotations

from typing import Tuple

import jax


class MeshShapeError(ValueError):
    """A mesh shape that cannot be built on this host.

    Carries the offending ``shape`` (what was asked for) and ``n_devices``
    (what the host exposes) so `launch.serve --mesh` failures are actionable
    — e.g. "2x2 needs 4 devices, host has 1; set
    XLA_FLAGS=--xla_force_host_platform_device_count=4".
    """

    def __init__(self, message: str, *, shape=None, n_devices=None):
        super().__init__(message)
        self.shape = tuple(shape) if shape is not None else None
        self.n_devices = n_devices


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this host has (CPU tests): (n_dev/model, model)."""
    n = len(jax.devices())
    if model_axis <= 0 or n % model_axis != 0:
        raise MeshShapeError(
            f"host has {n} device(s), not divisible into a "
            f"({n}/{model_axis}, {model_axis}) (data, model) mesh",
            shape=(n, model_axis),
            n_devices=n,
        )
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def parse_mesh(spec: str) -> Tuple[int, int]:
    """Parse a ``DxM`` mesh spec ("2x2" -> (2, 2)); raises MeshShapeError."""
    parts = str(spec).lower().split("x")
    try:
        d, m = (int(p) for p in parts)
    except ValueError:
        d = m = 0
    if len(parts) != 2 or d < 1 or m < 1:
        raise MeshShapeError(
            f"mesh spec {spec!r} is not of the form DxM (e.g. '2x2')",
            shape=None,
        )
    return d, m


def make_serve_mesh(data: int = 1, model: int = 1):
    """A (data, model) mesh over the first data*model host devices.

    Unlike `make_host_mesh` (which consumes every device the host has),
    this builds exactly the shape asked for — the serving golden contract
    runs the same traffic over 1x1 / 2x1 / 1x2 / 2x2 on one forced-device
    host.  Raises MeshShapeError with a remediation hint when the host
    exposes fewer devices than data*model.
    """
    devices = jax.devices()
    need = data * model
    if data < 1 or model < 1:
        raise MeshShapeError(
            f"mesh shape ({data}, {model}) has a non-positive axis",
            shape=(data, model),
            n_devices=len(devices),
        )
    if need > len(devices):
        raise MeshShapeError(
            f"mesh {data}x{model} needs {need} device(s), host has "
            f"{len(devices)}; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"(before the process starts) or shrink the mesh",
            shape=(data, model),
            n_devices=len(devices),
        )
    import numpy as np

    grid = np.asarray(devices[:need]).reshape(data, model)
    return jax.sharding.Mesh(grid, ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes that carry the batch (ZeRO/data-parallel) dimension."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
