"""Cell execution: drive ServeEngine per scenario, diff golden twins,
check SLOs, record one BenchRun per cell into the perf ledger.

Execution paths:

* **engine path** (``none``/``preempt``/``malformed``): one
  :class:`~repro.serve.engine.ServeEngine` per cell, traffic delivered by
  a :class:`TrafficFeeder` step hook honoring sampled arrival steps, the
  fault plan's hook (if any) riding alongside.
* **resilient path** (``device-loss``): the trace is partitioned into
  chunks and served under
  :class:`~repro.distributed.fault_tolerance.ResilientLoop` — every chunk
  commits its served tokens into a fixed-shape state checkpointed through
  :class:`~repro.checkpoint.CheckpointStore`; the injected
  :class:`~repro.scenarios.faults.SimulatedDeviceLoss` kills the drain
  mid-chunk, the loop restores the newest committed checkpoint, and the
  replayed chunk must (and does) regenerate identical tokens.

Every faulted cell is diffed against its fault-free **golden twin** (same
seed, same traffic — the fault axis is excluded from seed derivation):
served token streams must match uid-for-uid, token-for-token.  A twin
mismatch, an SLO violation, or a cell error all fail the cell; the gate
CLI turns failed cells into a non-zero exit.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import shutil
import tempfile
import threading
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

import repro.configs as configs
from repro.checkpoint import CheckpointStore
from repro.distributed.fault_tolerance import FaultToleranceConfig, ResilientLoop
from repro.scenarios import faults as faults_mod
from repro.scenarios.matrix import MatrixSpec, Scenario
from repro.scenarios.traffic import RequestSpec, sample_trace
from repro.serve.engine import Request, RequestTooLong, ServeEngine
from repro.train import steps as steps_mod

# one smoke model per architecture, shared across every cell (and thread)
_PARAMS_LOCK = threading.Lock()
_PARAMS: Dict[str, Tuple[Any, Any]] = {}

#: draft model every speculating cell uses (the ISSUE's small-draft
#: setup); cells targeting this same arch self-speculate at acceptance
#: 1.0, other archs exercise the rejection/rewind path
DRAFT_ARCH = "gpt2-124m"


def _params_for(arch: str) -> Tuple[Any, Any]:
    with _PARAMS_LOCK:
        if arch not in _PARAMS:
            cfg = configs.get_smoke_config(arch)
            _PARAMS[arch] = (cfg, steps_mod.init_model(
                jax.random.PRNGKey(0), cfg))
        return _PARAMS[arch]


def _spec_kwargs(cell: Scenario) -> Dict[str, Any]:
    """ServeEngine speculation kwargs for a cell (empty when off)."""
    if cell.spec_k <= 0:
        return {}
    draft_cfg, draft_params = _params_for(DRAFT_ARCH)
    return {"spec_k": cell.spec_k, "draft_cfg": draft_cfg,
            "draft_params": draft_params}


# one jax Mesh per shape, shared across cells (and threads, and the
# per-chunk engines the resilient path rebuilds after a simulated device
# loss — re-entering _mesh_for on restart IS the resharding path)
_MESH_LOCK = threading.Lock()
_MESHES: Dict[str, Any] = {}


def _mesh_for(cell: Scenario):
    if cell.mesh is None:
        return None
    from repro.launch.mesh import make_serve_mesh, parse_mesh

    with _MESH_LOCK:
        if cell.mesh not in _MESHES:
            _MESHES[cell.mesh] = make_serve_mesh(*parse_mesh(cell.mesh))
        return _MESHES[cell.mesh]


class TrafficFeeder:
    """Step hook delivering the sampled trace on the engine's step clock.

    ``clock = engine.steps + offset``: when the engine goes fully idle
    before the next arrival, the feeder fast-forwards ``offset`` to it
    (compressing dead air instead of spinning), which keeps arrival
    *patterns* — bursts, gaps, overlaps — while staying deterministic.
    Malformed submissions are caught typed and counted, never raised.
    """

    def __init__(self, trace: List[RequestSpec]):
        self.pending = deque(sorted(trace, key=lambda r: (r.arrive_step, r.uid)))
        self.offset = 0
        self.submitted = 0
        self.rejected: List[Tuple[int, str]] = []

    def _deliver(self, engine: ServeEngine) -> int:
        n = 0
        while (self.pending
               and self.pending[0].arrive_step <= engine.steps + self.offset):
            spec = self.pending.popleft()
            try:
                engine.submit(Request(
                    uid=spec.uid, prompt=np.array(spec.prompt, np.int32),
                    max_new_tokens=spec.max_new_tokens, eos_id=spec.eos_id,
                ))
                self.submitted += 1
            except (RequestTooLong, ValueError) as e:
                self.rejected.append((spec.uid, str(e)))
            n += 1
        return n

    def __call__(self, engine: ServeEngine, busy: bool) -> bool:
        delivered = self._deliver(engine)
        if (self.pending and not delivered and not busy and not engine.queue):
            # fully idle with future arrivals: jump the clock to the next one
            self.offset = max(
                self.offset, self.pending[0].arrive_step - engine.steps
            )
            self._deliver(engine)
        return bool(self.pending)


# ---------------------------------------------------------------------------
# Execution paths
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Execution:
    """Raw outcome of serving one trace (either path)."""

    stats: Dict[str, Any]
    tokens: Dict[int, List[int]]
    rejected: List[Tuple[int, str]]
    restarts: int = 0


def _execute_engine(cell: Scenario, cfg, params,
                    trace: List[RequestSpec],
                    fault_hook=None) -> _Execution:
    engine = ServeEngine(
        cfg, params, max_batch=cell.max_batch, max_len=cell.max_len,
        scheduler=cell.scheduler, block_size=cell.block_size,
        prefill_chunk=cell.prefill_chunk,
        prefill_budget=cell.prefill_budget,
        share_prefixes=cell.share_prefixes,
        mesh=_mesh_for(cell),
        **_spec_kwargs(cell),
    )
    feeder = TrafficFeeder(trace)
    engine.add_step_hook(feeder)
    if fault_hook is not None:
        engine.add_step_hook(fault_hook)
    engine.run_until_drained()
    stats = engine.stats()
    stats["rejected"] = len(feeder.rejected)
    stats["restarts"] = 0
    return _Execution(
        stats=stats,
        tokens={uid: list(r.generated) for uid, r in engine.completed.items()},
        rejected=feeder.rejected,
    )


def _percentile(vals: List[float], q: float) -> float:
    return float(np.percentile(vals, q)) if vals else 0.0


def _execute_resilient(cell: Scenario, cfg, params,
                       trace: List[RequestSpec],
                       plan: Optional[faults_mod.DeviceLossPlan]) -> _Execution:
    """Chunked serving under ResilientLoop + CheckpointStore.

    The trace is split into ``max_batch``-request chunks (arrival order);
    each chunk is one resilient *step*: serve it on a fresh engine, merge
    its tokens into the fixed-shape state, checkpoint.  A crash mid-chunk
    loses only the uncommitted chunk, and the replay regenerates it
    bit-identically (greedy decode over a seeded trace).
    """
    chunks = [trace[i:i + cell.max_batch]
              for i in range(0, len(trace), cell.max_batch)]
    uid_row = {spec.uid: i for i, spec in enumerate(trace)}
    R = len(trace)
    crash = plan.make_crash_hook() if plan is not None else None
    fail_chunk = (min(plan.fail_chunk, len(chunks) - 1)
                  if plan is not None else -1)
    # host-side per-chunk observations, overwritten on replay so the
    # retried chunk counts exactly once in the aggregate
    chunk_obs: Dict[int, Dict[str, Any]] = {}
    rejected: Dict[int, List[Tuple[int, str]]] = {}

    def make_state():
        return {
            "tokens": np.full((R, cell.max_new), -1, np.int32),
            "served": np.zeros((R,), np.int32),
        }

    def step_fn(chunk_idx: int, state):
        sub = chunks[chunk_idx]
        base = min(s.arrive_step for s in sub)
        rebased = [dataclasses.replace(s, arrive_step=s.arrive_step - base)
                   for s in sub]
        engine = ServeEngine(
            cfg, params, max_batch=cell.max_batch, max_len=cell.max_len,
            scheduler=cell.scheduler, block_size=cell.block_size,
            prefill_chunk=cell.prefill_chunk,
            prefill_budget=cell.prefill_budget,
            share_prefixes=cell.share_prefixes,
            mesh=_mesh_for(cell),
            **_spec_kwargs(cell),
        )
        feeder = TrafficFeeder(rebased)
        engine.add_step_hook(feeder)
        if crash is not None and chunk_idx == fail_chunk:
            engine.add_step_hook(crash)
        engine.run_until_drained()
        tokens = np.array(state["tokens"])
        served = np.array(state["served"])
        lats, ttfts, ttft_steps = [], [], []
        for uid, r in engine.completed.items():
            row = uid_row[uid]
            tokens[row, : len(r.generated)] = r.generated
            served[row] = len(r.generated)
            if r.latency_s is not None:
                lats.append(r.latency_s)
            if r.ttft_s is not None:
                ttfts.append(r.ttft_s)
            if r.ttft_steps is not None:
                ttft_steps.append(r.ttft_steps)
        chunk_obs[chunk_idx] = {"stats": engine.stats(), "lats": lats,
                                "ttfts": ttfts, "ttft_steps": ttft_steps}
        rejected[chunk_idx] = feeder.rejected
        return {"tokens": tokens, "served": served}

    ckpt_dir = tempfile.mkdtemp(prefix="scenario-ckpt-")
    try:
        loop = ResilientLoop(
            CheckpointStore(ckpt_dir),
            FaultToleranceConfig(checkpoint_every=1, async_save=False,
                                 max_restarts=4),
            step_fn, make_state,
        )
        out = loop.run(total_steps=len(chunks))
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)

    tokens_arr = np.asarray(out["state"]["tokens"])
    served = np.asarray(out["state"]["served"])
    tokens = {
        spec.uid: tokens_arr[uid_row[spec.uid], : int(served[uid_row[spec.uid]])]
        .tolist()
        for spec in trace if int(served[uid_row[spec.uid]]) > 0
    }
    # aggregate the per-chunk engines into one cell-level stats row
    obs = [chunk_obs[i] for i in sorted(chunk_obs)]
    totals = {k: sum(o["stats"][k] for o in obs) for k in (
        "requests", "new_tokens", "fused_steps", "busy_slot_steps",
        "slot_steps", "preemptions", "wall_s",
        "logical_blocks", "physical_blocks", "shared_block_hits",
        "cow_copies", "kv_bytes_served", "kv_bytes_stored",
        "drafted_tokens", "accepted_tokens", "rejected_tokens",
        "draft_steps", "target_steps")}
    lats = [v for o in obs for v in o["lats"]]
    ttfts = [v for o in obs for v in o["ttfts"]]
    ttft_steps = [float(v) for o in obs for v in o["ttft_steps"]]
    rej = [r for i in sorted(rejected) for r in rejected[i]]
    from repro.core import metrics as core_metrics

    stats = {
        "scheduler": cell.scheduler,
        "prefill_chunk": cell.prefill_chunk,
        "share_prefixes": cell.share_prefixes,
        "spec_k": cell.spec_k,
        "mesh": cell.mesh,
        "mesh_devices": max(
            (int(o["stats"].get("mesh_devices", 1)) for o in obs), default=1),
        # the min over chunk engines: the cell's worst device-lane
        # utilization across the whole (possibly restarted) run
        "device_lane_utilization": min(
            (float(o["stats"].get("device_lane_utilization", 0.0))
             for o in obs), default=0.0),
        **{k: totals[k] for k in ("requests", "new_tokens", "fused_steps",
                                  "busy_slot_steps", "slot_steps",
                                  "preemptions", "logical_blocks",
                                  "physical_blocks", "shared_block_hits",
                                  "cow_copies", "kv_bytes_served",
                                  "kv_bytes_stored", "drafted_tokens",
                                  "accepted_tokens", "rejected_tokens",
                                  "draft_steps", "target_steps")},
        "acceptance_rate": core_metrics.acceptance_rate(
            totals["accepted_tokens"], totals["drafted_tokens"]),
        # block-granular fallback for pure-SSM archs (zero paged KV bytes)
        "block_dedup_ratio": core_metrics.block_dedup_ratio(
            totals["kv_bytes_served"], totals["kv_bytes_stored"]
        ) if totals["kv_bytes_stored"] > 0 else
        core_metrics.block_dedup_ratio(
            totals["logical_blocks"], totals["physical_blocks"]),
        "slot_utilization": (totals["busy_slot_steps"] / totals["slot_steps"]
                             if totals["slot_steps"] else 0.0),
        "wall_s": totals["wall_s"],
        "tok_s": (totals["new_tokens"] / totals["wall_s"]
                  if totals["wall_s"] > 0 else 0.0),
        "p50_latency_s": _percentile(lats, 50),
        "p95_latency_s": _percentile(lats, 95),
        "ttft_p50_s": _percentile(ttfts, 50),
        "ttft_p95_s": _percentile(ttfts, 95),
        "ttft_p50_steps": _percentile(ttft_steps, 50),
        "ttft_p95_steps": _percentile(ttft_steps, 95),
        "rejected": len(rej),
        "restarts": int(out["restarts"]),
    }
    return _Execution(stats=stats, tokens=tokens, rejected=rej,
                      restarts=int(out["restarts"]))


def _execute(cell: Scenario, inject: bool) -> _Execution:
    cfg, params = _params_for(cell.arch)
    trace = sample_trace(cell, cfg.vocab)
    plan = faults_mod.get_plan(cell.fault)
    if inject:
        trace = plan.mutate_trace(trace, cell)
    if plan.resilient:
        return _execute_resilient(cell, cfg, params, trace,
                                  plan if inject else None)
    hook = plan.make_hook(cell) if inject else None
    return _execute_engine(cell, cfg, params, trace, fault_hook=hook)


# ---------------------------------------------------------------------------
# Cell results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CellResult:
    cell: Scenario
    stats: Dict[str, Any] = dataclasses.field(default_factory=dict)
    tokens: Dict[int, List[int]] = dataclasses.field(default_factory=dict)
    rejected: List[Tuple[int, str]] = dataclasses.field(default_factory=list)
    restarts: int = 0
    golden_checked: bool = False
    golden_diffs: List[str] = dataclasses.field(default_factory=list)
    slo_failures: List[str] = dataclasses.field(default_factory=list)
    error: str = ""

    @property
    def golden_ok(self) -> bool:
        return not self.golden_diffs

    @property
    def ok(self) -> bool:
        return not (self.error or self.golden_diffs or self.slo_failures)

    def report(self) -> Dict[str, Any]:
        """Machine-readable cell report (the ledger's scenario source)."""
        return {
            "kind": "scenario_cell",
            "cell_id": self.cell.cell_id,
            "ledger_key": self.cell.ledger_key,
            "arch": self.cell.arch,
            "scheduler": self.cell.scheduler,
            "fault": self.cell.fault,
            "prefill_chunk": self.cell.prefill_chunk,
            "prefill_budget": self.cell.prefill_budget,
            "prompt_sharing": self.cell.prompt_sharing,
            "spec_k": self.cell.spec_k,
            "mesh": self.cell.mesh,
            "seed": self.cell.seed,
            "ok": self.ok,
            "stats": self.stats,
            "rejected": [{"uid": u, "reason": r} for u, r in self.rejected],
            "restarts": self.restarts,
            "golden_checked": self.golden_checked,
            "golden_ok": self.golden_ok,
            "golden_diffs": self.golden_diffs,
            "slo_failures": self.slo_failures,
            "error": self.error,
            "requests": [
                {"uid": uid, "new_tokens": len(toks)}
                for uid, toks in sorted(self.tokens.items())
            ],
        }


def _diff_tokens(faulted: Dict[int, List[int]],
                 golden: Dict[int, List[int]]) -> List[str]:
    """Served-stream differences (empty = bit-identical on every uid)."""
    diffs = []
    for uid in sorted(golden):
        if uid not in faulted:
            diffs.append(f"uid {uid}: served in golden twin, missing here")
        elif faulted[uid] != golden[uid]:
            diffs.append(
                f"uid {uid}: tokens diverged ({faulted[uid]} != {golden[uid]})"
            )
    for uid in sorted(set(faulted) - set(golden)):
        diffs.append(f"uid {uid}: served here, absent from the golden twin")
    return diffs


def run_cell(cell: Scenario, *, check_twin: bool = True) -> CellResult:
    """Run one cell (and, when faulted, its golden twin) to a CellResult."""
    result = CellResult(cell=cell)
    try:
        ex = _execute(cell, inject=True)
    except Exception as e:  # noqa: BLE001 — a cell error fails the cell, not the matrix
        result.error = f"{type(e).__name__}: {e}"
        return result
    result.stats = ex.stats
    result.tokens = ex.tokens
    result.rejected = ex.rejected
    result.restarts = ex.restarts
    if cell.fault != "none" and check_twin:
        try:
            twin = _execute(cell.twin(), inject=False)
        except Exception as e:  # noqa: BLE001
            result.error = f"golden twin failed: {type(e).__name__}: {e}"
            return result
        result.golden_checked = True
        result.golden_diffs = _diff_tokens(result.tokens, twin.tokens)
    if cell.prefill_chunk > 1 and check_twin:
        # the chunk axis gets the same golden treatment as faults: chunked
        # serving must reproduce the token-by-token streams exactly
        try:
            ctwin = _execute(cell.chunk_twin(), inject=False)
        except Exception as e:  # noqa: BLE001
            result.error = f"chunk twin failed: {type(e).__name__}: {e}"
            return result
        result.golden_checked = True
        result.golden_diffs += [
            f"[vs prefill_chunk=1] {d}"
            for d in _diff_tokens(result.tokens, ctwin.tokens)
        ]
    if cell.prompt_sharing == "shared" and check_twin:
        # the sharing axis gets golden treatment too: the COW engine must
        # serve the sharing-disabled twin's exact streams while actually
        # deduplicating (strictly fewer physical blocks, dedup ratio > 1)
        try:
            stwin = _execute(cell.sharing_twin(), inject=False)
        except Exception as e:  # noqa: BLE001
            result.error = f"sharing twin failed: {type(e).__name__}: {e}"
            return result
        result.golden_checked = True
        result.golden_diffs += [
            f"[vs sharing-off] {d}"
            for d in _diff_tokens(result.tokens, stwin.tokens)
        ]
        mine = result.stats.get("physical_blocks")
        base = stwin.stats.get("physical_blocks")
        if mine is not None and base is not None and not mine < base:
            result.golden_diffs.append(
                f"[vs sharing-off] physical blocks not reduced "
                f"({mine} vs {base})")
        if float(result.stats.get("block_dedup_ratio", 1.0)) <= 1.0:
            result.golden_diffs.append(
                "[vs sharing-off] block_dedup_ratio "
                f"{result.stats.get('block_dedup_ratio')} <= 1 on "
                "shared-prefix traffic")
    if cell.spec_k > 0 and check_twin:
        # the speculation axis gets golden treatment too: the speculative
        # engine must serve the speculation-off twin's exact streams while
        # actually drafting (drafted > 0 and acceptance recorded); step
        # counts are NOT asserted here — acceptance-hostile cells (draft
        # disagreeing with the target) legitimately spend extra replay
        # steps, and the per-key perf ledger holds each trajectory instead
        try:
            vtwin = _execute(cell.spec_twin(), inject=False)
        except Exception as e:  # noqa: BLE001
            result.error = f"spec twin failed: {type(e).__name__}: {e}"
            return result
        result.golden_checked = True
        result.golden_diffs += [
            f"[vs spec-off] {d}"
            for d in _diff_tokens(result.tokens, vtwin.tokens)
        ]
        if (result.stats.get("requests", 0)
                and not result.stats.get("drafted_tokens", 0)):
            result.golden_diffs.append(
                "[vs spec-off] speculative cell drafted zero tokens")
    if cell.mesh is not None and check_twin:
        # the mesh axis gets golden treatment too: the sharded engine must
        # serve the unsharded twin's exact streams — head/expert/data
        # sharding may move the math across devices, never change it
        try:
            mtwin = _execute(cell.mesh_twin(), inject=False)
        except Exception as e:  # noqa: BLE001
            result.error = f"mesh twin failed: {type(e).__name__}: {e}"
            return result
        result.golden_checked = True
        result.golden_diffs += [
            f"[vs mesh-off] {d}"
            for d in _diff_tokens(result.tokens, mtwin.tokens)
        ]
    result.slo_failures = cell.slo.check(result.stats)
    return result


def record_cell(result: CellResult, ledger=None):
    """Append one BenchRun for this cell to the perf ledger; the row is
    keyed ``scenario/<cell_id>`` so ``python -m repro.perf gate`` compares
    each cell only against its own trajectory."""
    from repro.perf.ledger import default_ledger, metrics_from_scenario

    ledger = ledger or default_ledger()
    return ledger.record(
        metrics_from_scenario(result.report()),
        meta={"sources": ["scenario"], "scenario": result.cell.cell_id,
              "fault": result.cell.fault},
    )


def run_matrix(spec: MatrixSpec, *, only: Optional[str] = None,
               jobs: int = 1, check_twin: bool = True,
               record: bool = False, ledger=None) -> List[CellResult]:
    """Expand and run the matrix; optionally record one BenchRun per cell.

    ``only`` is an fnmatch glob over cell ids (``"*device-loss"``,
    ``"*continuous*gpt2*"``); ``jobs > 1`` fans cells over a thread pool
    (engines share compiled steps per (config, block_size), so threads
    contend on host-side scheduling, not compilation).
    """
    cells = spec.cells()
    if only:
        cells = [c for c in cells if fnmatch.fnmatch(c.cell_id, only)]
    if jobs > 1 and len(cells) > 1:
        with ThreadPoolExecutor(max_workers=jobs) as pool:
            results = list(pool.map(
                lambda c: run_cell(c, check_twin=check_twin), cells))
    else:
        results = [run_cell(c, check_twin=check_twin) for c in cells]
    if record:
        for r in results:
            if not r.error:
                record_cell(r, ledger=ledger)
    return results


def format_matrix_markdown(results: List[CellResult]) -> str:
    """The per-cell matrix report CI uploads."""
    lines = [
        "# Scenario matrix",
        "",
        f"{sum(r.ok for r in results)}/{len(results)} cells ok",
        "",
        "| cell | tok/s | p95 (s) | ttft p95 (s) | util | rej | pre | rst "
        "| twin | slo |",
        "|---|---:|---:|---:|---:|---:|---:|---:|:-:|:-:|",
    ]
    for r in results:
        s = r.stats
        if r.error:
            lines.append(f"| `{r.cell.cell_id}` | — | — | — | — | — | — | — "
                         f"| — | ERROR: {r.error} |")
            continue
        twin = ("=" if r.golden_checked and r.golden_ok
                else ("DIFF" if r.golden_checked else "n/a"))
        slo = "ok" if not r.slo_failures else "; ".join(r.slo_failures)
        lines.append(
            f"| `{r.cell.cell_id}` | {s.get('tok_s', 0):.1f} "
            f"| {s.get('p95_latency_s', 0):.3f} "
            f"| {s.get('ttft_p95_s', 0):.3f} "
            f"| {s.get('slot_utilization', 0):.3f} "
            f"| {s.get('rejected', 0)} | {s.get('preemptions', 0)} "
            f"| {s.get('restarts', 0)} | {twin} | {slo} |"
        )
    lines.append("")
    return "\n".join(lines)
