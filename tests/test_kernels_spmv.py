"""Predicated block-ELL SpMV Pallas kernel vs the pure-jnp oracle
(interpret mode), swept over shapes / dtypes / raggedness / repeat-K."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.spmv import ops, ref
from repro.kernels.spmv.kernel import spmv_blockell, spmv_fixed_width

SWEEP = [
    # n_rows, n_cols, row_block, max_nnz, width_pad, dtype
    (16, 64, 8, 32, 32, jnp.float32),
    (32, 128, 8, 64, 64, jnp.float32),
    (64, 256, 8, 128, 128, jnp.float32),
    (16, 64, 8, 17, 32, jnp.float32),     # ragged, non-multiple nnz
    (8, 32, 8, 8, 32, jnp.float32),
    (16, 64, 8, 32, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("n_rows,n_cols,rb,max_nnz,wp,dtype", SWEEP)
def test_blockell_matches_ref(n_rows, n_cols, rb, max_nnz, wp, dtype):
    vals, cols, nnz = ref.make_problem(
        jax.random.PRNGKey(0), n_rows, n_cols, row_block=rb, max_nnz=max_nnz,
        width_pad=wp, dtype=dtype,
    )
    x = jax.random.normal(jax.random.PRNGKey(1), (n_cols,), dtype)
    y_kernel = spmv_blockell(vals, cols, nnz, x, interpret=True)
    y_ref = ref.spmv_ref(vals, cols, nnz, x)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(y_kernel, np.float32), np.asarray(y_ref, np.float32),
        rtol=tol, atol=tol,
    )


def test_blockell_matches_dense_matmul():
    vals, cols, nnz = ref.make_problem(
        jax.random.PRNGKey(2), 24, 48, row_block=8, max_nnz=16, width_pad=16
    )
    x = jax.random.normal(jax.random.PRNGKey(3), (48,), jnp.float32)
    a = ref.dense_from_blockell(vals, cols, nnz, 48)
    y_dense = a @ np.asarray(x, np.float64)
    y_kernel = spmv_blockell(vals, cols, nnz, x, interpret=True)
    np.testing.assert_allclose(np.asarray(y_kernel), y_dense, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("repeat", [1, 4, 20])
def test_repeat_k_preserves_result(repeat):
    """The paper's synthetic intensity knob must not change the answer."""
    vals, cols, nnz = ref.make_problem(
        jax.random.PRNGKey(4), 16, 64, row_block=8, max_nnz=32, width_pad=32
    )
    x = jax.random.normal(jax.random.PRNGKey(5), (64,), jnp.float32)
    y1 = spmv_blockell(vals, cols, nnz, x, repeat=1, interpret=True)
    yk = spmv_blockell(vals, cols, nnz, x, repeat=repeat, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(yk), rtol=1e-4, atol=1e-5)


def test_fixed_width_equals_predicated_numerically():
    """ASIMD strawman = same numbers (padding is zero), different cost model."""
    vals, cols, nnz = ref.make_problem(
        jax.random.PRNGKey(6), 16, 64, row_block=8, max_nnz=32, width_pad=32
    )
    x = jax.random.normal(jax.random.PRNGKey(7), (64,), jnp.float32)
    yp = spmv_blockell(vals, cols, nnz, x, interpret=True)
    yf = spmv_fixed_width(vals, cols, nnz, x, interpret=True)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yf), rtol=1e-5, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), max_nnz=st.sampled_from([4, 16, 31]))
def test_property_random_problems(seed, max_nnz):
    vals, cols, nnz = ref.make_problem(
        jax.random.PRNGKey(seed), 16, 32, row_block=8, max_nnz=max_nnz, width_pad=32
    )
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (32,), jnp.float32)
    y_kernel = spmv_blockell(vals, cols, nnz, x, interpret=True)
    y_ref = ref.spmv_ref(vals, cols, nnz, x)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


def test_issue_count_model_matches_paper_shape():
    """Predicated wins exactly when rows are ragged (paper Fig. 3a SpMV)."""
    uniform = np.full(64, 128)
    counts_u = ops.issue_counts(uniform, width=128, lane=128)
    assert counts_u["predicated"] == counts_u["fixed_width"]
    ragged = np.concatenate([np.full(32, 8), np.full(32, 128)])
    counts_r = ops.issue_counts(ragged, width=128, lane=128)
    assert counts_r["predicated"] == counts_r["fixed_width"]  # both 1 tile/row
    # with lane < width the padded variant pays for the padding
    counts_l = ops.issue_counts(ragged, width=128, lane=16)
    assert counts_l["predicated"] < counts_l["fixed_width"]


def test_flops_bytes_model():
    fb = ops.flops_bytes(np.full(8, 16), repeat=10, dtype_bytes=4)
    nnz = 8 * 16
    assert fb["flops"] == 2.0 * 10 * nnz
    assert fb["bytes"] == nnz * 12
    assert fb["ai"] == pytest.approx(20 / 12)
