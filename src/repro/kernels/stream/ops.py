"""STREAM kernel call surface (served by the kernel registry) + the paper's
ELEN instruction model.

``copy``/``scale``/``add``/``triad`` are :class:`~repro.kernels.registry.
KernelOps` objects: call directly for interpret mode, ``.kernel(...)`` for
the compiled Pallas path, ``.ref(...)`` for the oracle.
"""

from __future__ import annotations

import math

from repro.kernels.registry import (
    STREAM_ADD as add,
    STREAM_COPY as copy,
    STREAM_SCALE as scale,
    STREAM_TRIAD as triad,
)

__all__ = ["copy", "scale", "add", "triad", "issue_counts"]


def issue_counts(n_elements: int, elen_bits: int, vlen_bits: int = 128) -> dict:
    """Paper Sec. 4.2: R_ins for STREAM tracks VB = VLEN/ELEN even though
    wall time is bandwidth-bound and flat."""
    lanes = vlen_bits // elen_bits
    return {
        "scalar": n_elements,
        "vector": math.ceil(n_elements / lanes),
        "r_ins": n_elements / math.ceil(n_elements / lanes),
        "vb": lanes,
    }
