"""Jacobi 2D 5-point stencil — the paper's memory-bound PDE sweep.

TPU adaptation: the grid is tiled over row-blocks; each program writes one
(br, W) output tile, reading its rows plus a one-row halo from the resident
input (a production variant double-buffers halo DMAs; the BlockSpec'd output
tiling and the shifted-adds vector body — no gather, pure VPU — are the
structure that matters).  Roofline: AI = 4 flops / 12 bytes per point
(fp32), firmly memory-bound (paper Fig. 7 / Table 3: Class 2 at 1 thread).

Boundary semantics: Dirichlet — the outermost ring passes through unchanged,
interior points get the 4-neighbour average.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jacobi_kernel(u_ref, out_ref, *, br: int, H: int, W: int):
    i = pl.program_id(0)
    r0 = i * br  # first output row of this tile

    mid = u_ref[pl.dslice(r0, br), :]

    # north neighbours: rows r0-1 .. r0+br-2.  The start is clamped at the
    # top edge; the clamped (r0 == 0) read is row-misaligned by one, fixed
    # with a roll — the affected row 0 is a boundary row and masked anyway.
    north = u_ref[pl.dslice(jnp.maximum(r0 - 1, 0), br), :]
    north = jnp.where(r0 == 0, jnp.roll(north, 1, axis=0), north)

    # south neighbours: rows r0+1 .. r0+br, clamped at the bottom edge.
    south = u_ref[pl.dslice(jnp.minimum(r0 + 1, H - br), br), :]
    south = jnp.where(r0 + br >= H, jnp.roll(south, -1, axis=0), south)

    west = jnp.pad(mid, ((0, 0), (1, 0)))[:, :W]
    east = jnp.pad(mid, ((0, 0), (0, 1)))[:, 1:]
    avg = 0.25 * (north + south + west + east)

    row = r0 + jax.lax.broadcasted_iota(jnp.int32, (br, W), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (br, W), 1)
    interior = (row > 0) & (row < H - 1) & (col > 0) & (col < W - 1)
    out_ref[...] = jnp.where(interior, avg.astype(out_ref.dtype), mid)


def jacobi_step(u: jax.Array, *, block_rows: int = 128, interpret: bool = True):
    """One Jacobi sweep over u (H, W)."""
    H, W = u.shape
    br = min(block_rows, H)
    assert H % br == 0, (H, br)
    return pl.pallas_call(
        lambda u_ref, o_ref: _jacobi_kernel(u_ref, o_ref, br=br, H=H, W=W),
        grid=(H // br,),
        in_specs=[pl.BlockSpec((H, W), lambda i: (0, 0))],  # resident + halo
        out_specs=pl.BlockSpec((br, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), u.dtype),
        interpret=interpret,
    )(u)
