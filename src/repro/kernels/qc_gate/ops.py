"""RX-gate kernel call surface (served by the kernel registry) + circuit
drivers."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.registry import RX_GATE as rx_gate

__all__ = ["rx_gate", "rx_layer", "zero_state"]


def rx_layer(re, im, n_qubits: int, theta: float, *, interpret: bool = True):
    """The paper's benchmark: one RX on every qubit (21-qubit problem)."""
    for q in range(n_qubits):
        re, im = rx_gate(re, im, qubit=q, theta=theta, interpret=interpret)
    return re, im


def zero_state(n_qubits: int):
    n_amp = 1 << n_qubits
    re = jnp.zeros((n_amp,), jnp.float32).at[0].set(1.0)
    im = jnp.zeros((n_amp,), jnp.float32)
    return re, im
