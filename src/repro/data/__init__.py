from repro.data.pipeline import DataConfig, global_batch, host_slice_for  # noqa: F401
