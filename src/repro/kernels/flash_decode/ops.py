"""Flash-decode kernel call surface (served by the kernel registry).

``flash_decode`` is the registry-managed contiguous-cache op.  The paged
variant (block-table indirection via scalar prefetch, the continuous-
batching serve path) is exported directly from the kernel module — its
block-pool calling convention doesn't fit the registry's
same-shaped-ref contract for event capture.
"""

from __future__ import annotations

from repro.kernels.flash_decode.kernel import flash_decode_paged
from repro.kernels.registry import FLASH_DECODE as flash_decode

__all__ = ["flash_decode", "flash_decode_paged"]
