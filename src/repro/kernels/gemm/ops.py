"""GEMM kernel call surface (served by the kernel registry).

The VMEM-footprint tile model that used to live here as a private search
loop is now owned by the shared tuning subsystem
(:mod:`repro.tuning.spaces`); ``vmem_bytes`` / ``pick_tiles`` stay as thin
delegates with identical behavior (golden-pinned in ``tests/test_tuning.
py``).  Prefer ``repro.tuning.tune("gemm")`` — it prunes the same space
with the adapted roofline and *times* the survivors instead of guessing by
tile volume.
"""

from __future__ import annotations

from repro.kernels.registry import GEMM as gemm
from repro.tuning.spaces import gemm_vmem_bytes, pick_gemm_tiles

__all__ = ["gemm", "vmem_bytes", "pick_tiles"]


def vmem_bytes(bm: int, bn: int, bk: int, in_bytes: int = 2) -> int:
    """Working set per grid step: x tile + y tile + fp32 acc + out tile."""
    return gemm_vmem_bytes(bm, bn, bk, in_bytes)


def pick_tiles(M: int, N: int, K: int, *, vmem_budget: int = 96 * 2**20,
               in_bytes: int = 2) -> tuple:
    """Largest MXU-aligned (multiple-of-128) tiles fitting the VMEM budget."""
    return pick_gemm_tiles(M, N, K, vmem_budget=vmem_budget, in_bytes=in_bytes)
