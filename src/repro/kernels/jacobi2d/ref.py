"""Oracle + analytic terms for the Jacobi2D stencil."""

from __future__ import annotations

import jax.numpy as jnp


def jacobi_ref(u):
    """One sweep, Dirichlet boundary (edges pass through)."""
    avg = 0.25 * (u[:-2, 1:-1] + u[2:, 1:-1] + u[1:-1, :-2] + u[1:-1, 2:])
    return u.at[1:-1, 1:-1].set(avg.astype(u.dtype))


def flops_bytes(H: int, W: int, dtype_bytes: int = 4) -> dict:
    """Per sweep: 4 flops/point; traffic = read u + write out (cold)."""
    n = float(H * W)
    flops = 4.0 * n
    bytes_ = 2.0 * n * dtype_bytes + n * dtype_bytes  # 5-pt reads ~cached: 3N words
    return {"flops": flops, "bytes": bytes_, "ai": flops / bytes_}
