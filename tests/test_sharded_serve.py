"""Tensor-parallel serving: mesh golden streams, head-sharded pools,
per-device utilization, and the mesh-aware perf ledger forks.

The tentpole contract: serving over a ``("data", "model")`` mesh must be
invisible in the tokens.  In-process tests pin the single-device corner
(mesh ``1x1`` — same engine code path, no forced devices needed) plus the
host-side lane accounting, sharding specs, and metric algebra; the
multi-device contract {2x1, 1x2, 2x2} runs as a SUBPROCESS under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (conftest forbids
forcing devices in-process) through :mod:`repro.serve.mesh_check`.
"""

import json
import os
import pathlib
import subprocess
import sys

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.core import metrics as core_metrics
from repro.launch.mesh import MeshShapeError, make_serve_mesh, parse_mesh
from repro.serve.engine import Request, ServeEngine
from repro.train import steps as steps_mod

SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")


def _mesh_1x1():
    return make_serve_mesh(1, 1)


@pytest.fixture(scope="module")
def gpt2():
    cfg = configs.get_smoke_config("gpt2-124m")
    return cfg, steps_mod.init_model(jax.random.PRNGKey(0), cfg)


def _traffic(cfg, n=4, seed=0, prefix_len=0):
    rng = np.random.default_rng(seed)
    prefix = (rng.integers(0, cfg.vocab, size=prefix_len).astype(np.int32)
              if prefix_len else None)
    out = []
    for uid in range(n):
        p = rng.integers(0, cfg.vocab,
                         size=int(rng.integers(3, 9))).astype(np.int32)
        if prefix is not None:
            p = np.concatenate([prefix, p])
        out.append(Request(uid=uid, prompt=p, max_new_tokens=6))
    return out


def _serve(cfg, params, mesh=None, **kw):
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                      scheduler="continuous", block_size=8, mesh=mesh, **kw)
    # two full shared blocks so prefix sharing actually dedups
    for r in _traffic(cfg, prefix_len=16 if kw.get("share_prefixes") else 0):
        eng.submit(r)
    done = eng.run_until_drained()
    return {u: list(r.generated) for u, r in done.items()}, eng


# ---------------------------------------------------------------------------
# Mesh construction and typed errors
# ---------------------------------------------------------------------------


class TestMeshErrors:
    def test_parse_mesh(self):
        assert parse_mesh("2x2") == (2, 2)
        assert parse_mesh("1x1") == (1, 1)
        assert parse_mesh("8X4") == (8, 4)

    @pytest.mark.parametrize("junk", ["", "2", "2x", "x2", "axb", "2x2x2",
                                      "0x2", "2x0", "-1x2"])
    def test_parse_mesh_junk_is_typed(self, junk):
        with pytest.raises(MeshShapeError):
            parse_mesh(junk)

    def test_mesh_shape_error_is_value_error(self):
        # argparse callers that catch ValueError keep working
        assert issubclass(MeshShapeError, ValueError)
        with pytest.raises(ValueError):
            parse_mesh("junk")

    def test_make_serve_mesh_too_many_devices(self):
        with pytest.raises(MeshShapeError) as ei:
            make_serve_mesh(64, 64)
        # the message must hand the operator the fix
        assert "xla_force_host_platform_device_count" in str(ei.value)
        assert ei.value.shape == (64, 64)
        assert ei.value.n_devices == jax.device_count()

    def test_make_host_mesh_indivisible_is_typed(self):
        from repro.launch.mesh import make_host_mesh
        n = jax.device_count()
        with pytest.raises(MeshShapeError):
            make_host_mesh(model_axis=n + 1)

    def test_serve_mesh_axes(self):
        mesh = _mesh_1x1()
        assert mesh.axis_names == ("data", "model")
        assert mesh.devices.size == 1


# ---------------------------------------------------------------------------
# Metric algebra (Eq. 1 one level up)
# ---------------------------------------------------------------------------


class TestDeviceMetrics:
    def test_device_lane_utilization_is_min_over_shards(self):
        # shard 0: 5 busy lane-steps of 4 steps x 2 lanes; shard 1: 3
        assert core_metrics.device_lane_utilization([5, 3], 4, 2) == 3 / 8
        # single shard degenerates to plain slot utilization
        assert core_metrics.device_lane_utilization([6], 4, 2) == 6 / 8

    def test_device_lane_utilization_degenerate(self):
        assert core_metrics.device_lane_utilization([], 4, 2) == 0.0
        assert core_metrics.device_lane_utilization([5, 3], 0, 2) == 0.0
        # clamped: a shard can't be more than fully busy
        assert core_metrics.device_lane_utilization([99], 4, 2) == 1.0

    def test_expert_imbalance(self):
        assert core_metrics.expert_imbalance([2, 2, 2]) == 1.0
        assert core_metrics.expert_imbalance([6, 0, 0]) == 3.0
        assert core_metrics.expert_imbalance([3, 1]) == 1.5
        assert core_metrics.expert_imbalance([]) == 1.0
        assert core_metrics.expert_imbalance([0, 0]) == 1.0

    def test_expert_imbalance_on_moe_router_census(self):
        # route real tokens through the deepseek-moe router params: the
        # census feeds expert_imbalance, which must stay in its algebraic
        # range [1, n_experts] on any routing
        cfg = configs.get_smoke_config("deepseek-moe-16b")
        params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
        found = []

        def visit(path, leaf):
            if any("router" in str(getattr(p, "key", p)) for p in path):
                found.append(leaf)
            return leaf

        jax.tree_util.tree_map_with_path(visit, params)
        assert found, "deepseek-moe has no router param"
        router = np.asarray(found[0]).reshape(-1, found[0].shape[-1])
        d, e = router.shape
        rng = np.random.default_rng(0)
        x = rng.standard_normal((64, d)).astype(np.float32)
        top = np.argmax(x @ router, axis=-1)
        loads = np.bincount(top, minlength=e)
        imb = core_metrics.expert_imbalance(loads.tolist())
        assert 1.0 <= imb <= e


# ---------------------------------------------------------------------------
# Sharding specs: the head-sharded paged pool
# ---------------------------------------------------------------------------


class TestPoolSharding:
    def test_sharded_pool_bytes_equal_replicated(self):
        # placement must be invisible in the bytes: the mesh-placed cache
        # round-trips to exactly the host cache
        from repro.models import transformer
        cfg = configs.get_smoke_config("gpt2-124m")
        mesh = _mesh_1x1()
        plain = transformer.init_paged_cache(cfg, 2, 64, 8, "int8")
        sharded = transformer.init_paged_cache(cfg, 2, 64, 8, "int8",
                                               mesh=mesh)
        flat_p = jax.tree_util.tree_leaves_with_path(plain)
        flat_s = dict(jax.tree_util.tree_leaves_with_path(sharded))
        assert set(flat_s) == {p for p, _ in flat_p}
        for path, leaf in flat_p:
            got = np.asarray(flat_s[path])
            assert np.array_equal(np.asarray(leaf), got), path

    def test_pool_specs_shard_heads_not_blocks(self):
        from repro.distributed.sharding import paged_cache_spec
        mesh = _mesh_1x1()
        # k/v pools (nsb, n_blocks, bs, KV, hd): heads over model,
        # block axis replicated (block tables stay device-local)
        spec = paged_cache_spec("k", (4, 9, 8, 4, 16), mesh)
        assert spec[3] == "model"
        assert spec[1] is None and spec[4] is None
        # scales are per token row: every head shard needs all of them
        assert tuple(paged_cache_spec("k_scale", (4, 9, 8), mesh)) == \
            (None, None, None)
        # MLA latent pools are per-token, not per-head
        assert tuple(paged_cache_spec("c", (4, 9, 8, 32), mesh)) == \
            (None, None, None, None)

    def test_ssm_state_never_model_sharded_on_this_mesh(self):
        # the CPU SPMD partitioner miscompiles partially-replicated mamba
        # scan operands on 2-D meshes, so the recurrent state takes the
        # model axis only on a single-axis mesh (flat == model size);
        # on 1x1 (model size 1) it must not pick up "model" at all —
        # the multi-device behaviour is pinned cross-mesh in the
        # subprocess golden check
        from repro.distributed.sharding import paged_cache_spec
        mesh = _mesh_1x1()
        for key, shape in (("ssm_state", (4, 2, 8, 16, 16)),
                           ("conv_state", (4, 2, 3, 32))):
            spec = paged_cache_spec(key, shape, mesh)
            assert "model" not in tuple(spec), (key, spec)

    def test_serve_param_shardings_on_1x1(self):
        # on a single-axis mesh serve_param_shardings is exactly
        # param_shardings (the mamba replication fallback fires only on
        # 2-D meshes)
        from repro.distributed import sharding as sh
        cfg = configs.get_smoke_config("mamba2-370m")
        params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
        mesh = _mesh_1x1()
        a = jax.tree_util.tree_leaves(sh.param_shardings(params, mesh))
        b = jax.tree_util.tree_leaves(sh.serve_param_shardings(params, mesh))
        assert [s.spec for s in a] == [s.spec for s in b]


# ---------------------------------------------------------------------------
# Mesh 1x1: the in-process golden corner
# ---------------------------------------------------------------------------


class TestMesh1x1:
    def test_streams_match_unsharded(self, gpt2):
        cfg, params = gpt2
        base, _ = _serve(cfg, params, mesh=None)
        mesh, eng = _serve(cfg, params, mesh=_mesh_1x1())
        assert mesh == base
        assert eng.mesh_shape == "1x1"
        st = eng.stats()
        assert st["mesh"] == "1x1"
        assert st["mesh_devices"] == 1

    def test_streams_match_with_int8_sharing_speculation(self, gpt2):
        cfg, params = gpt2
        kw = dict(kv_dtype="int8", share_prefixes=True, spec_k=2,
                  draft_cfg=cfg, draft_params=params)
        base, _ = _serve(cfg, params, mesh=None, **kw)
        mesh, eng = _serve(cfg, params, mesh=_mesh_1x1(), **kw)
        assert mesh == base
        st = eng.stats()
        assert st["drafted_tokens"] > 0
        assert st["shared_block_hits"] > 0

    def test_device_lane_utilization_pinned(self, gpt2):
        # single shard: device_lane_utilization IS slot_utilization, and
        # both are step-clock deterministic for a fixed trace
        cfg, params = gpt2
        _, eng = _serve(cfg, params, mesh=_mesh_1x1())
        st = eng.stats()
        assert st["device_lane_utilization"] == pytest.approx(
            st["slot_utilization"])
        assert st["device_lane_utilization"] == pytest.approx(
            int(eng.device_busy_lane_steps.sum())
            / (st["fused_steps"] * eng.max_batch))

    def test_block_pool_invariants_under_sharded_cow(self, gpt2):
        # COW + int8 + mesh: the pool's refcount/free-list algebra must
        # hold after every fused step, not just at drain
        cfg, params = gpt2
        eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                          scheduler="continuous", block_size=8,
                          kv_dtype="int8", share_prefixes=True,
                          mesh=_mesh_1x1())
        checked = [0]

        def check(engine, busy):
            engine._live["pool"].check_invariants()
            checked[0] += 1
            return False

        eng.add_step_hook(check)
        for r in _traffic(cfg, prefix_len=16):
            eng.submit(r)
        eng.run_until_drained()
        assert checked[0] > 0

    def test_mesh_requires_continuous(self, gpt2):
        cfg, params = gpt2
        with pytest.raises(ValueError, match="continuous"):
            ServeEngine(cfg, params, max_batch=2, max_len=64,
                        scheduler="wave", mesh=_mesh_1x1())


# ---------------------------------------------------------------------------
# Cross-mesh: the subprocess contract
# ---------------------------------------------------------------------------


def _run_mesh_check(*args):
    env = {**os.environ, "PYTHONPATH": SRC,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4"}
    return subprocess.run(
        [sys.executable, "-m", "repro.serve.mesh_check", *args],
        capture_output=True, text=True, env=env)


def _assert_verdict(verdict, want_workloads):
    assert verdict["ok"], verdict["diffs"]
    assert set(verdict["workloads"]) == want_workloads
    for name, per in verdict["workloads"].items():
        digests = {d["digest"] for d in per.values()}
        assert len(digests) == 1, (name, per)
        assert per["2x2"]["mesh_devices"] == 4
        # min-over-shards can only tighten as data shards split the lanes
        assert per["2x1"]["device_lane_utilization"] <= \
            per["none"]["device_lane_utilization"] + 1e-9
        # speculation keeps drafting under sharding
        if "spec" in name:
            assert all(d["drafted_tokens"] > 0 for d in per.values())


def test_cross_mesh_streams_base_archs(tmp_path):
    """THE tentpole gate, part 1: all six serve architectures produce
    byte-identical token streams on every mesh shape."""
    out = tmp_path / "verdict.json"
    proc = _run_mesh_check(
        "--workloads", "gpt2,qwen3,mamba2,mla,moe,jamba",
        "--meshes", "none,2x1,1x2,2x2",
        "--requests", "2", "--max-new", "5", "--out", str(out))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    verdict = json.loads(out.read_text())
    assert verdict["shapes"] == ["none", "2x1", "1x2", "2x2"]
    _assert_verdict(verdict, {"gpt2", "qwen3", "mamba2", "mla", "moe",
                              "jamba"})


def test_cross_mesh_streams_compositions(tmp_path):
    """THE tentpole gate, part 2: int8 paging + prefix sharing and
    (adaptive) speculation survive sharding byte-for-byte."""
    out = tmp_path / "verdict.json"
    proc = _run_mesh_check(
        "--workloads", "gpt2-int8-shared,gpt2-spec,gpt2-spec-adapt",
        "--meshes", "none,1x1,2x1,1x2,2x2",
        "--requests", "3", "--max-new", "6", "--out", str(out))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    verdict = json.loads(out.read_text())
    assert verdict["shapes"] == ["none", "1x1", "2x1", "1x2", "2x2"]
    _assert_verdict(verdict, {"gpt2-int8-shared", "gpt2-spec",
                              "gpt2-spec-adapt"})
    for per in verdict["workloads"].values():
        # 1x1 is the same engine code path with a trivial mesh: exactly
        # the unsharded utilization
        assert per["1x1"]["device_lane_utilization"] == pytest.approx(
            per["none"]["device_lane_utilization"])


# ---------------------------------------------------------------------------
# Ledger and gate wiring
# ---------------------------------------------------------------------------


class TestLedgerMeshForks:
    def _report(self, **over):
        stats = {
            "requests": 4, "new_tokens": 32, "fused_steps": 17,
            "tok_s": 100.0, "p50_latency_s": 0.1, "p95_latency_s": 0.2,
            "ttft_p50_s": 0.05, "ttft_p95_s": 0.1, "slot_utilization": 0.8,
            "busy_slot_steps": 53, "slot_steps": 68,
            "scheduler": "continuous", "preemptions": 0,
            "device_lane_utilization": 0.65, "mesh_devices": 4,
        }
        rep = {"kind": "serve_report", "arch": "gpt2-124m",
               "scheduler": "continuous", "stats": stats,
               "spec_k": 0, "requests": []}
        rep.update(over)
        return rep

    def test_mesh_key_fork(self):
        from repro.perf.ledger import metrics_from_serving
        rows = metrics_from_serving(self._report(mesh="2x2"))
        (key,) = rows
        assert key == "serve/gpt2-124m@continuous+mesh2x2"
        assert rows[key]["mesh_devices"] == 4
        assert rows[key]["device_lane_utilization"] == 0.65

    def test_no_mesh_no_fork(self):
        from repro.perf.ledger import metrics_from_serving
        rows = metrics_from_serving(self._report())
        (key,) = rows
        assert key == "serve/gpt2-124m@continuous"

    def test_adapt_fork_orders_before_mesh(self):
        from repro.perf.ledger import metrics_from_serving
        rows = metrics_from_serving(self._report(
            mesh="2x1", spec_k=2, spec_adaptive=True))
        (key,) = rows
        assert key == "serve/gpt2-124m@continuous+spec2+adapt+mesh2x1"

    def test_device_lane_utilization_gated_at_tol0(self):
        # the new metrics are exact-trajectory gates: any drop regresses
        from repro.perf.compare import SPECS
        assert SPECS["device_lane_utilization"].worse == "lower"
        assert SPECS["device_lane_utilization"].rel_tol == 0.0
        assert not SPECS["device_lane_utilization"].noisy
        assert SPECS["mesh_devices"].worse == "lower"
        assert SPECS["mesh_devices"].rel_tol == 0.0

    def test_gate_flags_lane_utilization_drop(self):
        from repro.perf.compare import compare_runs
        from repro.perf.ledger import BenchRun, capture_env
        key = "serve/gpt2-124m@continuous+mesh2x2"

        def run(seq, dlu):
            return BenchRun(
                run_id=f"r{seq}", seq=seq, timestamp=float(seq),
                env=capture_env(),
                metrics={key: {"tok_s": 100.0, "mesh_devices": 4,
                               "device_lane_utilization": dlu}})

        drop = compare_runs(run(1, 0.65), run(2, 0.60))
        assert any(r.metric == "device_lane_utilization"
                   for r in drop.regressions)
        same = compare_runs(run(1, 0.65), run(3, 0.65))
        assert not any(r.metric == "device_lane_utilization"
                       for r in same.regressions)


# ---------------------------------------------------------------------------
# Sharded kernel surface
# ---------------------------------------------------------------------------


class TestHeadShardedKernel:
    def _toy(self):
        rng = np.random.default_rng(0)
        B, KV, G, D, bs, nblk, nb = 2, 4, 2, 8, 4, 9, 3
        q = rng.standard_normal((B, KV, G, D)).astype(np.float32)
        kp = rng.standard_normal((nblk, bs, KV, D)).astype(np.float32)
        vp = rng.standard_normal((nblk, bs, KV, D)).astype(np.float32)
        bt = (rng.permutation(nblk - 1)[:B * nb].reshape(B, nb) + 1
              ).astype(np.int32)
        vl = np.array([7, 11], np.int32)
        return q, kp, vp, bt, vl

    def test_head_shard_concat_equals_full(self):
        from repro.kernels.flash_decode.kernel import flash_decode_paged
        q, kp, vp, bt, vl = self._toy()
        full = np.asarray(flash_decode_paged(q, kp, vp, bt, vl))
        parts = [np.asarray(flash_decode_paged(q, kp, vp, bt, vl,
                                               head_shard=(i, 2)))
                 for i in range(2)]
        assert parts[0].shape[1] == q.shape[1] // 2
        assert np.array_equal(np.concatenate(parts, axis=1), full)

    def test_head_shard_validation(self):
        from repro.kernels.flash_decode.kernel import flash_decode_paged
        q, kp, vp, bt, vl = self._toy()
        with pytest.raises(ValueError, match="not divisible"):
            flash_decode_paged(q, kp, vp, bt, vl, head_shard=(0, 3))
        with pytest.raises(ValueError, match="outside"):
            flash_decode_paged(q, kp, vp, bt, vl, head_shard=(2, 2))

    def test_sharded_wrapper_on_trivial_mesh(self):
        from repro.kernels.flash_decode.kernel import (
            flash_decode_paged, flash_decode_paged_sharded)
        q, kp, vp, bt, vl = self._toy()
        mesh = _mesh_1x1()
        full = np.asarray(flash_decode_paged(q, kp, vp, bt, vl))
        sh = np.asarray(flash_decode_paged_sharded(q, kp, vp, bt, vl,
                                                   mesh=mesh))
        assert np.array_equal(full, sh)
