"""Architecture registry + ShapeDtypeStruct input specs for every cell.

``get_config(arch)`` / ``get_smoke_config(arch)`` return ModelConfigs;
``input_specs(cfg, shape)`` returns the ShapeDtypeStruct stand-ins for the
step function that the (arch x shape) cell lowers:

* train_*   -> ``train_step``  : tokens/labels (+ modality stubs)
* prefill_* -> ``prefill``     : prompt tokens (+ modality stubs)
* decode_*  -> ``decode_step`` : one new token + a seq_len KV/SSM cache

Shape applicability (DESIGN.md §4): ``long_500k`` only for sub-quadratic
archs (mamba2, jamba); every other (arch x shape) cell runs.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_ARCH_MODULES = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "internvl2-76b": "internvl2_76b",
    "mamba2-370m": "mamba2_370m",
    "qwen2.5-14b": "qwen2_5_14b",
    "olmo-1b": "olmo_1b",
    "qwen3-32b": "qwen3_32b",
    "qwen3-1.7b": "qwen3_1_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "whisper-large-v3": "whisper_large_v3",
    "gpt2-124m": "gpt2_124m",
}

ASSIGNED_ARCHS: List[str] = [a for a in _ARCH_MODULES if a != "gpt2-124m"]
ALL_ARCHS: List[str] = list(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).FULL


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False  # full-attention archs skip 500k decode (DESIGN.md §4)
    return True


def cells(include_paper_arch: bool = False):
    """All applicable (arch, shape) cells."""
    archs = ALL_ARCHS if include_paper_arch else ASSIGNED_ARCHS
    out = []
    for a in archs:
        cfg = get_config(a)
        for s in SHAPES.values():
            if shape_applicable(cfg, s):
                out.append((a, s.name))
    return out


# --------------------------------------------------------------------------
# input specs
# --------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    No device allocation; weak-type-correct; shardable.  The dict keys match
    the keyword arguments of the step functions in ``repro.train.steps``.
    """
    B, S = shape.global_batch, shape.seq_len
    cdtype = cfg.compute_dtype

    if not shape_applicable(cfg, shape):
        raise ValueError(f"{cfg.name} x {shape.name} is skipped (see DESIGN.md §4)")

    if cfg.is_encoder_decoder:
        s_enc = max(S // 4, 8)  # stubbed 2x stride-2 conv frontend
        if shape.kind == "train":
            return {
                "enc_frames": _sds((B, s_enc, cfg.d_model), cdtype),
                "tokens": _sds((B, S), jnp.int32),
                "labels": _sds((B, S), jnp.int32),
            }
        if shape.kind == "prefill":
            return {
                "enc_frames": _sds((B, s_enc, cfg.d_model), cdtype),
                "tokens": _sds((B, S), jnp.int32),
            }
        # decode: one token over a seq_len self-KV cache + cross-KV
        from repro.models import whisper as whisper_mod

        cache = jax.eval_shape(
            lambda: whisper_mod.init_dec_cache(cfg, B, S, s_enc)
        )
        return {"tokens": _sds((B, 1), jnp.int32), "cache": cache}

    if cfg.family == "vlm":
        n_img = cfg.n_img_tokens
        s_text = S - n_img
        if shape.kind == "train":
            return {
                "img_embeds": _sds((B, n_img, cfg.d_model), cdtype),
                "tokens": _sds((B, s_text), jnp.int32),
                "labels": _sds((B, s_text), jnp.int32),
            }
        if shape.kind == "prefill":
            return {
                "img_embeds": _sds((B, n_img, cfg.d_model), cdtype),
                "tokens": _sds((B, s_text), jnp.int32),
            }

    if shape.kind == "train":
        return {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
    if shape.kind == "prefill":
        return {"tokens": _sds((B, S), jnp.int32)}

    # decode: one new token with a seq_len cache
    from repro.models import transformer as tf_mod

    cache = jax.eval_shape(lambda: tf_mod.init_cache(cfg, B, S))
    return {"tokens": _sds((B, 1), jnp.int32), "cache": cache}
