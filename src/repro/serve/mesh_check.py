"""Cross-mesh golden harness: serve the same traffic on every mesh shape
and demand byte-identical token streams.

The tentpole contract of tensor-parallel serving is that the mesh is
invisible in the tokens: sharding attention heads, MoE experts, and the
paged KV block pool over a ``("data", "model")`` mesh may move the math
across devices but must never change it.  This module is the executable
form of that contract — ``run_check`` serves one seeded workload per
architecture on each requested mesh shape (``None`` = the unsharded
engine) and diffs every stream against the unsharded baseline,
uid-for-uid, token-for-token.

Because host platforms only expose multiple devices when
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` is set *before*
jax initializes, multi-device checks run this module as a SUBPROCESS::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        python -m repro.serve.mesh_check --meshes none,1x1,2x1,1x2,2x2

The JSON verdict on stdout carries per-(arch, mesh) stream digests, the
diff list (empty = contract holds), and per-device utilization — both
the CI mesh-smoke job and tests/test_sharded_serve.py consume it.
"""

from __future__ import annotations

import argparse
import hashlib
import json
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

#: (name, arch, engine-kwargs overrides) — the six golden-verified serve
#: architectures plus the composition cells the ISSUE pins: quantized
#: paging + prefix sharing + speculation must survive sharding too.
DEFAULT_WORKLOADS = (
    ("gpt2", "gpt2-124m", {}),
    ("qwen3", "qwen3-1.7b", {}),
    ("mamba2", "mamba2-370m", {}),
    ("mla", "deepseek-v2-lite-16b", {}),
    ("moe", "deepseek-moe-16b", {}),
    ("jamba", "jamba-1.5-large-398b", {}),
    ("gpt2-int8-shared", "gpt2-124m",
     {"kv_dtype": "int8", "share_prefixes": True, "shared_prefix_len": 6}),
    ("gpt2-spec", "gpt2-124m", {"spec_k": 2, "draft": "gpt2-124m"}),
    ("gpt2-spec-adapt", "gpt2-124m",
     {"spec_k": 2, "draft": "gpt2-124m", "spec_adaptive": True}),
)


def _mesh_from_shape(shape: Optional[str]):
    if shape is None:
        return None
    from repro.launch.mesh import make_serve_mesh, parse_mesh

    return make_serve_mesh(*parse_mesh(shape))


def serve_workload(arch: str, mesh, *, requests: int = 4, max_new: int = 8,
                   max_batch: int = 2, max_len: int = 64,
                   block_size: int = 8, seed: int = 0,
                   kv_dtype: str = "f32", share_prefixes: bool = False,
                   shared_prefix_len: int = 0, spec_k: int = 0,
                   draft: Optional[str] = None,
                   spec_adaptive: bool = False) -> Dict[str, Any]:
    """Serve one seeded workload; returns streams + engine stats.

    Traffic depends only on (arch, seed, sizing) — never on the mesh —
    so the same call with a different ``mesh`` is a golden twin.
    """
    import jax

    import repro.configs as configs
    from repro.serve.engine import Request, ServeEngine
    from repro.train import steps as steps_mod

    cfg = configs.get_smoke_config(arch)
    params = steps_mod.init_model(jax.random.PRNGKey(seed), cfg)
    draft_cfg = draft_params = None
    if spec_k > 0:
        draft_cfg = configs.get_smoke_config(draft or arch)
        draft_params = steps_mod.init_model(jax.random.PRNGKey(seed),
                                            draft_cfg)
    engine = ServeEngine(
        cfg, params, max_batch=max_batch, max_len=max_len,
        scheduler="continuous", block_size=block_size, kv_dtype=kv_dtype,
        share_prefixes=share_prefixes, spec_k=spec_k, draft_cfg=draft_cfg,
        draft_params=draft_params, spec_adaptive=spec_adaptive, mesh=mesh,
    )
    rng = np.random.default_rng(seed)
    prefix = (rng.integers(0, cfg.vocab, size=shared_prefix_len)
              .astype(np.int32) if shared_prefix_len > 0 else None)
    for uid in range(requests):
        plen = int(rng.integers(3, 10))
        prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        if prefix is not None:
            prompt = np.concatenate([prefix, prompt])
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=max_new))
    done = engine.run_until_drained()
    stats = engine.stats()
    return {
        "streams": {int(u): [int(t) for t in r.generated]
                    for u, r in done.items()},
        "mesh": engine.mesh_shape,
        "device_lane_utilization": stats["device_lane_utilization"],
        "mesh_devices": stats["mesh_devices"],
        "fused_steps": stats["fused_steps"],
        "drafted_tokens": stats.get("drafted_tokens", 0),
        "physical_blocks": stats.get("physical_blocks", 0),
        "logical_blocks": stats.get("logical_blocks", 0),
    }


def _digest(streams: Dict[int, List[int]]) -> str:
    blob = json.dumps({str(k): streams[k] for k in sorted(streams)},
                      sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def run_check(meshes: Sequence[Optional[str]],
              workloads=DEFAULT_WORKLOADS, *, requests: int = 4,
              max_new: int = 8, seed: int = 0) -> Dict[str, Any]:
    """Serve every workload on every mesh shape; diff against unsharded.

    The ``None`` baseline is always run (prepended when absent) — it is
    the stream every meshed run must reproduce byte-for-byte.
    """
    shapes = list(meshes)
    if None not in shapes:
        shapes.insert(0, None)
    results: Dict[str, Any] = {"workloads": {}, "diffs": [], "shapes": [
        s or "none" for s in shapes]}
    for name, arch, overrides in workloads:
        per_mesh: Dict[str, Any] = {}
        baseline = None
        for shape in shapes:
            out = serve_workload(arch, _mesh_from_shape(shape),
                                 requests=requests, max_new=max_new,
                                 seed=seed, **overrides)
            per_mesh[shape or "none"] = {
                "digest": _digest(out["streams"]),
                "device_lane_utilization": out["device_lane_utilization"],
                "mesh_devices": out["mesh_devices"],
                "fused_steps": out["fused_steps"],
                "drafted_tokens": out["drafted_tokens"],
            }
            if shape is None:
                baseline = out["streams"]
            else:
                for uid in sorted(baseline):
                    got = out["streams"].get(uid)
                    if got != baseline[uid]:
                        results["diffs"].append(
                            f"{name}@{shape}: uid {uid} diverged "
                            f"({got} != {baseline[uid]})")
        results["workloads"][name] = per_mesh
    results["ok"] = not results["diffs"]
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--meshes", default="none,1x1,2x1,1x2,2x2",
                    help="comma list of DxM shapes ('none' = unsharded "
                         "baseline; always included)")
    ap.add_argument("--workloads", default=None,
                    help="comma list of workload names to run "
                         "(default: all)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write the verdict here "
                    "instead of stdout")
    args = ap.parse_args(argv)
    shapes = [None if s in ("none", "") else s
              for s in args.meshes.split(",")]
    workloads = DEFAULT_WORKLOADS
    if args.workloads:
        want = set(args.workloads.split(","))
        unknown = want - {w[0] for w in DEFAULT_WORKLOADS}
        if unknown:
            ap.error(f"unknown workloads: {sorted(unknown)}")
        workloads = tuple(w for w in DEFAULT_WORKLOADS if w[0] in want)
    verdict = run_check(shapes, workloads, requests=args.requests,
                        max_new=args.max_new, seed=args.seed)
    blob = json.dumps(verdict, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob)
        print(f"mesh check {'OK' if verdict['ok'] else 'FAILED'} "
              f"-> {args.out}")
    else:
        print(blob)
    return 0 if verdict["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
