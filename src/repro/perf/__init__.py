"""Perf trajectory ledger + decision-tree regression gate.

The layer that makes every other persistent artifact pay rent: benchmark
``summary.json``, autotuner ``tuning.json``, SVE analysis reports, and
serving reports (``python -m repro.launch.serve`` — tok/s, p50/p95 request
latency, slot utilization) are ingested into an append-only,
content-addressed ledger of
:class:`~repro.perf.ledger.BenchRun` records (stamped with a
:class:`~repro.perf.ledger.RunEnv` fingerprint: chip, dtype, git SHA, jax
version, tuned-config hash), baselines are resolved by policy
(``latest`` / ``pinned:<sha>`` / ``median:<K>``), regressions are detected
with noise-aware per-metric tolerances, and every confirmed regression is
routed back through the paper's Fig. 8 decision tree and Eq. 2 adapted
roofline so the gate reports *why* — a PerfClass transition with the AI
vs AI_IRV quantities that justify it — not just "slower".

    from repro.perf import Ledger, capture_env, gate_run, metrics_from_analysis

    run = ledger.record(metrics_from_analysis([analysis]), env=capture_env())
    result = gate_run(run, ledger, policy="latest")
    sys.exit(result.exit_code)

CLI: ``python -m repro.perf record|compare|gate|report`` (see
``docs/PERF.md`` for the executable walkthrough); ``python -m
benchmarks.run --record --gate`` wires the same path behind the benchmark
driver.
"""

from repro.perf.ledger import (  # noqa: F401
    PERF_VERSION,
    BenchRun,
    Ledger,
    RunEnv,
    capture_env,
    default_ledger,
    default_perf_dir,
    git_sha,
    metrics_from_analysis,
    metrics_from_serving,
    metrics_from_summary,
    metrics_from_tuning,
    tuned_state_hash,
)
from repro.perf.baseline import resolve_baseline  # noqa: F401
from repro.perf.compare import (  # noqa: F401
    SPECS,
    MetricDelta,
    MetricSpec,
    Regression,
    RunComparison,
    compare_runs,
)
from repro.perf.triage import Triage, triage_regressions  # noqa: F401
from repro.perf.gate import (  # noqa: F401
    GateResult,
    export_trajectory,
    format_markdown,
    gate_run,
)
