"""Regression triage: explain each regression with the paper's analytics.

A gate that says "8% slower" sends the engineer off to bisect; the paper's
Fig. 8 decision tree can usually say *why*.  For every regressed workload
whose BenchRun metrics carry the analytic quantities (AI, R_ins, traffic,
gather share), the triage re-runs :func:`repro.core.decision_tree.classify`
on both the baseline point and the regressed point, reads the Eq. 2
inflection points off :func:`repro.core.roofline.adapted_roofline`, and
reports the class transition in the paper's own terms:

    kernel/gemm@grace-core/fp32: slipped from Class 4 (SPEEDUP) to
    Class 2 (MEMORY_BANDWIDTH_BOUND): AI fell 42.7 -> 0.67, left of
    AI_IRV=0.833 (AI_IRR=0.208); hbm_bytes grew 64.0x

plus a suspect list: a tuned-config change between the runs, a stale
:class:`~repro.tuning.records.TuningRecord` (the tuning store's current
best for that kernel disagrees with the config the run used — found by
enumerating the store, which is what :meth:`~repro.analysis.store.
ArtifactStore.iter_json` exists for), a git SHA change, or — when every
deterministic counter is unchanged — plain wall-clock noise.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.core import hw
from repro.core.decision_tree import Decision, PerfClass, classify
from repro.core.metrics import VectorizationReport
from repro.core.roofline import adapted_roofline
from repro.perf.compare import Regression, RunComparison
from repro.perf.ledger import BenchRun

#: Metric names whose values are deterministic counters (not wall noise).
_COUNTER_METRICS = (
    "ai", "r_ins", "flops", "hbm_bytes", "gather_bytes",
    "vectorizable_fraction", "perf_class", "predicted_speedup", "rows",
) + (
    # serving scheduler counters: pure functions of the seeded request
    # trace + scheduler config, so their movement is a behavior change
    "fused_steps", "busy_slot_steps", "slot_steps", "slot_utilization",
    "ttft_p50_steps", "ttft_p95_steps", "prefill_chunk",
    "preemptions", "rejected", "restarts", "requests", "new_tokens",
)

#: The subset that the continuous scheduler's admission/chunking/budget
#: policy controls directly — regressions here get a scheduling suspect.
_SCHED_METRICS = (
    "fused_steps", "busy_slot_steps", "slot_steps",
    "ttft_p50_steps", "ttft_p95_steps", "prefill_chunk",
)


def split_key(key: str) -> Tuple[str, Optional[str], Optional[str]]:
    """``"kernel/gemm@grace-core/fp32"`` -> (workload, chip, dtype)."""
    if "@" not in key:
        return key, None, None
    workload, _, rest = key.partition("@")
    chip, _, dtype = rest.partition("/")
    return workload, chip or None, dtype or None


def report_from_metrics(
    key: str, m: Mapping[str, Any], dtype: str
) -> Optional[VectorizationReport]:
    """Rebuild the decision tree's input from one stored metric dict.

    ``r_ins`` is stored directly, so the scalar/vector issue counts are
    reconstructed as (r_ins, 1) — ``instruction_reduction`` is their ratio
    and nothing downstream reads the absolute counts.
    """
    if "flops" not in m or "hbm_bytes" not in m:
        return None
    return VectorizationReport(
        name=key,
        dtype=dtype,
        flops=float(m["flops"]),
        hbm_bytes=float(m["hbm_bytes"]),
        gather_bytes=float(m.get("gather_bytes", 0.0)),
        ins_scalar=float(m.get("r_ins", 1.0)),
        ins_vec=1.0,
        vectorizable_fraction=float(m.get("vectorizable_fraction", 1.0)),
    )


@dataclasses.dataclass(frozen=True)
class Triage:
    """The explained form of one workload's regression(s)."""

    key: str
    metrics: Tuple[str, ...]  # regressed metric names
    class_before: Optional[PerfClass]
    class_after: Optional[PerfClass]
    decision_before: Optional[Decision]
    decision_after: Optional[Decision]
    ai_before: Optional[float]
    ai_after: Optional[float]
    ai_irr: Optional[float]
    ai_irv: Optional[float]
    suspects: Tuple[str, ...]
    narrative: str

    @property
    def class_transition(self) -> Optional[str]:
        if self.class_before is None or self.class_after is None:
            return None
        return (
            f"Class {int(self.class_before)} ({self.class_before.name}) -> "
            f"Class {int(self.class_after)} ({self.class_after.name})"
        )

    def to_dict(self) -> Dict[str, Any]:
        return {
            "key": self.key,
            "metrics": list(self.metrics),
            "class_before": None if self.class_before is None else int(self.class_before),
            "class_after": None if self.class_after is None else int(self.class_after),
            "class_transition": self.class_transition,
            "ai_before": self.ai_before,
            "ai_after": self.ai_after,
            "ai_irr": self.ai_irr,
            "ai_irv": self.ai_irv,
            "rationale_after": (
                None if self.decision_after is None else self.decision_after.rationale
            ),
            "suspects": list(self.suspects),
            "narrative": self.narrative,
        }


# ---------------------------------------------------------------------------
# Suspects
# ---------------------------------------------------------------------------


def _store_configs(tuning_store: Any) -> Dict[Tuple[str, str, str], set]:
    """(kernel, chip, dtype) -> set of persisted config tokens.

    Enumerates the tuning store through ``iter_json`` — content addresses
    cannot be recomputed here (they cover example args and bytecode), but
    the payloads carry the identity triple.  A triple routinely holds
    several records (different problem shapes, capped CI spaces), so the
    staleness check collects them ALL: a run is suspect only when its
    config matches none of the store's known-best configs.
    """
    from repro.perf.ledger import _config_token
    from repro.tuning.records import TUNING_VERSION, resolve_store

    out: Dict[Tuple[str, str, str], set] = {}
    try:
        store = resolve_store(tuning_store)
    except Exception:  # noqa: BLE001 — triage is advisory, never raises
        return out
    if store is None:
        return out
    for _, payload in store.iter_json():
        if payload.get("tuning_version") != TUNING_VERSION:
            continue
        rec = payload.get("record") or {}
        triple = (str(rec.get("kernel")), str(rec.get("chip")), str(rec.get("dtype")))
        out.setdefault(triple, set()).add(_config_token(rec.get("config") or {}))
    return out


def _suspects(
    key: str,
    regressed: List[Regression],
    before_m: Mapping[str, Any],
    after_m: Mapping[str, Any],
    baseline: BenchRun,
    run: BenchRun,
    store_configs: Mapping[Tuple[str, str, str], set],
) -> List[str]:
    out: List[str] = []
    workload, chip, dtype = split_key(key)
    kernel = workload.rsplit("/", 1)[-1]
    cfg_before = before_m.get("config")
    cfg_after = after_m.get("config")
    if cfg_before != cfg_after:
        out.append(f"tuned config changed: {cfg_before!r} -> {cfg_after!r}")
    store_cfgs = store_configs.get((kernel, chip or "", dtype or ""))
    if store_cfgs and cfg_after is not None and cfg_after not in store_cfgs:
        known = ", ".join(repr(c) for c in sorted(store_cfgs))
        out.append(
            f"stale TuningRecord: run used {cfg_after!r}, store best is "
            f"{known} — re-run `python -m repro.tuning`"
        )
    if baseline.env.tuned_hash != run.env.tuned_hash:
        out.append(
            f"active tuned-config set changed "
            f"({baseline.env.tuned_hash or 'none'} -> {run.env.tuned_hash or 'none'})"
        )
    if baseline.env.git_sha != run.env.git_sha:
        out.append(
            f"code changed: {baseline.env.git_sha} -> {run.env.git_sha}"
        )
    hbm_b, hbm_a = before_m.get("hbm_bytes"), after_m.get("hbm_bytes")
    if (isinstance(hbm_b, (int, float)) and isinstance(hbm_a, (int, float))
            and hbm_b > 0 and hbm_a > hbm_b * 1.02):
        out.append(f"HBM traffic grew {hbm_a / hbm_b:.3g}x")
    sched = sorted({r.metric for r in regressed if r.metric in _SCHED_METRICS})
    if sched:
        out.append(
            "deterministic scheduler counters moved ("
            + ", ".join(sched)
            + "): admission/chunking/budget policy changed, not machine noise"
        )
    if not any(r.metric in _COUNTER_METRICS for r in regressed):
        out.append(
            "wall-time regression with unchanged counters: suspect machine "
            "noise or runtime environment, not the kernel"
        )
    return out


# ---------------------------------------------------------------------------
# The triage pass
# ---------------------------------------------------------------------------


def _triage_one(
    key: str,
    regressed: List[Regression],
    baseline: BenchRun,
    run: BenchRun,
    store_configs: Mapping[Tuple[str, str, str], set],
) -> Triage:
    before_m = baseline.metrics.get(key) or {}
    after_m = run.metrics.get(key) or {}
    workload, chip_name, dtype = split_key(key)
    dtype = dtype or run.env.dtype
    chip: Optional[hw.ChipSpec] = None
    try:
        chip = hw.get_chip(chip_name or run.env.chip)
    except KeyError:
        chip = None

    dec_before = dec_after = None
    rl = None
    if chip is not None:
        rl = adapted_roofline(chip, dtype)
        rep_before = report_from_metrics(key, before_m, dtype)
        rep_after = report_from_metrics(key, after_m, dtype)
        if rep_before is not None:
            dec_before = classify(rep_before, chip, roofline=rl)
        if rep_after is not None:
            dec_after = classify(rep_after, chip, roofline=rl)

    suspects = _suspects(
        key, regressed, before_m, after_m, baseline, run, store_configs
    )
    names = tuple(r.metric for r in regressed)

    # -- narrative: the paper's terms first, raw deltas second --------------
    parts: List[str] = []
    if dec_before is not None and dec_after is not None and rl is not None:
        if dec_after.perf_class != dec_before.perf_class:
            verb = ("slipped" if dec_after.perf_class < dec_before.perf_class
                    else "moved")
            parts.append(
                f"{verb} from Class {int(dec_before.perf_class)} "
                f"({dec_before.perf_class.name}) to Class "
                f"{int(dec_after.perf_class)} ({dec_after.perf_class.name})"
            )
        else:
            parts.append(
                f"stays Class {int(dec_after.perf_class)} "
                f"({dec_after.perf_class.name})"
            )
        side = "left" if dec_after.ai < rl.ai_irv else "right"
        moved = "fell" if dec_after.ai < dec_before.ai else "sits"
        parts.append(
            f"AI {moved} {dec_before.ai:.3g} -> {dec_after.ai:.3g}, {side} of "
            f"AI_IRV={rl.ai_irv:.3g} (AI_IRR={rl.ai_irr:.3g})"
        )
    else:
        parts.append(
            "regressed: " + "; ".join(r.describe() for r in regressed[:3])
        )
    if suspects:
        parts.append("suspect " + "; ".join(suspects))
    narrative = f"{key}: " + "; ".join(parts)

    return Triage(
        key=key,
        metrics=names,
        class_before=None if dec_before is None else dec_before.perf_class,
        class_after=None if dec_after is None else dec_after.perf_class,
        decision_before=dec_before,
        decision_after=dec_after,
        ai_before=None if dec_before is None else dec_before.ai,
        ai_after=None if dec_after is None else dec_after.ai,
        ai_irr=None if rl is None else rl.ai_irr,
        ai_irv=None if rl is None else rl.ai_irv,
        suspects=tuple(suspects),
        narrative=narrative,
    )


def triage_regressions(
    comparison: RunComparison,
    baseline: BenchRun,
    run: BenchRun,
    *,
    tuning_store: Any = "default",
) -> List[Triage]:
    """One :class:`Triage` per regressed workload key, gate-severity order.

    ``tuning_store`` feeds the staleness check (``"default"`` for the
    shared store, a directory, an ArtifactStore, or ``None`` to skip it).
    """
    by_key: Dict[str, List[Regression]] = {}
    for reg in comparison.regressions:
        by_key.setdefault(reg.key, []).append(reg)
    store_configs = _store_configs(tuning_store) if by_key else {}
    return [
        _triage_one(key, regs, baseline, run, store_configs)
        for key, regs in by_key.items()
    ]
