"""AdamW with fp32 master weights, ZeRO-shardable state, and grad clipping.

Params live in bf16 (so the data-parallel gradient all-reduce moves half the
bytes of an fp32 scheme — the paper's ELEN insight applied to collectives);
the fp32 master copy and moments live in the optimizer state, which the
sharding layer spreads over the data axes (ZeRO).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    master_weights: bool = True
    state_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    sdt = jnp.dtype(cfg.state_dtype)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
    }
    if cfg.master_weights:
        # copy=True: fp32 params would otherwise ALIAS the master buffer and
        # break donation (donate(params) + donate(opt) would hand the same
        # buffer to Execute() twice)
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params
        )
    return state


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_update(
    params: Any, grads: Any, state: Dict[str, Any], cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    masters = state.get("master", params)

    def upd(p, g, m, v, w):
        g32 = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m_new / bc1
        vhat = v_new / bc2
        w32 = w.astype(jnp.float32)
        step_w = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * w32
        w_new = w32 - lr * step_w
        return w_new.astype(p.dtype), m_new.astype(sdt), v_new.astype(sdt), w_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"], masters)
    # unzip the 4-tuples
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"step": step, "m": new_m, "v": new_v}
    if cfg.master_weights:
        new_state["master"] = jax.tree.map(
            lambda t: t[3], out, is_leaf=lambda t: isinstance(t, tuple)
        )
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, stats
