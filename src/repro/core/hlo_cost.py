"""While-aware structural cost model over post-optimization HLO text.

Why this exists (the paper's Sec. 3.1 lesson, replayed on XLA artifacts):
``compiled.cost_analysis()`` is the obvious "hardware counter" for a dry-run
roofline — and it is *wrong* for any program with ``lax.scan``/``while``:
XLA counts the loop body ONCE, not trip-count times (validated in
tests/test_hlo_cost.py, exactly like the paper validating PMU events and
rejecting STALL_BACKEND_MEM).  Every model in this framework scans over
layers, so cost_analysis under-reports FLOPs and bytes by ~n_layers.

This module re-derives the three roofline inputs structurally from the HLO
text, walking the call graph with per-computation multipliers:

* ``flops``             — dot/convolution FLOPs (MXU-eligible) plus a 1-FLOP/
                          element estimate for fusion outputs (VPU work).
* ``traffic_bytes``     — HBM traffic model: operand+output bytes of every
                          *memory-level* op (fusions, dots, convs, copies,
                          collectives, dynamic slices); ops inside fusion
                          computations move no HBM bytes.  Control plumbing
                          (tuple/gte/parameter/bitcast/while shells) is free.
* ``collective_bytes``  — operand bytes of all-gather / all-reduce /
                          reduce-scatter / all-to-all / collective-permute,
                          by kind.

Multipliers: a ``while`` body and condition execute ``trip_count`` times
(extracted from the canonical XLA counted-loop pattern: the condition's
``compare(%iv, %K), direction=LT`` against a constant); fusion/call/
conditional computations inherit the caller's multiplier.  Unknown trip
counts fall back to 1 and are reported in ``unknown_trip_counts``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.core.counters import (
    _COLLECTIVE_KINDS,
    _SHAPE_RE,
    shape_bytes,
    shape_elements,
)

# ---------------------------------------------------------------------------
# HLO text -> computations
# ---------------------------------------------------------------------------

_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^((?:\([^()]*(?:\([^()]*\)[^()]*)*\)|\S+))\s+([\w\-]+)\(")
_CALLED_RE = re.compile(
    r"(?:to_apply|calls|body|condition|branch_computations)="
    r"(?:\{([^}]*)\}|%?([\w\.\-]+))"
)
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
# XLA annotates counted loops: backend_config={"known_trip_count":{"n":"8"},...}
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{\s*"n"\s*:\s*"(\d+)"')


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    out_shape: str
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op] = dataclasses.field(default_factory=list)
    is_entry: bool = False
    shapes: Dict[str, str] = dataclasses.field(default_factory=dict)

    def operand_shapes(self, op: "_Op") -> List[str]:
        """Shapes of an op's operands.  Scheduled HLO prints operands as bare
        ``%name`` references; resolve them against this computation's symbol
        table (falling back to inline shapes when printed)."""
        region = _operands_region(op.line)
        out: List[str] = []
        for token in _split_top_level(region):
            token = token.strip()
            if not token:
                continue
            if _SHAPE_RE.search(token):
                out.append(token)
                continue
            m = re.search(r"%([\w\.\-]+)", token)
            if m and m.group(1) in self.shapes:
                out.append(self.shapes[m.group(1)])
        return out


def _split_top_level(region: str) -> List[str]:
    """Split an operand region on commas not nested in (), {} or []."""
    parts, depth, cur = [], 0, []
    for c in region:
        if c in "({[":
            depth += 1
        elif c in ")}]":
            depth -= 1
        if c == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(c)
    if cur:
        parts.append("".join(cur))
    return parts


def parse_computations(hlo_text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    current: Optional[_Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if current is None:
            stripped = line.strip()
            m = _COMP_HEADER_RE.match(stripped)
            if m and stripped.endswith("{") and "->" in stripped:
                current = _Computation(name=m.group(2), is_entry=bool(m.group(1)))
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        rhs = m.group(2)
        mo = _OPCODE_RE.match(rhs)
        if not mo:
            continue
        out_shape, opcode = mo.group(1), mo.group(2)
        op = _Op(m.group(1), opcode, out_shape, line)
        current.ops.append(op)
        current.shapes[op.name] = out_shape
    if current is not None:
        comps[current.name] = current
    return comps


def _called_computations(line: str) -> List[str]:
    out = []
    for m in _CALLED_RE.finditer(line):
        if m.group(1) is not None:  # {a, b} list form
            for name in m.group(1).split(","):
                name = name.strip().lstrip("%")
                if name:
                    out.append(name)
        else:
            out.append(m.group(2))
    return out


def _while_body_cond(line: str) -> Tuple[Optional[str], Optional[str]]:
    body = cond = None
    mb = re.search(r"body=%?([\w\.\-]+)", line)
    mc = re.search(r"condition=%?([\w\.\-]+)", line)
    if mb:
        body = mb.group(1)
    if mc:
        cond = mc.group(1)
    return body, cond


def trip_count_of(cond_comp: _Computation, while_line: str = "") -> Optional[int]:
    """Trip count of a counted loop.

    Preference order: an explicit ``trip_count=N`` backend annotation on the
    while line, else the comparison constant in the condition computation
    (canonical scan lowering: iv starts at 0, step 1, compare LT K).
    """
    m = _TRIP_RE.search(while_line)
    if m:
        return int(m.group(1))
    consts = [int(c) for op in cond_comp.ops for c in _CONST_RE.findall(op.line)]
    if consts:
        return max(consts)
    return None


# ---------------------------------------------------------------------------
# per-op structural costs
# ---------------------------------------------------------------------------

_DOT_LINE_RE = re.compile(r"\bdot\((.*?)\)(?:,|$)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONV_WINDOW_RE = re.compile(r"window=\{[^}]*?size=([\dx]+)")

# ops whose operands/outputs move HBM bytes (when not inside a fusion comp)
_MEMORY_OPCODES = {
    "fusion", "dot", "convolution", "copy", "copy-start", "transpose",
    "gather", "scatter", "dynamic-slice", "dynamic-update-slice",
    "reduce", "sort", "fft", "broadcast", "iota", "concatenate", "slice",
    "pad", "reverse", "reduce-window", "select-and-scatter", "cholesky",
    "triangular-solve", "rng", "exponential", "add", "multiply", "subtract",
    "divide", "maximum", "minimum", "compare", "select", "tanh", "convert",
    "reshape",
} | set(_COLLECTIVE_KINDS) | {k + "-start" for k in _COLLECTIVE_KINDS}

# pure plumbing: never HBM traffic
_FREE_OPCODES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "custom-call",
    "partition-id", "replica-id", "all-gather-done", "all-reduce-done",
    "collective-permute-done", "copy-done", "async-done", "async-start",
    "get-dimension-size", "opt-barrier",
}


def _operands_region(line: str) -> str:
    """Text between the opcode's '(' and its matching ')'."""
    mo = re.search(r"\b[\w\-]+\(", line)
    if not mo:
        return ""
    depth, start = 0, None
    for i in range(mo.end() - 1, len(line)):
        c = line[i]
        if c == "(":
            if depth == 0:
                start = i + 1
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0 and start is not None:
                return line[start:i]
    return ""


def _dot_flops(op: _Op, comp: _Computation) -> float:
    out_elems = shape_elements(op.out_shape)
    operand_shapes = comp.operand_shapes(op)
    mc = _CONTRACT_RE.search(op.line)
    if not operand_shapes or not mc:
        return 0.0
    lhs = _SHAPE_RE.findall(operand_shapes[0])
    if not lhs:
        return 0.0
    lhs_dims = [int(d) for d in lhs[0][1].split(",") if d]
    k = 1
    for ci in mc.group(1).split(","):
        if ci and int(ci) < len(lhs_dims):
            k *= lhs_dims[int(ci)]
    return 2.0 * out_elems * k


def _conv_flops(op: _Op, comp: _Computation) -> float:
    out_elems = shape_elements(op.out_shape)
    mw = _CONV_WINDOW_RE.search(op.line)
    window = 1
    if mw:
        for w in mw.group(1).split("x"):
            window *= int(w)
    operand_shapes = comp.operand_shapes(op)
    cin = 1
    if len(operand_shapes) >= 2:
        rhs = _SHAPE_RE.findall(operand_shapes[1])
        if rhs:
            rhs_dims = [int(d) for d in rhs[0][1].split(",") if d]
            if rhs_dims:
                cin = min(rhs_dims)
    return 2.0 * out_elems * window * cin


# ---------------------------------------------------------------------------
# the cost walk
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HloCost:
    """Structural, loop-scaled cost of one compiled module (PER DEVICE)."""

    mxu_flops: float = 0.0
    vpu_flop_estimate: float = 0.0
    nonvec_flops: float = 0.0  # fft/sort/rng/scalar-while work: no lane parallelism
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count_by_kind: Dict[str, int] = dataclasses.field(default_factory=dict)
    gather_bytes: float = 0.0
    while_trip_counts: List[int] = dataclasses.field(default_factory=list)
    unknown_trip_counts: int = 0

    @property
    def flops(self) -> float:
        return self.mxu_flops + self.vpu_flop_estimate

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["flops"] = self.flops
        return d


def _collective_kind(opcode: str) -> Optional[str]:
    base = opcode[:-6] if opcode.endswith("-start") else opcode
    return base if base in _COLLECTIVE_KINDS else None


def cost_of_module(hlo_text: str) -> HloCost:
    comps = parse_computations(hlo_text)
    cost = HloCost()
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None and comps:
        entry = list(comps.values())[0]
    if entry is None:
        return cost

    # computation -> (multiplier, counts_memory) jobs; a computation may be
    # visited multiple times (e.g. shared fusions) — costs add per call site.
    stack: List[Tuple[str, float, bool]] = [(entry.name, 1.0, True)]
    seen_guard = 0

    while stack:
        seen_guard += 1
        if seen_guard > 100_000:  # malformed module safety valve
            break
        name, mult, memory_level = stack.pop()
        comp = comps.get(name)
        if comp is None:
            continue
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                body, cond = _while_body_cond(op.line)
                trip = None
                if cond and cond in comps:
                    trip = trip_count_of(comps[cond], op.line)
                if trip is None:
                    trip = 1
                    cost.unknown_trip_counts += 1
                else:
                    cost.while_trip_counts.append(trip)
                if body:
                    stack.append((body, mult * trip, memory_level))
                if cond and cond in comps:
                    # condition work is negligible; skip
                    pass
                continue
            called = _called_computations(op.line)
            if oc == "fusion":
                # fusion interior: flops yes, memory no
                for c in called:
                    stack.append((c, mult, False))
            elif oc in ("call", "conditional"):
                for c in called:
                    stack.append((c, mult, memory_level))
            elif oc in ("reduce", "sort", "scatter", "select-and-scatter",
                        "reduce-window", "map") and called:
                pass  # tiny scalar to_apply bodies: ignore

            # --- flops ---
            if oc == "dot":
                cost.mxu_flops += mult * _dot_flops(op, comp)
            elif oc == "convolution":
                cost.mxu_flops += mult * _conv_flops(op, comp)
            elif oc in ("fft", "sort", "rng", "rng-bit-generator"):
                # library/serial structure defeats lane vectorization (the
                # paper's FFTW finding); ~5 log-factor flops per element
                est = 5.0 * mult * shape_elements(op.out_shape)
                cost.vpu_flop_estimate += est
                cost.nonvec_flops += est
            elif oc in ("scatter", "dynamic-update-slice"):
                # in-place updates: charge the UPDATE elements, not the
                # whole buffer (a (E*C, d) MoE dispatch buffer is not 1e9
                # flops of work per scatter)
                operand_elems = [shape_elements(s) for s in comp.operand_shapes(op)]
                upd = (sum(operand_elems) - max(operand_elems)
                       if operand_elems else shape_elements(op.out_shape))
                cost.vpu_flop_estimate += mult * upd
            elif oc == "fusion" or oc not in _FREE_OPCODES:
                # elementwise estimate: 1 flop per output element
                cost.vpu_flop_estimate += mult * shape_elements(op.out_shape)

            # --- collectives ---
            kind = _collective_kind(oc)
            if kind is not None:
                nbytes = sum(shape_bytes(s) for s in comp.operand_shapes(op))
                if nbytes == 0.0:
                    nbytes = shape_bytes(op.out_shape)
                cost.collective_bytes += mult * nbytes
                cost.collective_bytes_by_kind[kind] = (
                    cost.collective_bytes_by_kind.get(kind, 0.0) + mult * nbytes
                )
                cost.collective_count_by_kind[kind] = (
                    cost.collective_count_by_kind.get(kind, 0) + int(mult)
                )

            # --- memory traffic ---
            if memory_level and oc not in _FREE_OPCODES:
                if oc == "dynamic-update-slice":
                    # in-place on TPU: only the update slice moves (read+write);
                    # charging the whole buffer would bill a 32k-token KV cache
                    # per decoded token.
                    operand_bytes = [shape_bytes(s) for s in comp.operand_shapes(op)]
                    update = (sum(operand_bytes) - max(operand_bytes)
                              if operand_bytes else 0.0)
                    traffic = 2.0 * update
                elif oc in ("dynamic-slice", "gather"):
                    traffic = 2.0 * shape_bytes(op.out_shape)  # read + write
                else:
                    traffic = shape_bytes(op.out_shape)
                    traffic += sum(shape_bytes(s) for s in comp.operand_shapes(op))
                cost.traffic_bytes += mult * traffic

            # gathers are random-access traffic wherever they appear —
            # XLA often fuses them, but the loads still chase pointers
            if oc in ("gather", "scatter"):
                cost.gather_bytes += mult * shape_bytes(op.out_shape)
            elif memory_level and oc == "dynamic-slice":
                cost.gather_bytes += mult * shape_bytes(op.out_shape)

    return cost
