"""The regression gate + trajectory reporting.

``gate_run`` is the enforcement point: resolve a baseline (policy), run the
noise-aware comparison, triage every confirmed regression through the
Fig. 8 decision tree, and fold it all into a :class:`GateResult` whose
``exit_code`` CI can act on.  ``format_markdown`` renders the trajectory
and the latest gate for humans; ``export_trajectory`` writes one
machine-readable ``BENCH_<seq>.json`` per run — the stable interchange
format downstream dashboards consume.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional

from repro.perf.baseline import resolve_baseline
from repro.perf.compare import RunComparison, compare_runs
from repro.perf.ledger import PERF_VERSION, BenchRun, Ledger, default_ledger
from repro.perf.triage import Triage, triage_regressions


@dataclasses.dataclass
class GateResult:
    """Outcome of gating one run against one resolved baseline."""

    ok: bool
    run_id: str
    baseline_id: Optional[str]
    policy: str
    comparison: Optional[RunComparison]
    triages: List[Triage]
    note: str = ""

    @property
    def exit_code(self) -> int:
        return 0 if self.ok else 1

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": "perf_gate",
            "ok": self.ok,
            "exit_code": self.exit_code,
            "run_id": self.run_id,
            "baseline_id": self.baseline_id,
            "policy": self.policy,
            "note": self.note,
            "comparison": None if self.comparison is None else self.comparison.to_dict(),
            "triage": [t.to_dict() for t in self.triages],
        }

    def describe(self) -> str:
        if self.comparison is None:
            status = "PASS" if self.ok else "FAIL"
            return f"gate {status} ({self.note or 'no baseline'})"
        if self.ok:
            out = (
                f"gate PASS: {len(self.comparison.deltas)} metrics vs "
                f"baseline {self.baseline_id[:12]} ({self.policy}), "
                f"{len(self.comparison.improvements)} improved"
            )
            return out + (f"\n  NOTE: {self.note}" if self.note else "")
        lines = [
            f"gate FAIL: {len(self.comparison.regressions)} regression(s) vs "
            f"baseline {self.baseline_id[:12]} ({self.policy})"
        ]
        for t in self.triages:
            lines.append(f"  - {t.narrative}")
        return "\n".join(lines)


def gate_run(
    run: BenchRun,
    ledger: Optional[Ledger] = None,
    *,
    policy: str = "latest",
    wall_tol_scale: float = 1.0,
    tuning_store: Any = "default",
) -> GateResult:
    """Gate ``run`` against the baseline ``policy`` resolves to.

    The run under test is always excluded from baseline resolution (a
    freshly recorded run must not gate against itself), and resolution is
    restricted to the run's own (chip, dtype) series.  A series with no
    prior run passes trivially — the first point of a trajectory has
    nothing to regress from.
    """
    ledger = ledger or default_ledger()
    baseline = resolve_baseline(
        ledger, policy, series=run.env.series_key(), exclude=(run.run_id,)
    )
    note = ""
    if (baseline is not None and policy == "latest"
            and not set(baseline.metrics) & set(run.metrics)):
        # the shared ledger holds heterogeneous records (benchmark runs,
        # service reports): "latest" means the latest COMPARABLE run, or a
        # disjoint record would silently turn the gate vacuous
        for cand in reversed(ledger.runs(run.env.series_key())):
            if (cand.run_id != run.run_id and not cand.meta.get("failed")
                    and set(cand.metrics) & set(run.metrics)):
                note = (f"latest run {baseline.run_id[:12]} shares no metrics; "
                        f"fell back to {cand.run_id[:12]} (seq {cand.seq})")
                baseline = cand
                break
    if baseline is None:
        # the first point of a trajectory has nothing to regress from —
        # but an EXPLICIT pin that fails to resolve is an operator error,
        # not a trivial pass: a typo'd SHA must never go permanently green
        pinned_miss = policy.startswith("pinned:")
        return GateResult(
            ok=not pinned_miss,
            run_id=run.run_id,
            baseline_id=None,
            policy=policy,
            comparison=None,
            triages=[],
            note=(f"pinned baseline {policy!r} did not resolve to any run"
                  if pinned_miss else
                  f"no baseline for series {run.env.series_key()!r} "
                  f"under policy {policy!r}"),
        )
    comparison = compare_runs(baseline, run, wall_tol_scale=wall_tol_scale)
    if comparison.missing_metrics:
        # a gated metric that stops being reported is lost coverage, not a
        # pass — it doesn't flip the verdict, but it must be said out loud
        note = (note + "; " if note else "") + (
            "metrics vanished vs baseline: "
            + ", ".join(comparison.missing_metrics[:5])
            + ("..." if len(comparison.missing_metrics) > 5 else "")
        )
    if not comparison.deltas:
        # still passes (disjoint subsets are an operator choice), but a
        # vacuous gate must say so out loud, never look like coverage
        note = (note + "; " if note else "") + (
            "VACUOUS: baseline shares no metrics with this run — "
            "nothing was actually gated"
        )
    triages = triage_regressions(
        comparison, baseline, run, tuning_store=tuning_store
    )
    return GateResult(
        ok=comparison.ok,
        run_id=run.run_id,
        baseline_id=baseline.run_id,
        policy=policy,
        comparison=comparison,
        triages=triages,
        note=note,
    )


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------


def _headline_wall(run: BenchRun) -> float:
    return sum(
        m["wall_s"] for m in run.metrics.values()
        if isinstance(m.get("wall_s"), (int, float))
    )


def format_markdown(
    ledger: Ledger,
    *,
    series: Optional[str] = None,
    gate: Optional[GateResult] = None,
) -> str:
    """Human-readable trajectory report (optionally with the latest gate)."""
    lines = ["# Performance trajectory", ""]
    all_series = [series] if series else (ledger.series() or [])
    if not all_series:
        lines.append("_(empty ledger)_")
    for s in all_series:
        runs = ledger.runs(s)
        if not runs:
            continue
        lines.append(f"## series `{s}` — {len(runs)} run(s)")
        lines.append("")
        lines.append("| seq | run | git | tuned | workloads | wall (s) |")
        lines.append("|---:|---|---|---|---:|---:|")
        for r in runs:
            lines.append(
                f"| {r.seq} | `{r.run_id[:12]}` | `{r.env.git_sha}` | "
                f"`{r.env.tuned_hash or '-'}` | {len(r.metrics)} | "
                f"{_headline_wall(r):.3f} |"
            )
        lines.append("")
    if gate is not None:
        lines.append("## gate")
        lines.append("")
        lines.append(f"**{'PASS' if gate.ok else 'FAIL'}** — run "
                     f"`{gate.run_id[:12]}` vs baseline "
                     f"`{(gate.baseline_id or 'none')[:12]}` "
                     f"(policy `{gate.policy}`)")
        if gate.note:
            lines.append(f"- {gate.note}")
        if gate.comparison is not None:
            for reg in gate.comparison.regressions:
                lines.append(f"- REGRESSION: {reg.describe()}")
            for imp in gate.comparison.improvements:
                lines.append(
                    f"- improved: {imp.key}: {imp.metric} {imp.before} -> "
                    f"{imp.after} ({imp.rel_delta:+.1%})"
                )
        for t in gate.triages:
            lines.append(f"- triage: {t.narrative}")
        lines.append("")
    return "\n".join(lines)


def export_trajectory(
    ledger: Ledger,
    out_dir: str,
    *,
    series: Optional[str] = None,
) -> List[str]:
    """Write one ``BENCH_<seq>.json`` per run; returns the paths written.

    Each file is a self-contained trajectory point (``perf_version`` +
    the full BenchRun dict), so downstream consumers never need the
    ledger directory itself.
    """
    os.makedirs(out_dir, exist_ok=True)
    paths: List[str] = []
    seen_seqs: set = set()
    for run in ledger.runs(series):
        # concurrent recorders may race to one seq (both entries survive in
        # the ledger); a duplicate seq gets the run id in its filename so
        # the export never silently drops a trajectory point
        name = (f"BENCH_{run.seq}.json" if run.seq not in seen_seqs
                else f"BENCH_{run.seq}_{run.run_id[:8]}.json")
        seen_seqs.add(run.seq)
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            json.dump(
                {"kind": "perf_trajectory_point",
                 "perf_version": PERF_VERSION,
                 "run": run.to_dict()},
                f,
                indent=1,
            )
        paths.append(path)
    return paths
