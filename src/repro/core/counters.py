"""PMU-analogue performance counters from XLA compiled artifacts.

The paper (Sec. 3.1, Table 1) profiles six validated ARM PMU events through a
perf wrapper.  A TPU dry-run has no PMU, but the compiled artifact is richer
than a counter file: ``compiled.cost_analysis()`` gives FLOPs and bytes, the
post-SPMD HLO text gives the exact collective schedule and the op mix.  This
module maps the paper's event list onto artifact-derived quantities:

==================  ==========================================================
paper event          TPU artifact definition
==================  ==========================================================
INST_RETIRED         vector-issue count (elements / lanes, per op census)
LL_CACHE_MISS_RD     HBM read bytes / transaction granule
MEM_ACCESS_RD        total bytes accessed / transaction granule
STALL_BACKEND        max(0, mem_time - compute_time) in cycles-equivalent
CPU_CYCLES           max(compute, memory, collective) time x clock
VFP_SPEC             FLOPs
==================  ==========================================================

plus the structural counters the decision tree needs: collective bytes by
kind (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), gather/scatter bytes (pointer-chasing traffic), and the
MXU/VPU-eligible FLOP share ("vectorizable fraction").
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Mapping

DTYPE_BYTES: Mapping[str, int] = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
    "s16": 2,
    "u16": 2,
    "f16": 2,
    "bf16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
    "token": 0,
}

# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"\b([a-z]+\d+(?:e\d+m\d+(?:fn)?)?|pred|token)\[([\d,]*)\]")

_COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
)

# async collectives appear as <kind>-start / <kind>-done; count starts only.
_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^=]*?\)|\S+)\s+("
    + "|".join(_COLLECTIVE_KINDS)
    + r")(-start)?\("
)

_GATHERISH_RE = re.compile(r"=\s*(\S+)\s+(gather|scatter|dynamic-slice|dynamic-update-slice)\(")

_DOT_RE = re.compile(
    r"=\s*(\S+)\s+dot\((.*?)\),.*?lhs_contracting_dims=\{([\d,]*)\}",
)

_CONV_RE = re.compile(r"=\s*(\S+)\s+convolution\((.*?)\), window=\{size=([\dx]+)")

_FFT_RE = re.compile(r"\bfft\(")
_SORT_RE = re.compile(r"\bsort\(")
_WHILE_RE = re.compile(r"\bwhile\(")


def shape_bytes(shape_str: str) -> float:
    """Bytes of one HLO shape string like ``f32[128,256]{1,0}``."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        nbytes = DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                if d:
                    elems *= int(d)
        total += elems * nbytes
    return total


def shape_elements(shape_str: str) -> float:
    elems_total = 0.0
    for _, dims in _SHAPE_RE.findall(shape_str):
        elems = 1
        if dims:
            for d in dims.split(","):
                if d:
                    elems *= int(d)
        elems_total += elems
    return elems_total


def _operand_region(line: str, opname_end: int) -> str:
    """Text between the op's '(' and its matching ')'."""
    depth = 0
    start = None
    for i in range(opname_end, len(line)):
        c = line[i]
        if c == "(":
            if depth == 0:
                start = i + 1
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0 and start is not None:
                return line[start:i]
    return line[opname_end:]


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    count_by_kind: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "CollectiveStats":
        return cls(
            bytes_by_kind={k: float(v) for k, v in (d.get("bytes_by_kind") or {}).items()},
            count_by_kind={k: int(v) for k, v in (d.get("count_by_kind") or {}).items()},
        )


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective op in (post-SPMD) HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        region = _operand_region(line, m.end() - 1)
        nbytes = shape_bytes(region)
        if nbytes == 0.0:
            # operands printed without shapes -> fall back to output shape
            eq = line.find("=")
            out_region = line[eq + 1 : m.start(1)]
            nbytes = shape_bytes(out_region)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0.0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


def parse_gather_bytes(hlo_text: str) -> float:
    """Bytes produced by gather/scatter/dynamic-slice ops (latency traffic)."""
    total = 0.0
    for line in hlo_text.splitlines():
        m = _GATHERISH_RE.search(line)
        if not m:
            continue
        total += shape_bytes(m.group(1))
    return total


def parse_mxu_flops(hlo_text: str) -> float:
    """FLOPs in dot/convolution ops, structurally, from HLO text."""
    flops = 0.0
    for line in hlo_text.splitlines():
        m = _DOT_RE.search(line)
        if m:
            out_elems = shape_elements(m.group(1))
            region = m.group(2)
            # contracted extent: product of lhs contracting dims
            operand_shapes = _SHAPE_RE.findall(region)
            if operand_shapes and m.group(3):
                lhs_dims = [int(d) for d in operand_shapes[0][1].split(",") if d]
                k = 1
                for ci in m.group(3).split(","):
                    if ci and int(ci) < len(lhs_dims):
                        k *= lhs_dims[int(ci)]
                flops += 2.0 * out_elems * k
            continue
        mc = _CONV_RE.search(line)
        if mc:
            out_elems = shape_elements(mc.group(1))
            window = 1
            for w in mc.group(3).split("x"):
                window *= int(w)
            # per output element: 2 * window * C_in; C_in from rhs shape dim 0/1
            operand_shapes = _SHAPE_RE.findall(mc.group(2))
            cin = 1
            if len(operand_shapes) >= 2:
                rhs_dims = [int(d) for d in operand_shapes[1][1].split(",") if d]
                if rhs_dims:
                    cin = min(rhs_dims)  # heuristic: feature dim
            flops += 2.0 * out_elems * window * cin
    return flops


def op_census(hlo_text: str) -> Dict[str, int]:
    census = {
        "dot": len(re.findall(r"\bdot\(", hlo_text)),
        "convolution": len(re.findall(r"\bconvolution\(", hlo_text)),
        "fusion": len(re.findall(r"\bfusion\(", hlo_text)),
        "gather": len(re.findall(r"\bgather\(", hlo_text)),
        "scatter": len(re.findall(r"\bscatter\(", hlo_text)),
        "fft": len(_FFT_RE.findall(hlo_text)),
        "sort": len(_SORT_RE.findall(hlo_text)),
        "while": len(_WHILE_RE.findall(hlo_text)),
    }
    for kind in _COLLECTIVE_KINDS:
        census[kind] = len(re.findall(rf"\b{kind}(?:-start)?\(", hlo_text))
    return census


# ---------------------------------------------------------------------------
# Event extraction from jax.stages artifacts
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Events:
    """The paper's Table-1 event set, artifact-derived, GLOBAL across chips."""

    flops: float = 0.0  # VFP_SPEC analogue
    bytes_accessed: float = 0.0  # MEM_ACCESS_* analogue (bytes)
    hbm_read_bytes: float = 0.0  # LL_CACHE_MISS_RD analogue (bytes)
    gather_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: CollectiveStats = dataclasses.field(default_factory=CollectiveStats)
    mxu_flops: float = 0.0
    census: Dict[str, int] = dataclasses.field(default_factory=dict)
    n_devices: int = 1
    # raw per-device cost_analysis numbers (NOT loop-scaled; see
    # events_from_compiled docstring) — kept for counter validation
    xla_raw_flops: float = 0.0
    xla_raw_bytes: float = 0.0
    hlo_traffic_bytes: float = 0.0  # structural HLO traffic (diagnostic)
    nonvec_flops: float = 0.0  # fft/sort/serial flops (not lane-parallel)
    while_trip_counts: list = dataclasses.field(default_factory=list)
    unknown_trip_counts: int = 0
    # memory_analysis (per device, bytes)
    argument_bytes_per_device: float = 0.0
    output_bytes_per_device: float = 0.0
    temp_bytes_per_device: float = 0.0
    code_bytes_per_device: float = 0.0

    @property
    def vectorizable_fraction(self) -> float:
        """Share of FLOPs that can use a data-parallel engine (MXU matmuls
        or VPU lanes); fft/sort/serial library structure is the exception —
        the paper's 'can it vectorize' filter."""
        if self.flops <= 0:
            return 0.0
        return max(0.0, min(1.0, 1.0 - self.nonvec_flops / self.flops))

    @property
    def mxu_fraction(self) -> float:
        if self.flops <= 0:
            return 0.0
        return min(1.0, self.mxu_flops / self.flops)

    @property
    def peak_bytes_per_device(self) -> float:
        return (
            self.argument_bytes_per_device
            + self.output_bytes_per_device
            + self.temp_bytes_per_device
        )

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["collectives"] = {
            "bytes_by_kind": dict(self.collectives.bytes_by_kind),
            "count_by_kind": dict(self.collectives.count_by_kind),
        }
        d["vectorizable_fraction"] = self.vectorizable_fraction
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Events":
        """Inverse of :meth:`to_dict` (the artifact store's JSON round-trip).

        Derived keys (``vectorizable_fraction``) and unknown keys from newer
        writers are ignored; missing fields keep their defaults, so stored
        events written by older code still load.
        """
        ev = cls()
        valid = {f.name for f in dataclasses.fields(cls)}
        for k, v in d.items():
            if k == "collectives":
                ev.collectives = CollectiveStats.from_dict(v or {})
            elif k == "census":
                ev.census = {str(n): int(c) for n, c in (v or {}).items()}
            elif k == "while_trip_counts":
                ev.while_trip_counts = list(v or [])
            elif k in valid:
                setattr(ev, k, type(getattr(ev, k))(v))
        return ev


def _cost_get(cost: Any, key: str) -> float:
    if cost is None:
        return 0.0
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    try:
        return float(cost.get(key, 0.0))
    except AttributeError:
        return 0.0


def events_from_compiled(
    compiled: Any, *, hlo_text: str | None = None, n_devices: int | None = None
) -> Events:
    """Extract Events from a ``jax.stages.Compiled`` artifact.

    Primary source is the while-aware structural model (``core.hlo_cost``):
    ``cost_analysis()`` counts ``lax.scan``/while bodies ONCE (validated in
    tests/test_hlo_cost.py), so on layer-scanned models it under-reports by
    ~n_layers — the XLA analogue of the paper's unreliable PMU events.  The
    raw per-device cost_analysis numbers are kept as ``xla_raw_*`` for the
    counter-validation table in EXPERIMENTS.md.

    All primary quantities are GLOBAL across chips (x n_devices) so roofline
    terms follow  term = global / (chips * per_chip_rate).
    """
    from repro.core import hlo_cost as hlo_cost_mod

    ev = Events()
    if n_devices is None:
        try:
            n_devices = len(compiled._executable.xla_executable.local_devices())  # type: ignore
        except Exception:
            n_devices = 1
    ev.n_devices = max(int(n_devices), 1)

    cost = None
    try:
        cost = compiled.cost_analysis()
    except Exception:
        cost = None
    ev.xla_raw_flops = _cost_get(cost, "flops")
    ev.xla_raw_bytes = _cost_get(cost, "bytes accessed")

    if hlo_text is None:
        try:
            hlo_text = compiled.as_text()
        except Exception:
            hlo_text = ""
    if hlo_text:
        hc = hlo_cost_mod.cost_of_module(hlo_text)
        ev.flops = hc.flops * ev.n_devices
        ev.mxu_flops = hc.mxu_flops * ev.n_devices
        ev.bytes_accessed = hc.traffic_bytes * ev.n_devices
        ev.hbm_read_bytes = hc.traffic_bytes * 0.5 * ev.n_devices
        ev.gather_bytes = hc.gather_bytes * ev.n_devices
        ev.nonvec_flops = hc.nonvec_flops * ev.n_devices
        ev.collective_bytes = hc.collective_bytes * ev.n_devices
        ev.collectives = CollectiveStats(
            bytes_by_kind={k: v * ev.n_devices
                           for k, v in hc.collective_bytes_by_kind.items()},
            count_by_kind=dict(hc.collective_count_by_kind),
        )
        ev.census = op_census(hlo_text)
        ev.while_trip_counts = list(hc.while_trip_counts)
        ev.unknown_trip_counts = hc.unknown_trip_counts
    else:
        # no text available: fall back to (unscaled) cost_analysis
        ev.flops = ev.xla_raw_flops * ev.n_devices
        ev.bytes_accessed = ev.xla_raw_bytes * ev.n_devices
        ev.hbm_read_bytes = ev.bytes_accessed * 0.7

    try:
        mem = compiled.memory_analysis()
        ev.argument_bytes_per_device = float(getattr(mem, "argument_size_in_bytes", 0))
        ev.output_bytes_per_device = float(getattr(mem, "output_size_in_bytes", 0))
        ev.temp_bytes_per_device = float(getattr(mem, "temp_size_in_bytes", 0))
        ev.code_bytes_per_device = float(getattr(mem, "generated_code_size_in_bytes", 0))
    except Exception:
        pass
    return ev


def events_from_analytic(
    *,
    flops: float,
    hbm_bytes: float,
    gather_bytes: float = 0.0,
    mxu_flops: float | None = None,
    collective_bytes: float = 0.0,
    n_devices: int = 1,
) -> Events:
    """Build Events from an analytic app model (paper Sec. 3.3 style)."""
    ev = Events()
    ev.flops = flops
    ev.bytes_accessed = hbm_bytes
    ev.hbm_read_bytes = hbm_bytes
    ev.gather_bytes = gather_bytes
    ev.mxu_flops = flops if mxu_flops is None else mxu_flops
    ev.collective_bytes = collective_bytes
    ev.n_devices = n_devices
    return ev
