"""Quickstart: build a model, take a train step, and run the paper's
vectorization analysis on the compiled step — the 60-second tour.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.configs.base import ShapeConfig
from repro.core import hw
from repro.core.counters import events_from_compiled
from repro.core.decision_tree import classify
from repro.core.metrics import VectorizationReport, vectorization_bound
from repro.core.roofline import adapted_roofline
from repro.data import pipeline
from repro.optim import adamw
from repro.train import steps as steps_mod


def main():
    # 1. pick an architecture (all 10 assigned archs are selectable by name)
    cfg = configs.get_smoke_config("qwen3-1.7b")
    print(f"arch={cfg.name}  family={cfg.family}  params~{cfg.param_count()/1e6:.1f}M")

    # 2. one training step
    run = steps_mod.RunConfig(remat="none", zero=False)
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_opt_state(params, run.opt)
    shape = ShapeConfig("quickstart", 64, 4, "train")
    batch = {k: jnp.asarray(v) for k, v in
             pipeline.global_batch(cfg, shape, pipeline.DataConfig(), 0).items()}
    train_step = jax.jit(steps_mod.make_train_step(cfg, run))
    params, opt, metrics = train_step(params, opt, batch)
    print(f"step 0: loss={float(metrics['loss']):.4f} "
          f"grad_norm={float(metrics['grad_norm']):.3f}")

    # 3. the paper's analysis, applied to the compiled step artifact
    compiled = train_step.lower(params, opt, batch).compile()
    ev = events_from_compiled(compiled, n_devices=1)
    print(f"\ncompiled-step events (while-aware structural model):")
    print(f"  flops={ev.flops:.3e}  mxu_share={ev.vectorizable_fraction:.2%}  "
          f"hlo_traffic={ev.bytes_accessed:.3e}B")

    chip = hw.TPU_V5E
    rl = adapted_roofline(chip, "bf16")
    print(f"\nadapted roofline on {chip.name} (paper Eq. 2):")
    print(f"  VB={vectorization_bound(chip, 'bf16'):.0f}  "
          f"AI_IRR={rl.ai_irr:.1f}  AI_IRV={rl.ai_irv:.1f} flop/B")

    report = VectorizationReport(
        name="train_step", dtype="bf16",
        flops=ev.flops, hbm_bytes=ev.bytes_accessed,
        gather_bytes=ev.gather_bytes,
        ins_scalar=ev.flops / 2, ins_vec=ev.flops / 2 / rl.vb,
        vectorizable_fraction=ev.vectorizable_fraction,
    )
    decision = classify(report, chip)
    print(f"\ndecision tree (paper Fig. 8): Class {int(decision.perf_class)} "
          f"— {decision.perf_class.describe()}")
    print(f"  {decision.rationale}")


if __name__ == "__main__":
    main()
