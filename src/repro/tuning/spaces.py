"""Built-in tuning spaces for the registry kernels (paper Sec. 3.2 suite).

One :class:`~repro.tuning.space.TuningSpace` per Pallas kernel, declaring
its block/tile axes, the kernel's hard-coded defaults (so tuned-vs-default
is well defined), the VMEM working-set model, and — where tile shape
changes traffic — an HBM traffic model for roofline pruning.

This module also owns the GEMM tile model that used to live privately in
``kernels/gemm/ops.py`` (:func:`gemm_vmem_bytes`, :func:`pick_gemm_tiles`):
the old per-kernel heuristic is now one projection of the shared space, and
``gemm/ops.py`` delegates here unchanged (golden-pinned in
``tests/test_tuning.py``).

SpMV has no space on purpose: its tunable quantities (``row_block``,
``width_pad``) are data-layout parameters fixed at problem construction,
not kernel call arguments.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

from repro.tuning.space import TuningSpace

# ---------------------------------------------------------------------------
# GEMM — the only kernel with a 3-axis tile space (and the legacy heuristic)
# ---------------------------------------------------------------------------

#: The legacy `pick_tiles` candidate values, preserved verbatim (order is
#: the tie-break: first-seen max-volume config wins, exactly as the old
#: triple loop behaved).
GEMM_AXES: Dict[str, Tuple[int, ...]] = {
    "bm": (512, 256, 128),
    "bn": (512, 256, 128),
    "bk": (1024, 512, 256, 128),
}


def gemm_vmem_bytes(bm: int, bn: int, bk: int, in_bytes: int = 2) -> int:
    """Working set per grid step: x tile + y tile + fp32 acc + out tile.

    (The exact formula that lived in ``kernels/gemm/ops.py``.)
    """
    return bm * bk * in_bytes + bk * bn * in_bytes + bm * bn * 4 + bm * bn * in_bytes


def _gemm_dims(args: Tuple) -> Tuple[int, int, int]:
    x, y = args[0], args[1]
    M, K = x.shape
    N = y.shape[1]
    return M, N, K


def _gemm_clamp(cfg: Dict[str, Any], args: Tuple) -> Dict[str, Any]:
    M, N, K = _gemm_dims(args)
    return {"bm": min(cfg["bm"], M), "bn": min(cfg["bn"], N), "bk": min(cfg["bk"], K)}


def _gemm_ok(cfg: Dict[str, Any], args: Tuple) -> bool:
    M, N, K = _gemm_dims(args)
    bm, bn, bk = min(cfg["bm"], M), min(cfg["bn"], N), min(cfg["bk"], K)
    return M % bm == 0 and N % bn == 0 and K % bk == 0


def _gemm_vmem(cfg: Dict[str, Any], args: Tuple, dtype_bytes: int) -> float:
    return gemm_vmem_bytes(cfg["bm"], cfg["bn"], cfg["bk"], dtype_bytes)


def _gemm_traffic(cfg: Dict[str, Any], args: Tuple) -> float:
    """Tile-reuse model: x streams once per bn-tile of y, y once per
    bm-tile of x, the output is written once."""
    M, N, K = _gemm_dims(args)
    in_b = args[0].dtype.itemsize
    bm, bn = min(cfg["bm"], M), min(cfg["bn"], N)
    return float(M * K * (N // bn) * in_b + K * N * (M // bm) * in_b + M * N * in_b)


def _gemm_flops(args: Tuple) -> float:
    M, N, K = _gemm_dims(args)
    return 2.0 * M * N * K


def gemm_space() -> TuningSpace:
    return TuningSpace(
        kernel="gemm",
        axes=dict(GEMM_AXES),
        default={"bm": 128, "bn": 128, "bk": 128},
        dtypes=("fp32", "bf16"),
        clamp=_gemm_clamp,
        constraint=_gemm_ok,
        vmem_model=_gemm_vmem,
        traffic_model=_gemm_traffic,
        flops_model=_gemm_flops,
    )


def pick_gemm_tiles(
    M: int,
    N: int,
    K: int,
    *,
    vmem_budget: int = 96 * 2**20,
    in_bytes: int = 2,
) -> Tuple[int, int, int]:
    """Largest MXU-aligned tiles fitting the VMEM budget (legacy projection
    of the GEMM space: max bm*bn*bk volume, first-seen wins ties)."""
    space = gemm_space()
    best = (128, 128, 128)
    for cfg in space.configs():
        bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
        if M % bm or N % bn or K % bk:
            continue
        if gemm_vmem_bytes(bm, bn, bk, in_bytes) <= vmem_budget:
            if bm * bn * bk > best[0] * best[1] * best[2]:
                best = (bm, bn, bk)
    return best


# ---------------------------------------------------------------------------
# STREAM — pure streaming; traffic is config-independent, timing decides
# ---------------------------------------------------------------------------


def _rows_of(args: Tuple) -> Tuple[int, int]:
    a = args[0]
    rows, width = a.shape
    return rows, width


def _stream_clamp(cfg: Dict[str, Any], args: Tuple) -> Dict[str, Any]:
    rows, _ = _rows_of(args)
    return {"block_rows": min(cfg["block_rows"], rows)}


def _stream_ok(cfg: Dict[str, Any], args: Tuple) -> bool:
    rows, _ = _rows_of(args)
    br = min(cfg["block_rows"], rows)
    return rows % br == 0


def stream_space(n_arrays: int, flops_per_elem: float) -> TuningSpace:
    def vmem(cfg: Dict[str, Any], args: Tuple, dtype_bytes: int) -> float:
        rows, width = _rows_of(args)
        br = min(cfg["block_rows"], rows)
        return float((n_arrays + 1) * br * width * dtype_bytes)

    def traffic(cfg: Dict[str, Any], args: Tuple) -> float:
        rows, width = _rows_of(args)
        return float((n_arrays + 1) * rows * width * args[0].dtype.itemsize)

    def flops(args: Tuple) -> float:
        rows, width = _rows_of(args)
        return flops_per_elem * rows * width

    return TuningSpace(
        kernel="stream",
        axes={"block_rows": (1024, 512, 256, 128, 64, 32, 8)},
        default={"block_rows": 256},
        dtypes=("fp32", "bf16", "fp16"),
        clamp=_stream_clamp,
        constraint=_stream_ok,
        vmem_model=vmem,
        traffic_model=traffic,
        flops_model=flops,
    )


# ---------------------------------------------------------------------------
# Jacobi2D — input resident per program: block_rows trades re-reads
# ---------------------------------------------------------------------------


def _jacobi_clamp(cfg: Dict[str, Any], args: Tuple) -> Dict[str, Any]:
    H, _ = args[0].shape
    return {"block_rows": min(cfg["block_rows"], H)}


def _jacobi_ok(cfg: Dict[str, Any], args: Tuple) -> bool:
    H, _ = args[0].shape
    br = min(cfg["block_rows"], H)
    return H % br == 0


def _jacobi_vmem(cfg: Dict[str, Any], args: Tuple, dtype_bytes: int) -> float:
    H, W = args[0].shape
    br = min(cfg["block_rows"], H)
    return float(H * W * dtype_bytes + br * W * dtype_bytes)  # resident + tile


def _jacobi_traffic(cfg: Dict[str, Any], args: Tuple) -> float:
    """The resident input is re-fetched by every grid step (no inter-program
    reuse guarantee), so larger row blocks mean fewer sweeps over u."""
    H, W = args[0].shape
    b = args[0].dtype.itemsize
    br = min(cfg["block_rows"], H)
    return float(H * W * b * (H // br) + H * W * b)


def jacobi2d_space() -> TuningSpace:
    return TuningSpace(
        kernel="jacobi2d",
        axes={"block_rows": (256, 128, 64, 32, 16, 8)},
        default={"block_rows": 128},
        dtypes=("fp32",),
        clamp=_jacobi_clamp,
        constraint=_jacobi_ok,
        vmem_model=_jacobi_vmem,
        traffic_model=_jacobi_traffic,
        flops_model=lambda args: 4.0 * args[0].shape[0] * args[0].shape[1],
    )


# ---------------------------------------------------------------------------
# QC RX gate — outer-axis tiling over the (outer, 2, inner) state view
# ---------------------------------------------------------------------------


def _qc_outer(cfg: Dict[str, Any], args: Tuple) -> int:
    n_amp = args[0].shape[0]
    inner = 1 << int(cfg.get("qubit", 0))
    return n_amp // (2 * inner)


def _qc_clamp(cfg: Dict[str, Any], args: Tuple) -> Dict[str, Any]:
    # clamp against the qubit-0 view (the widest outer axis); the per-call
    # constraint re-checks with the caller's actual qubit
    outer = args[0].shape[0] // 2
    return {"block_outer": min(cfg["block_outer"], max(outer, 1))}


def _qc_ok(cfg: Dict[str, Any], args: Tuple) -> bool:
    outer = _qc_outer(cfg, args)
    if outer <= 0:
        return False
    bo = min(cfg["block_outer"], outer)
    return outer % bo == 0


def _qc_vmem(cfg: Dict[str, Any], args: Tuple, dtype_bytes: int) -> float:
    n_amp = args[0].shape[0]
    inner = 1 << int(cfg.get("qubit", 0))
    outer = n_amp // (2 * inner)
    bo = min(cfg["block_outer"], max(outer, 1))
    return float(4 * bo * 2 * inner * dtype_bytes)  # re/im in + out tiles


def qc_gate_space() -> TuningSpace:
    return TuningSpace(
        kernel="qc-gate",
        axes={"block_outer": (2048, 1024, 512, 256, 128, 64)},
        default={"block_outer": 256},
        dtypes=("fp32",),
        fixed={"qubit": 0, "theta": 0.25},
        clamp=_qc_clamp,
        constraint=_qc_ok,
        vmem_model=_qc_vmem,
        traffic_model=lambda cfg, args: float(
            4 * args[0].shape[0] * args[0].dtype.itemsize
        ),
        flops_model=lambda args: 6.0 * args[0].shape[0],
    )


# ---------------------------------------------------------------------------
# Flash-decode — KV-block length over the streamed cache
# ---------------------------------------------------------------------------


def _fd_s(args: Tuple) -> int:
    return args[1].shape[1]  # k: (B, S, KV, D)


def _fd_clamp(cfg: Dict[str, Any], args: Tuple) -> Dict[str, Any]:
    return {"block_s": min(cfg["block_s"], _fd_s(args))}


def _fd_ok(cfg: Dict[str, Any], args: Tuple) -> bool:
    S = _fd_s(args)
    bs = min(cfg["block_s"], S)
    return S % bs == 0


def _fd_vmem(cfg: Dict[str, Any], args: Tuple, dtype_bytes: int) -> float:
    q = args[0]
    D = q.shape[-1]
    G = q.shape[-2]
    bs = min(cfg["block_s"], _fd_s(args))
    return float((2 * bs * D + 2 * G * D) * dtype_bytes)  # k/v tiles + q + acc


def _fd_traffic(cfg: Dict[str, Any], args: Tuple) -> float:
    q, k = args[0], args[1]
    b = q.dtype.itemsize
    B, KV, G, D = q.shape
    S = k.shape[1]
    return float((2 * B * S * KV * D + 2 * B * KV * G * D) * b)


def flash_decode_space() -> TuningSpace:
    return TuningSpace(
        kernel="flash-decode",
        axes={"block_s": (1024, 512, 256, 128, 64, 32, 16)},
        default={"block_s": 512},
        dtypes=("fp32", "bf16"),
        clamp=_fd_clamp,
        constraint=_fd_ok,
        vmem_model=_fd_vmem,
        traffic_model=_fd_traffic,
        flops_model=lambda args: 4.0
        * args[0].shape[0] * args[0].shape[1] * args[0].shape[2]
        * args[0].shape[3] * args[1].shape[1],
    )


# ---------------------------------------------------------------------------
# Flash-prefill — chunk (query) tile x KV sub-tile over the paged pool
# ---------------------------------------------------------------------------
#
# args convention = the kernel call: (q (B,C,KV,G,D), k_new, v_new,
# k_pool (n_blocks,bs,KV,D), v_pool, block_tables (B,nb), q_start (B,)).


def _fp_dims(args: Tuple) -> Tuple[int, int, int, int, int, int, int]:
    q, k_pool, bt = args[0], args[3], args[5]
    B, C, KV, G, D = q.shape
    return B, C, KV, G, D, k_pool.shape[1], bt.shape[1]


def _fp_clamp(cfg: Dict[str, Any], args: Tuple) -> Dict[str, Any]:
    _, C, _, _, _, bs, _ = _fp_dims(args)
    bks = min(cfg["block_s"], bs) if cfg["block_s"] else bs  # 0 = pool block
    return {"block_c": min(cfg["block_c"], C), "block_s": bks}


def _fp_ok(cfg: Dict[str, Any], args: Tuple) -> bool:
    _, C, _, _, _, bs, _ = _fp_dims(args)
    bc = min(cfg["block_c"], C)
    bks = min(cfg["block_s"], bs) if cfg["block_s"] else bs
    return C % bc == 0 and bs % bks == 0


def _fp_vmem(cfg: Dict[str, Any], args: Tuple, dtype_bytes: int) -> float:
    _, C, _, G, D, bs, _ = _fp_dims(args)
    bc = min(cfg["block_c"], C)
    bks = min(cfg["block_s"], bs) if cfg["block_s"] else bs
    # q tile + k/v tiles + fp32 (m, l, acc) scratch + out tile
    return float(
        2 * bc * G * D * dtype_bytes
        + 2 * bks * D * dtype_bytes
        + bc * G * (D + 2) * 4
    )


def _fp_live(args: Tuple) -> float:
    """Mean causal frontier per chunk row: context plus half the chunk."""
    import numpy as np

    _, C, _, _, _, _, _ = _fp_dims(args)
    return float(np.mean(np.asarray(args[6]))) + (C + 1) / 2.0


def _fp_traffic(cfg: Dict[str, Any], args: Tuple) -> float:
    """Every query tile re-streams its causal KV prefix, so fewer/wider
    chunk tiles mean fewer passes over the context — monotone in
    ``block_c`` — while the chunk commit itself is written exactly once."""
    B, C, KV, G, D, bs, _ = _fp_dims(args)
    b = args[0].dtype.itemsize
    bc = min(cfg["block_c"], C)
    nq = C // bc
    live = _fp_live(args)
    return float(
        2 * B * KV * nq * live * D * b      # K+V streamed per query tile
        + 3 * B * C * KV * D * b            # chunk K/V read + committed
        + 2 * B * C * KV * G * D * b        # q read + out written
    )


def _fp_flops(args: Tuple) -> float:
    B, C, KV, G, D, _, _ = _fp_dims(args)
    return 4.0 * KV * G * D * B * C * _fp_live(args)


def flash_prefill_space() -> TuningSpace:
    return TuningSpace(
        kernel="flash-prefill",
        axes={
            "block_c": (64, 32, 16, 8, 4, 2, 1),
            "block_s": (512, 256, 128, 64, 32, 16, 8),
        },
        default={"block_c": 8, "block_s": 0},  # 0 = one tile per pool block
        dtypes=("fp32", "bf16"),
        clamp=_fp_clamp,
        constraint=_fp_ok,
        vmem_model=_fp_vmem,
        traffic_model=_fp_traffic,
        flops_model=_fp_flops,
    )


# ---------------------------------------------------------------------------
# Paged-KV storage dtype — the ELEN axis of the serve-path block pool
# ---------------------------------------------------------------------------
#
# args convention = the paged decode call: (q (B,KV,G,D),
# k_pool (n_blocks,bs,KV,D), v_pool, block_tables (B,nb), valid_len (B,)).
#
# Unlike the per-kernel ``dtypes`` tuple (which casts the COMPUTE operands,
# paper Eq. 1 applied to the arithmetic), ``kv_dtype`` narrows only the
# STORED cache: queries and the softmax stay at the compute dtype while
# each KV tile DMAs at 1/2 (bf16) or 1/4 (int8, plus one fp32 scale per
# row) of the f32 bytes and is widened in VMEM.  The tuner must therefore
# never cast the example operands for this axis — it is a distinct static
# argument of the serve path (``ServeEngine(kv_dtype=...)``), searched by
# the accuracy-vs-speed sweep, not by operand substitution.

#: Pool bytes per stored element for each kv_dtype candidate.
KV_DTYPE_ITEMSIZE: Dict[str, int] = {"f32": 4, "bf16": 2, "int8": 1}


def _kv_dims(args: Tuple) -> Tuple[int, int, int, int, int]:
    q, k_pool = args[0], args[1]
    B, KV, G, D = q.shape
    return B, KV, G, D, k_pool.shape[1]


def _kv_traffic(cfg: Dict[str, Any], args: Tuple) -> float:
    """Decode-step HBM traffic: live K+V rows stream at the pool itemsize
    (int8 adds the two fp32 scale rows per block); q/out traffic is at the
    compute dtype and independent of the axis."""
    import numpy as np

    B, KV, G, D, bs = _kv_dims(args)
    kv_dtype = cfg["kv_dtype"]
    item = KV_DTYPE_ITEMSIZE[kv_dtype]
    live = float(np.sum(np.asarray(args[4])))
    kv_bytes = 2.0 * live * KV * D * item
    if kv_dtype == "int8":
        kv_bytes += 2.0 * live * 4.0  # per-row fp32 scales
    q_bytes = 2.0 * B * KV * G * D * args[0].dtype.itemsize
    return kv_bytes + q_bytes


def _kv_vmem(cfg: Dict[str, Any], args: Tuple, dtype_bytes: int) -> float:
    """One pool block of K+V at the storage dtype, widened tile + q + acc
    at fp32 (dequant happens in VMEM, so both copies are resident)."""
    _, _, G, D, bs = _kv_dims(args)
    item = KV_DTYPE_ITEMSIZE[cfg["kv_dtype"]]
    return float(2 * bs * D * (item + 4) + 2 * G * D * 4)


def _kv_flops(args: Tuple) -> float:
    import numpy as np

    _, KV, G, D, _ = _kv_dims(args)
    live = float(np.sum(np.asarray(args[4])))
    return 4.0 * KV * G * D * live


def paged_kv_space() -> TuningSpace:
    """The ``kv_dtype`` axis of the paged serve path (quantized paging).

    Candidates are ordered widest-first so ``subset(1)`` (the CI tiny-space
    knob) keeps the exact f32 baseline.  ``dtypes`` is deliberately empty:
    the axis is a static serve-path argument, not an operand cast."""
    return TuningSpace(
        kernel="paged-kv",
        axes={"kv_dtype": ("f32", "bf16", "int8")},
        default={"kv_dtype": "f32"},
        dtypes=(),
        vmem_model=_kv_vmem,
        traffic_model=_kv_traffic,
        flops_model=_kv_flops,
    )
